//! pallas-lint: token-level static analysis of the repo's cross-layer
//! invariants — the contracts that runtime tests can only sample but a
//! build-time scan can prove exhaustively:
//!
//! | rule | invariant |
//! |------|-----------|
//! | `protocol-exhaustiveness` | every `KIND_*` message constant in `kmeans/remote/protocol.rs` has an encode arm, a decode arm, and a pin in `tests/frame_properties.rs` |
//! | `metrics-parity` | every counter field of `CoordMetrics`/`ServeMetrics` appears in its summary formatter *and* its JSON emitter |
//! | `fault-coverage` | every `Fault` variant in `util/fault.rs` is exercised by `tests/chaos_remote.rs` |
//! | `panic-hygiene` | no `unwrap`/`expect`/panic macros/unchecked indexing in the hostile-input decode paths (`util/frame.rs`, `kmeans/remote/protocol.rs`) |
//! | `unsafe-audit` | `unsafe` only in an explicit allowlist, each use under a `// SAFETY:` comment |
//!
//! The scanner is deliberately *not* a Rust parser: it strips comments,
//! string/char literals and `#[cfg(test)]` regions, then matches tokens
//! with identifier boundaries.  That is enough to make every rule above
//! sound on this codebase, with zero dependencies (`std` only — the
//! workspace's offline `crates/` policy).
//!
//! A site that is provably safe but textually flagged can carry a
//! justification comment on the same or preceding line:
//!
//! ```text
//! // pallas-lint: allow(panic-hygiene) index masked to 0..=255 above
//! ```
//!
//! The annotation *requires* a justification; a bare allow is itself a
//! violation.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

// ---------------------------------------------------------------------------
// Rule catalogue
// ---------------------------------------------------------------------------

pub const RULE_PROTOCOL: &str = "protocol-exhaustiveness";
pub const RULE_METRICS: &str = "metrics-parity";
pub const RULE_FAULT: &str = "fault-coverage";
pub const RULE_PANIC: &str = "panic-hygiene";
pub const RULE_UNSAFE: &str = "unsafe-audit";

/// Every rule, in report order.
pub static RULES: &[(&str, fn(&Path) -> Vec<Violation>)] = &[
    (RULE_PROTOCOL, rule_protocol_exhaustiveness),
    (RULE_METRICS, rule_metrics_parity),
    (RULE_FAULT, rule_fault_coverage),
    (RULE_PANIC, rule_panic_hygiene),
    (RULE_UNSAFE, rule_unsafe_audit),
];

/// The annotation marker `panic-hygiene` sites may carry.
pub const ALLOW_PANIC: &str = "pallas-lint: allow(panic-hygiene)";

/// Files `unsafe` is permitted in (each use still needs `// SAFETY:`).
pub const UNSAFE_ALLOWLIST: &[&str] = &[
    "rust/src/util/bench.rs",
    "rust/src/runtime/client.rs",
    "rust/src/kmeans/panel/simd.rs",
];

/// The hostile-input decode paths the panic-hygiene rule guards.
pub const DECODE_PATHS: &[&str] = &[
    "rust/src/util/frame.rs",
    "rust/src/kmeans/remote/protocol.rs",
];

const PROTOCOL_RS: &str = "rust/src/kmeans/remote/protocol.rs";
const FRAME_PROPS_RS: &str = "rust/tests/frame_properties.rs";
const COORD_METRICS_RS: &str = "rust/src/coordinator/metrics.rs";
const KMEANS_MOD_RS: &str = "rust/src/kmeans/mod.rs";
const SERVE_METRICS_RS: &str = "rust/src/serve/metrics.rs";
const MAIN_RS: &str = "rust/src/main.rs";
const FAULT_RS: &str = "rust/src/util/fault.rs";
const CHAOS_RS: &str = "rust/tests/chaos_remote.rs";

/// One invariant violation, pointing at a repo-relative file and line.
#[derive(Clone, Debug)]
pub struct Violation {
    pub file: String,
    /// 1-based; 0 when the violation is about the file as a whole.
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{} [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Run every rule against the repo at `root`.
pub fn run_all(root: &Path) -> Vec<Violation> {
    let mut out = Vec::new();
    for (_, rule) in RULES {
        out.extend(rule(root));
    }
    out
}

// ---------------------------------------------------------------------------
// Source model: stripped token text + string literals + test ranges
// ---------------------------------------------------------------------------

/// A scanned source file.  `stripped_lines` aligns 1:1 with `raw_lines`
/// but has comments and string/char-literal contents blanked, so token
/// searches and brace matching never trip on prose or format strings.
pub struct Source {
    pub rel: String,
    pub raw_lines: Vec<String>,
    pub stripped_lines: Vec<String>,
    /// `(line, contents)` of every string literal, for rules that match
    /// against quoted tokens (e.g. fault schedule strings).
    pub literals: Vec<(usize, String)>,
    /// 0-based inclusive line ranges of `#[cfg(test)]` items.
    pub test_ranges: Vec<(usize, usize)>,
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Blank comments and literal contents while preserving the line grid;
/// collect string-literal contents on the side.
fn strip_code(src: &str) -> (String, Vec<(usize, String)>) {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out: Vec<char> = Vec::with_capacity(n);
    let mut literals: Vec<(usize, String)> = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;

    while i < n {
        let c = chars[i];
        if c == '\n' {
            out.push('\n');
            line += 1;
            i += 1;
            continue;
        }
        // Line comment (covers /// and //! doc forms).
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            while i < n && chars[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        // Block comment, nesting-aware.
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1usize;
            out.push(' ');
            out.push(' ');
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '\n' {
                    out.push('\n');
                    line += 1;
                    i += 1;
                } else if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else {
                    out.push(' ');
                    i += 1;
                }
            }
            continue;
        }
        // Raw string r"..." / r#"..."# (optionally b-prefixed), only when
        // the prefix is not the tail of an identifier.
        if (c == 'r' || c == 'b') && (i == 0 || !is_ident(chars[i - 1])) {
            let mut j = i;
            if chars[j] == 'b' && j + 1 < n && chars[j + 1] == 'r' {
                j += 1;
            }
            if chars[j] == 'r' {
                let mut k = j + 1;
                let mut hashes = 0usize;
                while k < n && chars[k] == '#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && chars[k] == '"' {
                    for _ in i..=k {
                        out.push(' ');
                    }
                    i = k + 1;
                    let start_line = line;
                    let mut lit = String::new();
                    while i < n {
                        if chars[i] == '"' {
                            let mut m = 0usize;
                            while m < hashes && i + 1 + m < n && chars[i + 1 + m] == '#' {
                                m += 1;
                            }
                            if m == hashes {
                                for _ in 0..=hashes {
                                    out.push(' ');
                                }
                                i += 1 + hashes;
                                break;
                            }
                        }
                        if chars[i] == '\n' {
                            out.push('\n');
                            line += 1;
                            lit.push('\n');
                        } else {
                            out.push(' ');
                            lit.push(chars[i]);
                        }
                        i += 1;
                    }
                    literals.push((start_line, lit));
                    continue;
                }
            }
        }
        // Normal or byte string literal.
        let byte_str =
            c == 'b' && i + 1 < n && chars[i + 1] == '"' && (i == 0 || !is_ident(chars[i - 1]));
        if c == '"' || byte_str {
            if c == 'b' {
                out.push(' ');
                i += 1;
            }
            out.push(' '); // opening quote
            i += 1;
            let start_line = line;
            let mut lit = String::new();
            while i < n {
                let d = chars[i];
                if d == '"' {
                    out.push(' ');
                    i += 1;
                    break;
                }
                if d == '\\' && i + 1 < n {
                    out.push(' ');
                    if chars[i + 1] == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                    lit.push(d);
                    lit.push(chars[i + 1]);
                    i += 2;
                    continue;
                }
                if d == '\n' {
                    out.push('\n');
                    line += 1;
                    lit.push('\n');
                } else {
                    out.push(' ');
                    lit.push(d);
                }
                i += 1;
            }
            literals.push((start_line, lit));
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if i + 1 < n && chars[i + 1] == '\\' {
                // Escaped char literal: blank through the closing quote.
                out.push(' ');
                out.push(' ');
                i += 2;
                while i < n && chars[i] != '\'' {
                    if chars[i] == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                    i += 1;
                }
                if i < n {
                    out.push(' ');
                    i += 1;
                }
                continue;
            }
            if i + 2 < n && chars[i + 2] == '\'' && chars[i + 1] != '\'' && chars[i + 1] != '\n' {
                // 'x' — includes '{' / '"' payloads that must not open
                // a brace or string state.
                out.push(' ');
                out.push(' ');
                out.push(' ');
                i += 3;
                continue;
            }
            // Lifetime or loop label: blank the quote, keep the ident.
            out.push(' ');
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }

    (out.into_iter().collect(), literals)
}

impl Source {
    pub fn from_text(rel: &str, raw: &str) -> Source {
        let (stripped, literals) = strip_code(raw);
        let raw_lines: Vec<String> = raw.lines().map(|l| l.to_string()).collect();
        let stripped_lines: Vec<String> = stripped.lines().map(|l| l.to_string()).collect();
        let test_ranges = find_test_ranges(&stripped_lines);
        Source {
            rel: rel.to_string(),
            raw_lines,
            stripped_lines,
            literals,
            test_ranges,
        }
    }

    pub fn load(root: &Path, rel: &str) -> Result<Source, Violation> {
        match fs::read_to_string(root.join(rel)) {
            Ok(raw) => Ok(Source::from_text(rel, &raw)),
            Err(e) => Err(Violation {
                file: rel.to_string(),
                line: 0,
                rule: "io",
                msg: format!("cannot read: {e}"),
            }),
        }
    }

    /// Is 0-based `line` inside a `#[cfg(test)]` item?
    pub fn in_tests(&self, line: usize) -> bool {
        self.test_ranges.iter().any(|&(a, b)| line >= a && line <= b)
    }

    /// 0-based inclusive line range of the body of `fn name`, including
    /// the brace lines.  Finds the *declaration*, not call sites.
    pub fn fn_range(&self, name: &str) -> Option<(usize, usize)> {
        for (li, l) in self.stripped_lines.iter().enumerate() {
            if let Some(col) = find_fn_decl(l, name) {
                return brace_range(&self.stripped_lines, li, col);
            }
        }
        None
    }

    /// Stripped text of a 0-based inclusive line range.
    pub fn stripped_text(&self, range: (usize, usize)) -> String {
        self.stripped_lines[range.0..=range.1.min(self.stripped_lines.len() - 1)].join("\n")
    }

    /// Raw text of a 0-based inclusive line range (for quoted-key checks).
    pub fn raw_text(&self, range: (usize, usize)) -> String {
        self.raw_lines[range.0..=range.1.min(self.raw_lines.len() - 1)].join("\n")
    }

    /// All stripped non-test text (token space of production code).
    pub fn production_text(&self) -> String {
        self.stripped_lines
            .iter()
            .enumerate()
            .filter(|(i, _)| !self.in_tests(*i))
            .map(|(_, l)| l.as_str())
            .collect::<Vec<_>>()
            .join("\n")
    }

    fn violation(&self, line0: usize, rule: &'static str, msg: String) -> Violation {
        Violation {
            file: self.rel.clone(),
            line: line0 + 1,
            rule,
            msg,
        }
    }
}

/// `#[cfg(test)]` item ranges: from each marker, brace-match the next
/// block (the `mod tests { .. }` or annotated item).
fn find_test_ranges(stripped_lines: &[String]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for (li, l) in stripped_lines.iter().enumerate() {
        if l.contains("#[cfg(test)]") {
            if let Some((a, b)) = brace_range(stripped_lines, li, 0) {
                out.push((li.min(a), b));
            }
        }
    }
    out
}

/// Match the first `{` at/after `(from_line, from_col)` to its closing
/// `}`; returns 0-based inclusive line range.
fn brace_range(lines: &[String], from_line: usize, from_col: usize) -> Option<(usize, usize)> {
    let mut depth = 0usize;
    let mut started = false;
    let mut start = from_line;
    for (li, l) in lines.iter().enumerate().skip(from_line) {
        for (ci, ch) in l.chars().enumerate() {
            if li == from_line && ci < from_col {
                continue;
            }
            if ch == '{' {
                if !started {
                    started = true;
                    start = li;
                }
                depth += 1;
            } else if ch == '}' && started {
                depth -= 1;
                if depth == 0 {
                    return Some((start, li));
                }
            }
        }
    }
    None
}

/// Column of `name` on a line that *declares* `fn name`, else None.
fn find_fn_decl(line: &str, name: &str) -> Option<usize> {
    for pos in token_positions(line, name) {
        let prefix: String = line.chars().take(pos).collect();
        let p = prefix.trim_end();
        if p.ends_with("fn") {
            let head: Vec<char> = p.chars().collect();
            if head.len() == 2 || !is_ident(head[head.len() - 3]) {
                return Some(pos);
            }
        }
    }
    None
}

/// Char positions where `token` occurs with identifier boundaries.
fn token_positions(text: &str, token: &str) -> Vec<usize> {
    let t: Vec<char> = text.chars().collect();
    let k: Vec<char> = token.chars().collect();
    let mut out = Vec::new();
    if k.is_empty() || t.len() < k.len() {
        return out;
    }
    for i in 0..=t.len() - k.len() {
        if t[i..i + k.len()] == k[..] {
            let before_ok = i == 0 || !is_ident(t[i - 1]);
            let after = i + k.len();
            let after_ok = after >= t.len() || !is_ident(t[after]);
            if before_ok && after_ok {
                out.push(i);
            }
        }
    }
    out
}

/// Does `text` contain `token` with identifier boundaries?
pub fn has_token(text: &str, token: &str) -> bool {
    !token_positions(text, token).is_empty()
}

/// Recursively collect `.rs` files under `dir`, sorted for stable output.
fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return,
    };
    let mut paths: Vec<PathBuf> = entries.filter_map(|e| e.ok().map(|e| e.path())).collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            walk_rs(&p, out);
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
}

// ---------------------------------------------------------------------------
// Rule 1: protocol exhaustiveness
// ---------------------------------------------------------------------------

/// Every `pub const KIND_*: u8` in the protocol module must appear in
/// the encode path (`fn encode` or `fn encode_job`), in `fn decode`,
/// and in the frame property-test suite.  A message kind someone adds
/// without all three is exactly the cross-layer skew that shipped the
/// paper's co-design contract: the constant compiles, the match arms
/// silently `_ =>` it away, and the first hostile peer finds out.
///
/// When the tree carries a top-level `DESIGN.md` (the real repo always
/// does; code-only fixtures need not), every kind must also appear in
/// its wire table — the human contract rots just as silently as the
/// match arms, and a kind nobody documented is a kind nobody reviews.
pub fn rule_protocol_exhaustiveness(root: &Path) -> Vec<Violation> {
    let mut out = Vec::new();
    let proto = match Source::load(root, PROTOCOL_RS) {
        Ok(s) => s,
        Err(v) => return vec![with_rule(v, RULE_PROTOCOL)],
    };
    let props = match Source::load(root, FRAME_PROPS_RS) {
        Ok(s) => s,
        Err(v) => return vec![with_rule(v, RULE_PROTOCOL)],
    };

    // Collect `const KIND_*: u8` declarations with their lines.
    let mut kinds: Vec<(String, usize)> = Vec::new();
    for (li, l) in proto.stripped_lines.iter().enumerate() {
        if proto.in_tests(li) || !l.contains(": u8") {
            continue;
        }
        if let Some(p) = l.find("const KIND_") {
            let name: String = l[p + "const ".len()..]
                .chars()
                .take_while(|&c| is_ident(c))
                .collect();
            if !name.is_empty() {
                kinds.push((name, li));
            }
        }
    }
    if kinds.is_empty() {
        out.push(proto.violation(
            0,
            RULE_PROTOCOL,
            "no `const KIND_*: u8` message-kind constants found — rule would be vacuous".into(),
        ));
        return out;
    }

    let mut enc_text = String::new();
    for f in ["encode_job", "encode"] {
        match proto.fn_range(f) {
            Some(r) => {
                enc_text.push_str(&proto.stripped_text(r));
                enc_text.push('\n');
            }
            None => out.push(proto.violation(
                0,
                RULE_PROTOCOL,
                format!("cannot locate `fn {f}` — encode surface moved?"),
            )),
        }
    }
    let dec_text = match proto.fn_range("decode") {
        Some(r) => proto.stripped_text(r),
        None => {
            out.push(proto.violation(
                0,
                RULE_PROTOCOL,
                "cannot locate `fn decode` — decode surface moved?".into(),
            ));
            String::new()
        }
    };
    let props_text = props.stripped_lines.join("\n");

    for (kind, li) in &kinds {
        if !enc_text.is_empty() && !has_token(&enc_text, kind) {
            out.push(proto.violation(
                *li,
                RULE_PROTOCOL,
                format!("{kind} has no encode arm in `fn encode`/`fn encode_job`"),
            ));
        }
        if !dec_text.is_empty() && !has_token(&dec_text, kind) {
            out.push(proto.violation(
                *li,
                RULE_PROTOCOL,
                format!("{kind} has no decode arm in `fn decode`"),
            ));
        }
        if !has_token(&props_text, kind) {
            out.push(proto.violation(
                *li,
                RULE_PROTOCOL,
                format!("{kind} is not pinned by {FRAME_PROPS_RS}"),
            ));
        }
    }
    if let Ok(design) = fs::read_to_string(root.join("DESIGN.md")) {
        for (kind, li) in &kinds {
            if !has_token(&design, kind) {
                out.push(proto.violation(
                    *li,
                    RULE_PROTOCOL,
                    format!("{kind} is missing from DESIGN.md's wire table"),
                ));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 2: metrics parity
// ---------------------------------------------------------------------------

/// Every public counter field of `CoordMetrics` and `ServeMetrics` must
/// appear in its human summary *and* its machine-readable JSON emitter.
/// A counter that exists but never surfaces is how "exactly-once under
/// chaos" claims quietly stop being observable.  On trees that carry a
/// DESIGN.md, every `RunStats` and `ServeMetrics` counter must also be
/// named in its counters table — telemetry nobody documented is
/// telemetry nobody can read.
pub fn rule_metrics_parity(root: &Path) -> Vec<Violation> {
    let mut out = Vec::new();

    // CoordMetrics: summary lives next to the struct; the JSON emitter
    // is `write_coord_report` in the CLI.
    match (Source::load(root, COORD_METRICS_RS), Source::load(root, MAIN_RS)) {
        (Ok(cm), Ok(main)) => {
            check_struct_parity(
                &cm,
                "CoordMetrics",
                &cm,
                "summary",
                &main,
                "write_coord_report",
                &mut out,
            );
        }
        (cm, main) => {
            if let Err(v) = cm {
                out.push(with_rule(v, RULE_METRICS));
            }
            if let Err(v) = main {
                out.push(with_rule(v, RULE_METRICS));
            }
        }
    }

    // ServeMetrics: summary and to_json both live in serve/metrics.rs.
    match Source::load(root, SERVE_METRICS_RS) {
        Ok(sm) => {
            check_struct_parity(&sm, "ServeMetrics", &sm, "summary", &sm, "to_json", &mut out);
        }
        Err(v) => out.push(with_rule(v, RULE_METRICS)),
    }

    // Docs side: every declared `RunStats` and `ServeMetrics` counter
    // must be named in DESIGN.md's counters table (§10) — the same
    // docs-or-fail pattern as the wire table in the protocol rule.
    // Gated on DESIGN.md existing so fixture trees can exercise the
    // summary/JSON half in isolation.
    if let Ok(design) = fs::read_to_string(root.join("DESIGN.md")) {
        for (rel, name) in [(KMEANS_MOD_RS, "RunStats"), (SERVE_METRICS_RS, "ServeMetrics")] {
            let Ok(src) = Source::load(root, rel) else {
                continue;
            };
            for (field, li) in struct_fields(&src, name) {
                if !has_token(&design, &field) {
                    out.push(src.violation(
                        li,
                        RULE_METRICS,
                        format!("{name}.{field} is missing from DESIGN.md's counters table"),
                    ));
                }
            }
        }
    }
    out
}

/// Shared core: fields of `struct_name` in `decl` must appear as tokens
/// in `summary_fn` of `summary_src` and as quoted keys in `json_fn` of
/// `json_src`.
fn check_struct_parity(
    decl: &Source,
    struct_name: &str,
    summary_src: &Source,
    summary_fn: &str,
    json_src: &Source,
    json_fn: &str,
    out: &mut Vec<Violation>,
) {
    let fields = struct_fields(decl, struct_name);
    if fields.is_empty() {
        out.push(decl.violation(
            0,
            RULE_METRICS,
            format!("no public fields found for struct {struct_name} — rule would be vacuous"),
        ));
        return;
    }
    let summary = match summary_src.fn_range(summary_fn) {
        Some(r) => summary_src.stripped_text(r),
        None => {
            out.push(summary_src.violation(
                0,
                RULE_METRICS,
                format!("cannot locate `fn {summary_fn}` for {struct_name}"),
            ));
            return;
        }
    };
    // JSON keys are string literals, so match against raw text.
    let json = match json_src.fn_range(json_fn) {
        Some(r) => json_src.raw_text(r),
        None => {
            out.push(json_src.violation(
                0,
                RULE_METRICS,
                format!("cannot locate `fn {json_fn}` for {struct_name}"),
            ));
            return;
        }
    };
    for (field, li) in fields {
        if !has_token(&summary, &field) {
            out.push(decl.violation(
                li,
                RULE_METRICS,
                format!("{struct_name}.{field} is declared but missing from `fn {summary_fn}`"),
            ));
        }
        if !json.contains(&format!("\"{field}\"")) {
            out.push(decl.violation(
                li,
                RULE_METRICS,
                format!(
                    "{struct_name}.{field} is declared but missing from the `{json_fn}` JSON emitter ({})",
                    json_src.rel
                ),
            ));
        }
    }
}

/// `(name, 0-based line)` of each `pub field:` in the struct's body.
fn struct_fields(src: &Source, struct_name: &str) -> Vec<(String, usize)> {
    let mut decl_line = None;
    for (li, l) in src.stripped_lines.iter().enumerate() {
        if has_token(l, "struct") && has_token(l, struct_name) {
            decl_line = Some(li);
            break;
        }
    }
    let Some(li) = decl_line else {
        return Vec::new();
    };
    let Some((a, b)) = brace_range(&src.stripped_lines, li, 0) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    for fl in a + 1..b {
        let t = src.stripped_lines[fl].trim();
        if let Some(rest) = t.strip_prefix("pub ") {
            let name: String = rest.chars().take_while(|&c| is_ident(c)).collect();
            if !name.is_empty() && rest[name.len()..].trim_start().starts_with(':') {
                out.push((name, fl));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 3: fault coverage
// ---------------------------------------------------------------------------

/// Every `Fault` variant must be exercised by the chaos suite — either
/// named as `Fault::Variant` or spelled in a schedule string via its
/// wire token (taken from the `Display` impl, so the mapping can never
/// drift from the code).  A fault class nobody injects is a recovery
/// path nobody has proven.
pub fn rule_fault_coverage(root: &Path) -> Vec<Violation> {
    let mut out = Vec::new();
    let fault = match Source::load(root, FAULT_RS) {
        Ok(s) => s,
        Err(v) => return vec![with_rule(v, RULE_FAULT)],
    };
    let chaos = match Source::load(root, CHAOS_RS) {
        Ok(s) => s,
        Err(v) => return vec![with_rule(v, RULE_FAULT)],
    };

    let variants = enum_variants(&fault, "Fault");
    if variants.is_empty() {
        out.push(fault.violation(
            0,
            RULE_FAULT,
            "no variants found for enum Fault — rule would be vacuous".into(),
        ));
        return out;
    }
    let display = display_tokens(&fault, "Fault");
    let chaos_code = chaos.stripped_lines.join("\n");
    let chaos_strings: Vec<&str> = chaos.literals.iter().map(|(_, s)| s.as_str()).collect();

    for (variant, li) in variants {
        let named = has_token(&chaos_code, &format!("Fault::{variant}"))
            || chaos_code.contains(&format!("Fault::{variant}"));
        let token = display.iter().find(|(v, _)| *v == variant).map(|(_, t)| t.clone());
        let spelled = match &token {
            Some(t) if !t.is_empty() => chaos_strings.iter().any(|s| s.contains(t.as_str())),
            _ => false,
        };
        if token.is_none() {
            out.push(fault.violation(
                li,
                RULE_FAULT,
                format!("Fault::{variant} has no Display arm — schedule strings cannot spell it"),
            ));
        }
        if !named && !spelled {
            out.push(fault.violation(
                li,
                RULE_FAULT,
                format!(
                    "Fault::{variant} (token {}) is never exercised by {CHAOS_RS}",
                    token.as_deref().unwrap_or("?")
                ),
            ));
        }
    }
    out
}

/// `(name, 0-based line)` of each variant of `pub enum <name>`.
fn enum_variants(src: &Source, enum_name: &str) -> Vec<(String, usize)> {
    let mut decl_line = None;
    for (li, l) in src.stripped_lines.iter().enumerate() {
        if has_token(l, "enum") && has_token(l, enum_name) && !has_token(l, "impl") {
            decl_line = Some(li);
            break;
        }
    }
    let Some(li) = decl_line else {
        return Vec::new();
    };
    let Some((a, b)) = brace_range(&src.stripped_lines, li, 0) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    for vl in a + 1..b {
        let t = src.stripped_lines[vl].trim();
        let name: String = t.chars().take_while(|&c| is_ident(c)).collect();
        if !name.is_empty() && name.chars().next().map(|c| c.is_uppercase()).unwrap_or(false) {
            out.push((name, vl));
        }
    }
    out
}

/// `(variant, wire token)` pairs from `impl Display for <enum>`: the
/// text inside the first string literal of each `Fault::X => write!(..)`
/// arm, cut at the first `{` interpolation.
fn display_tokens(src: &Source, enum_name: &str) -> Vec<(String, String)> {
    let marker = format!("Display for {enum_name}");
    let mut start = None;
    for (li, l) in src.stripped_lines.iter().enumerate() {
        if l.contains(&marker) {
            start = Some(li);
            break;
        }
    }
    let Some(li) = start else {
        return Vec::new();
    };
    let Some((a, b)) = brace_range(&src.stripped_lines, li, 0) else {
        return Vec::new();
    };
    let prefix = format!("{enum_name}::");
    let mut out = Vec::new();
    for rl in a..=b.min(src.raw_lines.len() - 1) {
        let raw = &src.raw_lines[rl];
        let Some(vp) = raw.find(&prefix) else { continue };
        let variant: String = raw[vp + prefix.len()..]
            .chars()
            .take_while(|&c| is_ident(c))
            .collect();
        if variant.is_empty() {
            continue;
        }
        // First string literal on the line, cut at interpolation.
        let Some(q1) = raw.find('"') else { continue };
        let rest = &raw[q1 + 1..];
        let tok: String = rest.chars().take_while(|&c| c != '"' && c != '{').collect();
        out.push((variant, tok));
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 4: panic hygiene
// ---------------------------------------------------------------------------

/// The hostile-input decode paths must stay panic-free: a worker decode
/// must survive a port scanner, a coordinator must survive a half-dead
/// worker.  Flags `.unwrap()`, `.expect(`, panic-family macros, and
/// index/slice expressions (`x[..]`) outside `#[cfg(test)]`, unless the
/// site carries a justified [`ALLOW_PANIC`] annotation.
pub fn rule_panic_hygiene(root: &Path) -> Vec<Violation> {
    let mut out = Vec::new();
    for rel in DECODE_PATHS {
        let src = match Source::load(root, rel) {
            Ok(s) => s,
            Err(v) => {
                out.push(with_rule(v, RULE_PANIC));
                continue;
            }
        };
        for (li, line) in src.stripped_lines.iter().enumerate() {
            if src.in_tests(li) {
                continue;
            }
            let sites = panic_sites(line);
            if sites.is_empty() {
                continue;
            }
            match annotation_status(&src, li) {
                Annot::Allowed => continue,
                Annot::MissingReason => {
                    out.push(src.violation(
                        li,
                        RULE_PANIC,
                        "allow annotation present but carries no justification".into(),
                    ));
                    continue;
                }
                Annot::None => {}
            }
            for site in sites {
                out.push(src.violation(
                    li,
                    RULE_PANIC,
                    format!(
                        "{site} in a hostile-input decode path (return a FrameError or annotate: `// {ALLOW_PANIC} <reason>`)"
                    ),
                ));
            }
        }
    }
    out
}

enum Annot {
    None,
    Allowed,
    MissingReason,
}

fn annotation_status(src: &Source, li: usize) -> Annot {
    for l in [Some(li), li.checked_sub(1)].into_iter().flatten() {
        if let Some(raw) = src.raw_lines.get(l) {
            if let Some(p) = raw.find(ALLOW_PANIC) {
                let reason = raw[p + ALLOW_PANIC.len()..]
                    .trim_matches(|c: char| c.is_whitespace() || c == ':' || c == '-' || c == '—');
                return if reason.len() >= 3 {
                    Annot::Allowed
                } else {
                    Annot::MissingReason
                };
            }
        }
    }
    Annot::None
}

/// Panic-capable constructs on one stripped line.
fn panic_sites(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let chars: Vec<char> = line.chars().collect();

    for (method, label) in [(".unwrap", ".unwrap()"), (".expect", ".expect(..)")] {
        for p in find_all(line, method) {
            let after = p + method.len();
            if chars.get(after) == Some(&'(') {
                out.push(label.to_string());
            }
        }
    }
    for mac in ["panic!", "unreachable!", "todo!", "unimplemented!"] {
        let bare = &mac[..mac.len() - 1];
        for p in token_positions(line, bare) {
            if chars.get(p + bare.len()) == Some(&'!') {
                out.push(format!("{mac}(..)"));
            }
        }
    }
    // Index/slice expression: `[` directly after an identifier char or a
    // closing bracket — never after `!` (macros), `&`, `=`, `(`, space.
    for (i, &c) in chars.iter().enumerate() {
        if c == '[' && i > 0 {
            let prev = chars[i - 1];
            if is_ident(prev) || prev == ')' || prev == ']' {
                out.push("unchecked index/slice expression".to_string());
            }
        }
    }
    out
}

fn find_all(text: &str, pat: &str) -> Vec<usize> {
    let t: Vec<char> = text.chars().collect();
    let k: Vec<char> = pat.chars().collect();
    let mut out = Vec::new();
    if k.is_empty() || t.len() < k.len() {
        return out;
    }
    for i in 0..=t.len() - k.len() {
        if t[i..i + k.len()] == k[..] {
            out.push(i);
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 5: unsafe audit
// ---------------------------------------------------------------------------

/// `unsafe` is allowed only in [`UNSAFE_ALLOWLIST`] files, and every use
/// there must sit under a `// SAFETY:` comment (within the preceding 10
/// lines, so one comment can cover adjacent `unsafe impl` pairs).
pub fn rule_unsafe_audit(root: &Path) -> Vec<Violation> {
    let mut out = Vec::new();
    let src_root = root.join("rust").join("src");
    let mut files = Vec::new();
    walk_rs(&src_root, &mut files);
    if files.is_empty() {
        out.push(Violation {
            file: "rust/src".into(),
            line: 0,
            rule: RULE_UNSAFE,
            msg: "no Rust sources found under rust/src — rule would be vacuous".into(),
        });
        return out;
    }
    for path in files {
        let rel = match path.strip_prefix(root) {
            Ok(r) => r.to_string_lossy().replace('\\', "/"),
            Err(_) => path.to_string_lossy().to_string(),
        };
        let raw = match fs::read_to_string(&path) {
            Ok(r) => r,
            Err(e) => {
                out.push(Violation {
                    file: rel.clone(),
                    line: 0,
                    rule: RULE_UNSAFE,
                    msg: format!("cannot read: {e}"),
                });
                continue;
            }
        };
        if !raw.contains("unsafe") {
            continue; // cheap pre-filter before full stripping
        }
        let src = Source::from_text(&rel, &raw);
        let allowed = UNSAFE_ALLOWLIST.contains(&rel.as_str());
        for (li, line) in src.stripped_lines.iter().enumerate() {
            if src.in_tests(li) || !has_token(line, "unsafe") {
                continue;
            }
            if !allowed {
                out.push(src.violation(
                    li,
                    RULE_UNSAFE,
                    format!(
                        "`unsafe` outside the audited allowlist ({}) — justify and allowlist it or remove it",
                        UNSAFE_ALLOWLIST.join(", ")
                    ),
                ));
                continue;
            }
            let window_start = li.saturating_sub(10);
            let documented = (window_start..=li)
                .any(|w| src.raw_lines.get(w).map(|r| r.contains("SAFETY:")).unwrap_or(false));
            if !documented {
                out.push(src.violation(
                    li,
                    RULE_UNSAFE,
                    "`unsafe` without a `// SAFETY:` comment in the preceding 10 lines".into(),
                ));
            }
        }
    }
    out
}

fn with_rule(mut v: Violation, rule: &'static str) -> Violation {
    v.rule = rule;
    v
}

// ---------------------------------------------------------------------------
// Unit tests for the scanner core
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strip_blanks_comments_and_strings_but_keeps_lines() {
        let src = "let a = 1; // trailing [comment] with .unwrap()\nlet s = \"panic! [0]\";\n/* block\nspans lines */ let b = 2;\n";
        let (stripped, lits) = strip_code(src);
        assert_eq!(stripped.lines().count(), src.lines().count());
        assert!(!stripped.contains("unwrap"));
        assert!(!stripped.contains("panic"));
        assert!(stripped.contains("let a = 1;"));
        assert!(stripped.contains("let b = 2;"));
        assert_eq!(lits.len(), 1);
        assert_eq!(lits[0].1, "panic! [0]");
        assert_eq!(lits[0].0, 2);
    }

    #[test]
    fn strip_handles_char_literals_and_lifetimes() {
        let src = "fn f<'a>(x: &'a [u8]) -> char { if x.is_empty() { '{' } else { b'\"' as char } }";
        let (stripped, _) = strip_code(src);
        // The '{' char literal must not unbalance brace matching.
        let opens = stripped.chars().filter(|&c| c == '{').count();
        let closes = stripped.chars().filter(|&c| c == '}').count();
        assert_eq!(opens, closes);
        // Lifetime identifier survives as a token (quote blanked).
        assert!(stripped.contains("a>"));
    }

    #[test]
    fn strip_handles_raw_and_escaped_strings() {
        let src = "let a = r#\"raw \"quoted\" [0]\"#;\nlet b = \"esc \\\" quote\";\n";
        let (stripped, lits) = strip_code(src);
        assert!(!stripped.contains("raw"));
        assert!(!stripped.contains("quote"));
        assert_eq!(lits.len(), 2);
        assert!(lits[0].1.contains("raw \"quoted\" [0]"));
        assert_eq!(stripped.lines().count(), 2);
    }

    #[test]
    fn token_matching_respects_ident_boundaries() {
        assert!(has_token("self.points as f64", "points"));
        assert!(!has_token("self.mean_batch_points as f64", "points"));
        assert!(has_token("KIND_JOB => {", "KIND_JOB"));
        assert!(!has_token("KIND_JOB_EXTRA => {", "KIND_JOB"));
    }

    #[test]
    fn fn_decl_finder_skips_calls_and_prefixed_names() {
        assert!(find_fn_decl("    pub fn encode(&self) -> u8 {", "encode").is_some());
        assert!(find_fn_decl("pub fn encode_job(a: u32) {", "encode").is_none());
        assert!(find_fn_decl("    let x = self.encode();", "encode").is_none());
        assert!(find_fn_decl("    write_coord_report(&a, &b);", "write_coord_report").is_none());
    }

    #[test]
    fn cfg_test_ranges_cover_the_test_module() {
        let src = "pub fn live() { }\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { x.unwrap(); }\n}\n";
        let s = Source::from_text("x.rs", src);
        assert!(!s.in_tests(0));
        assert!(s.in_tests(2));
        assert!(s.in_tests(4));
        assert!(s.in_tests(5));
    }

    #[test]
    fn panic_sites_flag_the_right_constructs() {
        assert_eq!(panic_sites("let x = v.unwrap();"), vec![".unwrap()".to_string()]);
        assert!(panic_sites("let x = v.unwrap_or(0);").is_empty());
        assert!(panic_sites("let y = buf[0];").iter().any(|s| s.contains("index")));
        assert!(panic_sites("let y = vec![0u8; n];").is_empty());
        assert!(panic_sites("let t = [0u8; 9];").is_empty());
        assert!(panic_sites("unreachable!(\"x\")").iter().any(|s| s.contains("unreachable")));
        assert!(panic_sites("let z = a.get(i);").is_empty());
    }
}
