//! CLI for the repo's invariant analyzer.
//!
//! ```text
//! cargo run -p pallas-lint            # scan the repo, exit 1 on violations
//! cargo lint                          # same, via the .cargo/config.toml alias
//! cargo run -p pallas-lint -- --list  # print the rule catalogue
//! cargo run -p pallas-lint -- --rule metrics-parity --root /path/to/repo
//! ```

use std::path::PathBuf;
use std::process::ExitCode;

use pallas_lint::{run_all, RULES};

fn usage() -> &'static str {
    "pallas-lint: static invariant analyzer for this repo\n\
     \n\
     USAGE: pallas-lint [--root <dir>] [--rule <name>]... [--list]\n\
     \n\
     --root <dir>   repo root to scan (default: this workspace)\n\
     --rule <name>  run only the named rule (repeatable)\n\
     --list         print the rule catalogue and exit"
}

fn main() -> ExitCode {
    let mut root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("..");
    let mut only: Vec<String> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--list" => {
                for (name, _) in RULES {
                    println!("{name}");
                }
                return ExitCode::SUCCESS;
            }
            "--root" => match args.next() {
                Some(d) => root = PathBuf::from(d),
                None => {
                    eprintln!("--root needs a directory\n\n{}", usage());
                    return ExitCode::FAILURE;
                }
            },
            "--rule" => match args.next() {
                Some(r) if RULES.iter().any(|(n, _)| *n == r) => only.push(r),
                Some(r) => {
                    eprintln!("unknown rule `{r}` (see --list)");
                    return ExitCode::FAILURE;
                }
                None => {
                    eprintln!("--rule needs a rule name\n\n{}", usage());
                    return ExitCode::FAILURE;
                }
            },
            "-h" | "--help" => {
                println!("{}", usage());
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("unknown argument `{other}`\n\n{}", usage());
                return ExitCode::FAILURE;
            }
        }
    }

    let violations = if only.is_empty() {
        run_all(&root)
    } else {
        let mut v = Vec::new();
        for (name, rule) in RULES {
            if only.iter().any(|o| o == name) {
                v.extend(rule(&root));
            }
        }
        v
    };

    if violations.is_empty() {
        let ran = if only.is_empty() {
            RULES.len()
        } else {
            only.len()
        };
        println!("pallas-lint: clean ({ran} rule(s), root {})", root.display());
        ExitCode::SUCCESS
    } else {
        for v in &violations {
            eprintln!("{v}");
        }
        eprintln!("pallas-lint: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}
