//! Every rule is proven *live* against a deliberately-violating fixture
//! tree (a rule that can never fire is a rule that silently rotted),
//! and proven *clean* against the real repo — the same invocation CI's
//! `analysis` job runs, so a red `real_tree_is_clean` here is exactly a
//! red CI wall there.

use std::path::{Path, PathBuf};

use pallas_lint::{
    rule_fault_coverage, rule_metrics_parity, rule_panic_hygiene, rule_protocol_exhaustiveness,
    rule_unsafe_audit, run_all, Violation, RULES,
};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name)
}

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("..")
}

fn render(v: &[Violation]) -> String {
    v.iter().map(|x| format!("  {x}\n")).collect()
}

#[test]
fn protocol_rule_fires_on_rogue_kind() {
    let v = rule_protocol_exhaustiveness(&fixture("protocol"));
    assert_eq!(v.len(), 3, "expected encode+decode+pin gaps:\n{}", render(&v));
    assert!(v.iter().all(|x| x.msg.contains("KIND_ROGUE")), "{}", render(&v));
    assert!(v.iter().any(|x| x.msg.contains("no encode arm")), "{}", render(&v));
    assert!(v.iter().any(|x| x.msg.contains("no decode arm")), "{}", render(&v));
    assert!(v.iter().any(|x| x.msg.contains("not pinned")), "{}", render(&v));
    // All three point at the rogue constant's declaration line.
    assert!(v.iter().all(|x| x.line == 7), "{}", render(&v));
}

#[test]
fn protocol_rule_fires_on_undocumented_kind() {
    // Same rogue-kind tree, but this one carries a DESIGN.md whose wire
    // table documents every kind except the rogue: the docs-side check
    // must add exactly one violation to the three code-side gaps.
    let v = rule_protocol_exhaustiveness(&fixture("protocol_docs"));
    assert_eq!(v.len(), 4, "expected encode+decode+pin+docs gaps:\n{}", render(&v));
    assert!(v.iter().all(|x| x.msg.contains("KIND_ROGUE")), "{}", render(&v));
    assert!(
        v.iter().any(|x| x.msg.contains("DESIGN.md")),
        "the docs gap must fire:\n{}",
        render(&v)
    );
}

#[test]
fn metrics_rule_fires_on_ghost_counter() {
    let v = rule_metrics_parity(&fixture("metrics"));
    assert_eq!(v.len(), 2, "expected summary+JSON gaps:\n{}", render(&v));
    assert!(v.iter().all(|x| x.msg.contains("ghost_counter")), "{}", render(&v));
    assert!(v.iter().any(|x| x.msg.contains("summary")), "{}", render(&v));
    assert!(
        v.iter().any(|x| x.msg.contains("JSON emitter")),
        "{}",
        render(&v)
    );
    // The in-parity ServeMetrics half must not fire.
    assert!(v.iter().all(|x| x.file.contains("coordinator")), "{}", render(&v));
}

#[test]
fn metrics_rule_fires_on_undocumented_counter() {
    // Same shape as the protocol docs check: this tree is in full
    // summary/JSON parity but carries a DESIGN.md whose counters table
    // omits one declared RunStats counter — exactly one docs violation,
    // pointing at the field's declaration line.
    let v = rule_metrics_parity(&fixture("metrics_docs"));
    assert_eq!(v.len(), 1, "expected one docs gap:\n{}", render(&v));
    assert!(
        v[0].msg.contains("RunStats.undocumented_counter"),
        "{}",
        render(&v)
    );
    assert!(
        v[0].msg.contains("DESIGN.md's counters table"),
        "{}",
        render(&v)
    );
    assert!(v[0].file.contains("kmeans"), "{}", render(&v));
}

#[test]
fn fault_rule_fires_on_uninjected_variant() {
    let v = rule_fault_coverage(&fixture("fault"));
    assert_eq!(v.len(), 1, "expected one uncovered variant:\n{}", render(&v));
    assert!(v[0].msg.contains("Fault::Vanish"), "{}", render(&v));
    assert!(v[0].msg.contains("vanish"), "token should come from Display: {}", render(&v));
}

#[test]
fn panic_rule_fires_on_decode_sites_and_bare_allow() {
    let v = rule_panic_hygiene(&fixture("panic"));
    assert_eq!(
        v.len(),
        3,
        "expected index + unwrap + reasonless allow:\n{}",
        render(&v)
    );
    assert!(
        v.iter().any(|x| x.msg.contains("index/slice")),
        "{}",
        render(&v)
    );
    assert!(v.iter().any(|x| x.msg.contains(".unwrap()")), "{}", render(&v));
    assert!(
        v.iter().any(|x| x.msg.contains("no justification")),
        "a reasonless allow must itself be a violation:\n{}",
        render(&v)
    );
    // The justified site and the #[cfg(test)] unwraps stay silent.
    assert!(v.iter().all(|x| x.file.ends_with("frame.rs")), "{}", render(&v));
}

#[test]
fn unsafe_rule_fires_outside_allowlist_and_on_undocumented_blocks() {
    let v = rule_unsafe_audit(&fixture("unsafe"));
    assert_eq!(
        v.len(),
        3,
        "expected allowlist escape + two missing SAFETY:\n{}",
        render(&v)
    );
    assert!(
        v.iter()
            .any(|x| x.file.ends_with("evil.rs") && x.msg.contains("allowlist")),
        "{}",
        render(&v)
    );
    assert!(
        v.iter()
            .any(|x| x.file.ends_with("client.rs") && x.msg.contains("SAFETY")),
        "{}",
        render(&v)
    );
    // The simd kernel file is allowlisted, but an undocumented intrinsic
    // call inside it must still demand its SAFETY comment.
    assert!(
        v.iter()
            .any(|x| x.file.ends_with("panel/simd.rs") && x.msg.contains("SAFETY")),
        "{}",
        render(&v)
    );
}

#[test]
fn rule_names_are_unique_and_registered() {
    assert_eq!(RULES.len(), 5);
    for i in 0..RULES.len() {
        for j in i + 1..RULES.len() {
            assert_ne!(RULES[i].0, RULES[j].0);
        }
    }
}

/// The gate CI's `analysis` job enforces: the real tree carries zero
/// violations.  If this fails, either fix the flagged code or — for a
/// provably-safe site — annotate it with a justification.
#[test]
fn real_tree_is_clean() {
    let v = run_all(&repo_root());
    assert!(
        v.is_empty(),
        "pallas-lint found violations on the real tree:\n{}",
        render(&v)
    );
}
