//! Fixture: a hostile-input decode path with one unchecked index, one
//! bare unwrap, one allow-annotation missing its justification, one
//! properly justified annotation (silent), and test-only unwraps
//! (silent).  `panic-hygiene` must fire exactly three times.

pub fn decode_len(buf: &[u8]) -> u32 {
    let b0 = buf[0];
    let b1 = *buf.iter().nth(1).unwrap();
    // pallas-lint: allow(panic-hygiene)
    let b2 = *buf.get(2).unwrap();
    // pallas-lint: allow(panic-hygiene) caller pinned len >= 4 via the header check
    let b3 = *buf.get(3).unwrap();
    u32::from_le_bytes([b0, b1, b2, b3])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips() {
        let buf = vec![1u8, 0, 0, 0];
        assert_eq!(decode_len(&buf), 1);
        let opt: Option<u8> = Some(1);
        opt.unwrap();
    }
}
