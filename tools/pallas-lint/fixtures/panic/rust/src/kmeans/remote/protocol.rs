//! Fixture: this half of the decode surface is clean, so every firing
//! in the fixture tree is attributable to `frame.rs`.

pub fn decode_kind(buf: &[u8]) -> Option<u8> {
    buf.first().copied()
}
