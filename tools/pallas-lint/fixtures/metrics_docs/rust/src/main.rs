//! Fixture CLI: the report emitter knows every CoordMetrics counter.

fn write_coord_report(iters: u64) -> String {
    let pairs = [("iters", iters)];
    let mut out = String::from("{");
    for (k, v) in pairs {
        out.push_str(k);
        out.push_str(&v.to_string());
    }
    out.push('}');
    out
}
