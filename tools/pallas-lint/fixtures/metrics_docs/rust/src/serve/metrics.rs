//! Fixture serve metrics: in parity with summary and JSON, and its one
//! counter is listed in the fixture DESIGN.md — only the RunStats ghost
//! may fire.

pub struct ServeMetrics {
    pub requests: u64,
}

impl ServeMetrics {
    pub fn summary(&self) -> String {
        format!("requests {}", self.requests)
    }

    pub fn to_json(&self) -> String {
        let pairs = [("requests", self.requests)];
        let mut out = String::from("{");
        for (k, v) in pairs {
            out.push_str(k);
            out.push_str(&v.to_string());
        }
        out.push('}');
        out
    }
}
