//! Fixture coordinator metrics: fully in parity (summary + report JSON
//! both know `iters`), so this tree isolates the docs-side gap.

pub struct CoordMetrics {
    pub iters: u64,
}

impl CoordMetrics {
    pub fn summary(&self) -> String {
        format!("iters {}", self.iters)
    }
}
