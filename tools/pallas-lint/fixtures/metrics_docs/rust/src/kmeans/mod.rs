//! Fixture solver stats: `undocumented_counter` is declared on RunStats
//! but absent from the fixture DESIGN.md's counters table — the
//! metrics-parity docs check must fire exactly once, on its line.

pub struct RunStats {
    pub iters: u64,
    pub undocumented_counter: u64,
}
