//! Fixture chaos suite: exercises refuse and none via schedule strings,
//! never the third variant.

#[test]
fn refusals_fall_back() {
    let schedule = "refuse,none";
    assert!(!schedule.is_empty());
}
