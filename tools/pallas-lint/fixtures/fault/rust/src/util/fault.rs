//! Fixture: `Fault::Vanish` exists with a Display arm but no chaos test
//! ever injects it — `fault-coverage` must fire exactly once.

use std::fmt;

pub enum Fault {
    None,
    Refuse,
    Vanish,
}

impl fmt::Display for Fault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Fault::None => write!(f, "none"),
            Fault::Refuse => write!(f, "refuse"),
            Fault::Vanish => write!(f, "vanish"),
        }
    }
}
