//! Fixture serve metrics: fully in parity, so the rule stays silent on
//! this half and the fixture isolates the CoordMetrics gap.

pub struct ServeMetrics {
    pub requests: u64,
}

impl ServeMetrics {
    pub fn summary(&self) -> String {
        format!("requests {}", self.requests)
    }

    pub fn to_json(&self) -> String {
        let pairs = [("requests", self.requests)];
        let mut out = String::from("{");
        for (k, v) in pairs {
            out.push_str(k);
            out.push_str(&v.to_string());
        }
        out.push('}');
        out
    }
}
