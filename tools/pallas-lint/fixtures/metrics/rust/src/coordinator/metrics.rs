//! Fixture: `ghost_counter` is declared but surfaces in neither the
//! summary formatter nor the report JSON.  `metrics-parity` must fire
//! twice (summary + JSON), both pointing at the field's line.

pub struct CoordMetrics {
    pub iters: u64,
    pub ghost_counter: u64,
}

impl CoordMetrics {
    pub fn summary(&self) -> String {
        format!("iters {}", self.iters)
    }
}
