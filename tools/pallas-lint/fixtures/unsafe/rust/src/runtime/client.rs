//! Fixture: allowlisted file where the first `unsafe impl` lacks a
//! SAFETY comment (fires) and the second carries one (silent).

pub struct Handle(*mut u8);

unsafe impl Send for Handle {}

// SAFETY: the raw pointer is only dereferenced under the runtime lock.
unsafe impl Sync for Handle {}
