//! Fixture: `unsafe` outside the audited allowlist — even a documented
//! block must fire `unsafe-audit`.

pub fn as_bytes(x: &u32) -> &[u8] {
    // SAFETY: documentation does not substitute for the allowlist.
    unsafe { std::slice::from_raw_parts(x as *const u32 as *const u8, 4) }
}
