//! Fixture: the SIMD kernel file is allowlisted, but an intrinsic call
//! without a safety justification must still fire (first fn); a
//! documented one stays silent (second fn).

pub fn lane_splat_undocumented(x: f32) -> f32 {
    unsafe { core::arch::x86_64::_mm256_cvtss_f32(core::arch::x86_64::_mm256_set1_ps(x)) }
}

pub fn lane_splat_documented(x: f32) -> f32 {
    // SAFETY: set1/cvtss are value-only intrinsics with no memory access;
    // the caller verified the avx target feature at dispatch time.
    unsafe { core::arch::x86_64::_mm256_cvtss_f32(core::arch::x86_64::_mm256_set1_ps(x)) }
}
