//! Fixture: allowlisted and documented — must stay silent.

pub fn cycle_counter() -> u64 {
    // SAFETY: rdtsc reads a counter register and has no memory effects.
    unsafe { core::arch::x86_64::_rdtsc() }
}
