//! Fixture: a message-kind constant (`KIND_ROGUE`) declared but wired
//! into neither the encode path, nor the decode path, nor the frame
//! property suite.  `protocol-exhaustiveness` must fire three times.

pub const KIND_HELLO: u8 = 1;
pub const KIND_JOB: u8 = 2;
pub const KIND_ROGUE: u8 = 3;

pub fn encode(kind: u8) -> Vec<u8> {
    match kind {
        k if k == KIND_HELLO => vec![KIND_HELLO],
        _ => encode_job(),
    }
}

pub fn encode_job() -> Vec<u8> {
    vec![KIND_JOB]
}

pub fn decode(buf: &[u8]) -> Option<u8> {
    match buf.first().copied() {
        Some(k) if k == KIND_HELLO => Some(KIND_HELLO),
        Some(k) if k == KIND_JOB => Some(KIND_JOB),
        _ => None,
    }
}
