//! Fixture property suite: pins the two wired kinds; the rogue constant
//! is deliberately absent so the lint's third check fires.

#[test]
fn kinds_round_trip() {
    assert_eq!(KIND_HELLO, 1);
    assert_eq!(KIND_JOB, 2);
}
