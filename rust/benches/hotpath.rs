//! Hot-path micro-benchmarks (the §Perf working set): kd-tree build
//! (sequential vs parallel), the two filtering engines, the panel-engine
//! backends (flat scalar / blocked / multi-threaded), the software Lloyd
//! inner loop, and the coordinator end-to-end on the CPU backend.
//!
//! `cargo bench --bench hotpath`
//!
//! Knobs (CI smoke run): `MUCHSWIFT_BENCH_BUDGET_MS` caps the per-bench
//! measurement budget, `MUCHSWIFT_BENCH_N` overrides the dataset size.
//! Bench names embed the *actual* dataset scale (e.g. `_n20k`), so a
//! smoke-sized artifact can never masquerade as full-scale evidence.
//!
//! Besides the human-readable lines, the run writes the machine-readable
//! `BENCH_hotpath.json` (name → median/mad/min ns) at the repo root —
//! the perf-trajectory evidence tracked across PRs.  The acceptance
//! numbers are the `_n100k` entries (the default size).

use muchswift::coordinator::{Backend, Coordinator};
use muchswift::data::synthetic::generate_params;
use muchswift::kdtree::{KdTree, DEFAULT_LEAF_SIZE};
use muchswift::kmeans::filtering::{
    self, CpuPanels, FilterOpts, FilterScratch, KernelKind, ParCpuPanels, QuantPanels,
};
use muchswift::kmeans::init::{init_centroids, Init};
use muchswift::kmeans::solver::{Algo, KmeansSpec, SolverCtx};
use muchswift::kmeans::panel::{PanelBackend, PanelJobs, PanelSet};
use muchswift::kmeans::{BoundsMode, Metric};
use muchswift::util::bench::{self, Bench, BenchResult};
use std::path::PathBuf;
use std::time::Duration;

fn main() {
    let b = Bench {
        budget: bench::env_budget(Duration::from_secs(3)),
        ..Bench::default()
    };
    let quick = Bench {
        budget: bench::env_budget(Duration::from_secs(2)),
        ..Bench::quick()
    };
    let n: usize = std::env::var("MUCHSWIFT_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100_000);
    let d = 15;
    let k = 20;
    // Scale tag baked into every bench name, e.g. "n100k".
    let tag = format!("n{}k", (n + 500) / 1000);
    let workers = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1)
        .min(8);
    println!("hotpath: n={n} d={d} k={k} workers={workers}");

    let s = generate_params(n, d, k, 0.15, 1.0, 42);
    let init = init_centroids(&s.data, k, Init::UniformSample, Metric::Euclid, 7);
    let mut results: Vec<BenchResult> = Vec::new();

    results.push(b.run(&format!("kdtree_build_seq_{tag}_d15"), || {
        KdTree::build_par(&s.data, DEFAULT_LEAF_SIZE, 0)
    }));
    // Explicit hand-off depth: `KdTree::build` would silently fall back to
    // sequential below its size threshold, turning this into a no-op
    // comparison at smoke sizes.
    results.push(b.run(&format!("kdtree_build_par_{tag}_d15"), || {
        KdTree::build_par(&s.data, DEFAULT_LEAF_SIZE, 2)
    }));

    let tree = KdTree::build(&s.data);
    let mut assignments = vec![0u32; n];

    results.push(b.run(&format!("filter_iteration_recursive_{tag}"), || {
        filtering::filter_iteration(&tree, &s.data, &init, Metric::Euclid, &mut assignments)
    }));

    // The seed baseline path: scalar panels, single thread (now flat).
    let mut scratch = FilterScratch::new();
    results.push(b.run(&format!("filter_iteration_batched_cpu_{tag}"), || {
        filtering::filter_iteration_batched_scratch(
            &tree,
            &s.data,
            &init,
            Metric::Euclid,
            &mut CpuPanels,
            &mut assignments,
            &mut scratch,
        )
    }));

    // Blocked kernel, single thread: isolates the kernel win.
    let mut blocked = ParCpuPanels::with_kernel(1, filtering::PanelKernel::Blocked);
    results.push(b.run(&format!("filter_iteration_batched_blocked_{tag}"), || {
        filtering::filter_iteration_batched_scratch(
            &tree,
            &s.data,
            &init,
            Metric::Euclid,
            &mut blocked,
            &mut assignments,
            &mut scratch,
        )
    }));

    // The production profile: blocked kernel across all cores.
    let mut par = ParCpuPanels::new(workers);
    results.push(b.run(&format!("filter_iteration_batched_par_{tag}"), || {
        filtering::filter_iteration_batched_scratch(
            &tree,
            &s.data,
            &init,
            Metric::Euclid,
            &mut par,
            &mut assignments,
            &mut scratch,
        )
    }));

    // Raw panel throughput on a dense leaf-level-like batch.
    {
        let jobs_n = (n / 10).max(1);
        let mut jobs = PanelJobs::new();
        jobs.clear(d);
        let cands: Vec<u32> = (0..k as u32).collect();
        for j in 0..jobs_n {
            jobs.push(s.data.point(j % n), &cands);
        }
        let mut out = PanelSet::new();
        let mut par_panels = ParCpuPanels::new(workers);
        par_panels.begin_pass(&init, Metric::Euclid);
        results.push(b.run(&format!("panel_dense_{jobs_n}j_k20_par"), || {
            par_panels.panels(&jobs, &init, Metric::Euclid, &mut out);
        }));
        let mut scalar_panels = CpuPanels;
        results.push(b.run(&format!("panel_dense_{jobs_n}j_k20_scalar"), || {
            scalar_panels.panels(&jobs, &init, Metric::Euclid, &mut out);
        }));
    }

    // Kernel-tier isolation: the same dense candidate panel at widths
    // straddling the vector lanes (d ∈ {8, 16, 64, 128}), one thread, one
    // entry per tier.  CI's bench-smoke gate reads the `kernel_simd_d*`
    // vs `kernel_blocked_d*` medians and fails below 2x at d >= 16.  On a
    // host without AVX2/FMA or NEON `with_kind` demotes SIMD to blocked,
    // so the entries still exist (the gate, not the bench, is x86-only).
    for kd in [8usize, 16, 64, 128] {
        let kn = (n / 20).max(1);
        let ks = generate_params(kn, kd, k, 0.15, 1.0, 7 + kd as u64);
        let kcents = init_centroids(&ks.data, k, Init::UniformSample, Metric::Euclid, 11);
        let mut jobs = PanelJobs::new();
        jobs.clear(kd);
        let cands: Vec<u32> = (0..k as u32).collect();
        for j in 0..kn {
            jobs.push(ks.data.point(j), &cands);
        }
        let mut out = PanelSet::new();
        let mut scalar = CpuPanels;
        scalar.begin_pass(&kcents, Metric::Euclid);
        results.push(quick.run(&format!("kernel_scalar_d{kd}_k20"), || {
            scalar.panels(&jobs, &kcents, Metric::Euclid, &mut out);
        }));
        let mut blocked = ParCpuPanels::with_kind(1, KernelKind::Blocked);
        blocked.begin_pass(&kcents, Metric::Euclid);
        results.push(quick.run(&format!("kernel_blocked_d{kd}_k20"), || {
            blocked.panels(&jobs, &kcents, Metric::Euclid, &mut out);
        }));
        let mut simd = ParCpuPanels::with_kind(1, KernelKind::Simd);
        simd.begin_pass(&kcents, Metric::Euclid);
        results.push(quick.run(&format!("kernel_simd_d{kd}_k20"), || {
            simd.panels(&jobs, &kcents, Metric::Euclid, &mut out);
        }));
        let mut quant = QuantPanels::new();
        quant.begin_pass(&kcents, Metric::Euclid);
        results.push(quick.run(&format!("kernel_simd_i8_d{kd}_k20"), || {
            quant.panels(&jobs, &kcents, Metric::Euclid, &mut out);
        }));
    }

    // Bounds-plane win: the same short batched run with the
    // triangle-inequality bounds off vs on, at k straddling the Auto
    // threshold.  Identical data and init in both modes, forced On (Auto
    // would leave k=20 off by design, and the k=20 pair is exactly the
    // "don't pay below the threshold" evidence).  CI's bench gate reads
    // the `bounds_on_k{64,256}` vs `bounds_off_k{64,256}` medians and
    // requires a strict win at large k.
    for bk in [20usize, 64, 256] {
        let bn = (n / 5).max(bk);
        let bset = generate_params(bn, 8, bk, 0.05, 1.0, 19 + bk as u64);
        let btree = KdTree::build(&bset.data);
        let binit = init_centroids(&bset.data, bk, Init::UniformSample, Metric::Euclid, 23);
        for (mode, label) in [(BoundsMode::Off, "off"), (BoundsMode::On, "on")] {
            let opts = FilterOpts {
                metric: Metric::Euclid,
                tol: 0.0,
                max_iters: 4,
                bounds: mode,
            };
            results.push(quick.run(&format!("bounds_{label}_k{bk}"), || {
                filtering::run_batched(&bset.data, &btree, &binit, &opts, &mut CpuPanels)
            }));
        }
    }

    let lloyd_spec = KmeansSpec::new(k)
        .algo(Algo::Lloyd)
        .max_iters(3)
        .tol(0.0)
        .start(init.clone());
    results.push(quick.run(&format!("lloyd_full_run_{tag}_k20"), || {
        lloyd_spec.solve(&mut SolverCtx::new(&s.data))
    }));

    let coord = Coordinator::new(Backend::Cpu);
    let coord_spec = KmeansSpec::two_level(k).seed(3);
    results.push(quick.run(&format!("coordinator_cpu_{tag}_k20"), || {
        coord.run(&s.data, &coord_spec)
    }));

    // Shard-plane scaling sweep: the same two-level workload at P ∈
    // {1, 2, 4, 8, 16} shards over the machine's workers.  Each P gets a
    // whole-run wall entry plus a `_level1` entry distilled from the
    // coordinator's own phase stopwatch — the number the ROADMAP's
    // scaling claim reads (level-1 wall shrinking as P grows up to the
    // core count).
    for p in [1usize, 2, 4, 8, 16] {
        let spec = KmeansSpec::two_level(k).seed(3).shards(p).workers(workers);
        let mut level1_laps: Vec<f64> = Vec::new();
        let r = quick.run(&format!("shard_scaling_p{p}_{tag}_k20"), || {
            let out = coord.run(&s.data, &spec);
            level1_laps.push(out.metrics.level1_s);
            out
        });
        // Bench::run calls the closure once as a warmup before the measured
        // samples — drop that cold lap so the distilled level-1 stats line
        // up with the paired whole-run entry.
        let measured = &mut level1_laps[1..];
        measured.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = measured[measured.len() / 2];
        let min = measured.first().copied().unwrap_or(f64::NAN);
        println!(
            "shard_scaling P={p}: whole-run median {:.4}s, level1 median {med:.4}s",
            r.median_s
        );
        results.push(BenchResult {
            name: format!("shard_scaling_p{p}_level1_{tag}_k20"),
            samples: measured.len(),
            median_s: med,
            mad_s: 0.0,
            min_s: min,
        });
        results.push(r);
    }

    // Headline ratio for the perf trajectory.
    let med = |name: String| {
        results
            .iter()
            .find(|r| r.name == name)
            .map(|r| r.median_s)
            .unwrap_or(f64::NAN)
    };
    let base = med(format!("filter_iteration_batched_cpu_{tag}"));
    let fast = med(format!("filter_iteration_batched_par_{tag}"));
    if base.is_finite() && fast.is_finite() && fast > 0.0 {
        println!(
            "speedup filter_iteration_batched par-vs-scalar-cpu at {tag}: {:.2}x",
            base / fast
        );
    }

    // Machine-readable trajectory artifact at the repo root.
    let out_path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_hotpath.json");
    match bench::write_json(&out_path, &results) {
        Ok(()) => println!("wrote {}", out_path.display()),
        Err(e) => eprintln!("could not write {}: {e}", out_path.display()),
    }
}
