//! Hot-path micro-benchmarks (the §Perf working set): kd-tree build, the
//! two filtering engines, the software Lloyd inner loop, and the
//! coordinator end-to-end on the CPU backend.
//!
//! `cargo bench --bench hotpath`

use muchswift::coordinator::{Backend, Coordinator, CoordinatorOpts};
use muchswift::data::synthetic::generate_params;
use muchswift::kdtree::KdTree;
use muchswift::kmeans::filtering::{self, CpuPanels};
use muchswift::kmeans::init::{init_centroids, Init};
use muchswift::kmeans::lloyd::{self, LloydOpts};
use muchswift::kmeans::Metric;
use muchswift::util::bench::Bench;

fn main() {
    let b = Bench::default();
    let n = 100_000;
    let d = 15;
    let k = 20;
    let s = generate_params(n, d, k, 0.15, 1.0, 42);
    let init = init_centroids(&s.data, k, Init::UniformSample, Metric::Euclid, 7);

    b.run("kdtree_build_100k_d15", || KdTree::build(&s.data));

    let tree = KdTree::build(&s.data);
    let mut assignments = vec![0u32; n];

    b.run("filter_iteration_recursive_100k", || {
        filtering::filter_iteration(&tree, &s.data, &init, Metric::Euclid, &mut assignments)
    });

    b.run("filter_iteration_batched_cpu_100k", || {
        filtering::filter_iteration_batched(
            &tree,
            &s.data,
            &init,
            Metric::Euclid,
            &mut CpuPanels,
            &mut assignments,
        )
    });

    let quick = Bench::quick();
    quick.run("lloyd_full_run_100k_k20", || {
        lloyd::run(
            &s.data,
            &init,
            &LloydOpts {
                max_iters: 3,
                tol: 0.0,
                ..Default::default()
            },
        )
    });

    let coord = Coordinator::new(Backend::Cpu);
    quick.run("coordinator_cpu_100k_k20", || {
        coord.run(
            &s.data,
            &CoordinatorOpts {
                k,
                seed: 3,
                ..Default::default()
            },
        )
    });
}
