//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. partition strategy (round-robin vs kd-top) — merge-seed quality;
//! 2. transfer/compute overlap (double-buffered FIFO vs store-and-forward);
//! 3. module count scaling (1 / K / 4K modules);
//! 4. two-level vs single-level filtering — iteration counts;
//! 5. software baselines: Lloyd vs Elkan vs filtering.
//!
//! `cargo bench --bench ablations`

use muchswift::arch::{evaluate, measure, ArchKind};
use muchswift::config::{PlatformConfig, WorkloadConfig};
use muchswift::data::synthetic::generate_params;
use muchswift::hw::pl::PlArray;
use muchswift::hw::zynq::ZynqSim;
use muchswift::kmeans::init::Init;
use muchswift::kmeans::solver::{Algo, KmeansSpec, SolverCtx};
use muchswift::kmeans::twolevel::Partition;
use muchswift::kmeans::Metric;

fn wl(n: usize, d: usize, k: usize) -> WorkloadConfig {
    WorkloadConfig {
        n,
        d,
        k,
        true_k: k,
        sigma: 0.15,
        seed: 99,
        max_iters: 60,
        ..Default::default()
    }
}

fn main() {
    println!("== ablation 1: partition strategy (level-2 iterations, objective) ==");
    for part in [Partition::RoundRobin, Partition::KdTop] {
        let s = generate_params(60_000, 15, 8, 0.15, 1.0, 5);
        let r = KmeansSpec::two_level(8)
            .partition(part)
            .init(Init::UniformSample)
            .seed(11)
            .solve(&mut SolverCtx::new(&s.data));
        let ext = r.ext.two_level.as_ref().unwrap();
        println!(
            "  {:<12} level2_iters={:<4} objective={:.4e} l1_iters={:?}",
            format!("{part:?}"),
            r.stats.iterations(),
            r.objective(&s.data, Metric::Euclid),
            ext.level1_stats.iter().map(|s| s.iterations()).collect::<Vec<_>>()
        );
    }

    println!("\n== ablation 2: FIFO double-buffering (overlap) ==");
    let w = wl(1_000_000, 15, 20);
    let m = measure(ArchKind::MuchSwift, &w);
    let cfg = PlatformConfig::zcu102();
    let sim = ZynqSim::new(cfg.clone());
    let pl = PlArray::for_workload(&cfg, w.k, 4);
    for overlap in [true, false] {
        let mut total = 0.0;
        for it in &m.stats.iters {
            total += sim.filter_iteration(it, w.d, &pl, 4, overlap).total_s;
        }
        println!("  overlap={overlap:<5} level2 compute {total:.4} s");
    }

    println!("\n== ablation 3: module-count scaling (one Lloyd iteration) ==");
    for (label, pl) in [
        ("naive (II=8)", PlArray::naive(&cfg)),
        ("K modules", PlArray::for_workload(&cfg, w.k, 1)),
        ("4K modules", PlArray::for_workload(&cfg, w.k, 4)),
    ] {
        let t = sim.lloyd_iteration(w.n as u64, w.d, w.k, &pl, true);
        println!(
            "  {label:<12} modules={:<4} t/iter={:.4} s (pl {:.4}, xfer {:.4})",
            pl.modules, t.total_s, t.pl_s, t.xfer_s
        );
    }

    println!("\n== ablation 4: two-level vs single-level filtering iterations ==");
    let s = generate_params(60_000, 15, 8, 0.15, 1.0, 5);
    // One ctx: the full-dataset kd-tree is built once and shared by both
    // solves through the unified API.
    let mut ctx = SolverCtx::new(&s.data);
    let two = KmeansSpec::two_level(8).seed(11).solve(&mut ctx);
    let single = KmeansSpec::new(8)
        .algo(Algo::Filter)
        .seed(11)
        .solve(&mut ctx);
    let ext = two.ext.two_level.as_ref().unwrap();
    println!(
        "  two-level: l1(max)={} + l2={} | single-level: {}",
        ext.level1_stats.iter().map(|s| s.iterations()).max().unwrap_or(0),
        two.stats.iterations(),
        single.stats.iterations()
    );

    println!("\n== ablation 5: software algorithm comparison (simulated A53) ==");
    let w2 = wl(200_000, 15, 16);
    for kind in [ArchKind::SwLloyd, ArchKind::SwElkan, ArchKind::SwFilter] {
        println!("  {}", evaluate(kind, &w2).row());
    }
}
