//! Regenerates paper Fig. 2a: average clock cycles per iteration,
//! MUCH-SWIFT vs the single-core FPGA filtering architecture of [13].
//! Paper: ~8.5x average speedup.  `cargo bench --bench fig2a`
use muchswift::experiments::fig2;

fn main() {
    let sweep = fig2::fig2a();
    print!("{}", sweep.render());
}
