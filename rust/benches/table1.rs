//! Regenerates paper Table 1: PL resource utilization vs cluster count
//! (calibrated model; anchors reproduce the table verbatim).
//! `cargo bench --bench table1`
use muchswift::experiments::table1;

fn main() {
    print!("{}", table1::render());
}
