//! Regenerates paper Fig. 3a: execution time on 10^6 points, 15 dims,
//! K = 2..100, MUCH-SWIFT vs the multi-core FPGA k-means of [17].
//! Paper: ~12x average, gap grows with K.  `cargo bench --bench fig3a`
use muchswift::experiments::fig3;

fn main() {
    print!("{}", fig3::fig3a().render());
}
