//! Regenerates paper Fig. 2b: end-to-end speedup of MUCH-SWIFT over the
//! conventional single-module FPGA Lloyd implementation.
//! Paper: >210x average, up to 330x.  `cargo bench --bench fig2b`
use muchswift::experiments::fig2;

fn main() {
    let sweep = fig2::fig2b();
    print!("{}", sweep.render());
    let (sw, ms, speedup) = fig2::headline();
    println!("headline (10^6 x 15d, K=20): software-only {sw:.2}s vs much-swift {ms:.3}s -> {speedup:.0}x (paper ~330x)");
}
