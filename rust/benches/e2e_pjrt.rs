//! End-to-end bench: the coordinator with the PJRT (Pallas/XLA) backend —
//! the full three-layer stack on the request path.  Reports wall time and
//! offload characteristics.  Requires `make artifacts`.
//!
//! `cargo bench --bench e2e_pjrt`

use muchswift::coordinator::{Backend, Coordinator};
use muchswift::kmeans::solver::KmeansSpec;
use muchswift::data::synthetic::generate_params;
use muchswift::runtime::{self, PjrtRuntime};
use muchswift::util::bench::Bench;
use std::sync::Arc;

fn main() {
    let rt = match PjrtRuntime::load(&runtime::default_artifact_dir()) {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            eprintln!("skipping e2e_pjrt: {e}");
            return;
        }
    };

    let n = 30_000;
    let (d, k) = (15, 8);
    let s = generate_params(n, d, k, 0.15, 1.0, 42);
    let coord = Coordinator::new(Backend::Pjrt(Arc::clone(&rt)));
    let quick = Bench::quick();

    let spec = KmeansSpec::two_level(k).seed(3);
    let r = quick.run("coordinator_pjrt_30k_d15_k8", || {
        coord.run(&s.data, &spec)
    });

    // One instrumented run for the report.
    let out = coord.run(&s.data, &spec);
    println!("  {}", out.metrics.summary());
    println!(
        "  throughput: {:.1} kpoints/s (median)",
        n as f64 / r.median_s / 1e3
    );
    println!(
        "  pjrt share of wall: {:.1}%",
        100.0 * out.metrics.pjrt_exec_s / out.metrics.total_s
    );

    // CPU backend same workload for comparison.
    let cpu = Coordinator::new(Backend::Cpu);
    quick.run("coordinator_cpu_30k_d15_k8", || {
        cpu.run(&s.data, &spec)
    });
}
