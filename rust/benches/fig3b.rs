//! Regenerates paper Fig. 3b: execution time on 10^6 points, K = 6,
//! D = 2..50, MUCH-SWIFT vs [17].  `cargo bench --bench fig3b`
use muchswift::experiments::fig3;

fn main() {
    print!("{}", fig3::fig3b().render());
}
