//! `muchswift` — CLI for the MUCH-SWIFT reproduction.
//!
//! Subcommands:
//!   cluster     cluster synthetic/CSV data via the unified solver API
//!               (--algo lloyd|elkan|filter|filter-batched|two-level; the
//!               two-level default runs through the threaded coordinator)
//!   simulate    evaluate an architecture's ZCU102-scale time on a workload
//!   experiment  regenerate a paper figure/table (fig2a|fig2b|fig3a|fig3b|table1|headline|all)
//!   gen-data    write a synthetic dataset to CSV
//!   info        platform, resource model and artifact capabilities

use muchswift::arch::{self, ArchKind};
use muchswift::config::{PlatformConfig, WorkloadConfig};
use muchswift::coordinator::{Backend, Coordinator};
use muchswift::data::{csv, synthetic};
use muchswift::experiments::{fig2, fig3, table1};
use muchswift::kmeans::init::Init;
use muchswift::kmeans::solver::{Algo, IterEvent, IterFlow, IterObserver, KmeansSpec, SolverCtx};
use muchswift::kmeans::twolevel::Partition;
use muchswift::kmeans::{KmeansResult, Metric};
use muchswift::runtime::{self, PjrtPanels, PjrtRuntime};
use muchswift::util::cli::Command;
use muchswift::util::logger;
use std::path::Path;
use std::sync::Arc;

fn commands() -> Vec<Command> {
    vec![
        Command::new("cluster", "cluster a dataset through the unified solver API")
            .opt("n", "100000", "synthetic points (ignored with an input file)")
            .opt("d", "15", "dimensions")
            .opt("k", "8", "clusters")
            .opt("sigma", "0.15", "cluster stddev")
            .opt("seed", "42", "rng seed")
            .opt("algo", "two-level", "lloyd|elkan|filter|filter-batched|two-level")
            .opt("metric", "euclid", "euclid|manhattan")
            .opt("tol", "1e-6", "convergence tolerance (max squared centroid movement)")
            .opt("max-iters", "100", "iteration cap (level-1 and level-2 for two-level)")
            .opt("workers", "4", "worker threads (two-level) / panel threads (filter-batched)")
            .opt("backend", "pjrt", "pjrt|cpu (panel substrate; two-level and filter-batched)")
            .opt("partition", "round-robin", "round-robin|kd-top (two-level)")
            .opt("init", "uniform", "uniform|kmeans++")
            .flag("trace", "stream per-iteration stats through an observer (runs two-level via the sequential solver)")
            .pos("input", "optional CSV dataset (overrides synthetic)"),
        Command::new("simulate", "evaluate an architecture cost model")
            .req("arch", "sw-lloyd|sw-filter|sw-elkan|fpga-lloyd-single|fpga-filter-single|fpga-lloyd-multi|much-swift|all")
            .opt("n", "1000000", "points")
            .opt("d", "15", "dimensions")
            .opt("k", "20", "clusters")
            .opt("sigma", "0.15", "cluster stddev")
            .opt("seed", "42", "rng seed"),
        Command::new("experiment", "regenerate a paper figure/table")
            .pos("id", "fig2a|fig2b|fig3a|fig3b|table1|headline|all"),
        Command::new("gen-data", "write a synthetic dataset to CSV")
            .opt("n", "10000", "points")
            .opt("d", "3", "dimensions")
            .opt("k", "8", "planted clusters")
            .opt("sigma", "0.15", "cluster stddev")
            .opt("seed", "42", "rng seed")
            .pos("output", "output CSV path"),
        Command::new("info", "platform + artifact capabilities"),
    ]
}

fn usage(cmds: &[Command]) -> String {
    let mut s = String::from("muchswift — MUCH-SWIFT reproduction\n\ncommands:\n");
    for c in cmds {
        s.push_str(&format!("  {:<12} {}\n", c.name, c.about));
    }
    s.push_str("\nuse `muchswift <command> --help` for options\n");
    s
}

fn main() {
    logger::init();
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// `--trace`: stream every iteration to stdout through the observer seam.
struct TraceObserver;

impl IterObserver for TraceObserver {
    fn on_iter(&mut self, ev: &IterEvent<'_>) -> IterFlow {
        println!(
            "  [{:?}] iter {:>3}: dist_evals={:<10} node_visits={:<8} moved={:.3e}",
            ev.phase, ev.iter, ev.stats.dist_evals, ev.stats.node_visits, ev.stats.moved
        );
        IterFlow::Continue
    }
}

/// Shared result report for the `cluster` subcommand (all algorithms and
/// both execution paths produce the same [`KmeansResult`] shape).
fn report_result(r: &KmeansResult, data: &muchswift::data::Dataset, metric: Metric) {
    println!("converged: {}", r.stats.converged);
    if let Some(ext) = &r.ext.two_level {
        println!(
            "level-1 iterations per quarter: {:?}",
            ext.level1_stats.iter().map(|s| s.iterations()).collect::<Vec<_>>()
        );
        println!("level-2 iterations: {}", r.stats.iterations());
    } else {
        println!("iterations: {}", r.stats.iterations());
    }
    println!("cluster sizes: {:?}", r.sizes());
    println!("objective: {:.6e}", r.objective(data, metric));
    // Whole-run totals: for two-level, r.stats covers only the level-2
    // refinement — fold in the per-quarter level-1 work so the counters
    // are comparable across --algo choices.
    let mut dist = r.stats.total_dist_evals();
    let mut nodes = r.stats.total_node_visits();
    let mut prunes = r.stats.total_prune_tests();
    let mut leaves = r.stats.total_leaf_points();
    let mut interior = r.stats.total_interior_assigns();
    if let Some(ext) = &r.ext.two_level {
        for l1 in &ext.level1_stats {
            dist += l1.total_dist_evals();
            nodes += l1.total_node_visits();
            prunes += l1.total_prune_tests();
            leaves += l1.total_leaf_points();
            interior += l1.total_interior_assigns();
        }
    }
    println!(
        "work: {dist} dist evals, {nodes} node visits, {prunes} prune tests, \
         {leaves} leaf points, {interior} interior assigns",
    );
}

fn run() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmds = commands();
    let Some(cmd_name) = args.first() else {
        print!("{}", usage(&cmds));
        return Ok(());
    };
    if cmd_name == "--help" || cmd_name == "-h" {
        print!("{}", usage(&cmds));
        return Ok(());
    }
    let Some(cmd) = cmds.iter().find(|c| c.name == cmd_name) else {
        anyhow::bail!("unknown command `{cmd_name}` (try --help)");
    };
    let rest = &args[1..];
    if rest.iter().any(|a| a == "--help" || a == "-h") {
        print!("{}", cmd.help());
        return Ok(());
    }
    let m = cmd.parse(rest)?;

    match m.command {
        "cluster" => {
            let metric: Metric = m.str("metric").parse()?;
            let algo: Algo = m.str("algo").parse()?;
            let trace = m.flag("trace");
            // Fail fast on a bad backend before paying for data loading.
            let pjrt = match m.str("backend") {
                "cpu" => false,
                "pjrt" => true,
                other => anyhow::bail!("unknown backend `{other}`"),
            };
            let data = if let Some(path) = &m.positional {
                println!("loading {path} ...");
                csv::load(Path::new(path))?
            } else {
                let w = WorkloadConfig {
                    n: m.usize("n")?,
                    d: m.usize("d")?,
                    k: m.usize("k")?,
                    true_k: m.usize("k")?,
                    sigma: m.f64("sigma")? as f32,
                    seed: m.u64("seed")?,
                    metric,
                    ..Default::default()
                };
                w.validate()?;
                synthetic::generate(&w).data
            };
            let spec = KmeansSpec::new(m.usize("k")?)
                .algo(algo)
                .metric(metric)
                .tol(m.f64("tol")? as f32)
                .max_iters(m.usize("max-iters")?)
                .level2_max_iters(m.usize("max-iters")?)
                .partition(match m.str("partition") {
                    "round-robin" => Partition::RoundRobin,
                    "kd-top" => Partition::KdTop,
                    other => anyhow::bail!("unknown partition `{other}`"),
                })
                .init(match m.str("init") {
                    "uniform" => Init::UniformSample,
                    "kmeans++" => Init::KmeansPlusPlus,
                    other => anyhow::bail!("unknown init `{other}`"),
                })
                .seed(m.u64("seed")?)
                .workers(m.usize("workers")?);

            if algo == Algo::TwoLevel && !trace {
                // The deployable multi-threaded system.
                let backend = if pjrt {
                    let rt = PjrtRuntime::load(&runtime::default_artifact_dir())?;
                    Backend::Pjrt(Arc::new(rt))
                } else {
                    Backend::Cpu
                };
                let coord = Coordinator::new(backend);
                let out = coord.run(&data, &spec);
                report_result(&out.result, &data, metric);
                println!("{}", out.metrics.summary());
            } else {
                // Single-process path through the unified solver (also the
                // --trace path: the observer streams every iteration).
                if algo == Algo::TwoLevel {
                    // trace implies this path; be explicit that the threaded
                    // coordinator (and with it --backend pjrt / --workers)
                    // is not engaged here.
                    println!(
                        "note: --trace runs two-level through the sequential \
                         solver (cpu, single process); drop --trace for the \
                         threaded coordinator{}",
                        if pjrt { " and the pjrt backend" } else { "" }
                    );
                }
                // Declared before ctx so PJRT panels borrowing it outlive
                // the solve.
                let rt_holder: Option<PjrtRuntime> = if pjrt && algo == Algo::FilterBatched {
                    Some(PjrtRuntime::load(&runtime::default_artifact_dir())?)
                } else {
                    None
                };
                let mut ctx = SolverCtx::new(&data);
                if let Some(rt) = &rt_holder {
                    println!("backend: pjrt ({} artifacts)", rt.manifest().entries.len());
                    ctx = ctx.with_backend(PjrtPanels::new(rt));
                }
                if trace {
                    ctx = ctx.with_observer(TraceObserver);
                }
                let out = spec.solve(&mut ctx);
                report_result(&out, &data, metric);
            }
        }
        "simulate" => {
            let w = WorkloadConfig {
                n: m.usize("n")?,
                d: m.usize("d")?,
                k: m.usize("k")?,
                true_k: m.usize("k")?,
                sigma: m.f64("sigma")? as f32,
                seed: m.u64("seed")?,
                max_iters: 60,
                ..Default::default()
            };
            w.validate()?;
            let archs: Vec<ArchKind> = if m.str("arch") == "all" {
                ArchKind::all().to_vec()
            } else {
                vec![ArchKind::parse(m.str("arch"))?]
            };
            for a in archs {
                println!("{}", arch::evaluate(a, &w).row());
            }
        }
        "experiment" => {
            let id = m.positional.as_deref().unwrap_or("all");
            run_experiment(id)?;
        }
        "gen-data" => {
            let out = m
                .positional
                .clone()
                .ok_or_else(|| anyhow::anyhow!("gen-data needs an output path"))?;
            let s = synthetic::generate_params(
                m.usize("n")?,
                m.usize("d")?,
                m.usize("k")?,
                m.f64("sigma")? as f32,
                1.0,
                m.u64("seed")?,
            );
            csv::save(&s.data, Path::new(&out))?;
            println!("wrote {} points to {out}", s.data.len());
        }
        "info" => {
            let cfg = PlatformConfig::zcu102();
            println!("platform: {} ({} A53 @ {:.1} GHz, {} R5 @ {:.0} MHz, PL @ {:.0} MHz)",
                cfg.name, cfg.a53_cores, cfg.a53_freq_hz / 1e9, cfg.r5_cores,
                cfg.r5_freq_hz / 1e6, cfg.pl_freq_hz / 1e6);
            println!("{}", table1::render());
            match runtime::PjrtRuntime::load(&runtime::default_artifact_dir()) {
                Ok(rt) => {
                    println!("artifacts ({}):", rt.manifest().entries.len());
                    for a in &rt.manifest().entries {
                        println!(
                            "  {:<36} kind={:?} metric={} n={} d={} k={}",
                            a.name, a.kind, a.metric.name(), a.n, a.d, a.k
                        );
                    }
                }
                Err(e) => println!("artifacts: unavailable ({e})"),
            }
        }
        _ => unreachable!(),
    }
    Ok(())
}

fn run_experiment(id: &str) -> anyhow::Result<()> {
    let run_one = |id: &str| -> anyhow::Result<()> {
        match id {
            "fig2a" => print!("{}", fig2::fig2a().render()),
            "fig2b" => print!("{}", fig2::fig2b().render()),
            "fig3a" => print!("{}", fig3::fig3a().render()),
            "fig3b" => print!("{}", fig3::fig3b().render()),
            "table1" => print!("{}", table1::render()),
            "headline" => {
                let (sw, ms, speedup) = fig2::headline();
                println!("== headline: much-swift vs software-only Lloyd ==");
                println!("software-only: {sw:.3} s");
                println!("much-swift:    {ms:.4} s");
                println!("speedup:       {speedup:.0}x   (paper: ~330x)");
            }
            other => anyhow::bail!("unknown experiment `{other}`"),
        }
        Ok(())
    };
    if id == "all" {
        for e in ["table1", "fig2a", "fig2b", "fig3a", "fig3b", "headline"] {
            run_one(e)?;
            println!();
        }
    } else {
        run_one(id)?;
    }
    Ok(())
}
