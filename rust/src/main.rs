//! `muchswift` — CLI for the MUCH-SWIFT reproduction.
//!
//! Subcommands:
//!   cluster     cluster synthetic/CSV data via the unified solver API
//!               (--algo lloyd|elkan|filter|filter-batched|two-level; the
//!               two-level default runs through the threaded coordinator,
//!               optionally spreading level-1 shard solves over remote
//!               `shard-worker`s via repeatable --remote host:port)
//!   shard-worker serve level-1 shard solves over the wire protocol
//!               (the remote end of `cluster --remote`)
//!   chaos-proxy deterministic fault-injecting TCP proxy in front of a
//!               shard-worker (chaos testing / CI smoke)
//!   fit         train a model and save the KmeansModel artifact (JSON)
//!   predict     assign a dataset against a saved model (batched Predictor)
//!   serve-bench closed-loop load generator for the micro-batching
//!               ClusterService; emits BENCH_serve.json
//!   simulate    evaluate an architecture's ZCU102-scale time on a workload
//!   experiment  regenerate a paper figure/table (fig2a|fig2b|fig3a|fig3b|table1|headline|all)
//!   gen-data    write a synthetic dataset to CSV
//!   info        platform, resource model and artifact capabilities

use muchswift::arch::{self, ArchKind};
use muchswift::config::{PlatformConfig, WorkloadConfig};
use muchswift::coordinator::{Backend, CoordOutcome, Coordinator};
use muchswift::data::{csv, synthetic, Dataset};
use muchswift::experiments::{fig2, fig3, table1};
use muchswift::kmeans::init::Init;
use muchswift::kmeans::model::KmeansModel;
use muchswift::kmeans::panel::{KernelKind, ParCpuPanels};
use muchswift::kmeans::predict::Predictor;
use muchswift::kmeans::remote::{RemoteShardPool, RetryPolicy, WorkerServer, PROTOCOL_VERSION};
use muchswift::kmeans::solver::{Algo, IterEvent, IterFlow, IterObserver, KmeansSpec, SolverCtx};
use muchswift::kmeans::twolevel::Partition;
use muchswift::kmeans::{BoundsMode, KmeansResult, Metric};
use muchswift::runtime::{self, PjrtPanels, PjrtRuntime};
use muchswift::serve::{ClusterService, ServeConfig};
use muchswift::util::cli::{Command, Matches};
use muchswift::util::fault::{ChaosProxy, FaultSchedule};
use muchswift::util::json::Json;
use muchswift::util::logger;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn commands() -> Vec<Command> {
    vec![
        Command::new("cluster", "cluster a dataset through the unified solver API")
            .opt("n", "100000", "synthetic points (ignored with an input file)")
            .opt("d", "15", "dimensions")
            .opt("k", "8", "clusters")
            .opt("sigma", "0.15", "cluster stddev")
            .opt("seed", "42", "rng seed")
            .opt("algo", "two-level", "lloyd|elkan|filter|filter-batched|two-level")
            .opt("metric", "euclid", "euclid|l2|manhattan|l1")
            .opt("tol", "1e-6", "convergence tolerance (max squared centroid movement)")
            .opt("max-iters", "100", "iteration cap (level-1 and level-2 for two-level)")
            .opt("workers", "4", "worker threads (two-level) / panel threads (filter-batched)")
            .opt("shards", "4", "level-1 shard count P (two-level; 1 <= P <= n)")
            .opt("backend", "pjrt", "pjrt|cpu (panel substrate; two-level and filter-batched)")
            .opt("partition", "round-robin", "round-robin|kd-top|contiguous (two-level)")
            .opt("init", "uniform", "uniform|kmeans++")
            .opt("kernel", "", "scalar|blocked|simd|auto distance-kernel tier (empty = legacy default)")
            .opt("bounds", "off", "off|auto|on triangle-inequality pruning (batched engine; labels stay bitwise-exact)")
            .multi("remote", "shard-worker endpoint host:port for level-1 solves (repeatable)")
            .opt("remote-timeout-ms", "120000", "per-job deadline and io timeout for remote solves (ms)")
            .opt("remote-retries", "3", "attempts per remote operation, including the first")
            .opt("remote-backoff-ms", "100", "base retry backoff (ms; doubles per attempt, seeded jitter)")
            .opt("report", "", "write a machine-readable coordinator run report (JSON) here")
            .opt("out", "", "write final assignments CSV here (one label per line)")
            .flag("session", "level-1 over the session plane: shards go resident on the remotes once, each iteration ships only O(k*d) centroids/partials (two-level; works all-local too)")
            .flag("trace", "stream per-iteration stats through an observer (runs two-level via the sequential solver)")
            .pos("input", "optional CSV dataset (overrides synthetic)"),
        Command::new("shard-worker", "serve level-1 shard solves to remote coordinators (wire protocol)")
            .opt("listen", "127.0.0.1:7601", "host:port to bind (port 0 picks a free port)")
            .opt("kernel", "scalar", "scalar|blocked|simd|auto distance-kernel tier for shard solves"),
        Command::new("chaos-proxy", "deterministic fault-injecting TCP proxy in front of a shard-worker")
            .req("upstream", "shard-worker endpoint host:port to forward to")
            .opt("listen", "127.0.0.1:0", "host:port to bind (port 0 picks a free port)")
            .opt("schedule", "", "comma-separated fault schedule, e.g. kill@4,none,corrupt@1 (empty = derive from --seed)")
            .opt("seed", "42", "seed for a derived schedule when --schedule is empty")
            .opt("conns", "16", "derived schedule length (connections before it repeats)"),
        Command::new("fit", "train a model and save the KmeansModel artifact")
            .opt("n", "100000", "synthetic points (ignored with an input file)")
            .opt("d", "15", "dimensions")
            .opt("k", "8", "clusters")
            .opt("sigma", "0.15", "cluster stddev")
            .opt("seed", "42", "rng seed")
            .opt("algo", "lloyd", "lloyd|elkan|filter|filter-batched|two-level")
            .opt("metric", "euclid", "euclid|l2|manhattan|l1")
            .opt("tol", "1e-6", "convergence tolerance (max squared centroid movement)")
            .opt("max-iters", "100", "iteration cap (level-1 and level-2 for two-level)")
            .opt("workers", "4", "worker/panel threads")
            .opt("shards", "4", "level-1 shard count P (two-level; 1 <= P <= n)")
            .opt("partition", "round-robin", "round-robin|kd-top|contiguous (two-level)")
            .opt("init", "uniform", "uniform|kmeans++")
            .opt("kernel", "", "scalar|blocked|simd|auto distance-kernel tier (empty = legacy default)")
            .opt("bounds", "off", "off|auto|on triangle-inequality pruning (batched engine; labels stay bitwise-exact)")
            .opt("model", "model.json", "output model path")
            .opt("out", "", "also write training-set assignments CSV here")
            .pos("input", "optional CSV dataset (overrides synthetic)"),
        Command::new("predict", "assign a dataset against a saved model")
            .req("model", "trained model JSON (from `fit`)")
            .opt("out", "assignments.csv", "output labels CSV")
            .opt("workers", "4", "panel worker threads")
            .opt("kernel", "scalar", "scalar|blocked|simd|auto panel kernel (scalar = oracle arithmetic)")
            .flag("quantized", "i8 shortlist + exact f32 re-score (labels stay bitwise-exact)")
            .opt("prune", "auto", "auto|on|off centroid kd-tree prune")
            .opt("bounds", "off", "off|auto|on triangle-inequality candidate pruning")
            .pos("input", "CSV dataset to assign (required)"),
        Command::new("serve-bench", "closed-loop load generator for the ClusterService")
            .opt("n", "20000", "synthetic points backing the request stream")
            .opt("d", "8", "dimensions")
            .opt("k", "16", "clusters")
            .opt("sigma", "0.15", "cluster stddev")
            .opt("seed", "42", "rng seed")
            .opt("clients", "4", "concurrent closed-loop clients")
            .opt("requests", "50", "requests per client")
            .opt("batch", "64", "query points per request")
            .opt("workers", "4", "service panel workers (\"PL cores\")")
            .opt("dispatchers", "1", "dispatcher panel count P draining the shared queue")
            .opt("deadline-us", "0", "micro-batcher deadline in µs (0 = immediate drain)")
            .opt("max-batch", "4096", "micro-batcher point budget per panel batch")
            .opt("queue", "256", "bounded request-queue capacity")
            .opt("kernel", "blocked", "scalar|blocked|simd|auto service panel kernel")
            .flag("quantized", "serve through the i8 shortlist + exact re-score path")
            .opt("bounds", "off", "off|auto|on triangle-inequality candidate pruning")
            // Anchored to the repo root (like BENCH_hotpath.json) so runs
            // from any cwd refresh the checked-in artifact CI gates on.
            .opt(
                "out",
                concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json"),
                "machine-readable report path",
            ),
        Command::new("simulate", "evaluate an architecture cost model")
            .req("arch", "sw-lloyd|sw-filter|sw-elkan|fpga-lloyd-single|fpga-filter-single|fpga-lloyd-multi|much-swift|all")
            .opt("n", "1000000", "points")
            .opt("d", "15", "dimensions")
            .opt("k", "20", "clusters")
            .opt("sigma", "0.15", "cluster stddev")
            .opt("seed", "42", "rng seed"),
        Command::new("experiment", "regenerate a paper figure/table")
            .pos("id", "fig2a|fig2b|fig3a|fig3b|table1|headline|all"),
        Command::new("gen-data", "write a synthetic dataset to CSV")
            .opt("n", "10000", "points")
            .opt("d", "3", "dimensions")
            .opt("k", "8", "planted clusters")
            .opt("sigma", "0.15", "cluster stddev")
            .opt("seed", "42", "rng seed")
            .pos("output", "output CSV path"),
        Command::new("info", "platform + artifact capabilities"),
    ]
}

fn usage(cmds: &[Command]) -> String {
    let mut s = String::from("muchswift — MUCH-SWIFT reproduction\n\ncommands:\n");
    for c in cmds {
        s.push_str(&format!("  {:<12} {}\n", c.name, c.about));
    }
    s.push_str("\nuse `muchswift <command> --help` for options\n");
    s
}

fn main() {
    logger::init();
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// `--trace`: stream every iteration to stdout through the observer seam.
struct TraceObserver;

impl IterObserver for TraceObserver {
    fn on_iter(&mut self, ev: &IterEvent<'_>) -> IterFlow {
        println!(
            "  [{:?}] iter {:>3}: dist_evals={:<10} node_visits={:<8} moved={:.3e}",
            ev.phase, ev.iter, ev.stats.dist_evals, ev.stats.node_visits, ev.stats.moved
        );
        IterFlow::Continue
    }
}

/// Shared result report for the `cluster` subcommand (all algorithms and
/// both execution paths produce the same [`KmeansResult`] shape).
fn report_result(r: &KmeansResult, data: &muchswift::data::Dataset, metric: Metric) {
    println!("converged: {}", r.stats.converged);
    if let Some(ext) = &r.ext.two_level {
        println!(
            "level-1 iterations per shard ({}): {:?}",
            ext.level1_stats.len(),
            ext.level1_stats.iter().map(|s| s.iterations()).collect::<Vec<_>>()
        );
        println!("level-2 iterations: {}", r.stats.iterations());
    } else {
        println!("iterations: {}", r.stats.iterations());
    }
    println!("cluster sizes: {:?}", r.sizes());
    println!("objective: {:.6e}", r.objective(data, metric));
    // Whole-run totals: for two-level, r.stats covers only the level-2
    // refinement — fold in the per-quarter level-1 work so the counters
    // are comparable across --algo choices.
    let mut dist = r.stats.total_dist_evals();
    let mut nodes = r.stats.total_node_visits();
    let mut prunes = r.stats.total_prune_tests();
    let mut leaves = r.stats.total_leaf_points();
    let mut interior = r.stats.total_interior_assigns();
    if let Some(ext) = &r.ext.two_level {
        for l1 in &ext.level1_stats {
            dist += l1.total_dist_evals();
            nodes += l1.total_node_visits();
            prunes += l1.total_prune_tests();
            leaves += l1.total_leaf_points();
            interior += l1.total_interior_assigns();
        }
    }
    println!(
        "work: {dist} dist evals, {nodes} node visits, {prunes} prune tests, \
         {leaves} leaf points, {interior} interior assigns",
    );
    if r.stats.bound_pruned_points + r.stats.bound_pruned_candidates > 0 {
        println!(
            "bounds: {} jobs pruned outright, {} candidates pruned, {} maintenance evals",
            r.stats.bound_pruned_points,
            r.stats.bound_pruned_candidates,
            r.stats.bounds_matrix_cost
        );
    }
}

/// Synthetic-or-CSV dataset for the training-shaped subcommands.
fn load_or_generate(m: &Matches, metric: Metric) -> anyhow::Result<Dataset> {
    if let Some(path) = &m.positional {
        println!("loading {path} ...");
        Ok(csv::load(Path::new(path))?)
    } else {
        let w = WorkloadConfig {
            n: m.usize("n")?,
            d: m.usize("d")?,
            k: m.usize("k")?,
            true_k: m.usize("k")?,
            sigma: m.f64("sigma")? as f32,
            seed: m.u64("seed")?,
            metric,
            ..Default::default()
        };
        w.validate()?;
        Ok(synthetic::generate(&w).data)
    }
}

/// Solver spec shared by `cluster` and `fit`.  Takes the (already
/// loaded) dataset so the shard count can be range-checked against `n`.
fn spec_from_matches(
    m: &Matches,
    metric: Metric,
    algo: Algo,
    data: &Dataset,
) -> anyhow::Result<KmeansSpec> {
    let shards = m.usize("shards")?;
    anyhow::ensure!(shards >= 1, "--shards must be >= 1 (got {shards})");
    anyhow::ensure!(
        shards <= data.len(),
        "--shards {shards} exceeds the dataset size n={}",
        data.len()
    );
    let mut spec = KmeansSpec::new(m.usize("k")?)
        .algo(algo)
        .metric(metric)
        .tol(m.f64("tol")? as f32)
        .max_iters(m.usize("max-iters")?)
        .level2_max_iters(m.usize("max-iters")?)
        .partition(m.str("partition").parse::<Partition>()?)
        .shards(shards)
        .init(m.str("init").parse::<Init>()?)
        .seed(m.u64("seed")?)
        .workers(m.usize("workers")?);
    // Empty keeps the legacy backend choice (and its bitwise pins); an
    // explicit tier resolves leniently inside the solver.
    let kernel = m.str("kernel");
    if !kernel.is_empty() {
        spec = spec.kernel(kernel.parse::<KernelKind>().map_err(anyhow::Error::msg)?);
    }
    spec = spec.bounds(m.str("bounds").parse::<BoundsMode>().map_err(anyhow::Error::msg)?);
    Ok(spec)
}

/// `--out <path>` label emission shared by `cluster`/`fit`/`predict`
/// (empty path = skip).
fn write_labels_if_asked(out: &str, labels: &[u32]) -> anyhow::Result<()> {
    if !out.is_empty() {
        csv::save_labels(labels, Path::new(out))?;
        println!("wrote {} assignments to {out}", labels.len());
    }
    Ok(())
}

fn run() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmds = commands();
    let Some(cmd_name) = args.first() else {
        print!("{}", usage(&cmds));
        return Ok(());
    };
    if cmd_name == "--help" || cmd_name == "-h" {
        print!("{}", usage(&cmds));
        return Ok(());
    }
    let Some(cmd) = cmds.iter().find(|c| c.name == cmd_name) else {
        anyhow::bail!("unknown command `{cmd_name}` (try --help)");
    };
    let rest = &args[1..];
    if rest.iter().any(|a| a == "--help" || a == "-h") {
        print!("{}", cmd.help());
        return Ok(());
    }
    let m = cmd.parse(rest)?;

    match m.command {
        "cluster" => {
            let metric: Metric = m.str("metric").parse()?;
            let algo: Algo = m.str("algo").parse()?;
            let trace = m.flag("trace");
            let session = m.flag("session");
            // Fail fast on a bad backend before paying for data loading.
            let pjrt = match m.str("backend") {
                "cpu" => false,
                "pjrt" => true,
                other => anyhow::bail!("unknown backend `{other}`"),
            };
            let remotes: Vec<String> = m.all("remote").to_vec();
            let report_path = m.str("report").to_string();
            let data = load_or_generate(&m, metric)?;
            let spec = spec_from_matches(&m, metric, algo, &data)?;

            if algo == Algo::TwoLevel && !trace {
                // The deployable multi-threaded system.
                let backend = if pjrt {
                    let rt = PjrtRuntime::load(&runtime::default_artifact_dir())?;
                    Backend::Pjrt(Arc::new(rt))
                } else {
                    Backend::Cpu
                };
                let mut coord = Coordinator::new(backend).with_session(session);
                if session {
                    println!(
                        "session plane: shards resident on workers, O(k*d) per-iteration wire"
                    );
                }
                if !remotes.is_empty() {
                    let timeout_ms = m.u64("remote-timeout-ms")?;
                    let retries = m.u64("remote-retries")?;
                    let backoff_ms = m.u64("remote-backoff-ms")?;
                    anyhow::ensure!(
                        timeout_ms >= 1 && retries >= 1,
                        "--remote-timeout-ms and --remote-retries must be >= 1"
                    );
                    let policy = RetryPolicy {
                        max_attempts: retries.min(u32::MAX as u64) as u32,
                        backoff_base: Duration::from_millis(backoff_ms.max(1)),
                        io_timeout: Duration::from_millis(timeout_ms),
                        job_deadline: Duration::from_millis(timeout_ms),
                        connect_timeout: Duration::from_millis(timeout_ms)
                            .min(Duration::from_secs(5)),
                        ..RetryPolicy::default()
                    };
                    println!(
                        "remote shard workers: {} endpoint(s) {:?} \
                         (deadline {timeout_ms}ms, {retries} attempts, backoff {backoff_ms}ms)",
                        remotes.len(),
                        remotes
                    );
                    coord = coord
                        .with_remotes(RemoteShardPool::new(remotes.clone()).with_policy(policy));
                }
                let out = coord.run(&data, &spec);
                report_result(&out.result, &data, metric);
                println!("{}", out.metrics.summary());
                if !report_path.is_empty() {
                    write_coord_report(&report_path, &data, &spec, &out, &remotes)?;
                }
                write_labels_if_asked(m.str("out"), &out.result.assignments)?;
            } else {
                anyhow::ensure!(
                    remotes.is_empty(),
                    "--remote requires the two-level coordinator path \
                     (use --algo two-level without --trace)"
                );
                anyhow::ensure!(
                    report_path.is_empty(),
                    "--report requires the two-level coordinator path \
                     (use --algo two-level without --trace)"
                );
                anyhow::ensure!(
                    !session,
                    "--session requires the two-level coordinator path \
                     (use --algo two-level without --trace)"
                );
                // Single-process path through the unified solver (also the
                // --trace path: the observer streams every iteration).
                if algo == Algo::TwoLevel {
                    // trace implies this path; be explicit that the threaded
                    // coordinator (and with it --backend pjrt / --workers)
                    // is not engaged here.
                    println!(
                        "note: --trace runs two-level through the sequential \
                         solver (cpu, single process); drop --trace for the \
                         threaded coordinator{}",
                        if pjrt { " and the pjrt backend" } else { "" }
                    );
                }
                // Declared before ctx so PJRT panels borrowing it outlive
                // the solve.
                let rt_holder: Option<PjrtRuntime> = if pjrt && algo == Algo::FilterBatched {
                    Some(PjrtRuntime::load(&runtime::default_artifact_dir())?)
                } else {
                    None
                };
                let mut ctx = SolverCtx::new(&data);
                if let Some(rt) = &rt_holder {
                    println!("backend: pjrt ({} artifacts)", rt.manifest().entries.len());
                    ctx = ctx.with_backend(PjrtPanels::new(rt));
                }
                if trace {
                    ctx = ctx.with_observer(TraceObserver);
                }
                let out = spec.solve(&mut ctx);
                report_result(&out, &data, metric);
                write_labels_if_asked(m.str("out"), &out.assignments)?;
            }
        }
        "shard-worker" => {
            // Strict resolve: asking for SIMD on a host without AVX2/FMA
            // or NEON is an operator error, not a silent demotion.
            let kind: KernelKind = m.str("kernel").parse().map_err(anyhow::Error::msg)?;
            kind.resolve().map_err(anyhow::Error::msg)?;
            let server = WorkerServer::bind(m.str("listen"))?.with_kernel(kind);
            // The exact bound address on its own line (resolves `:0`
            // binds) so scripts/tests can scrape the port.
            println!(
                "shard-worker listening on {} (protocol v{PROTOCOL_VERSION})",
                server.local_addr()
            );
            server.run()?;
            println!("shard-worker: shutdown requested, exiting");
        }
        "chaos-proxy" => {
            let upstream = m.str("upstream").to_string();
            let schedule = if m.str("schedule").is_empty() {
                FaultSchedule::seeded(m.u64("seed")?, m.usize("conns")?.max(1))
            } else {
                FaultSchedule::parse(m.str("schedule")).map_err(anyhow::Error::msg)?
            };
            println!("fault schedule: {schedule}");
            let proxy = ChaosProxy::spawn(m.str("listen"), &upstream, schedule)?;
            // The exact bound address on its own line (resolves `:0`
            // binds) so scripts/tests can scrape the port.
            println!("chaos-proxy listening on {} -> {upstream}", proxy.addr());
            // Proxying happens on background threads; park until killed
            // (CI backgrounds this process and kills it after the smoke).
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
        "fit" => {
            let metric: Metric = m.str("metric").parse()?;
            let algo: Algo = m.str("algo").parse()?;
            let data = load_or_generate(&m, metric)?;
            let spec = spec_from_matches(&m, metric, algo, &data)?;
            let t0 = Instant::now();
            let model = spec.fit(&mut SolverCtx::new(&data));
            let secs = t0.elapsed().as_secs_f64();
            println!(
                "fit[{}]: n={} d={} k={} metric={} — {} iterations, converged={}, \
                 {} dist evals, objective {:.6e} in {:.3}s",
                algo.name(),
                model.train.n,
                model.dims(),
                model.k(),
                metric.name(),
                model.train.iterations,
                model.train.converged,
                model.train.dist_evals,
                model.train.objective.unwrap_or(f64::NAN),
                secs
            );
            let model_path = m.str("model");
            model.save(Path::new(model_path))?;
            println!("wrote model to {model_path}");
            if !m.str("out").is_empty() {
                // Training-set assignments re-derived against the *final*
                // centroids through the same predictor serving will use.
                let labels = Predictor::new(&model).assign(&data);
                write_labels_if_asked(m.str("out"), &labels)?;
            }
        }
        "predict" => {
            // Fail fast on bad flags before touching the filesystem.
            // Strict resolve so `--kernel simd` on an unsupported host is
            // a clean error instead of a silent demotion to blocked.
            let kind: KernelKind = m.str("kernel").parse().map_err(anyhow::Error::msg)?;
            let kernel = kind.resolve().map_err(anyhow::Error::msg)?;
            let prune = match m.str("prune") {
                "auto" => None,
                "on" => Some(true),
                "off" => Some(false),
                other => anyhow::bail!("unknown prune mode `{other}` (auto|on|off)"),
            };
            let input = m
                .positional
                .clone()
                .ok_or_else(|| anyhow::anyhow!("predict needs an input CSV dataset"))?;
            let model = KmeansModel::load(Path::new(m.str("model")))?;
            let data = csv::load(Path::new(&input))?;
            anyhow::ensure!(
                data.dims() == model.dims(),
                "{input} has {} dims but the model expects {}",
                data.dims(),
                model.dims()
            );
            let mut pred = if m.flag("quantized") {
                Predictor::quantized(&model)
            } else {
                Predictor::with_backend(
                    &model,
                    ParCpuPanels::with_kernel(m.usize("workers")?, kernel),
                )
            };
            if let Some(on) = prune {
                pred = pred.prune(on);
            }
            let bounds: BoundsMode = m.str("bounds").parse().map_err(anyhow::Error::msg)?;
            pred = pred.bounds(bounds);
            let t0 = Instant::now();
            let (labels, dists) = pred.assign_scored(&data);
            let secs = t0.elapsed().as_secs_f64();
            let objective: f64 = dists.iter().map(|&d| d as f64).sum();
            println!(
                "predict: {} points against k={} ({}, prune={}) in {:.3}s ({:.0} pts/s)",
                data.len(),
                model.k(),
                model.metric.name(),
                pred.pruning(),
                secs,
                if secs > 0.0 { data.len() as f64 / secs } else { 0.0 }
            );
            println!("objective on this dataset: {objective:.6e}");
            let ks = pred.kernel_stats();
            if ks.quantized_candidates > 0 {
                println!(
                    "kernel: {} candidates shortlisted in i8, {} re-scored in exact f32",
                    ks.quantized_candidates, ks.rescored_candidates
                );
            }
            if pred.bounding() {
                let bs = pred.bounds_stats();
                println!(
                    "bounds: {} candidates pruned, {} queries down to one candidate, \
                     {} maintenance evals",
                    bs.pruned_candidates, bs.pruned_points, bs.matrix_cost
                );
            }
            write_labels_if_asked(m.str("out"), &labels)?;
        }
        "serve-bench" => {
            let (clients, requests, batch) =
                (m.usize("clients")?, m.usize("requests")?, m.usize("batch")?);
            anyhow::ensure!(clients >= 1 && requests >= 1 && batch >= 1, "degenerate load shape");
            anyhow::ensure!(
                m.usize("queue")? >= 1 && m.usize("max-batch")? >= 1 && m.usize("workers")? >= 1,
                "--queue, --max-batch and --workers must all be >= 1"
            );
            anyhow::ensure!(
                m.usize("dispatchers")? >= 1,
                "--dispatchers must be >= 1"
            );
            let w = WorkloadConfig {
                n: m.usize("n")?.max(batch),
                d: m.usize("d")?,
                k: m.usize("k")?,
                true_k: m.usize("k")?,
                sigma: m.f64("sigma")? as f32,
                seed: m.u64("seed")?,
                ..Default::default()
            };
            w.validate()?;
            let data = synthetic::generate(&w).data;
            let spec = KmeansSpec::new(w.k).seed(w.seed).max_iters(40);
            let model = Arc::new(spec.fit(&mut SolverCtx::new(&data)));
            println!(
                "serve-bench: model k={} d={} (trained on {} pts), {clients} clients x \
                 {requests} reqs x {batch} pts",
                model.k(),
                model.dims(),
                model.train.n
            );
            let cfg = ServeConfig {
                workers: m.usize("workers")?,
                max_batch_points: m.usize("max-batch")?,
                queue_cap: m.usize("queue")?,
                dispatchers: m.usize("dispatchers")?,
                batch_deadline_us: m.u64("deadline-us")?,
                kernel: m.str("kernel").parse().map_err(anyhow::Error::msg)?,
                quantized: m.flag("quantized"),
                bounds: m.str("bounds").parse().map_err(anyhow::Error::msg)?,
                ..Default::default()
            };
            let svc = ClusterService::start(Arc::clone(&model), cfg.clone());
            let n = data.len();
            let d = data.dims();
            std::thread::scope(|scope| {
                for c in 0..clients {
                    let svc = &svc;
                    let data = &data;
                    scope.spawn(move || {
                        for r in 0..requests {
                            // Rotating window over the dataset: every
                            // request ships a distinct live slice.
                            let start = ((c * requests + r) * batch) % (n - batch + 1);
                            let slice = Dataset::from_flat(
                                batch,
                                d,
                                data.flat()[start * d..(start + batch) * d].to_vec(),
                            );
                            let reply = svc
                                .predict(slice)
                                .expect("serve-bench predict failed");
                            assert_eq!(reply.labels.len(), batch);
                        }
                    });
                }
            });
            let metrics = svc.shutdown();
            println!("{}", metrics.summary());
            let report = Json::obj(vec![
                ("format_version", Json::num(1.0)),
                // A real measured report; the checked-in schema placeholder
                // says `true` here and CI fails if that marker survives.
                ("placeholder", Json::Bool(false)),
                (
                    "config",
                    Json::obj(vec![
                        ("clients", Json::num(clients as f64)),
                        ("requests_per_client", Json::num(requests as f64)),
                        ("points_per_request", Json::num(batch as f64)),
                        ("workers", Json::num(cfg.workers as f64)),
                        ("dispatchers", Json::num(cfg.dispatchers as f64)),
                        ("batch_deadline_us", Json::num(cfg.batch_deadline_us as f64)),
                        ("max_batch_points", Json::num(cfg.max_batch_points as f64)),
                        ("queue_cap", Json::num(cfg.queue_cap as f64)),
                        ("kernel", Json::str(cfg.kernel.name())),
                        ("quantized", Json::Bool(cfg.quantized)),
                        ("bounds", Json::str(cfg.bounds.name())),
                        ("k", Json::num(model.k() as f64)),
                        ("d", Json::num(model.dims() as f64)),
                    ]),
                ),
                ("metrics", metrics.to_json()),
            ]);
            let out = m.str("out");
            std::fs::write(out, format!("{report}\n"))?;
            println!("wrote {out}");
        }
        "simulate" => {
            let w = WorkloadConfig {
                n: m.usize("n")?,
                d: m.usize("d")?,
                k: m.usize("k")?,
                true_k: m.usize("k")?,
                sigma: m.f64("sigma")? as f32,
                seed: m.u64("seed")?,
                max_iters: 60,
                ..Default::default()
            };
            w.validate()?;
            let archs: Vec<ArchKind> = if m.str("arch") == "all" {
                ArchKind::all().to_vec()
            } else {
                vec![ArchKind::parse(m.str("arch"))?]
            };
            for a in archs {
                println!("{}", arch::evaluate(a, &w).row());
            }
        }
        "experiment" => {
            let id = m.positional.as_deref().unwrap_or("all");
            run_experiment(id)?;
        }
        "gen-data" => {
            let out = m
                .positional
                .clone()
                .ok_or_else(|| anyhow::anyhow!("gen-data needs an output path"))?;
            let s = synthetic::generate_params(
                m.usize("n")?,
                m.usize("d")?,
                m.usize("k")?,
                m.f64("sigma")? as f32,
                1.0,
                m.u64("seed")?,
            );
            csv::save(&s.data, Path::new(&out))?;
            println!("wrote {} points to {out}", s.data.len());
        }
        "info" => {
            let cfg = PlatformConfig::zcu102();
            println!("platform: {} ({} A53 @ {:.1} GHz, {} R5 @ {:.0} MHz, PL @ {:.0} MHz)",
                cfg.name, cfg.a53_cores, cfg.a53_freq_hz / 1e9, cfg.r5_cores,
                cfg.r5_freq_hz / 1e6, cfg.pl_freq_hz / 1e6);
            println!("{}", table1::render());
            match runtime::PjrtRuntime::load(&runtime::default_artifact_dir()) {
                Ok(rt) => {
                    println!("artifacts ({}):", rt.manifest().entries.len());
                    for a in &rt.manifest().entries {
                        println!(
                            "  {:<36} kind={:?} metric={} n={} d={} k={}",
                            a.name, a.kind, a.metric.name(), a.n, a.d, a.k
                        );
                    }
                }
                Err(e) => println!("artifacts: unavailable ({e})"),
            }
        }
        _ => unreachable!(),
    }
    Ok(())
}

/// `cluster --report <path>`: the machine-readable coordinator run report
/// (CI's distributed smoke emits `BENCH_distributed.json` through this;
/// same placeholder-gate policy as the other two bench artifacts).
fn write_coord_report(
    path: &str,
    data: &Dataset,
    spec: &KmeansSpec,
    out: &CoordOutcome,
    remotes: &[String],
) -> anyhow::Result<()> {
    let cm = &out.metrics;
    let report = Json::obj(vec![
        ("format_version", Json::num(1.0)),
        // A real measured report; the checked-in schema placeholder says
        // `true` here and CI fails if that marker survives the run.
        ("placeholder", Json::Bool(false)),
        (
            "config",
            Json::obj(vec![
                ("n", Json::num(data.len() as f64)),
                ("d", Json::num(data.dims() as f64)),
                ("k", Json::num(spec.k as f64)),
                ("shards", Json::num(spec.shards as f64)),
                ("workers", Json::num(spec.workers as f64)),
                ("partition", Json::str(spec.partition.name())),
                ("metric", Json::str(spec.metric.name())),
                ("bounds", Json::str(spec.bounds.name())),
                (
                    "remote_endpoints",
                    Json::Arr(remotes.iter().map(|r| Json::str(r.as_str())).collect()),
                ),
            ]),
        ),
        (
            "metrics",
            Json::obj(vec![
                ("total_s", Json::num(cm.total_s)),
                ("partition_s", Json::num(cm.partition_s)),
                ("tree_build_s", Json::num(cm.tree_build_s)),
                ("level1_s", Json::num(cm.level1_s)),
                ("combine_s", Json::num(cm.combine_s)),
                ("level2_s", Json::num(cm.level2_s)),
                ("offload_batches", Json::num(cm.offload_batches as f64)),
                ("offload_jobs", Json::num(cm.offload_jobs as f64)),
                ("pjrt_executions", Json::num(cm.pjrt_executions as f64)),
                ("pjrt_exec_s", Json::num(cm.pjrt_exec_s)),
                ("observed_iters", Json::num(cm.observed_iters as f64)),
                (
                    "observed_dist_evals",
                    Json::num(cm.observed_dist_evals as f64),
                ),
                ("shards", Json::num(cm.shards as f64)),
                (
                    "shard_iters",
                    Json::Arr(cm.shard_iters.iter().map(|&x| Json::num(x as f64)).collect()),
                ),
                (
                    "shard_dist_evals",
                    Json::Arr(
                        cm.shard_dist_evals
                            .iter()
                            .map(|&x| Json::num(x as f64))
                            .collect(),
                    ),
                ),
                ("remote_workers", Json::num(cm.remote_workers as f64)),
                ("remote_shards", Json::num(cm.remote_shards as f64)),
                ("remote_fallbacks", Json::num(cm.remote_fallbacks as f64)),
                ("remote_retries", Json::num(cm.remote_retries as f64)),
                ("remote_timeouts", Json::num(cm.remote_timeouts as f64)),
                ("remote_reconnects", Json::num(cm.remote_reconnects as f64)),
                ("remote_rescheduled", Json::num(cm.remote_rescheduled as f64)),
                (
                    "remote_failed_endpoints",
                    Json::Arr(
                        cm.remote_failed_endpoints
                            .iter()
                            .map(|r| Json::str(r.as_str()))
                            .collect(),
                    ),
                ),
                ("remote_bytes_tx", Json::num(cm.remote_bytes_tx as f64)),
                ("remote_bytes_rx", Json::num(cm.remote_bytes_rx as f64)),
                ("sessions", Json::num(cm.sessions as f64)),
                ("centroid_bcasts", Json::num(cm.centroid_bcasts as f64)),
                ("partials_rx", Json::num(cm.partials_rx as f64)),
                ("session_bytes_tx", Json::num(cm.session_bytes_tx as f64)),
                ("session_bytes_rx", Json::num(cm.session_bytes_rx as f64)),
                ("shard_reloads", Json::num(cm.shard_reloads as f64)),
                (
                    "bound_pruned_points",
                    Json::num(cm.bound_pruned_points as f64),
                ),
                (
                    "bound_pruned_candidates",
                    Json::num(cm.bound_pruned_candidates as f64),
                ),
                ("bounds_matrix_cost", Json::num(cm.bounds_matrix_cost as f64)),
            ]),
        ),
        (
            "objective",
            Json::num(out.result.objective(data, spec.metric)),
        ),
        ("converged", Json::Bool(out.result.stats.converged)),
    ]);
    std::fs::write(path, format!("{report}\n"))?;
    println!("wrote {path}");
    Ok(())
}

fn run_experiment(id: &str) -> anyhow::Result<()> {
    let run_one = |id: &str| -> anyhow::Result<()> {
        match id {
            "fig2a" => print!("{}", fig2::fig2a().render()),
            "fig2b" => print!("{}", fig2::fig2b().render()),
            "fig3a" => print!("{}", fig3::fig3a().render()),
            "fig3b" => print!("{}", fig3::fig3b().render()),
            "table1" => print!("{}", table1::render()),
            "headline" => {
                let (sw, ms, speedup) = fig2::headline();
                println!("== headline: much-swift vs software-only Lloyd ==");
                println!("software-only: {sw:.3} s");
                println!("much-swift:    {ms:.4} s");
                println!("speedup:       {speedup:.0}x   (paper: ~330x)");
            }
            other => anyhow::bail!("unknown experiment `{other}`"),
        }
        Ok(())
    };
    if id == "all" {
        for e in ["table1", "fig2a", "fig2b", "fig3a", "fig3b", "headline"] {
            run_one(e)?;
            println!();
        }
    } else {
        run_one(id)?;
    }
    Ok(())
}
