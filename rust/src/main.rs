//! `muchswift` — CLI for the MUCH-SWIFT reproduction.
//!
//! Subcommands:
//!   cluster     run the coordinator (two-level k-means) on synthetic/CSV data
//!   simulate    evaluate an architecture's ZCU102-scale time on a workload
//!   experiment  regenerate a paper figure/table (fig2a|fig2b|fig3a|fig3b|table1|headline|all)
//!   gen-data    write a synthetic dataset to CSV
//!   info        platform, resource model and artifact capabilities

use muchswift::arch::{self, ArchKind};
use muchswift::config::{PlatformConfig, WorkloadConfig};
use muchswift::coordinator::{Backend, Coordinator, CoordinatorOpts};
use muchswift::data::{csv, synthetic};
use muchswift::experiments::{fig2, fig3, table1};
use muchswift::kmeans::init::Init;
use muchswift::kmeans::twolevel::Partition;
use muchswift::kmeans::Metric;
use muchswift::runtime::{self, PjrtRuntime};
use muchswift::util::cli::Command;
use muchswift::util::logger;
use std::path::Path;
use std::sync::Arc;

fn commands() -> Vec<Command> {
    vec![
        Command::new("cluster", "run two-level k-means through the coordinator")
            .opt("n", "100000", "synthetic points (ignored with an input file)")
            .opt("d", "15", "dimensions")
            .opt("k", "8", "clusters")
            .opt("sigma", "0.15", "cluster stddev")
            .opt("seed", "42", "rng seed")
            .opt("metric", "euclid", "euclid|manhattan")
            .opt("backend", "pjrt", "pjrt|cpu (panel compute substrate)")
            .opt("partition", "round-robin", "round-robin|kd-top")
            .opt("init", "uniform", "uniform|kmeans++")
            .pos("input", "optional CSV dataset (overrides synthetic)"),
        Command::new("simulate", "evaluate an architecture cost model")
            .req("arch", "sw-lloyd|sw-filter|sw-elkan|fpga-lloyd-single|fpga-filter-single|fpga-lloyd-multi|much-swift|all")
            .opt("n", "1000000", "points")
            .opt("d", "15", "dimensions")
            .opt("k", "20", "clusters")
            .opt("sigma", "0.15", "cluster stddev")
            .opt("seed", "42", "rng seed"),
        Command::new("experiment", "regenerate a paper figure/table")
            .pos("id", "fig2a|fig2b|fig3a|fig3b|table1|headline|all"),
        Command::new("gen-data", "write a synthetic dataset to CSV")
            .opt("n", "10000", "points")
            .opt("d", "3", "dimensions")
            .opt("k", "8", "planted clusters")
            .opt("sigma", "0.15", "cluster stddev")
            .opt("seed", "42", "rng seed")
            .pos("output", "output CSV path"),
        Command::new("info", "platform + artifact capabilities"),
    ]
}

fn usage(cmds: &[Command]) -> String {
    let mut s = String::from("muchswift — MUCH-SWIFT reproduction\n\ncommands:\n");
    for c in cmds {
        s.push_str(&format!("  {:<12} {}\n", c.name, c.about));
    }
    s.push_str("\nuse `muchswift <command> --help` for options\n");
    s
}

fn main() {
    logger::init();
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmds = commands();
    let Some(cmd_name) = args.first() else {
        print!("{}", usage(&cmds));
        return Ok(());
    };
    if cmd_name == "--help" || cmd_name == "-h" {
        print!("{}", usage(&cmds));
        return Ok(());
    }
    let Some(cmd) = cmds.iter().find(|c| c.name == cmd_name) else {
        anyhow::bail!("unknown command `{cmd_name}` (try --help)");
    };
    let rest = &args[1..];
    if rest.iter().any(|a| a == "--help" || a == "-h") {
        print!("{}", cmd.help());
        return Ok(());
    }
    let m = cmd.parse(rest)?;

    match m.command {
        "cluster" => {
            let metric: Metric = m.str("metric").parse()?;
            let data = if let Some(path) = &m.positional {
                println!("loading {path} ...");
                csv::load(Path::new(path))?
            } else {
                let w = WorkloadConfig {
                    n: m.usize("n")?,
                    d: m.usize("d")?,
                    k: m.usize("k")?,
                    true_k: m.usize("k")?,
                    sigma: m.f64("sigma")? as f32,
                    seed: m.u64("seed")?,
                    metric,
                    ..Default::default()
                };
                w.validate()?;
                synthetic::generate(&w).data
            };
            let backend = match m.str("backend") {
                "cpu" => Backend::Cpu,
                "pjrt" => {
                    let rt = PjrtRuntime::load(&runtime::default_artifact_dir())?;
                    Backend::Pjrt(Arc::new(rt))
                }
                other => anyhow::bail!("unknown backend `{other}`"),
            };
            let opts = CoordinatorOpts {
                k: m.usize("k")?,
                metric,
                partition: match m.str("partition") {
                    "round-robin" => Partition::RoundRobin,
                    "kd-top" => Partition::KdTop,
                    other => anyhow::bail!("unknown partition `{other}`"),
                },
                init: match m.str("init") {
                    "uniform" => Init::UniformSample,
                    "kmeans++" => Init::KmeansPlusPlus,
                    other => anyhow::bail!("unknown init `{other}`"),
                },
                seed: m.u64("seed")?,
                ..Default::default()
            };
            let coord = Coordinator::new(backend);
            let out = coord.run(&data, &opts);
            println!("converged: {}", out.result.stats.converged);
            println!(
                "level-1 iterations per quarter: {:?}",
                out.level1_stats.iter().map(|s| s.iterations()).collect::<Vec<_>>()
            );
            println!("level-2 iterations: {}", out.level2_stats.iterations());
            println!("cluster sizes: {:?}", out.result.sizes());
            println!(
                "objective: {:.6e}",
                out.result.objective(&data, metric)
            );
            println!("{}", out.metrics.summary());
        }
        "simulate" => {
            let w = WorkloadConfig {
                n: m.usize("n")?,
                d: m.usize("d")?,
                k: m.usize("k")?,
                true_k: m.usize("k")?,
                sigma: m.f64("sigma")? as f32,
                seed: m.u64("seed")?,
                max_iters: 60,
                ..Default::default()
            };
            w.validate()?;
            let archs: Vec<ArchKind> = if m.str("arch") == "all" {
                ArchKind::all().to_vec()
            } else {
                vec![ArchKind::parse(m.str("arch"))?]
            };
            for a in archs {
                println!("{}", arch::evaluate(a, &w).row());
            }
        }
        "experiment" => {
            let id = m.positional.as_deref().unwrap_or("all");
            run_experiment(id)?;
        }
        "gen-data" => {
            let out = m
                .positional
                .clone()
                .ok_or_else(|| anyhow::anyhow!("gen-data needs an output path"))?;
            let s = synthetic::generate_params(
                m.usize("n")?,
                m.usize("d")?,
                m.usize("k")?,
                m.f64("sigma")? as f32,
                1.0,
                m.u64("seed")?,
            );
            csv::save(&s.data, Path::new(&out))?;
            println!("wrote {} points to {out}", s.data.len());
        }
        "info" => {
            let cfg = PlatformConfig::zcu102();
            println!("platform: {} ({} A53 @ {:.1} GHz, {} R5 @ {:.0} MHz, PL @ {:.0} MHz)",
                cfg.name, cfg.a53_cores, cfg.a53_freq_hz / 1e9, cfg.r5_cores,
                cfg.r5_freq_hz / 1e6, cfg.pl_freq_hz / 1e6);
            println!("{}", table1::render());
            match runtime::PjrtRuntime::load(&runtime::default_artifact_dir()) {
                Ok(rt) => {
                    println!("artifacts ({}):", rt.manifest().entries.len());
                    for a in &rt.manifest().entries {
                        println!(
                            "  {:<36} kind={:?} metric={} n={} d={} k={}",
                            a.name, a.kind, a.metric.name(), a.n, a.d, a.k
                        );
                    }
                }
                Err(e) => println!("artifacts: unavailable ({e})"),
            }
        }
        _ => unreachable!(),
    }
    Ok(())
}

fn run_experiment(id: &str) -> anyhow::Result<()> {
    let run_one = |id: &str| -> anyhow::Result<()> {
        match id {
            "fig2a" => print!("{}", fig2::fig2a().render()),
            "fig2b" => print!("{}", fig2::fig2b().render()),
            "fig3a" => print!("{}", fig3::fig3a().render()),
            "fig3b" => print!("{}", fig3::fig3b().render()),
            "table1" => print!("{}", table1::render()),
            "headline" => {
                let (sw, ms, speedup) = fig2::headline();
                println!("== headline: much-swift vs software-only Lloyd ==");
                println!("software-only: {sw:.3} s");
                println!("much-swift:    {ms:.4} s");
                println!("speedup:       {speedup:.0}x   (paper: ~330x)");
            }
            other => anyhow::bail!("unknown experiment `{other}`"),
        }
        Ok(())
    };
    if id == "all" {
        for e in ["table1", "fig2a", "fig2b", "fig3a", "fig3b", "headline"] {
            run_one(e)?;
            println!();
        }
    } else {
        run_one(id)?;
    }
    Ok(())
}
