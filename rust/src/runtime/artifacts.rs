//! Artifact manifest: what `python/compile/aot.py` produced.
//!
//! The manifest (`artifacts/manifest.json`) lists every AOT-lowered HLO
//! module with its kind, metric and padded shape.  The runtime picks the
//! *smallest* variant a request fits into after padding (N up with
//! zero-weight rows, D up with zero columns, K up with sentinel centroid
//! rows — the contract tested end-to-end in `python/tests`).

use crate::kmeans::Metric;
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Padded-centroid sentinel — must match `ref.PAD_SENTINEL` on the python
/// side (the manifest carries it so drift is caught at load time).
pub const PAD_SENTINEL: f32 = 1.0e17;

/// What an artifact computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Kind {
    /// Full Lloyd iteration: (points, centroids, weights) ->
    /// (assignments, sums, counts, cost).
    Lloyd,
    /// Filtering distance panels: (mids, cands) -> dists.
    Filter,
}

/// One AOT artifact.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub kind: Kind,
    pub metric: Metric,
    /// Block size (points for Lloyd, jobs for Filter).
    pub n: usize,
    /// Padded dimensionality.
    pub d: usize,
    /// Padded cluster/candidate count.
    pub k: usize,
    pub path: PathBuf,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub entries: Vec<Artifact>,
    pub pad_sentinel: f32,
}

impl Manifest {
    /// Load from `dir/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let mpath = dir.join("manifest.json");
        let src = std::fs::read_to_string(&mpath).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} (run `make artifacts` first): {e}",
                mpath.display()
            )
        })?;
        Self::parse(&src, dir)
    }

    /// Parse manifest JSON; `dir` anchors relative artifact paths.
    pub fn parse(src: &str, dir: &Path) -> anyhow::Result<Self> {
        let root = Json::parse(src)?;
        let version = root
            .req("format_version")?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("bad format_version"))?;
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");
        let pad_sentinel = root.req("pad_sentinel")?.as_f64().unwrap_or(0.0) as f32;
        anyhow::ensure!(
            (pad_sentinel - PAD_SENTINEL).abs() / PAD_SENTINEL < 1e-6,
            "pad sentinel drift: manifest {pad_sentinel} vs runtime {PAD_SENTINEL}"
        );
        let mut entries = Vec::new();
        for e in root
            .req("entries")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("entries must be an array"))?
        {
            let name = e.req("name")?.as_str().unwrap_or_default().to_string();
            let kind = match e.req("kind")?.as_str() {
                Some("lloyd") => Kind::Lloyd,
                Some("filter") => Kind::Filter,
                other => anyhow::bail!("unknown artifact kind {other:?} in `{name}`"),
            };
            let metric: Metric = e
                .req("metric")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("metric must be a string"))?
                .parse()?;
            let n = e.req("n")?.as_usize().ok_or_else(|| anyhow::anyhow!("bad n"))?;
            let d = e.req("d")?.as_usize().ok_or_else(|| anyhow::anyhow!("bad d"))?;
            let k = e.req("k")?.as_usize().ok_or_else(|| anyhow::anyhow!("bad k"))?;
            let file = e
                .req("file")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("bad file"))?;
            entries.push(Artifact {
                name,
                kind,
                metric,
                n,
                d,
                k,
                path: dir.join(file),
            });
        }
        anyhow::ensure!(!entries.is_empty(), "manifest has no artifacts");
        Ok(Self {
            entries,
            pad_sentinel,
        })
    }

    /// Smallest variant of `kind`/`metric` that fits `(d, k)` after
    /// padding (block size `n` is always padded/looped by the caller).
    /// Among equal (d, k), prefers the smallest block.
    pub fn select(&self, kind: Kind, metric: Metric, d: usize, k: usize) -> Option<&Artifact> {
        self.entries
            .iter()
            .filter(|a| a.kind == kind && a.metric == metric && a.d >= d && a.k >= k)
            .min_by_key(|a| (a.d * a.k, a.d, a.k, a.n))
    }

    /// Like [`select`](Self::select) but block-size aware (§Perf L1-1):
    /// among the fitting (d, k) variants, pick the largest block not
    /// exceeding `jobs` (amortizing per-execution overhead), falling back
    /// to the smallest available block for small batches.
    pub fn select_block(
        &self,
        kind: Kind,
        metric: Metric,
        d: usize,
        k: usize,
        jobs: usize,
    ) -> Option<&Artifact> {
        let best = self.select(kind, metric, d, k)?;
        let (bd, bk) = (best.d, best.k);
        self.entries
            .iter()
            .filter(|a| a.kind == kind && a.metric == metric && a.d == bd && a.k == bk)
            .filter(|a| a.n <= jobs)
            .max_by_key(|a| a.n)
            .or(Some(best))
    }

    /// All `(d, k)` capability corners for a kind/metric (for reports).
    pub fn capabilities(&self, kind: Kind, metric: Metric) -> Vec<(usize, usize)> {
        self.entries
            .iter()
            .filter(|a| a.kind == kind && a.metric == metric)
            .map(|a| (a.d, a.k))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> &'static str {
        r#"{
          "format_version": 1,
          "pad_sentinel": 1e+17,
          "entries": [
            {"name": "lloyd_euclid_n1024_d4_k8", "kind": "lloyd", "metric": "euclid",
             "n": 1024, "d": 4, "k": 8, "file": "a.hlo.txt"},
            {"name": "lloyd_euclid_n1024_d16_k32", "kind": "lloyd", "metric": "euclid",
             "n": 1024, "d": 16, "k": 32, "file": "b.hlo.txt"},
            {"name": "lloyd_euclid_n1024_d16_k128", "kind": "lloyd", "metric": "euclid",
             "n": 1024, "d": 16, "k": 128, "file": "c.hlo.txt"},
            {"name": "filter_manhattan_j256_d16_k32", "kind": "filter", "metric": "manhattan",
             "n": 256, "d": 16, "k": 32, "file": "d.hlo.txt"}
          ]
        }"#
    }

    #[test]
    fn select_block_prefers_largest_fitting() {
        let src = r#"{
          "format_version": 1,
          "pad_sentinel": 1e+17,
          "entries": [
            {"name": "f256", "kind": "filter", "metric": "euclid",
             "n": 256, "d": 16, "k": 32, "file": "a"},
            {"name": "f1024", "kind": "filter", "metric": "euclid",
             "n": 1024, "d": 16, "k": 32, "file": "b"}
          ]
        }"#;
        let m = Manifest::parse(src, Path::new("/x")).unwrap();
        // Big batch: take the 1024 block.
        assert_eq!(m.select_block(Kind::Filter, Metric::Euclid, 15, 20, 5000).unwrap().name, "f1024");
        // Mid batch: 1024 doesn't fit under jobs, take 256.
        assert_eq!(m.select_block(Kind::Filter, Metric::Euclid, 15, 20, 600).unwrap().name, "f256");
        // Tiny batch: smallest block is the fallback.
        assert_eq!(m.select_block(Kind::Filter, Metric::Euclid, 15, 20, 10).unwrap().name, "f256");
        // plain select prefers the small block on ties.
        assert_eq!(m.select(Kind::Filter, Metric::Euclid, 15, 20).unwrap().name, "f256");
    }

    #[test]
    fn parse_and_select_smallest_fit() {
        let m = Manifest::parse(sample(), Path::new("/tmp/artifacts")).unwrap();
        assert_eq!(m.entries.len(), 4);
        let a = m.select(Kind::Lloyd, Metric::Euclid, 3, 5).unwrap();
        assert_eq!(a.name, "lloyd_euclid_n1024_d4_k8");
        let a = m.select(Kind::Lloyd, Metric::Euclid, 15, 20).unwrap();
        assert_eq!(a.name, "lloyd_euclid_n1024_d16_k32");
        let a = m.select(Kind::Lloyd, Metric::Euclid, 15, 100).unwrap();
        assert_eq!(a.name, "lloyd_euclid_n1024_d16_k128");
        // No euclid filter in this manifest.
        assert!(m.select(Kind::Filter, Metric::Euclid, 4, 4).is_none());
        // Too big to fit anything.
        assert!(m.select(Kind::Lloyd, Metric::Euclid, 100, 8).is_none());
        // Paths are anchored at the artifact dir.
        assert_eq!(a.path, Path::new("/tmp/artifacts").join("c.hlo.txt"));
    }

    #[test]
    fn sentinel_drift_detected() {
        let bad = sample().replace("1e+17", "1e+9");
        assert!(Manifest::parse(&bad, Path::new("/x")).is_err());
    }

    #[test]
    fn rejects_bad_version_and_kinds() {
        let bad = sample().replace("\"format_version\": 1", "\"format_version\": 9");
        assert!(Manifest::parse(&bad, Path::new("/x")).is_err());
        let bad = sample().replace("\"kind\": \"lloyd\"", "\"kind\": \"conv\"");
        assert!(Manifest::parse(&bad, Path::new("/x")).is_err());
        assert!(Manifest::parse("{}", Path::new("/x")).is_err());
    }
}
