//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on the
//! CPU PJRT client — the functional stand-in for the paper's PL.
//!
//! Lifecycle: `PjrtRuntime::load` compiles every needed artifact **once**
//! at startup (the analogue of bitstream configuration); the request path
//! then only pads buffers and calls `execute`.  Python is never involved —
//! the HLO text is self-contained.

use super::artifacts::{Artifact, Kind, Manifest, PAD_SENTINEL};
use crate::data::Dataset;
use crate::kmeans::panel::{PanelJobs, PanelSet};
use crate::kmeans::Metric;
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// Outputs of one Lloyd block execution (valid region only).
#[derive(Clone, Debug)]
pub struct LloydBlockOut {
    pub assignments: Vec<i32>,
    pub sums: Vec<f32>,
    pub counts: Vec<f32>,
    pub cost: f32,
}

/// Execution statistics (for perf reports and the coordinator metrics).
/// Atomic so the runtime can be shared across worker threads.
#[derive(Debug, Default)]
pub struct RuntimeStats {
    pub executions: AtomicU64,
    pub blocks_padded: AtomicU64,
    /// Accumulated execution seconds, stored as f64 bits.
    exec_ns: AtomicU64,
}

impl RuntimeStats {
    pub fn record(&self, elapsed: std::time::Duration, padded: bool) {
        self.executions.fetch_add(1, Ordering::Relaxed);
        if padded {
            self.blocks_padded.fetch_add(1, Ordering::Relaxed);
        }
        self.exec_ns
            .fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn exec_seconds(&self) -> f64 {
        self.exec_ns.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn executions(&self) -> u64 {
        self.executions.load(Ordering::Relaxed)
    }
}

/// A compiled artifact plus its shape info.
struct Loaded {
    exe: xla::PjRtLoadedExecutable,
    art: Artifact,
}

/// Per-iteration centroid state for the filter offload: the `d`→`dp`
/// zero-padded centroid rows, built once per pass instead of re-padded by
/// every chunk.  Keyed by centroid-buffer identity (address + length,
/// same scheme as `ParCpuPanels`' norm cache) so a stale pass self-heals
/// instead of producing wrong panels; keyed by `dp` because different
/// tree levels can select different artifact shapes within one pass.
#[derive(Debug, Default)]
pub struct FilterPass {
    key: Option<(usize, usize)>,
    metric: Option<Metric>,
    /// `(dp, k*dp padded row bank)` per artifact dimensionality.
    banks: Vec<(usize, Vec<f32>)>,
}

/// Centroid-buffer identity (see `kmeans::panel::centroid_key` for the
/// reallocation caveat — a `reset` per iteration sidesteps it).
fn centroid_pass_key(centroids: &Dataset) -> (usize, usize) {
    (centroids.flat().as_ptr() as usize, centroids.flat().len())
}

impl FilterPass {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start a pass over fixed `centroids`: drop stale banks, remember the
    /// buffer identity.  Banks themselves are built lazily per `dp` on
    /// first use, so a pass only pays for the artifact shapes it touches.
    pub fn reset(&mut self, centroids: &Dataset, metric: Metric) {
        self.key = Some(centroid_pass_key(centroids));
        self.metric = Some(metric);
        self.banks.clear();
    }

    /// Re-key if `centroids`/`metric` are not the ones this pass was reset
    /// for (the self-heal path for callers that skip `begin_pass`).
    fn ensure(&mut self, centroids: &Dataset, metric: Metric) {
        if self.key != Some(centroid_pass_key(centroids)) || self.metric != Some(metric) {
            self.reset(centroids, metric);
        }
    }

    /// The padded row bank for artifact dimensionality `dp` (each of the
    /// `k` centroid rows zero-extended from `d` to `dp`), building it on
    /// first request within the pass.
    fn bank(&mut self, centroids: &Dataset, dp: usize) -> &[f32] {
        debug_assert!(dp >= centroids.dims());
        if let Some(pos) = self.banks.iter().position(|(w, _)| *w == dp) {
            return &self.banks[pos].1;
        }
        let d = centroids.dims();
        let k = centroids.len();
        let mut rows = vec![0f32; k * dp];
        for c in 0..k {
            rows[c * dp..c * dp + d].copy_from_slice(centroids.point(c));
        }
        self.banks.push((dp, rows));
        &self.banks.last().unwrap().1
    }
}

/// The PJRT-backed "PL".
pub struct PjrtRuntime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    manifest: Manifest,
    loaded: HashMap<String, Loaded>,
    pub stats: RuntimeStats,
}

// SAFETY: the `xla` crate wraps raw PJRT pointers without auto traits, but
// the underlying XLA CPU objects may be handed between threads: nothing in
// `PjRtClient`/`PjRtLoadedExecutable` is thread-affine, and after `load`
// the maps are never mutated, so moving the runtime to another thread
// cannot race its construction.
unsafe impl Send for PjrtRuntime {}

// SAFETY: shared references are safe concurrently for the same reason —
// XLA documents `PjRtLoadedExecutable::Execute` and `PjRtClient` as
// callable from multiple threads, and the coordinator additionally
// serializes access through a single PL-service thread (see
// `coordinator::offload`), mirroring the paper's single DMA manager.
unsafe impl Sync for PjrtRuntime {}

impl PjrtRuntime {
    /// Load every artifact in `dir`'s manifest and compile it on the CPU
    /// PJRT client.
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        log::info!(
            "pjrt: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        let mut loaded = HashMap::new();
        for art in &manifest.entries {
            let proto = xla::HloModuleProto::from_text_file(
                art.path
                    .to_str()
                    .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            log::debug!("pjrt: compiled {}", art.name);
            loaded.insert(art.name.clone(), Loaded { exe, art: art.clone() });
        }
        Ok(Self {
            client,
            manifest,
            loaded,
            stats: RuntimeStats::default(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn pick(&self, kind: Kind, metric: Metric, d: usize, k: usize) -> anyhow::Result<&Loaded> {
        let art = self.manifest.select(kind, metric, d, k).ok_or_else(|| {
            anyhow::anyhow!(
                "no artifact covers kind={kind:?} metric={} d={d} k={k} — \
                 extend the variant grid in python/compile/aot.py",
                metric.name()
            )
        })?;
        Ok(&self.loaded[&art.name])
    }

    /// One Lloyd iteration over `data` (any N) against `centroids`,
    /// blocked through the padded artifact.  Returns merged valid-region
    /// outputs: per-point assignments, per-cluster sums/counts, total cost.
    pub fn lloyd_step(
        &self,
        data: &Dataset,
        centroids: &Dataset,
        metric: Metric,
    ) -> anyhow::Result<LloydBlockOut> {
        let d = data.dims();
        let k = centroids.len();
        let lo = self.pick(Kind::Lloyd, metric, d, k)?;
        let (bn, dp, kp) = (lo.art.n, lo.art.d, lo.art.k);

        // Padded centroid panel (shared across blocks).
        let mut cpad = vec![PAD_SENTINEL; kp * dp];
        for c in 0..k {
            let row = &mut cpad[c * dp..c * dp + dp];
            row.fill(0.0);
            row[..d].copy_from_slice(centroids.point(c));
        }
        let cents_lit = xla::Literal::vec1(&cpad).reshape(&[kp as i64, dp as i64])?;

        let n = data.len();
        let mut out = LloydBlockOut {
            assignments: Vec::with_capacity(n),
            sums: vec![0.0; k * d],
            counts: vec![0.0; k],
            cost: 0.0,
        };

        let mut xpad = vec![0f32; bn * dp];
        let mut wpad = vec![0f32; bn];
        let mut start = 0usize;
        while start < n {
            let take = (n - start).min(bn);
            xpad.fill(0.0);
            wpad.fill(0.0);
            for i in 0..take {
                let p = data.point(start + i);
                xpad[i * dp..i * dp + d].copy_from_slice(p);
                wpad[i] = 1.0;
            }
            let x = xla::Literal::vec1(&xpad).reshape(&[bn as i64, dp as i64])?;
            let w = xla::Literal::vec1(&wpad);

            let t0 = std::time::Instant::now();
            let result = lo.exe.execute::<&xla::Literal>(&[&x, &cents_lit, &w])?[0][0]
                .to_literal_sync()?;
            self.stats.record(t0.elapsed(), take < bn);

            let (idx, sums, counts, cost) = result.to_tuple4()?;
            let idx = idx.to_vec::<i32>()?;
            let sums = sums.to_vec::<f32>()?;
            let counts = counts.to_vec::<f32>()?;
            let cost = cost.to_vec::<f32>()?[0];

            out.assignments.extend_from_slice(&idx[..take]);
            for c in 0..k {
                for j in 0..d {
                    out.sums[c * d + j] += sums[c * dp + j];
                }
                out.counts[c] += counts[c];
            }
            out.cost += cost;
            start += take;
        }
        Ok(out)
    }

    /// Distance panels for a batch of filtering jobs in the flat
    /// [`PanelJobs`] representation; rows are written into `out` (re-shaped
    /// via [`PanelSet::reset_from`], aligned with each job's candidates).
    ///
    /// One-shot form: pads the centroid panel from scratch.  Iteration
    /// loops should hold a [`FilterPass`] and call
    /// [`filter_panels_in_pass`](Self::filter_panels_in_pass) so the
    /// centroid padding is done once per pass, not once per chunk.
    pub fn filter_panels(
        &self,
        jobs: &PanelJobs,
        centroids: &Dataset,
        metric: Metric,
        out: &mut PanelSet,
    ) -> anyhow::Result<()> {
        let mut pass = FilterPass::new();
        pass.reset(centroids, metric);
        self.filter_panels_in_pass(jobs, centroids, metric, &mut pass, out)
    }

    /// [`filter_panels`](Self::filter_panels) with per-pass centroid
    /// reuse: the `d`→`dp` padded centroid rows are built once per
    /// [`FilterPass`] (i.e. once per solver iteration) and every chunk's
    /// candidate gather becomes a straight row memcpy from that bank —
    /// the slimmed first step of the ROADMAP's "ship the centroid panel
    /// once per iteration, not once per chunk" follow-up (the device-side
    /// persistent panel needs a gather-shaped artifact signature and
    /// stays future work).
    pub fn filter_panels_in_pass(
        &self,
        jobs: &PanelJobs,
        centroids: &Dataset,
        metric: Metric,
        pass: &mut FilterPass,
        out: &mut PanelSet,
    ) -> anyhow::Result<()> {
        let d = centroids.dims();
        debug_assert_eq!(jobs.dims(), d);
        let njobs = jobs.len();
        let kmax = jobs.max_cands();
        out.reset_from(jobs);
        if njobs == 0 || kmax == 0 {
            return Ok(());
        }
        // Self-heal if the caller forgot begin_pass for these centroids —
        // the cost is per-pass padding, never wrong results.
        pass.ensure(centroids, metric);
        let mut mpad: Vec<f32> = Vec::new();
        let mut cpad: Vec<f32> = Vec::new();
        let mut start = 0usize;
        while start < njobs {
            // §Perf L1-1: re-pick per chunk so large levels use the big
            // block and the tail falls back to the small one.
            let art = self
                .manifest
                .select_block(Kind::Filter, metric, d, kmax, njobs - start)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "no filter artifact covers metric={} d={d} k={kmax}",
                        metric.name()
                    )
                })?;
            let lo = &self.loaded[&art.name];
            let (bj, dp, kp) = (lo.art.n, lo.art.d, lo.art.k);
            // Padded centroid rows for this dp, built at most once per pass.
            let bank = pass.bank(centroids, dp);
            mpad.clear();
            mpad.resize(bj * dp, 0.0);
            cpad.clear();
            cpad.resize(bj * kp * dp, PAD_SENTINEL);
            let take = (njobs - start).min(bj);
            for j in 0..take {
                mpad[j * dp..j * dp + d].copy_from_slice(jobs.mid(start + j));
                for (slot, &c) in jobs.cands(start + j).iter().enumerate() {
                    let ci = c as usize;
                    cpad[(j * kp + slot) * dp..(j * kp + slot + 1) * dp]
                        .copy_from_slice(&bank[ci * dp..(ci + 1) * dp]);
                }
            }
            let m = xla::Literal::vec1(&mpad).reshape(&[bj as i64, dp as i64])?;
            let c = xla::Literal::vec1(&cpad).reshape(&[bj as i64, kp as i64, dp as i64])?;

            let t0 = std::time::Instant::now();
            let result =
                lo.exe.execute::<&xla::Literal>(&[&m, &c])?[0][0].to_literal_sync()?;
            self.stats.record(t0.elapsed(), take < bj);
            let dists = result.to_tuple1()?.to_vec::<f32>()?;
            for j in 0..take {
                let row = out.row_mut(start + j);
                for (slot, slot_out) in row.iter_mut().enumerate() {
                    *slot_out = dists[j * kp + slot];
                }
            }
            start += take;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filter_pass_banks_pad_lazily_and_rekey() {
        let c1 = Dataset::from_flat(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let mut pass = FilterPass::new();
        pass.reset(&c1, Metric::Euclid);
        assert!(pass.banks.is_empty(), "banks are lazy");
        let bank = pass.bank(&c1, 4).to_vec();
        assert_eq!(bank, vec![1.0, 2.0, 3.0, 0.0, 4.0, 5.0, 6.0, 0.0]);
        // Same dp → cached; another dp → second bank.
        let _ = pass.bank(&c1, 4);
        assert_eq!(pass.banks.len(), 1);
        let _ = pass.bank(&c1, 8);
        assert_eq!(pass.banks.len(), 2);
        // Same buffer, same metric → ensure keeps the banks.
        pass.ensure(&c1, Metric::Euclid);
        assert_eq!(pass.banks.len(), 2);
        // Metric flip or a different centroid buffer re-keys the pass.
        pass.ensure(&c1, Metric::Manhattan);
        assert!(pass.banks.is_empty());
        let _ = pass.bank(&c1, 4);
        let c2 = Dataset::from_flat(2, 3, vec![9.0; 6]);
        pass.ensure(&c2, Metric::Manhattan);
        assert!(pass.banks.is_empty());
        assert_eq!(pass.bank(&c2, 3), c2.flat(), "dp == d pads nothing");
    }
}
