//! [`PanelBackend`] adapter: plugs the PJRT runtime into the batched
//! filtering engine — the actual HW/SW seam of the reproduction.  The
//! level-batched traversal (`kmeans::filtering::filter_iteration_batched`)
//! ships each tree level's flat [`PanelJobs`] batch here; everything else
//! stays on the coordinator ("PS") side.

use super::client::{FilterPass, PjrtRuntime};
use crate::data::Dataset;
use crate::kmeans::panel::{PanelBackend, PanelJobs, PanelSet};
use crate::kmeans::Metric;

/// PJRT-offloaded panels.  Holds a shared reference to the runtime so the
/// four worker threads can each own one (the runtime itself is used from
/// one thread at a time per executable call; workers get their own
/// `PjrtPanels` over an `Arc`).
///
/// The engine's `begin_pass` (once per iteration, fixed centroids) resets
/// the backend-local [`FilterPass`], so the `d`→`dp` centroid padding is
/// done once per iteration and every chunk gathers candidate rows by
/// straight memcpy from the padded bank.
pub struct PjrtPanels<'rt> {
    pub rt: &'rt PjrtRuntime,
    /// Panels computed since construction (metrics).
    pub jobs_offloaded: u64,
    /// Per-iteration padded-centroid state.
    pass: FilterPass,
}

impl<'rt> PjrtPanels<'rt> {
    pub fn new(rt: &'rt PjrtRuntime) -> Self {
        Self {
            rt,
            jobs_offloaded: 0,
            pass: FilterPass::new(),
        }
    }
}

impl PanelBackend for PjrtPanels<'_> {
    fn begin_pass(&mut self, centroids: &Dataset, metric: Metric) {
        self.pass.reset(centroids, metric);
    }

    fn panels(
        &mut self,
        jobs: &PanelJobs,
        centroids: &Dataset,
        metric: Metric,
        out: &mut PanelSet,
    ) {
        self.jobs_offloaded += jobs.len() as u64;
        self.rt
            .filter_panels_in_pass(jobs, centroids, metric, &mut self.pass, out)
            .expect("pjrt filter panel execution failed");
    }
}
