//! PJRT runtime layer: AOT artifact manifest, compiled-executable cache,
//! and the [`PanelBackend`](crate::kmeans::filtering::PanelBackend)
//! adapter the coordinator uses to offload distance arithmetic.
//!
//! Python runs only at build time (`make artifacts`); this module loads
//! the resulting HLO text through the `xla` crate's PJRT CPU client.

pub mod artifacts;
pub mod client;
pub mod panels;

pub use artifacts::{Artifact, Kind, Manifest, PAD_SENTINEL};
pub use client::{FilterPass, LloydBlockOut, PjrtRuntime};
pub use panels::PjrtPanels;

use std::path::PathBuf;

/// Default artifact directory: `$MUCHSWIFT_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("MUCHSWIFT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
