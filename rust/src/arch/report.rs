//! Architecture evaluation reports.

use super::ArchKind;
use crate::hw::zynq::PhaseTime;
use crate::util::json::Json;
use crate::util::stats::{eng, fmt_secs};

/// Result of evaluating one architecture on one workload.
#[derive(Clone, Debug)]
pub struct ArchReport {
    pub arch: ArchKind,
    pub n: usize,
    pub d: usize,
    pub k: usize,
    /// Total clustering iterations (level-1 max + level-2 for MUCH-SWIFT).
    pub iterations: usize,
    pub converged: bool,
    /// Host->board PCIe ingest (zero for software architectures).
    pub ingest_s: f64,
    /// Iteration compute+transfer time.
    pub compute_s: f64,
    /// End-to-end (`ingest + compute`).
    pub total_s: f64,
    /// Average per-iteration time.
    pub per_iter_s: f64,
    /// Average per-iteration cycles on the architecture's own compute
    /// clock (PL for FPGA archs, A53 for software) — the Fig. 2a unit.
    pub per_iter_cycles: f64,
    pub breakdown: PhaseTime,
}

impl ArchReport {
    /// One row for the experiment tables.
    pub fn row(&self) -> String {
        format!(
            "{:<24} n={:<9} d={:<3} k={:<4} iters={:<4} cyc/iter={:<10} t/iter={:<12} total={}",
            self.arch.name(),
            self.n,
            self.d,
            self.k,
            self.iterations,
            eng(self.per_iter_cycles),
            fmt_secs(self.per_iter_s),
            fmt_secs(self.total_s),
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("arch", Json::str(self.arch.name())),
            ("n", Json::num(self.n as f64)),
            ("d", Json::num(self.d as f64)),
            ("k", Json::num(self.k as f64)),
            ("iterations", Json::num(self.iterations as f64)),
            ("converged", Json::Bool(self.converged)),
            ("ingest_s", Json::num(self.ingest_s)),
            ("compute_s", Json::num(self.compute_s)),
            ("total_s", Json::num(self.total_s)),
            ("per_iter_s", Json::num(self.per_iter_s)),
            ("per_iter_cycles", Json::num(self.per_iter_cycles)),
            ("pl_s", Json::num(self.breakdown.pl_s)),
            ("ps_s", Json::num(self.breakdown.ps_s)),
            ("xfer_s", Json::num(self.breakdown.xfer_s)),
            ("stall_s", Json::num(self.breakdown.stall_s)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_and_json_carry_key_fields() {
        let r = ArchReport {
            arch: ArchKind::MuchSwift,
            n: 1000,
            d: 15,
            k: 8,
            iterations: 12,
            converged: true,
            ingest_s: 0.01,
            compute_s: 0.09,
            total_s: 0.1,
            per_iter_s: 0.0075,
            per_iter_cycles: 2.25e6,
            breakdown: PhaseTime::default(),
        };
        let row = r.row();
        assert!(row.contains("much-swift"));
        assert!(row.contains("iters=12"));
        let j = r.to_json();
        assert_eq!(j.get("k").unwrap().as_usize().unwrap(), 8);
        assert_eq!(j.get("arch").unwrap().as_str().unwrap(), "much-swift");
    }
}
