//! The paper's comparison architectures as platform cost models.
//!
//! Each architecture = (which algorithm runs) × (what hardware executes
//! it).  The *algorithm* is always run functionally (on this host) to get
//! exact per-iteration work counters; the *hardware* turns the counters
//! into ZCU102-scale time via `hw::ZynqSim`.  This separation is what lets
//! one reproduction produce every row of Figs. 2–3:
//!
//! | arch               | algorithm              | hardware model                      |
//! |--------------------|------------------------|-------------------------------------|
//! | `SwLloyd`          | Lloyd                  | 1 A53 core, software cost model     |
//! | `SwFilter`         | kd-filtering           | 1 A53 core, software cost model     |
//! | `FpgaLloydSingle`  | Lloyd                  | 1 distance module, store-and-forward (the "conventional FPGA-based architecture without optimization") |
//! | `FpgaFilterSingle` | kd-filtering           | [13]: K modules, 1 core, 200 MHz, no transfer/compute overlap |
//! | `FpgaLloydMulti`   | Lloyd                  | [17]: K×4 modules, overlap, no algorithmic optimization |
//! | `MuchSwift`        | two-level kd-filtering | K×4 modules, 4 cores, overlap (the paper) |
//!
//! Functional runs are capped at [`DEFAULT_MEASURE_CAP`] points and the
//! counters linearly extrapolated to the requested `n` (iteration counts
//! are taken as measured — they are N-insensitive for i.i.d. workloads).
//! Set `MUCHSWIFT_FULL=1` to measure at full size.

pub mod report;

pub use report::ArchReport;

use crate::config::{PlatformConfig, WorkloadConfig};
use crate::data::synthetic;
use crate::hw::pl::PlArray;
use crate::hw::zynq::{PhaseTime, ZynqSim};
use crate::kmeans::solver::{Algo, KmeansSpec, SolverCtx};
use crate::kmeans::{IterStats, RunStats};

/// Functional-measurement cap (points).  Extrapolation above this.
pub const DEFAULT_MEASURE_CAP: usize = 65_536;

/// The architectures of the paper's evaluation (+ the Elkan software
/// baseline from the related work, as an extension).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArchKind {
    SwLloyd,
    SwFilter,
    SwElkan,
    FpgaLloydSingle,
    FpgaFilterSingle,
    FpgaLloydMulti,
    MuchSwift,
}

impl ArchKind {
    pub fn name(self) -> &'static str {
        match self {
            ArchKind::SwLloyd => "sw-lloyd",
            ArchKind::SwFilter => "sw-filter",
            ArchKind::SwElkan => "sw-elkan",
            ArchKind::FpgaLloydSingle => "fpga-lloyd-single",
            ArchKind::FpgaFilterSingle => "fpga-filter-single",
            ArchKind::FpgaLloydMulti => "fpga-lloyd-multi",
            ArchKind::MuchSwift => "much-swift",
        }
    }

    pub fn all() -> &'static [ArchKind] {
        &[
            ArchKind::SwLloyd,
            ArchKind::SwFilter,
            ArchKind::SwElkan,
            ArchKind::FpgaLloydSingle,
            ArchKind::FpgaFilterSingle,
            ArchKind::FpgaLloydMulti,
            ArchKind::MuchSwift,
        ]
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "sw-lloyd" | "sw" => ArchKind::SwLloyd,
            "sw-filter" => ArchKind::SwFilter,
            "sw-elkan" => ArchKind::SwElkan,
            "fpga-lloyd-single" | "fpga-conventional" => ArchKind::FpgaLloydSingle,
            "fpga-filter-single" | "winterstein" => ArchKind::FpgaFilterSingle,
            "fpga-lloyd-multi" | "canilho" => ArchKind::FpgaLloydMulti,
            "much-swift" | "muchswift" => ArchKind::MuchSwift,
            other => anyhow::bail!("unknown architecture `{other}`"),
        })
    }
}

fn measure_cap() -> usize {
    if std::env::var_os("MUCHSWIFT_FULL").is_some() {
        usize::MAX
    } else {
        DEFAULT_MEASURE_CAP
    }
}

/// Scale an iteration's counters from the measured subsample size `m` to
/// the target size `n` (linear extrapolation of per-iteration work; tree
/// depth grows only logarithmically and is left unscaled — see DESIGN.md).
fn scale_iter(it: &IterStats, f: f64) -> IterStats {
    let s = |v: u64| -> u64 { (v as f64 * f).round() as u64 };
    IterStats {
        dist_evals: s(it.dist_evals),
        node_visits: s(it.node_visits),
        leaf_points: s(it.leaf_points),
        interior_assigns: s(it.interior_assigns),
        prune_tests: s(it.prune_tests),
        moved: it.moved,
        cost: it.cost,
        levels: it
            .levels
            .iter()
            .map(|l| crate::kmeans::LevelWork {
                interior_jobs: s(l.interior_jobs),
                leaf_jobs: s(l.leaf_jobs),
                cand_evals: s(l.cand_evals),
                prune_tests: s(l.prune_tests),
            })
            .collect(),
    }
}

fn scale_stats(stats: &RunStats, f: f64) -> RunStats {
    RunStats {
        iters: stats.iters.iter().map(|it| scale_iter(it, f)).collect(),
        converged: stats.converged,
        early_stopped: stats.early_stopped,
        // Kernel-tier telemetry: the lane gauge is scale-invariant, the
        // candidate counters scale with the subsampled workload.
        simd_lanes: stats.simd_lanes,
        quantized_candidates: (stats.quantized_candidates as f64 * f) as u64,
        rescored_candidates: (stats.rescored_candidates as f64 * f) as u64,
    }
}

/// Functional measurement of a workload under each algorithm.
pub struct Measured {
    pub stats: RunStats,
    /// For MUCH-SWIFT: per-quarter level-1 stats + level-2 stats.
    pub level1: Option<Vec<RunStats>>,
}

fn subsampled(w: &WorkloadConfig) -> (WorkloadConfig, f64) {
    let cap = measure_cap();
    if w.n <= cap {
        (w.clone(), 1.0)
    } else {
        (
            WorkloadConfig {
                n: cap,
                ..w.clone()
            },
            w.n as f64 / cap as f64,
        )
    }
}

/// The algorithm each architecture runs, in unified-solver terms.
pub fn algo_for(kind: ArchKind) -> Algo {
    match kind {
        ArchKind::SwLloyd | ArchKind::FpgaLloydSingle | ArchKind::FpgaLloydMulti => Algo::Lloyd,
        ArchKind::SwElkan => Algo::Elkan,
        ArchKind::SwFilter | ArchKind::FpgaFilterSingle => Algo::Filter,
        ArchKind::MuchSwift => Algo::TwoLevel,
    }
}

/// Measure the algorithm an architecture runs, extrapolated to `w.n`.
/// One code path for every architecture: a [`KmeansSpec`] driven through
/// the unified solver API (the seed reproduces the pre-solver behaviour:
/// uniform seeding at `w.seed ^ 0xA5`, same per-quarter xor inside
/// two-level).
pub fn measure(kind: ArchKind, w: &WorkloadConfig) -> Measured {
    let (wm, f) = subsampled(w);
    let s = synthetic::generate(&wm);
    let spec = KmeansSpec::new(wm.k)
        .algo(algo_for(kind))
        .metric(wm.metric)
        .tol(wm.tol)
        .max_iters(wm.max_iters)
        .level2_max_iters(wm.max_iters)
        .shards(wm.shards)
        .seed(wm.seed ^ 0xA5);
    let r = spec.solve(&mut SolverCtx::new(&s.data));
    let level1 = r.ext.two_level.as_ref().map(|ext| {
        ext.level1_stats
            .iter()
            .map(|st| scale_stats(st, f))
            .collect()
    });
    Measured {
        stats: scale_stats(&r.stats, f),
        level1,
    }
}

/// Platform profile an architecture runs on.
fn platform_for(kind: ArchKind) -> PlatformConfig {
    match kind {
        ArchKind::FpgaFilterSingle => PlatformConfig::winterstein_fpl13(),
        ArchKind::FpgaLloydMulti => PlatformConfig::canilho_fpl16(),
        _ => PlatformConfig::zcu102(),
    }
}

/// Full evaluation: measure the algorithm, charge the platform model.
pub fn evaluate(kind: ArchKind, w: &WorkloadConfig) -> ArchReport {
    let measured = measure(kind, w);
    let cfg = platform_for(kind);
    let sim = ZynqSim::new(cfg.clone());
    let bytes = w.dataset_bytes();
    let d = w.d;
    let k = w.k;

    // Host->board ingest applies to every FPGA architecture ("all data
    // communications ... via PCIe interface are counted", section 5).
    let (ingest_s, is_fpga) = match kind {
        ArchKind::SwLloyd | ArchKind::SwFilter | ArchKind::SwElkan => (0.0, false),
        // No DDR3 residency in the unoptimized baseline: PCIe transfer is
        // charged per iteration inside the compute loop instead.
        ArchKind::FpgaLloydSingle => (0.0, true),
        _ => (sim.ingest_time_s(bytes), true),
    };

    let mut compute = PhaseTime::default();
    #[allow(unused_assignments)]
    let mut iterations = 0usize;
    match kind {
        ArchKind::SwLloyd => {
            for it in &measured.stats.iters {
                let _ = it;
                compute.add(&sim.sw_lloyd_iteration(w.n as u64, d, k, 1));
            }
            iterations = measured.stats.iterations();
        }
        ArchKind::SwElkan => {
            // Elkan's remaining distance work at software rates + bound
            // bookkeeping (~4 cycles per point-centroid bound per pass).
            for it in &measured.stats.iters {
                let mut t = sim.sw_filter_iteration(it, d, 1);
                let bounds = (w.n as f64) * (k as f64) * 4.0 / cfg.a53_freq_hz;
                t.total_s += bounds;
                t.ps_s += bounds;
                compute.add(&t);
            }
            iterations = measured.stats.iterations();
        }
        ArchKind::SwFilter => {
            for it in &measured.stats.iters {
                compute.add(&sim.sw_filter_iteration(it, d, 1));
            }
            iterations = measured.stats.iterations();
        }
        ArchKind::FpgaLloydSingle => {
            // The unoptimized direct mapping: one scalar II-8 datapath, no
            // DDR3 residency (every iteration re-streams the dataset from
            // the host over PCIe), store-and-forward.
            let pl = PlArray::naive(&cfg);
            let evals = w.n as u64 * k as u64;
            let cycles = pl.distance_cycles(evals, d) + pl.update_cycles(w.n as u64, d);
            let bytes = w.n as u64 * (d as u64 * 4 + 8);
            for _ in &measured.stats.iters {
                compute.add(&sim.pl_phase_from(
                    &pl,
                    bytes,
                    cycles,
                    false,
                    cfg.pcie_bytes_per_s,
                ));
            }
            iterations = measured.stats.iterations();
        }
        ArchKind::FpgaLloydMulti => {
            // [17]: parallel hardware but a *fixed* MAC array (8 pipelined
            // units on the Zynq-7010 fabric) — parallelism does not grow
            // with K, which is exactly the scaling contrast of Fig. 3.
            let mut pl = PlArray::for_workload(&cfg, k, 1);
            pl.modules = 8;
            pl.share = 1;
            for _ in &measured.stats.iters {
                compute.add(&sim.lloyd_iteration(w.n as u64, d, k, &pl, true));
            }
            iterations = measured.stats.iterations();
        }
        ArchKind::FpgaFilterSingle => {
            // [13]: K parallel modules, one filtering datapath, no
            // transfer/compute overlap (on-chip memory architecture).
            let pl = PlArray::for_workload(&cfg, k, 1);
            for it in &measured.stats.iters {
                compute.add(&sim.filter_iteration(it, d, &pl, 1, false));
            }
            iterations = measured.stats.iterations();
        }
        ArchKind::MuchSwift => {
            // Level 1: P shards, each on its own PL module group.  Shards
            // run concurrently over the A53s; with P > cores they are
            // packed longest-first onto the cores (the coordinator's
            // chunked schedule), so the phase wall time is the heaviest
            // core's load — which degenerates to "slowest shard" in the
            // paper's P = cores configuration.
            let level1 = measured.level1.as_ref().unwrap();
            let shards = level1.len().max(1);
            let pl_shard = PlArray::for_workload(&cfg, k, 1);
            let mut shard_times: Vec<(PhaseTime, usize)> = Vec::with_capacity(shards);
            for qstats in level1 {
                let mut qt = PhaseTime::default();
                for it in &qstats.iters {
                    qt.add(&sim.filter_iteration(it, d, &pl_shard, 1, true));
                }
                shard_times.push((qt, qstats.iterations()));
            }
            shard_times
                .sort_by(|a, b| b.0.total_s.partial_cmp(&a.0.total_s).unwrap());
            let lanes = shards.min(cfg.a53_cores.max(1));
            let mut loads = vec![PhaseTime::default(); lanes];
            let mut lane_iters = vec![0usize; lanes];
            for (qt, qi) in &shard_times {
                let lightest = (0..lanes)
                    .min_by(|&a, &b| {
                        loads[a].total_s.partial_cmp(&loads[b].total_s).unwrap()
                    })
                    .unwrap();
                loads[lightest].add(qt);
                lane_iters[lightest] += *qi;
            }
            let heaviest = loads
                .into_iter()
                .max_by(|a, b| a.total_s.partial_cmp(&b.total_s).unwrap())
                .unwrap();
            compute.add(&heaviest);
            // A core serializes the iterations of every shard packed onto
            // it; the phase iteration count is the busiest lane's total
            // (for P <= cores: one shard per lane, i.e. the legacy max).
            let l1_iters = lane_iters.into_iter().max().unwrap_or(0);
            // Combine: hierarchical fan-in-4 tree reduce; total matching
            // work stays O(P·k²·d), charged on one A53 (P·k anchors x k
            // candidates each across the tree levels).
            let combine_s =
                (shards * k * k * d) as f64 * cfg.sw_cycles_per_term / cfg.a53_freq_hz;
            compute.total_s += combine_s;
            compute.ps_s += combine_s;
            // Level 2: all P module groups + every core on the full tree.
            let pl_full = PlArray::for_workload(&cfg, k, shards);
            for it in &measured.stats.iters {
                compute.add(&sim.filter_iteration(it, d, &pl_full, cfg.a53_cores, true));
            }
            iterations = l1_iters + measured.stats.iterations();
        }
    }

    let total_s = ingest_s + compute.total_s;
    let per_iter_s = compute.total_s / iterations.max(1) as f64;
    let pl_hz = cfg.pl_freq_hz;
    ArchReport {
        arch: kind,
        n: w.n,
        d,
        k,
        iterations,
        converged: measured.stats.converged,
        ingest_s,
        compute_s: compute.total_s,
        total_s,
        per_iter_s,
        per_iter_cycles: per_iter_s * if is_fpga { pl_hz } else { cfg.a53_freq_hz },
        breakdown: compute,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wl(n: usize, d: usize, k: usize) -> WorkloadConfig {
        WorkloadConfig {
            n,
            d,
            k,
            true_k: k,
            sigma: 0.15,
            seed: 3,
            max_iters: 40,
            ..Default::default()
        }
    }

    #[test]
    fn muchswift_beats_all_baselines() {
        let w = wl(200_000, 15, 10);
        let ms = evaluate(ArchKind::MuchSwift, &w);
        for kind in [
            ArchKind::SwLloyd,
            ArchKind::FpgaLloydSingle,
            ArchKind::FpgaFilterSingle,
            ArchKind::FpgaLloydMulti,
        ] {
            let other = evaluate(kind, &w);
            assert!(
                other.total_s > ms.total_s,
                "{} ({}s) should be slower than much-swift ({}s)",
                kind.name(),
                other.total_s,
                ms.total_s
            );
        }
    }

    #[test]
    fn headline_speedup_vs_software_in_paper_band() {
        // Paper: ~330x vs software-only (up to), >210x on average for the
        // Fig 2 workloads. Accept a broad band — shape, not absolutes.
        let w = wl(1_000_000, 15, 20);
        let ms = evaluate(ArchKind::MuchSwift, &w);
        let sw = evaluate(ArchKind::SwLloyd, &w);
        let speedup = sw.total_s / ms.total_s;
        assert!(
            (60.0..2000.0).contains(&speedup),
            "speedup vs software {speedup:.0}x outside plausible band"
        );
    }

    #[test]
    fn fig2a_band_vs_winterstein() {
        // Paper: ~8.5x fewer per-iteration cycles than [13].
        let w = wl(131_072, 3, 8);
        let ms = evaluate(ArchKind::MuchSwift, &w);
        let w13 = evaluate(ArchKind::FpgaFilterSingle, &w);
        let ratio = w13.per_iter_s / ms.per_iter_s;
        assert!(
            (2.0..40.0).contains(&ratio),
            "per-iteration ratio vs [13] = {ratio:.1}, expected O(8.5)"
        );
    }

    #[test]
    fn extrapolation_is_linear_in_n() {
        let small = evaluate(ArchKind::SwLloyd, &wl(50_000, 8, 5));
        let big = evaluate(ArchKind::SwLloyd, &wl(500_000, 8, 5));
        // Same seed/recipe => same iteration counts; time scales ~10x.
        let per_iter_ratio = big.per_iter_s / small.per_iter_s;
        assert!(
            (9.0..11.0).contains(&per_iter_ratio),
            "per-iteration scaling {per_iter_ratio}"
        );
    }

    #[test]
    fn parse_names_round_trip() {
        for k in ArchKind::all() {
            assert_eq!(ArchKind::parse(k.name()).unwrap(), *k);
        }
        assert!(ArchKind::parse("gpu").is_err());
    }
}
