//! Table 1 reproduction: PL resource utilization vs cluster count, plus
//! the fully-parallel feasibility limit on the ZU9EG.

use crate::hw::resources::{self, ResourceUse, ZU9EG};

/// One rendered row.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Row {
    pub k: usize,
    pub usage: ResourceUse,
    pub fits: bool,
}

/// The paper's sweep.
pub const KS: [usize; 6] = [2, 3, 4, 5, 10, 20];

pub fn table1() -> Vec<Row> {
    KS.iter()
        .map(|&k| Row {
            k,
            usage: resources::utilization(k),
            fits: resources::fits(k),
        })
        .collect()
}

pub fn render() -> String {
    let mut out = String::new();
    out.push_str("== table1: resource utilization vs cluster size ==\n");
    out.push_str(&format!(
        "{:<16}{:>10}{:>12}{:>8}{:>8}{:>7}\n",
        "Cluster Size", "LUTs", "Registers", "BRAMs", "DSPs", "fits"
    ));
    for row in table1() {
        out.push_str(&format!(
            "{:<16}{:>10}{:>12}{:>8}{:>8}{:>7}\n",
            row.k,
            row.usage.luts,
            row.usage.registers,
            row.usage.brams,
            row.usage.dsps,
            if row.fits { "yes" } else { "NO" }
        ));
    }
    out.push_str(&format!(
        "{:<16}{:>10}{:>12}{:>8}{:>8}\n",
        "Total Available", ZU9EG.luts, ZU9EG.registers, ZU9EG.brams, ZU9EG.dsps
    ));
    out.push_str(&format!(
        "max fully-parallel clusters: {}\n",
        resources::max_parallel_clusters()
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_match_paper_values() {
        let rows = table1();
        assert_eq!(rows.len(), 6);
        // Spot-check against Table 1.
        assert_eq!(rows[0].usage.luts, 32_985);
        assert_eq!(rows[3].usage.dsps, 344);
        assert_eq!(rows[5].usage.registers, 287_951);
        assert!(rows.iter().all(|r| r.fits));
    }

    #[test]
    fn render_contains_totals() {
        let s = render();
        assert!(s.contains("274000"));
        assert!(s.contains("2520"));
        assert!(s.contains("max fully-parallel"));
    }
}
