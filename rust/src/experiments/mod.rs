//! Experiment drivers: one per table/figure of the paper's evaluation.
//!
//! Every driver prints the same rows/series the paper plots and returns
//! the numbers for EXPERIMENTS.md.  Regenerate via `cargo bench` (one
//! bench target per experiment) or `muchswift experiment <id>`.
//!
//! | id      | paper artifact                                   |
//! |---------|--------------------------------------------------|
//! | fig2a   | avg clock cycles / iteration vs [13]             |
//! | fig2b   | speedup vs conventional single-module FPGA Lloyd |
//! | fig3a   | exec time vs [17], 10^6 pts, 15 dims, K sweep    |
//! | fig3b   | exec time vs [17], 10^6 pts, K=6, D sweep        |
//! | table1  | PL resource utilization vs cluster count         |
//! | headline| end-to-end speedup vs software-only Lloyd        |

pub mod fig2;
pub mod fig3;
pub mod table1;

use crate::util::stats::geomean;

/// A generic two-series sweep result.
#[derive(Clone, Debug)]
pub struct Sweep {
    pub id: &'static str,
    /// X-axis label and values.
    pub x_label: &'static str,
    pub xs: Vec<f64>,
    /// (series name, y values) — time or cycles depending on experiment.
    pub series: Vec<(String, Vec<f64>)>,
    /// Ratio series (baseline / muchswift) if meaningful.
    pub ratio: Vec<f64>,
}

impl Sweep {
    /// Render the paper-shaped table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.id));
        out.push_str(&format!("{:<12}", self.x_label));
        for (name, _) in &self.series {
            out.push_str(&format!("{name:>24}"));
        }
        if !self.ratio.is_empty() {
            out.push_str(&format!("{:>12}", "ratio"));
        }
        out.push('\n');
        for (i, x) in self.xs.iter().enumerate() {
            out.push_str(&format!("{x:<12}"));
            for (_, ys) in &self.series {
                out.push_str(&format!("{:>24.6e}", ys[i]));
            }
            if !self.ratio.is_empty() {
                out.push_str(&format!("{:>11.1}x", self.ratio[i]));
            }
            out.push('\n');
        }
        if !self.ratio.is_empty() {
            out.push_str(&format!(
                "geomean ratio: {:.1}x   max: {:.1}x\n",
                geomean(&self.ratio),
                self.ratio.iter().cloned().fold(f64::MIN, f64::max)
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_render_shape() {
        let s = Sweep {
            id: "fig-test",
            x_label: "k",
            xs: vec![2.0, 4.0],
            series: vec![
                ("muchswift".into(), vec![1.0, 2.0]),
                ("baseline".into(), vec![10.0, 30.0]),
            ],
            ratio: vec![10.0, 15.0],
        };
        let r = s.render();
        assert!(r.contains("fig-test"));
        assert!(r.contains("muchswift"));
        assert!(r.contains("geomean ratio: 12.2x"));
        assert!(r.contains("max: 15.0x"));
    }
}
