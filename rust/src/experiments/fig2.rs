//! Fig. 2 reproduction.
//!
//! (a) Average clock cycles per iteration: MUCH-SWIFT vs the single-core
//!     FPGA filtering architecture of Winterstein et al. [13].  Paper
//!     result: ≈ 8.5× speedup on average.
//! (b) Speedup of MUCH-SWIFT over a conventional (single distance module,
//!     no optimization) FPGA Lloyd implementation.  Paper result:
//!     > 210× on average, up to 330×.
//!
//! Workloads follow [13]'s evaluation style (small D, K=8, normal
//! clusters with varying σ), sweeping the dataset size.

use super::Sweep;
use crate::arch::{evaluate, ArchKind};
use crate::config::WorkloadConfig;

/// Dataset sizes swept (paper: "test case ... with varying standard
/// deviation"; we vary N and σ together, one σ per size).
pub const SIZES: [usize; 5] = [16_384, 32_768, 65_536, 131_072, 262_144];
pub const SIGMAS: [f32; 5] = [0.05, 0.12, 0.20, 0.28, 0.35];
pub const D: usize = 3;
pub const K: usize = 8;

fn workload(n: usize, sigma: f32) -> WorkloadConfig {
    WorkloadConfig {
        n,
        d: D,
        k: K,
        true_k: K,
        sigma,
        seed: 1234,
        max_iters: 60,
        ..Default::default()
    }
}

/// Fig. 2a: cycles per iteration.
pub fn fig2a() -> Sweep {
    let mut xs = Vec::new();
    let mut ms = Vec::new();
    let mut w13 = Vec::new();
    let mut ratio = Vec::new();
    for (&n, &sigma) in SIZES.iter().zip(SIGMAS.iter()) {
        let w = workload(n, sigma);
        let a = evaluate(ArchKind::MuchSwift, &w);
        let b = evaluate(ArchKind::FpgaFilterSingle, &w);
        xs.push(n as f64);
        ms.push(a.per_iter_cycles);
        w13.push(b.per_iter_cycles);
        // The paper compares *time* per iteration across the two machines
        // (different clocks); ratio uses time.
        ratio.push(b.per_iter_s / a.per_iter_s);
    }
    Sweep {
        id: "fig2a: avg clock cycles per iteration (vs [13])",
        x_label: "n",
        xs,
        series: vec![
            ("much-swift cyc/iter".into(), ms),
            ("[13] cyc/iter".into(), w13),
        ],
        ratio,
    }
}

/// Fig. 2b: end-to-end speedup vs conventional FPGA Lloyd.
pub fn fig2b() -> Sweep {
    let mut xs = Vec::new();
    let mut ms = Vec::new();
    let mut conv = Vec::new();
    let mut ratio = Vec::new();
    for (&n, &sigma) in SIZES.iter().zip(SIGMAS.iter()) {
        let w = workload(n, sigma);
        let a = evaluate(ArchKind::MuchSwift, &w);
        let b = evaluate(ArchKind::FpgaLloydSingle, &w);
        xs.push(n as f64);
        ms.push(a.total_s);
        conv.push(b.total_s);
        ratio.push(b.total_s / a.total_s);
    }
    Sweep {
        id: "fig2b: speedup vs conventional single-module FPGA Lloyd",
        x_label: "n",
        xs,
        series: vec![
            ("much-swift total_s".into(), ms),
            ("conventional total_s".into(), conv),
        ],
        ratio,
    }
}

/// Headline: MUCH-SWIFT vs software-only Lloyd (~330× in the paper).
pub fn headline() -> (f64, f64, f64) {
    let w = WorkloadConfig {
        n: 1_000_000,
        d: 15,
        k: 20,
        true_k: 20,
        sigma: 0.15,
        seed: 42,
        max_iters: 60,
        ..Default::default()
    };
    let ms = evaluate(ArchKind::MuchSwift, &w);
    let sw = evaluate(ArchKind::SwLloyd, &w);
    (sw.total_s, ms.total_s, sw.total_s / ms.total_s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2a_shape_holds() {
        // Small subset for test speed: first two sizes.
        let w = workload(SIZES[0], SIGMAS[0]);
        let a = evaluate(ArchKind::MuchSwift, &w);
        let b = evaluate(ArchKind::FpgaFilterSingle, &w);
        let ratio = b.per_iter_s / a.per_iter_s;
        assert!(
            (2.0..40.0).contains(&ratio),
            "fig2a per-iteration ratio {ratio:.1} out of band"
        );
    }

    #[test]
    fn fig2b_shape_holds() {
        let w = workload(SIZES[1], SIGMAS[1]);
        let a = evaluate(ArchKind::MuchSwift, &w);
        let b = evaluate(ArchKind::FpgaLloydSingle, &w);
        let ratio = b.total_s / a.total_s;
        assert!(
            (30.0..2000.0).contains(&ratio),
            "fig2b speedup {ratio:.0} out of band (paper: 210-330x)"
        );
    }
}
