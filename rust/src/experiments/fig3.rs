//! Fig. 3 reproduction: execution time vs the multi-core FPGA k-means of
//! Canilho et al. [17] (parallel hardware, no algorithmic optimization).
//!
//! (a) 10^6 points, 15 dimensions, clusters K = 2..100.
//! (b) 10^6 points, K = 6, dimensions D = 2..50.
//!
//! Paper result: ≈ 12× average speedup, with the gap growing with K
//! (MUCH-SWIFT's parallel arithmetic scales with K until the K=20
//! fully-parallel limit, and filtering prunes most distance work).

use super::Sweep;
use crate::arch::{evaluate, ArchKind};
use crate::config::WorkloadConfig;

pub const N: usize = 1_000_000;
pub const KS: [usize; 8] = [2, 5, 10, 20, 40, 60, 80, 100];
pub const DS: [usize; 7] = [2, 5, 10, 15, 20, 30, 50];

fn workload(d: usize, k: usize) -> WorkloadConfig {
    WorkloadConfig {
        n: N,
        d,
        k,
        true_k: k,
        sigma: 0.15,
        seed: 777,
        max_iters: 60,
        ..Default::default()
    }
}

/// Fig. 3a: K sweep at D = 15.
pub fn fig3a() -> Sweep {
    sweep(
        "fig3a: exec time, 10^6 points, 15 dims, K sweep (vs [17])",
        "k",
        KS.iter().map(|&k| (15, k)).collect(),
    )
}

/// Fig. 3b: D sweep at K = 6.
pub fn fig3b() -> Sweep {
    sweep(
        "fig3b: exec time, 10^6 points, K=6, D sweep (vs [17])",
        "d",
        DS.iter().map(|&d| (d, 6)).collect(),
    )
}

fn sweep(id: &'static str, x_label: &'static str, points: Vec<(usize, usize)>) -> Sweep {
    let mut xs = Vec::new();
    let mut ms = Vec::new();
    let mut c17 = Vec::new();
    let mut ratio = Vec::new();
    for (d, k) in points {
        let w = workload(d, k);
        let a = evaluate(ArchKind::MuchSwift, &w);
        let b = evaluate(ArchKind::FpgaLloydMulti, &w);
        xs.push(if x_label == "k" { k as f64 } else { d as f64 });
        ms.push(a.total_s);
        c17.push(b.total_s);
        ratio.push(b.total_s / a.total_s);
    }
    Sweep {
        id,
        x_label,
        xs,
        series: vec![
            ("much-swift total_s".into(), ms),
            ("[17] total_s".into(), c17),
        ],
        ratio,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3a_gap_grows_with_k() {
        // k-means iteration counts are noisy run to run, so compare a
        // clearly-separated pair from the sweep band.
        let lo = workload(15, 5);
        let hi = workload(15, 40);
        let r_lo = evaluate(ArchKind::FpgaLloydMulti, &lo).total_s
            / evaluate(ArchKind::MuchSwift, &lo).total_s;
        let r_hi = evaluate(ArchKind::FpgaLloydMulti, &hi).total_s
            / evaluate(ArchKind::MuchSwift, &hi).total_s;
        assert!(
            r_hi > r_lo,
            "speedup should grow with K: K=4 -> {r_lo:.1}x, K=40 -> {r_hi:.1}x"
        );
        assert!(r_lo > 1.0, "must beat [17] even at small K ({r_lo:.2}x)");
    }

    #[test]
    fn fig3_band_около_paper() {
        // One mid-sweep point lands in the paper's ~12x neighbourhood.
        let w = workload(15, 20);
        let a = evaluate(ArchKind::MuchSwift, &w);
        let b = evaluate(ArchKind::FpgaLloydMulti, &w);
        let r = b.total_s / a.total_s;
        assert!((2.0..80.0).contains(&r), "fig3 ratio {r:.1} out of band");
    }
}
