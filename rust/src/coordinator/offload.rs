//! The PL offload service: a dedicated thread owning the compute backend
//! (PJRT runtime or CPU fallback), fed by the worker threads over a
//! channel.
//!
//! This mirrors the paper's control architecture: the A53 workers never
//! touch the PL directly — a single manager (one Cortex-R5 in MUCH-SWIFT)
//! owns the DMA/PL interface and serializes batches into it.  It also
//! keeps the `xla` FFI usage single-threaded regardless of worker count.
//!
//! The wire format is the flat panel representation of
//! [`crate::kmeans::panel`]: three arenas per request (`mids`, candidate
//! indices, ragged offsets) and one [`PanelSet`] arena per reply — no
//! nested `Vec`s cross the channel.

use crate::data::Dataset;
use crate::kmeans::panel::{CpuPanels, PanelBackend, PanelJobs, PanelSet};
use crate::kmeans::Metric;
use crate::runtime::PjrtRuntime;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Which compute substrate serves the panels.
pub enum Backend {
    /// Plain Rust math (software-only runs, tests).
    Cpu,
    /// The AOT Pallas/XLA artifacts through PJRT.
    Pjrt(Arc<PjrtRuntime>),
}

/// Message to the service thread.
enum Msg {
    Panels(Request),
    Shutdown,
}

/// One panel batch request (flat wire format).
struct Request {
    jobs: PanelJobs,
    centroids: Dataset,
    metric: Metric,
    reply: Sender<PanelSet>,
}

/// Panel-service counters (batches and jobs served).
#[derive(Debug, Default)]
pub struct OffloadStats {
    pub batches: AtomicU64,
    pub jobs: AtomicU64,
}

impl OffloadStats {
    #[inline]
    pub fn record(&self, jobs: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.jobs.fetch_add(jobs, Ordering::Relaxed);
    }
}

/// Handle the workers use; cloneable.
#[derive(Clone)]
pub struct OffloadHandle {
    tx: Sender<Msg>,
    stats: Arc<OffloadStats>,
}

impl OffloadHandle {
    /// Synchronously compute one panel batch through the service.
    pub fn panels(&self, jobs: &PanelJobs, centroids: &Dataset, metric: Metric) -> PanelSet {
        let (reply_tx, reply_rx) = channel();
        let (d, mids, cand, cand_off) = jobs.parts();
        self.tx
            .send(Msg::Panels(Request {
                jobs: PanelJobs::from_parts(d, mids.to_vec(), cand.to_vec(), cand_off.to_vec()),
                centroids: centroids.clone(),
                metric,
                reply: reply_tx,
            }))
            .expect("offload service died");
        reply_rx.recv().expect("offload service dropped reply")
    }

    pub fn stats(&self) -> &OffloadStats {
        &self.stats
    }
}

/// The running service; dropping joins the thread.
pub struct OffloadService {
    handle: OffloadHandle,
    join: Option<JoinHandle<()>>,
}

impl OffloadService {
    pub fn spawn(backend: Backend) -> Self {
        let (tx, rx): (Sender<Msg>, Receiver<Msg>) = channel();
        let stats = Arc::new(OffloadStats::default());
        let svc_stats = Arc::clone(&stats);
        let join = std::thread::Builder::new()
            .name("pl-offload".into())
            .spawn(move || {
                // The CPU fallback serves the scalar oracle kernel so the
                // service path stays bit-identical to the reference.
                let mut cpu = CpuPanels;
                // Padded-centroid state for the PJRT path, reset per
                // request below: requests clone centroids, and a freed
                // clone can be reallocated at the same address, so the
                // identity-key self-heal alone must not be relied on.
                // The reset still amortizes padding across the chunks
                // within one request.
                let mut pass = crate::runtime::FilterPass::new();
                while let Ok(msg) = rx.recv() {
                    let req = match msg {
                        Msg::Panels(r) => r,
                        Msg::Shutdown => break,
                    };
                    svc_stats.record(req.jobs.len() as u64);
                    let mut out = PanelSet::new();
                    match &backend {
                        Backend::Cpu => {
                            cpu.begin_pass(&req.centroids, req.metric);
                            cpu.panels(&req.jobs, &req.centroids, req.metric, &mut out);
                        }
                        Backend::Pjrt(rt) => {
                            pass.reset(&req.centroids, req.metric);
                            rt.filter_panels_in_pass(
                                &req.jobs,
                                &req.centroids,
                                req.metric,
                                &mut pass,
                                &mut out,
                            )
                            .expect("pjrt panel execution failed");
                        }
                    }
                    // Receiver may have given up (worker panic); ignore.
                    let _ = req.reply.send(out);
                }
            })
            .expect("cannot spawn offload service");
        Self {
            handle: OffloadHandle { tx, stats },
            join: Some(join),
        }
    }

    pub fn handle(&self) -> OffloadHandle {
        self.handle.clone()
    }
}

impl Drop for OffloadService {
    fn drop(&mut self) {
        // Ask the thread to stop (cloned handles may still hold senders,
        // so channel closure alone cannot be relied on), then join.
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// [`PanelBackend`] adapter over the service handle — what the batched
/// filtering engine sees inside each worker.
pub struct RemotePanels {
    pub handle: OffloadHandle,
}

impl PanelBackend for RemotePanels {
    fn panels(
        &mut self,
        jobs: &PanelJobs,
        centroids: &Dataset,
        metric: Metric,
        out: &mut PanelSet,
    ) {
        *out = self.handle.panels(jobs, centroids, metric);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::generate_params;

    #[test]
    fn cpu_service_round_trip() {
        let svc = OffloadService::spawn(Backend::Cpu);
        let s = generate_params(50, 3, 2, 0.2, 1.0, 1);
        let cents = s.data.gather(&[0, 1, 2]);
        let mut jobs = PanelJobs::new();
        jobs.clear(3);
        jobs.push(s.data.point(0), &[0, 1, 2]);
        jobs.push(s.data.point(1), &[1]);
        let got = svc.handle().panels(&jobs, &cents, Metric::Euclid);
        assert_eq!(got.len(), 2);
        assert_eq!(got.row(0).len(), 3);
        assert_eq!(got.row(1).len(), 1);
        // Distances match direct computation.
        let want = Metric::Euclid.dist(s.data.point(0), cents.point(1));
        assert!((got.row(0)[1] - want).abs() < 1e-6);
        assert_eq!(svc.handle().stats().batches.load(Ordering::Relaxed), 1);
        assert_eq!(svc.handle().stats().jobs.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn concurrent_workers_share_service() {
        let svc = OffloadService::spawn(Backend::Cpu);
        let s = generate_params(100, 2, 3, 0.3, 1.0, 2);
        let cents = Arc::new(s.data.gather(&[0, 1, 2]));
        let mut joins = Vec::new();
        for w in 0..4 {
            let h = svc.handle();
            let cents = Arc::clone(&cents);
            let data = s.data.clone();
            joins.push(std::thread::spawn(move || {
                let mut jobs = PanelJobs::new();
                for i in 0..20 {
                    jobs.clear(2);
                    jobs.push(data.point((w * 20 + i) % 100), &[0, 1, 2]);
                    let out = h.panels(&jobs, &cents, Metric::Manhattan);
                    assert_eq!(out.row(0).len(), 3);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(svc.handle().stats().batches.load(Ordering::Relaxed), 80);
    }

    #[test]
    fn service_shuts_down_cleanly() {
        let svc = OffloadService::spawn(Backend::Cpu);
        let h = svc.handle();
        drop(svc); // joins the thread without deadlock
        let _ = h; // handle may outlive; sends would now fail, not hang
    }
}
