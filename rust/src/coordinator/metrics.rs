//! Coordinator metrics: wall-clock per phase plus offload counters.
//! These are *host* measurements (the §Perf numbers); simulated ZCU102
//! time comes from `arch::*` over the same work counters.

use std::time::{Duration, Instant};

/// Phase timings of one coordinated run.
#[derive(Clone, Debug, Default)]
pub struct CoordMetrics {
    pub partition_s: f64,
    pub tree_build_s: f64,
    pub level1_s: f64,
    pub combine_s: f64,
    pub level2_s: f64,
    pub total_s: f64,
    /// Panel batches / jobs served by the offload service.
    pub offload_batches: u64,
    pub offload_jobs: u64,
    /// PJRT executions + seconds (zero for CPU backend).
    pub pjrt_executions: u64,
    pub pjrt_exec_s: f64,
    /// Iterations / distance evaluations streamed live through the
    /// workers' [`IterObserver`](crate::kmeans::solver::IterObserver)
    /// subscriptions (all phases) — the serving-path progress feed.
    pub observed_iters: u64,
    pub observed_dist_evals: u64,
    /// Level-1 shard count P of the run.
    pub shards: usize,
    /// Per-shard level-1 iterations / distance evaluations (length P),
    /// streamed live by the same observers — the scheduling-balance view
    /// the aggregate counters can't show.
    pub shard_iters: Vec<u64>,
    pub shard_dist_evals: Vec<u64>,
    /// Remote shard plane (all zero unless `--remote` endpoints were
    /// given): endpoints that connected and handshook at the start of
    /// the run …
    pub remote_workers: usize,
    /// … level-1 shards solved over the wire …
    pub remote_shards: u64,
    /// … and connect/handshake/mid-solve wire failures that exhausted
    /// the whole degradation ladder and fell back to a local solve (a
    /// nonzero value means the run degraded, not failed — results are
    /// unaffected).
    pub remote_fallbacks: u64,
    /// Re-attempts of failed remote operations (connects and jobs).
    pub remote_retries: u64,
    /// Remote reads that hit a socket timeout or the per-job deadline.
    pub remote_timeouts: u64,
    /// Fresh dial+handshake cycles replacing a dead stream.
    pub remote_reconnects: u64,
    /// Shards moved from a failed worker to another live remote (the
    /// middle rung of the ladder, before local fallback).
    pub remote_rescheduled: u64,
    /// Endpoints that never produced a usable connection at
    /// `connect_all` time — the dead fleet members to go look at.
    pub remote_failed_endpoints: Vec<String>,
    /// Wire traffic of the run's remote solves.
    pub remote_bytes_tx: u64,
    pub remote_bytes_rx: u64,
    /// Session plane (all zero unless `--session`): connections that
    /// hosted at least one resident shard …
    pub sessions: u64,
    /// … per-iteration `Centroids` broadcasts sent and `Partials`
    /// reduces folded …
    pub centroid_bcasts: u64,
    pub partials_rx: u64,
    /// … the steady-state O(k·d) traffic those frames cost (LoadShard
    /// uploads count into `remote_bytes_tx` instead) …
    pub session_bytes_tx: u64,
    pub session_bytes_rx: u64,
    /// … and shard uploads beyond the first (recovery re-loads after a
    /// reconnect or onto another live connection).
    pub shard_reloads: u64,
    /// Bounds plane (all zero unless the spec's `bounds` mode engaged —
    /// DESIGN.md §10): leaf panel jobs the triangle-inequality bounds
    /// dropped outright across the run's *local* solves (level 2 plus any
    /// locally-executed level-1 shards; remote partials decode these as
    /// 0) …
    pub bound_pruned_points: u64,
    /// … candidate entries removed from surviving jobs …
    pub bound_pruned_candidates: u64,
    /// … and the true-distance evaluations spent maintaining the bounds.
    pub bounds_matrix_cost: u64,
}

impl CoordMetrics {
    pub fn summary(&self) -> String {
        format!(
            "total {:.3}s = partition {:.3}s + trees {:.3}s + level1 {:.3}s + \
             combine {:.4}s + level2 {:.3}s | offload: {} batches / {} jobs | \
             pjrt: {} execs / {:.3}s | observed: {} iters / {} evals | \
             {} shards, iters/shard {:?}, evals/shard {:?} | remote: {} workers, {} shards, \
             {} fallbacks, {} retries, {} timeouts, {} reconnects, \
             {} rescheduled, dead endpoints {:?}, {}B tx / {}B rx | \
             session: {} sessions, {} centroid_bcasts, {} partials_rx, \
             {}B session tx / {}B session rx, {} shard_reloads | \
             bounds: {} pruned pts, {} pruned cands, {} matrix cost",
            self.total_s,
            self.partition_s,
            self.tree_build_s,
            self.level1_s,
            self.combine_s,
            self.level2_s,
            self.offload_batches,
            self.offload_jobs,
            self.pjrt_executions,
            self.pjrt_exec_s,
            self.observed_iters,
            self.observed_dist_evals,
            self.shards,
            self.shard_iters,
            self.shard_dist_evals,
            self.remote_workers,
            self.remote_shards,
            self.remote_fallbacks,
            self.remote_retries,
            self.remote_timeouts,
            self.remote_reconnects,
            self.remote_rescheduled,
            self.remote_failed_endpoints,
            self.remote_bytes_tx,
            self.remote_bytes_rx,
            self.sessions,
            self.centroid_bcasts,
            self.partials_rx,
            self.session_bytes_tx,
            self.session_bytes_rx,
            self.shard_reloads,
            self.bound_pruned_points,
            self.bound_pruned_candidates,
            self.bounds_matrix_cost,
        )
    }
}

/// Tiny scope timer.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Self(Instant::now())
    }

    pub fn lap(&mut self) -> f64 {
        let now = Instant::now();
        let d = now.duration_since(self.0);
        self.0 = now;
        d.as_secs_f64()
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_laps_monotone() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        let a = sw.lap();
        let b = sw.lap();
        assert!(a >= 0.002);
        assert!(b < a);
    }

    #[test]
    fn summary_contains_fields() {
        let m = CoordMetrics {
            total_s: 1.0,
            offload_jobs: 42,
            ..Default::default()
        };
        let s = m.summary();
        assert!(s.contains("42 jobs"));
        assert!(s.contains("total 1.000s"));
    }

    #[test]
    fn summary_reports_per_shard_counters() {
        let m = CoordMetrics {
            shards: 3,
            shard_iters: vec![5, 7, 6],
            shard_dist_evals: vec![100, 140, 120],
            ..Default::default()
        };
        let s = m.summary();
        assert!(s.contains("3 shards"), "{s}");
        assert!(s.contains("iters/shard [5, 7, 6]"), "{s}");
        assert!(s.contains("evals/shard [100, 140, 120]"), "{s}");
    }

    #[test]
    fn summary_reports_remote_counters() {
        let m = CoordMetrics {
            remote_workers: 2,
            remote_shards: 3,
            remote_fallbacks: 1,
            remote_retries: 4,
            remote_timeouts: 2,
            remote_reconnects: 3,
            remote_rescheduled: 1,
            remote_failed_endpoints: vec!["h:1".into()],
            remote_bytes_tx: 1024,
            remote_bytes_rx: 2048,
            ..Default::default()
        };
        let s = m.summary();
        assert!(s.contains("remote: 2 workers, 3 shards, 1 fallbacks"), "{s}");
        assert!(
            s.contains("4 retries, 2 timeouts, 3 reconnects, 1 rescheduled"),
            "{s}"
        );
        assert!(s.contains("dead endpoints [\"h:1\"]"), "{s}");
        assert!(s.contains("1024B tx / 2048B rx"), "{s}");
        // An all-local run reports a zeroed remote section.
        let s = CoordMetrics::default().summary();
        assert!(s.contains("remote: 0 workers"), "{s}");
        assert!(s.contains("0 retries"), "{s}");
    }

    #[test]
    fn summary_reports_session_counters() {
        let m = CoordMetrics {
            sessions: 2,
            centroid_bcasts: 40,
            partials_rx: 40,
            session_bytes_tx: 5120,
            session_bytes_rx: 6144,
            shard_reloads: 1,
            ..Default::default()
        };
        let s = m.summary();
        assert!(s.contains("session: 2 sessions"), "{s}");
        assert!(s.contains("40 centroid_bcasts, 40 partials_rx"), "{s}");
        assert!(s.contains("5120B session tx / 6144B session rx"), "{s}");
        assert!(s.contains("1 shard_reloads"), "{s}");
        // A one-shot run keeps the section zeroed, not absent.
        assert!(CoordMetrics::default().summary().contains("session: 0 sessions"));
    }

    #[test]
    fn summary_reports_bounds_counters() {
        let m = CoordMetrics {
            bound_pruned_points: 120,
            bound_pruned_candidates: 3400,
            bounds_matrix_cost: 560,
            ..Default::default()
        };
        let s = m.summary();
        assert!(
            s.contains("bounds: 120 pruned pts, 3400 pruned cands, 560 matrix cost"),
            "{s}"
        );
        // A bounds-off run keeps the section zeroed, not absent.
        assert!(CoordMetrics::default().summary().contains("bounds: 0 pruned pts"));
    }
}
