//! The deployable MUCH-SWIFT system: a leader orchestrating P level-1
//! workers (the Cortex-A53 quartet in the paper's configuration) and a PL
//! offload service (the R5-owned DMA/PL interface), executing the
//! two-level clustering of Alg. 2 with the distance arithmetic on the
//! PJRT-compiled Pallas kernels.
//!
//! Phase structure (leader):
//! 1. `Shard`     — partition the dataset into P parts
//!    ([`ShardPlan::build`]; round-robin, kd-top or contiguous).
//! 2. Level 1     — P shard solves scheduled over `spec.workers` threads
//!    (each thread pulls the next unsolved shard off a shared counter, so
//!    P > threads chunks instead of oversubscribing).  Each solve: build
//!    a kd-tree over the shard, then run an [`Algo::FilterBatched`]
//!    solver through the unified [`KmeansSpec`]/[`SolverCtx`] API with
//!    its panel backend injected (local CPU math or the offload service).
//! 3. `Combine`   — hierarchical count-weighted nearest-centroid merge
//!    ([`shard::combine_hierarchical`]; flat for P ≤ 4).
//! 4. Level 2     — batched filtering over the full tree from the merged
//!    seeds (few iterations), same solver API.
//!
//! Every worker subscribes an [`IterObserver`] to its solve — the
//! coordinator streams per-iteration (and per-shard) work counters into
//! [`CoordMetrics`] live (and `log::trace!`s them), which is the seam a
//! serving path would use for progress reporting.
//!
//! The *algorithmic* building blocks are shared with
//! [`crate::kmeans::shard`] / [`crate::kmeans::twolevel`] (the sequential
//! reference), so the threaded system cannot drift from the tested
//! semantics.

pub mod metrics;
pub mod offload;

pub use metrics::CoordMetrics;
pub use offload::{Backend, OffloadService};

use crate::data::Dataset;
use crate::kdtree::KdTree;
use crate::kmeans::init::init_centroids;
use crate::kmeans::panel::{CpuPanels, KernelKind, PanelBackend, PanelJobs, PanelSet, ParCpuPanels};
use crate::kmeans::remote::{run_session, RemoteShardPool, RemoteWorker, RetryPolicy, WireCounters};
use crate::kmeans::shard::{self, ShardExecutor, ShardPartial, ShardPlan};
use crate::kmeans::solver::{
    Algo, IterEvent, IterFlow, IterObserver, KmeansSpec, ObserveFn, SolverCtx,
};
use crate::kmeans::{IterStats, KmeansResult, Metric, Phase, RunStats, TwoLevelExt};
use metrics::Stopwatch;
use offload::OffloadStats;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// Everything a coordinated run produces.  The clustering result carries
/// the two-level extension ([`TwoLevelExt`]) exactly like the sequential
/// reference's, so consumers read one shape regardless of which system ran.
#[derive(Clone, Debug)]
pub struct CoordOutcome {
    pub result: KmeansResult,
    pub metrics: CoordMetrics,
}

/// A worker-side panel backend: either local CPU math (no channel — the
/// software-only deployment computes panels in place, scalar per level-1
/// worker, multi-threaded for the single-threaded level-2 phase) or the
/// PL offload service.  Local variants count batches/jobs into the shared
/// [`OffloadStats`]; the service counts its own.
enum SystemPanels {
    LocalScalar(CpuPanels, Arc<OffloadStats>),
    LocalPar(ParCpuPanels, Arc<OffloadStats>),
    Remote(offload::RemotePanels),
}

impl PanelBackend for SystemPanels {
    fn begin_pass(&mut self, centroids: &Dataset, metric: Metric) {
        match self {
            SystemPanels::LocalScalar(b, _) => b.begin_pass(centroids, metric),
            SystemPanels::LocalPar(b, _) => b.begin_pass(centroids, metric),
            SystemPanels::Remote(b) => b.begin_pass(centroids, metric),
        }
    }

    fn panels(
        &mut self,
        jobs: &PanelJobs,
        centroids: &Dataset,
        metric: Metric,
        out: &mut PanelSet,
    ) {
        match self {
            SystemPanels::LocalScalar(b, stats) => {
                stats.record(jobs.len() as u64);
                b.panels(jobs, centroids, metric, out);
            }
            SystemPanels::LocalPar(b, stats) => {
                stats.record(jobs.len() as u64);
                b.panels(jobs, centroids, metric, out);
            }
            SystemPanels::Remote(b) => b.panels(jobs, centroids, metric, out),
        }
    }
}

/// Live counters the per-worker observers stream into (Relaxed atomics —
/// monitoring data, not synchronization).  Aggregates cover every phase;
/// the per-shard slots cover the level-1 solves only.
#[derive(Debug)]
struct LiveIters {
    iters: AtomicU64,
    dist_evals: AtomicU64,
    shard_iters: Vec<AtomicU64>,
    shard_dist_evals: Vec<AtomicU64>,
}

impl LiveIters {
    fn new(shards: usize) -> Self {
        Self {
            iters: AtomicU64::new(0),
            dist_evals: AtomicU64::new(0),
            shard_iters: (0..shards).map(|_| AtomicU64::new(0)).collect(),
            shard_dist_evals: (0..shards).map(|_| AtomicU64::new(0)).collect(),
        }
    }
}

/// The coordinator's [`IterObserver`]: one per worker solve, tagging
/// events with the system phase the worker is executing.
struct LiveObserver {
    live: Arc<LiveIters>,
    phase: Phase,
}

impl IterObserver for LiveObserver {
    fn on_iter(&mut self, ev: &IterEvent<'_>) -> IterFlow {
        self.live.iters.fetch_add(1, Ordering::Relaxed);
        self.live.dist_evals.fetch_add(ev.stats.dist_evals, Ordering::Relaxed);
        if let Phase::Level1 { quarter } = self.phase {
            self.live.shard_iters[quarter].fetch_add(1, Ordering::Relaxed);
            self.live.shard_dist_evals[quarter]
                .fetch_add(ev.stats.dist_evals, Ordering::Relaxed);
        }
        log::trace!(
            "coordinator {:?} iter {}: dist_evals={} moved={:.3e}",
            self.phase,
            ev.iter,
            ev.stats.dist_evals,
            ev.stats.moved
        );
        IterFlow::Continue
    }
}

/// The in-process [`ShardExecutor`]: a worker-thread panel backend driving
/// the canonical shard solve.  Also the stand-in a remote puller demotes
/// to when its wire dies.
struct LocalShardExec {
    panels: SystemPanels,
}

impl ShardExecutor for LocalShardExec {
    fn describe(&self) -> String {
        "local".into()
    }

    fn solve_shard(
        &mut self,
        shard_idx: usize,
        data: &Dataset,
        base_spec: &KmeansSpec,
        on_iter: &mut dyn FnMut(&IterStats),
    ) -> anyhow::Result<ShardPartial> {
        let wspec = shard::level1_spec(base_spec, shard_idx);
        let observer = ObserveFn(|ev: &IterEvent<'_>| {
            on_iter(ev.stats);
            IterFlow::Continue
        });
        let r = shard::solve_level1_shard(data, &wspec, &mut self.panels, Some(observer));
        Ok(ShardPartial::from_result(r))
    }
}

/// One scheduler thread's executor: a primary (local thread or remote
/// worker) plus, for remote primaries, the degradation ladder — other
/// live endpoints the shard can be rescheduled on, and the local
/// fallback that takes over when every remote rung is exhausted.
struct Puller {
    primary: Box<dyn ShardExecutor>,
    fallback: Option<LocalShardExec>,
    remote: bool,
    /// Endpoints (deduped, excluding this puller's own and any that
    /// failed `connect_all`) to try before falling back local.
    alternates: Vec<String>,
}

/// The system entry point.
pub struct Coordinator {
    /// Spawned only for the PJRT backend — the software-only system keeps
    /// panel math inside the worker threads.
    service: Option<OffloadService>,
    pjrt: Option<Arc<crate::runtime::PjrtRuntime>>,
    /// Remote shard workers (empty = all-local; the legacy layout).
    remotes: RemoteShardPool,
    /// Level-1 over the session plane (`--session`): shards go resident
    /// on the remotes once, each iteration exchanges only O(k·d).
    session: bool,
}

impl Coordinator {
    /// Build with an explicit backend.
    pub fn new(backend: Backend) -> Self {
        match backend {
            Backend::Cpu => Self {
                service: None,
                pjrt: None,
                remotes: RemoteShardPool::default(),
                session: false,
            },
            Backend::Pjrt(rt) => Self {
                service: Some(OffloadService::spawn(Backend::Pjrt(Arc::clone(&rt)))),
                pjrt: Some(rt),
                remotes: RemoteShardPool::default(),
                session: false,
            },
        }
    }

    /// Run level 1 in session mode ([`crate::kmeans::remote::session`]):
    /// the coordinator drives the global iteration loop, remote workers
    /// keep their shard resident, and per-iteration traffic drops from
    /// O(n/P) to O(k·d).  Bitwise-identical to the one-shot plane (local
    /// session stepping uses the same scalar-oracle panels the workers
    /// do).
    pub fn with_session(mut self, session: bool) -> Self {
        self.session = session;
        self
    }

    /// Satisfy level-1 shard solves from these remote `shard-worker`
    /// endpoints too: each endpoint (repeatable for multiple connections
    /// to one worker) contributes one wire-backed executor per run,
    /// alongside up to `spec.workers` local threads.  Unreachable or
    /// failing endpoints fall back to local solves
    /// ([`CoordMetrics::remote_fallbacks`] counts them); remote solves
    /// are bit-identical to local ones, so the mix never changes the
    /// result.
    pub fn with_remotes(mut self, pool: RemoteShardPool) -> Self {
        self.remotes = pool;
        self
    }

    /// Panel backend for one level-1 worker (runs on that worker's
    /// thread).  The spec's kernel tier, when pinned, overrides the
    /// scalar-oracle default (which stays bitwise the remote workers').
    fn worker_panels(
        &self,
        kernel: Option<KernelKind>,
        local_stats: &Arc<OffloadStats>,
    ) -> SystemPanels {
        match (&self.service, kernel) {
            (Some(svc), _) => SystemPanels::Remote(offload::RemotePanels {
                handle: svc.handle(),
            }),
            (None, Some(kind)) => SystemPanels::LocalPar(
                ParCpuPanels::with_kind(1, kind),
                Arc::clone(local_stats),
            ),
            (None, None) => SystemPanels::LocalScalar(CpuPanels, Arc::clone(local_stats)),
        }
    }

    /// Panel backend for the single-threaded level-2 phase: on CPU it
    /// fans the panel arithmetic across `workers` threads (scalar tier
    /// unless the spec pins a kernel).
    fn level2_panels(
        &self,
        workers: usize,
        kernel: Option<KernelKind>,
        local_stats: &Arc<OffloadStats>,
    ) -> SystemPanels {
        match &self.service {
            Some(svc) => SystemPanels::Remote(offload::RemotePanels {
                handle: svc.handle(),
            }),
            None => SystemPanels::LocalPar(
                match kernel {
                    Some(kind) => ParCpuPanels::with_kind(workers, kind),
                    None => ParCpuPanels::scalar(workers),
                },
                Arc::clone(local_stats),
            ),
        }
    }

    /// Run the full two-level clustering over `data`.  The spec's `algo`
    /// field is not consulted — this *is* the two-level system; everything
    /// else (`k`, metric, tol, caps, init, partition, shards, seed,
    /// workers) drives the run exactly as it drives
    /// [`crate::kmeans::twolevel`].
    pub fn run(&self, data: &Dataset, spec: &KmeansSpec) -> CoordOutcome {
        assert!(spec.k >= 1 && spec.k <= data.len(), "k out of range");
        assert!(spec.workers >= 1);
        assert!(spec.shards >= 1, "shards must be >= 1");
        let mut sw = Stopwatch::start();
        let total_sw = Stopwatch::start();
        let mut m = CoordMetrics::default();
        // Batch/job counters for locally-computed (CPU) panels; the PJRT
        // path counts inside the offload service instead.
        let local_stats = Arc::new(OffloadStats::default());
        let live = Arc::new(LiveIters::new(spec.shards));
        let pjrt_exec0 = self.pjrt.as_ref().map(|rt| rt.stats.executions()).unwrap_or(0);
        let pjrt_secs0 = self.pjrt.as_ref().map(|rt| rt.stats.exec_seconds()).unwrap_or(0.0);

        // ---- Shard ---------------------------------------------------------
        let full_tree = Arc::new(KdTree::build(data));
        m.tree_build_s += sw.lap();
        let plan = ShardPlan::build(data, spec.shards, spec.partition, Some(&full_tree));
        m.partition_s = sw.lap();

        let fallback = !plan.supports_k(spec.k);
        let shard_sizes = plan.sizes();
        m.shards = plan.shards();

        // ---- Level 1 (P shard solves over the executor fleet) ----------------
        let (l1_centroids, l1_counts, level1_stats) = if fallback {
            (Vec::new(), Vec::new(), vec![RunStats::default(); plan.shards()])
        } else if self.session {
            // Session plane: the driver owns the global iteration loop;
            // workers (or local steppers) answer one canonical filter
            // pass per Centroids frame.  Bitwise the one-shot fleet.
            let wire = Arc::new(WireCounters::default());
            let mut on_iter = |si: usize, st: &IterStats| {
                live.iters.fetch_add(1, Ordering::Relaxed);
                live.dist_evals.fetch_add(st.dist_evals, Ordering::Relaxed);
                live.shard_iters[si].fetch_add(1, Ordering::Relaxed);
                live.shard_dist_evals[si].fetch_add(st.dist_evals, Ordering::Relaxed);
                log::trace!(
                    "coordinator Level1 shard {si} (session): dist_evals={} moved={:.3e}",
                    st.dist_evals,
                    st.moved
                );
            };
            let (partials, sm) =
                run_session(&plan.parts, spec, &self.remotes, &wire, &mut on_iter);
            m.remote_workers = sm.remote_workers;
            m.remote_shards = sm.remote_shards;
            m.remote_fallbacks += sm.remote_fallbacks;
            m.remote_failed_endpoints = sm.remote_failed_endpoints;
            m.sessions = sm.sessions;
            m.centroid_bcasts = sm.centroid_bcasts;
            m.partials_rx = sm.partials_rx;
            m.session_bytes_tx = sm.session_bytes_tx;
            m.session_bytes_rx = sm.session_bytes_rx;
            m.shard_reloads = sm.shard_reloads;
            m.remote_bytes_tx = sm.remote_bytes_tx;
            m.remote_bytes_rx = sm.remote_bytes_rx;
            let (retries, timeouts, reconnects) = wire.snapshot();
            m.remote_retries = retries;
            m.remote_timeouts = timeouts;
            m.remote_reconnects = reconnects;
            let counts: Vec<Vec<usize>> = partials.iter().map(|r| r.counts.clone()).collect();
            let cents: Vec<Dataset> = partials.iter().map(|r| r.centroids.clone()).collect();
            let stats: Vec<RunStats> = partials.into_iter().map(|r| r.stats).collect();
            (cents, counts, stats)
        } else {
            // The fleet: one puller per connected remote endpoint, plus
            // local threads up to `spec.workers` (and never more pullers
            // than shards).  Remotes that exhaust their connect retries
            // are counted as fallbacks, listed by name, and replaced by
            // local capacity.
            let wire = Arc::new(WireCounters::default());
            let (mut remote_execs, failed_endpoints) = if self.remotes.is_empty() {
                (Vec::new(), Vec::new())
            } else {
                self.remotes.connect_all_with(&wire)
            };
            remote_execs.truncate(plan.shards());
            m.remote_workers = remote_execs.len();
            m.remote_fallbacks += failed_endpoints.len() as u64;
            // Reschedule candidates: every distinct endpoint that did
            // produce a connection at the start of the run.
            let mut candidates: Vec<String> = Vec::new();
            for ep in self.remotes.endpoints() {
                if !failed_endpoints.contains(ep) && !candidates.contains(ep) {
                    candidates.push(ep.clone());
                }
            }
            m.remote_failed_endpoints = failed_endpoints;
            // Alternate connects get a single attempt: the shard is
            // already delayed, and local fallback is always behind it.
            let alt_policy = RetryPolicy {
                max_attempts: 1,
                ..self.remotes.policy().clone()
            };
            let locals = spec
                .workers
                .min(plan.shards().saturating_sub(remote_execs.len()));
            let mut pullers: Vec<Puller> = Vec::with_capacity(remote_execs.len() + locals);
            for w in remote_execs {
                let alternates: Vec<String> = candidates
                    .iter()
                    .filter(|a| a.as_str() != w.addr())
                    .cloned()
                    .collect();
                pullers.push(Puller {
                    primary: Box::new(w),
                    fallback: Some(LocalShardExec {
                        panels: self.worker_panels(spec.kernel, &local_stats),
                    }),
                    remote: true,
                    alternates,
                });
            }
            for _ in 0..locals {
                // One reusable panel backend per thread (begin_pass
                // resets it between shards).
                pullers.push(Puller {
                    primary: Box::new(LocalShardExec {
                        panels: self.worker_panels(spec.kernel, &local_stats),
                    }),
                    fallback: None,
                    remote: false,
                    alternates: Vec::new(),
                });
            }

            // Work-pulling schedule: pullers race to claim the next
            // unsolved shard, so P > pullers chunks the shards instead of
            // oversubscribing the cores, and P <= workers (no remotes)
            // degenerates to the legacy one-thread-per-quarter layout.
            // Per-shard solves are independent and deterministic — and
            // remote solves are bitwise local solves — so which puller
            // runs a shard never changes its result.
            let mut results: Vec<Option<ShardPartial>> =
                (0..plan.shards()).map(|_| None).collect();
            let next = AtomicUsize::new(0);
            let remote_shards = AtomicU64::new(0);
            let wire_fallbacks = AtomicU64::new(0);
            let rescheduled = AtomicU64::new(0);
            let bytes_tx = AtomicU64::new(0);
            let bytes_rx = AtomicU64::new(0);
            std::thread::scope(|scope| {
                let mut handles = Vec::new();
                for mut p in pullers {
                    let next = &next;
                    let parts = &plan.parts;
                    let live = &live;
                    let remote_shards = &remote_shards;
                    let wire_fallbacks = &wire_fallbacks;
                    let rescheduled = &rescheduled;
                    let wire = &wire;
                    let alt_policy = &alt_policy;
                    let (bytes_tx, bytes_rx) = (&bytes_tx, &bytes_rx);
                    handles.push(scope.spawn(move || {
                        let mut out: Vec<(usize, ShardPartial)> = Vec::new();
                        loop {
                            let qi = next.fetch_add(1, Ordering::Relaxed);
                            if qi >= parts.len() {
                                break;
                            }
                            let mut on_iter = |st: &IterStats| {
                                live.iters.fetch_add(1, Ordering::Relaxed);
                                live.dist_evals.fetch_add(st.dist_evals, Ordering::Relaxed);
                                live.shard_iters[qi].fetch_add(1, Ordering::Relaxed);
                                live.shard_dist_evals[qi]
                                    .fetch_add(st.dist_evals, Ordering::Relaxed);
                                log::trace!(
                                    "coordinator Level1 shard {qi}: dist_evals={} moved={:.3e}",
                                    st.dist_evals,
                                    st.moved
                                );
                            };
                            let partial =
                                match p.primary.solve_shard(qi, &parts[qi], spec, &mut on_iter) {
                                    Ok(part) => {
                                        if p.remote {
                                            remote_shards.fetch_add(1, Ordering::Relaxed);
                                        }
                                        part
                                    }
                                    Err(e) => {
                                        // The primary exhausted its own
                                        // retry/backoff budget (rung 1 of
                                        // the ladder, inside
                                        // RemoteWorker::solve).  Climb
                                        // the remaining rungs: reschedule
                                        // on another live remote, then
                                        // local fallback.  Shard seeds
                                        // are pure functions of (base
                                        // seed, shard index), so every
                                        // rung produces bitwise the same
                                        // partial.  The live per-shard
                                        // feed may see an aborted
                                        // stream's iterations again — it
                                        // is a monotone monitoring feed,
                                        // not the result path.
                                        log::warn!(
                                            "{} failed on shard {qi}: {e}",
                                            p.primary.describe()
                                        );
                                        let (tx, rx) = p.primary.wire_bytes();
                                        bytes_tx.fetch_add(tx, Ordering::Relaxed);
                                        bytes_rx.fetch_add(rx, Ordering::Relaxed);
                                        let mut part: Option<ShardPartial> = None;
                                        for alt in &p.alternates {
                                            let mut w = match RemoteWorker::connect_with(
                                                alt,
                                                alt_policy,
                                                Arc::clone(wire),
                                            ) {
                                                Ok(w) => w,
                                                Err(e2) => {
                                                    log::debug!(
                                                        "alternate {alt} unavailable for shard {qi}: {e2}"
                                                    );
                                                    continue;
                                                }
                                            };
                                            match w.solve_shard(
                                                qi, &parts[qi], spec, &mut on_iter,
                                            ) {
                                                Ok(pt) => {
                                                    log::info!(
                                                        "shard {qi} rescheduled onto {alt}"
                                                    );
                                                    rescheduled
                                                        .fetch_add(1, Ordering::Relaxed);
                                                    remote_shards
                                                        .fetch_add(1, Ordering::Relaxed);
                                                    // Adopt the alternate
                                                    // as this puller's
                                                    // primary — it proved
                                                    // live.
                                                    p.primary = Box::new(w);
                                                    part = Some(pt);
                                                    break;
                                                }
                                                Err(e2) => {
                                                    log::warn!(
                                                        "reschedule of shard {qi} on {alt} failed: {e2}"
                                                    );
                                                    let (tx, rx) = w.wire_bytes();
                                                    bytes_tx
                                                        .fetch_add(tx, Ordering::Relaxed);
                                                    bytes_rx
                                                        .fetch_add(rx, Ordering::Relaxed);
                                                }
                                            }
                                        }
                                        match part {
                                            Some(pt) => pt,
                                            None => {
                                                // Last rung: solve
                                                // locally and demote the
                                                // puller for the rest of
                                                // the run.
                                                wire_fallbacks
                                                    .fetch_add(1, Ordering::Relaxed);
                                                let mut local = p.fallback.take().expect(
                                                    "remote puller carries a local fallback",
                                                );
                                                let pt = local
                                                    .solve_shard(
                                                        qi, &parts[qi], spec, &mut on_iter,
                                                    )
                                                    .expect(
                                                        "local shard solve is infallible",
                                                    );
                                                p.primary = Box::new(local);
                                                p.remote = false;
                                                pt
                                            }
                                        }
                                    }
                                };
                            out.push((qi, partial));
                        }
                        let (tx, rx) = p.primary.wire_bytes();
                        bytes_tx.fetch_add(tx, Ordering::Relaxed);
                        bytes_rx.fetch_add(rx, Ordering::Relaxed);
                        out
                    }));
                }
                for h in handles {
                    for (qi, r) in h.join().expect("worker panicked") {
                        results[qi] = Some(r);
                    }
                }
            });
            m.remote_shards = remote_shards.load(Ordering::Relaxed);
            m.remote_fallbacks += wire_fallbacks.load(Ordering::Relaxed);
            m.remote_rescheduled = rescheduled.load(Ordering::Relaxed);
            let (retries, timeouts, reconnects) = wire.snapshot();
            m.remote_retries = retries;
            m.remote_timeouts = timeouts;
            m.remote_reconnects = reconnects;
            m.remote_bytes_tx = bytes_tx.load(Ordering::Relaxed);
            m.remote_bytes_rx = bytes_rx.load(Ordering::Relaxed);
            let results: Vec<ShardPartial> = results.into_iter().map(Option::unwrap).collect();
            let counts: Vec<Vec<usize>> = results.iter().map(|r| r.counts.clone()).collect();
            let cents: Vec<Dataset> = results.iter().map(|r| r.centroids.clone()).collect();
            let stats: Vec<RunStats> = results.into_iter().map(|r| r.stats).collect();
            (cents, counts, stats)
        };
        m.level1_s = sw.lap();

        // ---- Combine ---------------------------------------------------------
        let merged = if fallback {
            init_centroids(data, spec.k, spec.init, spec.metric, spec.seed)
        } else {
            shard::combine_hierarchical(&l1_centroids, &l1_counts, spec.metric)
        };
        m.combine_s = sw.lap();

        // ---- Level 2 ----------------------------------------------------------
        let panels = self.level2_panels(spec.workers, spec.kernel, &local_stats);
        let l2spec = spec
            .clone()
            .algo(Algo::FilterBatched)
            .max_iters(spec.level2_max_iters)
            .start(merged.clone());
        let mut ctx = SolverCtx::new(data)
            .with_tree(Arc::clone(&full_tree))
            .with_backend(panels)
            .with_observer(LiveObserver {
                live: Arc::clone(&live),
                phase: Phase::Level2,
            });
        let mut result = l2spec.solve(&mut ctx);
        m.level2_s = sw.lap();

        // Bounds-plane counters: level-2 plus every locally-executed
        // level-1 shard (remote partials decode them as 0, like the rest
        // of the local-process telemetry).
        m.bound_pruned_points = result.stats.bound_pruned_points;
        m.bound_pruned_candidates = result.stats.bound_pruned_candidates;
        m.bounds_matrix_cost = result.stats.bounds_matrix_cost;
        for st in &level1_stats {
            m.bound_pruned_points += st.bound_pruned_points;
            m.bound_pruned_candidates += st.bound_pruned_candidates;
            m.bounds_matrix_cost += st.bounds_matrix_cost;
        }

        m.total_s = total_sw.elapsed().as_secs_f64();
        let (batches, jobs_served) = match &self.service {
            Some(svc) => {
                let st = svc.handle();
                let batches = st.stats().batches.load(Ordering::Relaxed);
                let jobs = st.stats().jobs.load(Ordering::Relaxed);
                (batches, jobs)
            }
            None => (
                local_stats.batches.load(Ordering::Relaxed),
                local_stats.jobs.load(Ordering::Relaxed),
            ),
        };
        m.offload_batches = batches;
        m.offload_jobs = jobs_served;
        m.observed_iters = live.iters.load(Ordering::Relaxed);
        m.observed_dist_evals = live.dist_evals.load(Ordering::Relaxed);
        m.shard_iters = live
            .shard_iters
            .iter()
            .map(|a| a.load(Ordering::Relaxed))
            .collect();
        m.shard_dist_evals = live
            .shard_dist_evals
            .iter()
            .map(|a| a.load(Ordering::Relaxed))
            .collect();
        if let Some(rt) = &self.pjrt {
            m.pjrt_executions = rt.stats.executions() - pjrt_exec0;
            m.pjrt_exec_s = rt.stats.exec_seconds() - pjrt_secs0;
        }

        result.ext.two_level = Some(Box::new(TwoLevelExt {
            level1_stats,
            quarter_sizes: shard_sizes,
            merged_centroids: merged,
        }));
        CoordOutcome { result, metrics: m }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::generate_params;
    use crate::kmeans::twolevel::{self, Partition, TwoLevelOpts};

    #[test]
    fn coordinator_matches_sequential_reference() {
        let s = generate_params(3000, 3, 5, 0.15, 2.0, 33);
        let coord = Coordinator::new(Backend::Cpu);
        let spec = KmeansSpec::two_level(5).seed(9);
        let c = coord.run(&s.data, &spec);
        let r = twolevel::run(
            &s.data,
            5,
            &TwoLevelOpts {
                seed: 9,
                ..Default::default()
            },
        );
        // Same seeds, same partition, same building blocks: identical
        // counts and near-identical centroids (threading does not change
        // per-quarter math; only f32 sum order inside combine/level2 can).
        for (a, b) in c.result.centroids.iter().zip(r.centroids.iter()) {
            for (x, y) in a.iter().zip(b.iter()) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        }
        let ce = c.result.ext.two_level.as_ref().unwrap();
        let re = r.ext.two_level.as_ref().unwrap();
        assert_eq!(ce.quarter_sizes, vec![750; 4]);
        assert_eq!(
            ce.level1_stats.iter().map(|s| s.iterations()).collect::<Vec<_>>(),
            re.level1_stats.iter().map(|s| s.iterations()).collect::<Vec<_>>(),
        );
        assert!(c.metrics.offload_jobs > 0);
        assert!(c.metrics.total_s > 0.0);
        // The observer subscription streamed every iteration of every phase.
        let expect_iters: u64 = ce
            .level1_stats
            .iter()
            .map(|s| s.iterations() as u64)
            .sum::<u64>()
            + c.result.stats.iterations() as u64;
        assert_eq!(c.metrics.observed_iters, expect_iters);
        assert!(c.metrics.observed_dist_evals > 0);
        // Per-shard counters line up with the per-quarter stats.
        assert_eq!(c.metrics.shards, 4);
        assert_eq!(
            c.metrics.shard_iters,
            ce.level1_stats.iter().map(|s| s.iterations() as u64).collect::<Vec<_>>()
        );
        assert_eq!(
            c.metrics.shard_dist_evals,
            ce.level1_stats.iter().map(|s| s.total_dist_evals()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn shards_beyond_workers_are_chunked_and_deterministic() {
        // P=8 over 2 worker threads must equal the sequential 8-shard
        // reference (scheduling never changes per-shard math) and P=8 over
        // 8 threads must equal P=8 over 2 threads.
        let s = generate_params(4000, 3, 5, 0.15, 2.0, 51);
        let coord = Coordinator::new(Backend::Cpu);
        let spec8x2 = KmeansSpec::two_level(5).seed(9).shards(8).workers(2);
        let a = coord.run(&s.data, &spec8x2);
        let b = coord.run(&s.data, &spec8x2.clone().workers(8));
        assert_eq!(a.result.centroids, b.result.centroids);
        assert_eq!(a.result.assignments, b.result.assignments);
        assert_eq!(a.metrics.shard_iters, b.metrics.shard_iters);
        let seq = twolevel::run(
            &s.data,
            5,
            &TwoLevelOpts { seed: 9, shards: 8, ..Default::default() },
        );
        let ae = a.result.ext.two_level.as_ref().unwrap();
        let se = seq.ext.two_level.as_ref().unwrap();
        assert_eq!(ae.quarter_sizes, se.quarter_sizes);
        assert_eq!(
            ae.level1_stats.iter().map(|st| st.iterations()).collect::<Vec<_>>(),
            se.level1_stats.iter().map(|st| st.iterations()).collect::<Vec<_>>(),
        );
        assert_eq!(a.metrics.shards, 8);
        assert_eq!(a.metrics.shard_iters.len(), 8);
        assert!(a.metrics.shard_iters.iter().all(|&i| i > 0));
    }

    #[test]
    fn session_mode_is_bitwise_the_oneshot_fleet() {
        // No remotes: session mode degrades to pure-local lockstep
        // stepping, which must still equal the one-shot fleet bit for
        // bit — centroids, labels, merged seed, and per-shard counters.
        let s = generate_params(3000, 3, 5, 0.15, 2.0, 33);
        let spec = KmeansSpec::two_level(5).seed(9).shards(4).workers(2);
        let a = Coordinator::new(Backend::Cpu).run(&s.data, &spec);
        let b = Coordinator::new(Backend::Cpu)
            .with_session(true)
            .run(&s.data, &spec);
        assert_eq!(a.result.centroids, b.result.centroids);
        assert_eq!(a.result.assignments, b.result.assignments);
        let ae = a.result.ext.two_level.as_ref().unwrap();
        let be = b.result.ext.two_level.as_ref().unwrap();
        assert_eq!(ae.merged_centroids, be.merged_centroids);
        assert_eq!(a.metrics.shard_iters, b.metrics.shard_iters);
        assert_eq!(a.metrics.shard_dist_evals, b.metrics.shard_dist_evals);
        // All-local session: the remote/session counters stay zero.
        assert_eq!(b.metrics.sessions, 0);
        assert_eq!(b.metrics.centroid_bcasts, 0);
        assert_eq!(b.metrics.remote_fallbacks, 0);
        assert_eq!(b.metrics.session_bytes_tx + b.metrics.session_bytes_rx, 0);
    }

    #[test]
    fn single_shard_runs() {
        let s = generate_params(1500, 2, 3, 0.2, 1.0, 5);
        let coord = Coordinator::new(Backend::Cpu);
        let c = coord.run(&s.data, &KmeansSpec::two_level(3).shards(1));
        assert_eq!(c.result.assignments.len(), 1500);
        let ext = c.result.ext.two_level.as_ref().unwrap();
        assert_eq!(ext.quarter_sizes, vec![1500]);
        assert_eq!(c.metrics.shards, 1);
    }

    #[test]
    fn every_point_assigned() {
        let s = generate_params(1200, 2, 3, 0.2, 1.0, 7);
        let coord = Coordinator::new(Backend::Cpu);
        let c = coord.run(&s.data, &KmeansSpec::two_level(3));
        assert_eq!(c.result.assignments.len(), 1200);
        assert!(c.result.assignments.iter().all(|&a| a < 3));
        let sizes = c.result.sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 1200);
    }

    #[test]
    fn tiny_dataset_fallback() {
        let s = generate_params(12, 2, 2, 0.1, 1.0, 3);
        let coord = Coordinator::new(Backend::Cpu);
        let c = coord.run(&s.data, &KmeansSpec::two_level(6));
        assert_eq!(c.result.centroids.len(), 6);
        let ext = c.result.ext.two_level.as_ref().unwrap();
        assert!(ext.level1_stats.iter().all(|s| s.iterations() == 0));
    }

    #[test]
    fn kdtop_partition_works_too() {
        let s = generate_params(2000, 3, 4, 0.2, 1.0, 13);
        let coord = Coordinator::new(Backend::Cpu);
        let c = coord.run(
            &s.data,
            &KmeansSpec::two_level(4).partition(Partition::KdTop),
        );
        let ext = c.result.ext.two_level.as_ref().unwrap();
        assert_eq!(ext.quarter_sizes.iter().sum::<usize>(), 2000);
        assert!(c.result.stats.converged);
    }

    #[test]
    #[should_panic(expected = "k out of range")]
    fn k_larger_than_n_is_rejected() {
        let data = Dataset::from_flat(3, 1, vec![1.0, 2.0, 3.0]);
        let coord = Coordinator::new(Backend::Cpu);
        coord.run(&data, &KmeansSpec::two_level(10));
    }
}
