//! The deployable MUCH-SWIFT system: a leader orchestrating four worker
//! threads (the Cortex-A53 quartet) and a PL offload service (the R5-owned
//! DMA/PL interface), executing the two-level clustering of Alg. 2 with
//! the distance arithmetic on the PJRT-compiled Pallas kernels.
//!
//! Phase structure (leader):
//! 1. `Quarter`   — partition the dataset (round-robin or kd-top).
//! 2. Level 1     — four workers, each: build kd-tree over its quarter,
//!    seed k centroids, run batched filtering through the offload service.
//! 3. `Combine`   — greedy nearest-centroid merge, count-weighted.
//! 4. Level 2     — batched filtering over the full tree from the merged
//!    seeds (few iterations).
//!
//! The *algorithmic* building blocks are shared with
//! [`crate::kmeans::twolevel`] (the sequential reference), so the threaded
//! system cannot drift from the tested semantics.

pub mod metrics;
pub mod offload;

pub use metrics::CoordMetrics;
pub use offload::{Backend, OffloadService};

use crate::data::Dataset;
use crate::kdtree::KdTree;
use crate::kmeans::filtering::{self, FilterOpts};
use crate::kmeans::init::{init_centroids, Init};
use crate::kmeans::panel::{CpuPanels, PanelBackend, PanelJobs, PanelSet, ParCpuPanels};
use crate::kmeans::twolevel::{combine, quarter, quarter_round_robin, Partition, QUARTERS};
use crate::kmeans::{KmeansResult, Metric, RunStats};
use metrics::Stopwatch;
use offload::OffloadStats;
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorOpts {
    pub k: usize,
    pub metric: Metric,
    pub tol: f32,
    pub level1_max_iters: usize,
    pub level2_max_iters: usize,
    pub init: Init,
    pub partition: Partition,
    pub seed: u64,
    /// Worker threads (defaults to the paper's 4 A53 cores).
    pub workers: usize,
}

impl Default for CoordinatorOpts {
    fn default() -> Self {
        Self {
            k: 8,
            metric: Metric::Euclid,
            tol: 1e-6,
            level1_max_iters: 100,
            level2_max_iters: 100,
            init: Init::UniformSample,
            partition: Partition::RoundRobin,
            seed: 1,
            workers: QUARTERS,
        }
    }
}

/// Everything a coordinated run produces.
#[derive(Clone, Debug)]
pub struct CoordOutcome {
    pub result: KmeansResult,
    pub level1_stats: Vec<RunStats>,
    pub level2_stats: RunStats,
    pub merged_centroids: Dataset,
    pub quarter_sizes: Vec<usize>,
    pub metrics: CoordMetrics,
}

/// A worker-side panel backend: either local CPU math (no channel — the
/// software-only deployment computes panels in place, scalar per level-1
/// worker, multi-threaded for the single-threaded level-2 phase) or the
/// PL offload service.  Local variants count batches/jobs into the shared
/// [`OffloadStats`]; the service counts its own.
enum SystemPanels {
    LocalScalar(CpuPanels, Arc<OffloadStats>),
    LocalPar(ParCpuPanels, Arc<OffloadStats>),
    Remote(offload::RemotePanels),
}

impl PanelBackend for SystemPanels {
    fn begin_pass(&mut self, centroids: &Dataset, metric: Metric) {
        match self {
            SystemPanels::LocalScalar(b, _) => b.begin_pass(centroids, metric),
            SystemPanels::LocalPar(b, _) => b.begin_pass(centroids, metric),
            SystemPanels::Remote(b) => b.begin_pass(centroids, metric),
        }
    }

    fn panels(
        &mut self,
        jobs: &PanelJobs,
        centroids: &Dataset,
        metric: Metric,
        out: &mut PanelSet,
    ) {
        match self {
            SystemPanels::LocalScalar(b, stats) => {
                stats.record(jobs.len() as u64);
                b.panels(jobs, centroids, metric, out);
            }
            SystemPanels::LocalPar(b, stats) => {
                stats.record(jobs.len() as u64);
                b.panels(jobs, centroids, metric, out);
            }
            SystemPanels::Remote(b) => b.panels(jobs, centroids, metric, out),
        }
    }
}

/// The system entry point.
pub struct Coordinator {
    /// Spawned only for the PJRT backend — the software-only system keeps
    /// panel math inside the worker threads.
    service: Option<OffloadService>,
    pjrt: Option<Arc<crate::runtime::PjrtRuntime>>,
}

impl Coordinator {
    /// Build with an explicit backend.
    pub fn new(backend: Backend) -> Self {
        match backend {
            Backend::Cpu => Self {
                service: None,
                pjrt: None,
            },
            Backend::Pjrt(rt) => Self {
                service: Some(OffloadService::spawn(Backend::Pjrt(Arc::clone(&rt)))),
                pjrt: Some(rt),
            },
        }
    }

    /// Panel backend for one level-1 worker (runs on that worker's thread).
    fn worker_panels(&self, local_stats: &Arc<OffloadStats>) -> SystemPanels {
        match &self.service {
            Some(svc) => SystemPanels::Remote(offload::RemotePanels {
                handle: svc.handle(),
            }),
            None => SystemPanels::LocalScalar(CpuPanels, Arc::clone(local_stats)),
        }
    }

    /// Panel backend for the single-threaded level-2 phase: on CPU it
    /// fans the panel arithmetic across `workers` threads.
    fn level2_panels(&self, workers: usize, local_stats: &Arc<OffloadStats>) -> SystemPanels {
        match &self.service {
            Some(svc) => SystemPanels::Remote(offload::RemotePanels {
                handle: svc.handle(),
            }),
            None => SystemPanels::LocalPar(
                ParCpuPanels::scalar(workers),
                Arc::clone(local_stats),
            ),
        }
    }

    /// Run the full two-level clustering over `data`.
    pub fn run(&self, data: &Dataset, opts: &CoordinatorOpts) -> CoordOutcome {
        assert!(opts.k >= 1 && opts.k <= data.len(), "k out of range");
        assert!(opts.workers >= 1);
        let mut sw = Stopwatch::start();
        let total_sw = Stopwatch::start();
        let mut m = CoordMetrics::default();
        // Batch/job counters for locally-computed (CPU) panels; the PJRT
        // path counts inside the offload service instead.
        let local_stats = Arc::new(OffloadStats::default());
        let pjrt_exec0 = self.pjrt.as_ref().map(|rt| rt.stats.executions()).unwrap_or(0);
        let pjrt_secs0 = self.pjrt.as_ref().map(|rt| rt.stats.exec_seconds()).unwrap_or(0.0);

        // ---- Quarter -------------------------------------------------------
        let full_tree = KdTree::build(data);
        m.tree_build_s += sw.lap();
        let (quarters, _ids) = match opts.partition {
            Partition::RoundRobin => quarter_round_robin(data),
            Partition::KdTop => quarter(data, &full_tree),
        };
        m.partition_s = sw.lap();

        let fallback = quarters.iter().any(|q| q.len() < opts.k);
        let fopts = FilterOpts {
            metric: opts.metric,
            tol: opts.tol,
            max_iters: opts.level1_max_iters,
        };

        // ---- Level 1 (parallel workers) -------------------------------------
        let (l1_centroids, l1_counts, level1_stats, quarter_sizes) = if fallback {
            (
                Vec::new(),
                Vec::new(),
                vec![RunStats::default(); QUARTERS],
                quarters.iter().map(|q| q.len()).collect::<Vec<_>>(),
            )
        } else {
            let sizes: Vec<usize> = quarters.iter().map(|q| q.len()).collect();
            let mut results: Vec<Option<KmeansResult>> = (0..quarters.len()).map(|_| None).collect();
            std::thread::scope(|scope| {
                let mut handles = Vec::new();
                for (qi, qdata) in quarters.iter().enumerate() {
                    let mut panels = self.worker_panels(&local_stats);
                    let fopts = fopts.clone();
                    let opts = opts.clone();
                    handles.push((
                        qi,
                        scope.spawn(move || {
                            // Sequential build: this already runs on one of
                            // `QUARTERS` concurrent workers — nested build
                            // threads would oversubscribe the cores.
                            let tree = KdTree::build_par(
                                qdata,
                                crate::kdtree::DEFAULT_LEAF_SIZE,
                                0,
                            );
                            let init = init_centroids(
                                qdata,
                                opts.k,
                                opts.init,
                                opts.metric,
                                opts.seed ^ (qi as u64).wrapping_mul(0x9E37_79B9),
                            );
                            filtering::run_batched(qdata, &tree, &init, &fopts, &mut panels)
                        }),
                    ));
                }
                for (qi, h) in handles {
                    results[qi] = Some(h.join().expect("worker panicked"));
                }
            });
            let results: Vec<KmeansResult> = results.into_iter().map(Option::unwrap).collect();
            let counts: Vec<Vec<usize>> = results.iter().map(|r| r.sizes()).collect();
            let cents: Vec<Dataset> = results.iter().map(|r| r.centroids.clone()).collect();
            let stats: Vec<RunStats> = results.into_iter().map(|r| r.stats).collect();
            (cents, counts, stats, sizes)
        };
        m.level1_s = sw.lap();

        // ---- Combine ---------------------------------------------------------
        let merged = if fallback {
            init_centroids(data, opts.k, opts.init, opts.metric, opts.seed)
        } else {
            combine(&l1_centroids, &l1_counts, opts.metric)
        };
        m.combine_s = sw.lap();

        // ---- Level 2 ----------------------------------------------------------
        let mut panels = self.level2_panels(opts.workers, &local_stats);
        let result = filtering::run_batched(
            data,
            &full_tree,
            &merged,
            &FilterOpts {
                metric: opts.metric,
                tol: opts.tol,
                max_iters: opts.level2_max_iters,
            },
            &mut panels,
        );
        m.level2_s = sw.lap();

        m.total_s = total_sw.elapsed().as_secs_f64();
        let (batches, jobs_served) = match &self.service {
            Some(svc) => {
                let st = svc.handle();
                let batches = st.stats().batches.load(Ordering::Relaxed);
                let jobs = st.stats().jobs.load(Ordering::Relaxed);
                (batches, jobs)
            }
            None => (
                local_stats.batches.load(Ordering::Relaxed),
                local_stats.jobs.load(Ordering::Relaxed),
            ),
        };
        m.offload_batches = batches;
        m.offload_jobs = jobs_served;
        if let Some(rt) = &self.pjrt {
            m.pjrt_executions = rt.stats.executions() - pjrt_exec0;
            m.pjrt_exec_s = rt.stats.exec_seconds() - pjrt_secs0;
        }

        let level2_stats = result.stats.clone();
        CoordOutcome {
            result,
            level1_stats,
            level2_stats,
            merged_centroids: merged,
            quarter_sizes,
            metrics: m,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::generate_params;
    use crate::kmeans::twolevel::{self, TwoLevelOpts};

    #[test]
    fn coordinator_matches_sequential_reference() {
        let s = generate_params(3000, 3, 5, 0.15, 2.0, 33);
        let coord = Coordinator::new(Backend::Cpu);
        let opts = CoordinatorOpts {
            k: 5,
            seed: 9,
            ..Default::default()
        };
        let c = coord.run(&s.data, &opts);
        let r = twolevel::run(
            &s.data,
            5,
            &TwoLevelOpts {
                seed: 9,
                ..Default::default()
            },
        );
        // Same seeds, same partition, same building blocks: identical
        // counts and near-identical centroids (threading does not change
        // per-quarter math; only f32 sum order inside combine/level2 can).
        for (a, b) in c.result.centroids.iter().zip(r.result.centroids.iter()) {
            for (x, y) in a.iter().zip(b.iter()) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        }
        assert_eq!(c.quarter_sizes, vec![750; 4]);
        assert_eq!(
            c.level1_stats.iter().map(|s| s.iterations()).collect::<Vec<_>>(),
            r.level1_stats.iter().map(|s| s.iterations()).collect::<Vec<_>>(),
        );
        assert!(c.metrics.offload_jobs > 0);
        assert!(c.metrics.total_s > 0.0);
    }

    #[test]
    fn every_point_assigned() {
        let s = generate_params(1200, 2, 3, 0.2, 1.0, 7);
        let coord = Coordinator::new(Backend::Cpu);
        let c = coord.run(&s.data, &CoordinatorOpts { k: 3, ..Default::default() });
        assert_eq!(c.result.assignments.len(), 1200);
        assert!(c.result.assignments.iter().all(|&a| a < 3));
        let sizes = c.result.sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 1200);
    }

    #[test]
    fn tiny_dataset_fallback() {
        let s = generate_params(12, 2, 2, 0.1, 1.0, 3);
        let coord = Coordinator::new(Backend::Cpu);
        let c = coord.run(&s.data, &CoordinatorOpts { k: 6, ..Default::default() });
        assert_eq!(c.result.centroids.len(), 6);
        assert!(c.level1_stats.iter().all(|s| s.iterations() == 0));
    }

    #[test]
    fn kdtop_partition_works_too() {
        let s = generate_params(2000, 3, 4, 0.2, 1.0, 13);
        let coord = Coordinator::new(Backend::Cpu);
        let c = coord.run(
            &s.data,
            &CoordinatorOpts {
                k: 4,
                partition: Partition::KdTop,
                ..Default::default()
            },
        );
        assert_eq!(c.quarter_sizes.iter().sum::<usize>(), 2000);
        assert!(c.result.stats.converged);
    }
}
