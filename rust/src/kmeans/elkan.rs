//! Elkan's triangle-inequality accelerated k-means [8].
//!
//! The related-work software optimization the paper cites (implemented on
//! FPGA by [15]): identical results to Lloyd, but most exact distance
//! computations are skipped using upper/lower bounds maintained via the
//! triangle inequality.  Serves as a second software baseline so the
//! benches can show where kd-tree filtering wins (low-D) and where
//! triangle-inequality wins (high-D).
//!
//! Bounds need a *metric* (triangle inequality), so Euclidean runs on true
//! L2 internally and squares only when reporting; Manhattan is a metric
//! already.
//!
//! Since PR 10 the same bounding idea is *fused into the production
//! batched engine* as [`super::bounds`] (`BoundsMode` on
//! [`KmeansSpec`](super::solver::KmeansSpec), DESIGN.md §10): the
//! center-center matrix and movement-loosened upper bounds prune panel
//! jobs before enqueue while keeping labels and centroid bits identical
//! to the unpruned run.  This standalone engine remains the
//! whole-algorithm reference baseline; the bounds plane is its fused
//! successor on the panel path.

use super::{
    centroids_from_sums, max_sq_movement, metrics, IterHook, IterStats, KmeansResult, Metric,
    ResultExt, RunStats,
};
use crate::data::Dataset;

#[derive(Clone, Debug)]
pub struct ElkanOpts {
    pub metric: Metric,
    pub tol: f32,
    pub max_iters: usize,
}

impl Default for ElkanOpts {
    fn default() -> Self {
        Self {
            metric: Metric::Euclid,
            tol: 1e-6,
            max_iters: 100,
        }
    }
}

#[inline]
fn true_dist(metric: Metric, a: &[f32], b: &[f32]) -> f32 {
    match metric {
        Metric::Euclid => metrics::sq_l2(a, b).sqrt(),
        Metric::Manhattan => metrics::l1(a, b),
    }
}

/// Run Elkan's algorithm from the given initial centroids.
pub fn run(data: &Dataset, init: &Dataset, opts: &ElkanOpts) -> KmeansResult {
    run_hooked(data, init, opts, None)
}

/// [`run`] with a per-iteration hook (what the unified solver layer calls;
/// the hook returning `false` stops the run early).
pub fn run_hooked(
    data: &Dataset,
    init: &Dataset,
    opts: &ElkanOpts,
    mut hook: Option<IterHook<'_>>,
) -> KmeansResult {
    let n = data.len();
    let d = data.dims();
    let k = init.len();
    assert!(
        (n as u64) * (k as u64) <= 1 << 31,
        "elkan bounds matrix would exceed memory (n*k too large)"
    );
    let mut centroids = init.clone();
    let mut stats = RunStats::default();

    // Bounds state.
    let mut assign = vec![0u32; n];
    let mut upper = vec![f32::INFINITY; n];
    let mut lower = vec![0f32; n * k];
    let mut tight = vec![false; n]; // is `upper` exact?

    // Initial assignment: exact nearest with the true metric.
    let mut dist_evals = 0u64;
    for i in 0..n {
        let p = data.point(i);
        let mut best = 0usize;
        let mut best_d = f32::INFINITY;
        for c in 0..k {
            let dd = true_dist(opts.metric, p, centroids.point(c));
            lower[i * k + c] = dd;
            if dd < best_d {
                best_d = dd;
                best = c;
            }
        }
        dist_evals += k as u64;
        assign[i] = best as u32;
        upper[i] = best_d;
        tight[i] = true;
    }

    let mut cc_half = vec![0f32; k * k];
    let mut s = vec![0f32; k];
    let mut shifts = vec![0f32; k];
    let mut sums = vec![0f32; k * d];
    let mut counts = vec![0u32; k];

    for iter in 0..opts.max_iters {
        // Inter-center distances and s(c) = 0.5 min_{c' != c} d(c, c').
        for a in 0..k {
            let mut m = f32::INFINITY;
            for b in 0..k {
                if a == b {
                    continue;
                }
                let dd = 0.5 * true_dist(opts.metric, centroids.point(a), centroids.point(b));
                cc_half[a * k + b] = dd;
                if dd < m {
                    m = dd;
                }
            }
            s[a] = m;
            dist_evals += (k - 1) as u64 / 2 + 1; // symmetric halves
        }

        // Assignment with bound pruning (skip on the very first pass:
        // bounds are already exact from initialization).
        if iter > 0 {
            for i in 0..n {
                if upper[i] <= s[assign[i] as usize] {
                    continue; // lemma 1: nearest unchanged
                }
                let p = data.point(i);
                let mut a = assign[i] as usize;
                for c in 0..k {
                    if c == a {
                        continue;
                    }
                    if upper[i] <= lower[i * k + c] || upper[i] <= cc_half[a * k + c] {
                        continue; // pruned without arithmetic
                    }
                    // Tighten the upper bound (exact distance to current a).
                    if !tight[i] {
                        let dd = true_dist(opts.metric, p, centroids.point(a));
                        dist_evals += 1;
                        upper[i] = dd;
                        lower[i * k + a] = dd;
                        tight[i] = true;
                        if upper[i] <= lower[i * k + c] || upper[i] <= cc_half[a * k + c] {
                            continue;
                        }
                    }
                    let dd = true_dist(opts.metric, p, centroids.point(c));
                    dist_evals += 1;
                    lower[i * k + c] = dd;
                    if dd < upper[i] {
                        upper[i] = dd;
                        a = c;
                        tight[i] = true;
                    }
                }
                assign[i] = a as u32;
            }
        }

        // Update step.
        sums.iter_mut().for_each(|v| *v = 0.0);
        counts.iter_mut().for_each(|v| *v = 0);
        for (i, p) in data.iter().enumerate() {
            let a = assign[i] as usize;
            for (j, &v) in p.iter().enumerate() {
                sums[a * d + j] += v;
            }
            counts[a] += 1;
        }
        let next = centroids_from_sums(&sums, &counts, &centroids);

        // Shift bounds by centroid movement (true metric).
        for c in 0..k {
            shifts[c] = true_dist(opts.metric, centroids.point(c), next.point(c));
        }
        for i in 0..n {
            upper[i] += shifts[assign[i] as usize];
            tight[i] = false;
            for c in 0..k {
                lower[i * k + c] = (lower[i * k + c] - shifts[c]).max(0.0);
            }
        }

        let moved = max_sq_movement(&centroids, &next);
        centroids = next;
        stats.iters.push(IterStats {
            dist_evals,
            leaf_points: n as u64,
            moved,
            ..Default::default()
        });
        dist_evals = 0;

        let go = match hook.as_mut() {
            Some(h) => h(stats.iters.len() - 1, stats.iters.last().unwrap(), &centroids),
            None => true,
        };
        if moved <= opts.tol {
            stats.converged = true;
            break;
        }
        if !go {
            stats.early_stopped = true;
            break;
        }
    }

    KmeansResult {
        centroids,
        assignments: assign,
        stats,
        ext: ResultExt::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::generate_params;
    use crate::kmeans::init::{init_centroids, Init};
    use crate::kmeans::lloyd::{self, LloydOpts};

    #[test]
    fn elkan_matches_lloyd_result() {
        for metric in [Metric::Euclid, Metric::Manhattan] {
            let s = generate_params(700, 4, 5, 0.2, 1.0, 31);
            let init = init_centroids(&s.data, 5, Init::UniformSample, metric, 8);
            let re = run(
                &s.data,
                &init,
                &ElkanOpts { metric, tol: 1e-10, max_iters: 60 },
            );
            let rl = lloyd::run(
                &s.data,
                &init,
                &LloydOpts { metric, tol: 1e-10, max_iters: 60, ..Default::default() },
            );
            // Elkan is exact: converged centroids agree with Lloyd.
            for (a, b) in re.centroids.iter().zip(rl.centroids.iter()) {
                for (x, y) in a.iter().zip(b.iter()) {
                    assert!((x - y).abs() < 5e-3, "{metric:?}: {x} vs {y}");
                }
            }
            let same = re
                .assignments
                .iter()
                .zip(rl.assignments.iter())
                .filter(|(a, b)| a == b)
                .count();
            assert!(same >= 695, "{metric:?}: only {same}/700 assignments agree");
        }
    }

    #[test]
    fn elkan_skips_most_distance_work() {
        let s = generate_params(3000, 6, 10, 0.3, 2.0, 5);
        let init = init_centroids(&s.data, 10, Init::UniformSample, Metric::Euclid, 2);
        let r = run(&s.data, &init, &ElkanOpts::default());
        assert!(r.stats.converged);
        assert!(r.stats.iterations() >= 3, "want a multi-iteration run");
        // The first pass is a full exact assignment (n*k); the bound
        // machinery pays off from iteration 2 on.
        let steady: u64 = r.stats.iters[1..].iter().map(|i| i.dist_evals).sum();
        let lloyd_steady = 3000u64 * 10 * (r.stats.iterations() as u64 - 1);
        assert!(
            steady < lloyd_steady / 2,
            "triangle inequality should halve steady-state work: {steady} vs {lloyd_steady}"
        );
    }

    #[test]
    fn single_cluster_trivial() {
        let s = generate_params(50, 2, 1, 0.1, 1.0, 3);
        let init = s.data.gather(&[0]);
        let r = run(&s.data, &init, &ElkanOpts::default());
        assert!(r.assignments.iter().all(|&a| a == 0));
        assert!(r.stats.converged);
    }

    #[test]
    #[should_panic(expected = "bounds matrix")]
    fn rejects_oversized_bounds() {
        let data = Dataset::zeros(2, 1);
        // Fake: n*k too large is impossible with real data here, so check
        // the guard directly via an enormous k on a tiny dataset by
        // constructing init with repeated gathers. We simulate by calling
        // with n*k > 2^31 via a crafted dataset view.
        let big = Dataset::zeros(1 << 16, 1);
        let init = Dataset::zeros(1 << 16, 1);
        let _ = run(&big, &init, &ElkanOpts::default());
        let _ = data;
    }
}
