//! K-means clustering algorithms (paper sections 2–4).
//!
//! Four interchangeable solvers over the same [`Dataset`] substrate:
//!
//! - [`lloyd`]     — conventional Lloyd iteration (the "software-only" and
//!   "FPGA without optimization" baselines compute exactly this work).
//! - [`filtering`] — the kd-tree filtering algorithm of Kanungo et al. [7]
//!   (paper Alg. 1), in both a recursive form and a level-batched form
//!   whose distance panels can be offloaded (to the PJRT "PL").
//! - [`elkan`]     — triangle-inequality accelerated Lloyd [8], the
//!   related-work baseline of [15].
//! - [`twolevel`]  — the paper's contribution (Alg. 2): 4-way partition,
//!   per-quarter filtering k-means, centroid merge, second-level pass.
//!
//! All four are driven through the unified solver API in [`solver`]
//! (one [`KmeansSpec`], one [`Solver`] trait, pluggable panel backends,
//! per-iteration observers); the modules above are the numeric kernels
//! behind it.  Training and serving are split: `KmeansSpec::fit` freezes
//! a solve into a persistable [`model::KmeansModel`] artifact, and
//! [`predict::Predictor`] answers batched assign/score queries against a
//! model through the same panel seam (see also [`crate::serve`] for the
//! micro-batching service on top).
//!
//! Every solver records per-iteration *work counters* ([`IterStats`]) —
//! distance evaluations, kd-node visits, pruned subtree assignments — which
//! are exactly what the hardware simulator charges cycles for.  This keeps
//! "what the algorithm did" (measured) separate from "what the platform
//! would take" (modelled), so the same run feeds both the functional
//! results and the Fig. 2/3 timing reproductions.

pub mod bounds;
pub mod elkan;
pub mod filtering;
pub mod init;
pub mod lloyd;
pub mod metrics;
pub mod model;
pub mod panel;
pub mod predict;
pub mod remote;
pub mod shard;
pub mod solver;
pub mod twolevel;

pub use bounds::{BoundsMode, BoundsStats};
pub use metrics::Metric;
pub use model::{KmeansModel, TrainStats, MODEL_FORMAT_VERSION};
pub use predict::Predictor;
pub use remote::{RemoteShardPool, RemoteWorker};
pub use shard::{Partition, ShardExecutor, ShardPartial, ShardPlan};
pub use solver::{Algo, IterEvent, IterFlow, IterObserver, KmeansSpec, Solver, SolverCtx};

use crate::data::Dataset;

/// Which stage of a (possibly multi-phase) solve an iteration belongs to.
/// Single-level algorithms only ever report [`Phase::Main`]; the two-level
/// scheme reports one [`Phase::Level1`] stream per quarter and a
/// [`Phase::Level2`] stream for the full-dataset refinement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// The single iteration loop of Lloyd/Elkan/filtering.
    Main,
    /// Per-quarter level-1 clustering of the two-level scheme.
    Level1 { quarter: usize },
    /// The full-dataset level-2 refinement of the two-level scheme.
    Level2,
}

/// Low-level per-iteration hook threaded through the engine loops:
/// `(iteration index, that iteration's stats, post-update centroids)` →
/// `true` to continue, `false` to stop early.  The [`solver`] layer adapts
/// an [`IterObserver`] onto this; engines never see observers directly.
pub type IterHook<'h> = &'h mut dyn FnMut(usize, &IterStats, &Dataset) -> bool;

/// [`IterHook`] with a phase tag, for the multi-phase two-level engine.
pub type PhasedHook<'h> = &'h mut dyn FnMut(Phase, usize, &IterStats, &Dataset) -> bool;

/// Work performed at one kd-tree depth during a filtering pass — the
/// level-batched offload ships one distance-panel batch per level, and the
/// BRAM/FIFO model sizes transfers from these histograms (paper section 4.2
/// sizes its bridge "for each level of tree traversal ... separately").
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LevelWork {
    /// Interior-node visits at this depth (one midpoint job each).
    pub interior_jobs: u64,
    /// Leaf point jobs at this depth.
    pub leaf_jobs: u64,
    /// Total candidate distance evaluations across the level's jobs.
    pub cand_evals: u64,
    /// `is_farther` pruning tests at this depth (each costs a pair of
    /// point-to-vertex distance evaluations — floating-point work the
    /// paper's PL performs, like all other distance arithmetic).
    pub prune_tests: u64,
}

impl LevelWork {
    pub fn absorb(&mut self, other: &LevelWork) {
        self.interior_jobs += other.interior_jobs;
        self.leaf_jobs += other.leaf_jobs;
        self.cand_evals += other.cand_evals;
        self.prune_tests += other.prune_tests;
    }
}

/// Work performed in one clustering iteration — the currency the hardware
/// cost models consume.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct IterStats {
    /// Point-to-centroid distance evaluations (each `D` subtract/abs/mul +
    /// accumulate chains) — the PL-offloadable arithmetic.
    pub dist_evals: u64,
    /// kd-tree nodes visited (pointer/bookkeeping work on the PS).
    pub node_visits: u64,
    /// Points handled individually at leaves.
    pub leaf_points: u64,
    /// Points assigned wholesale via a pruned-to-one-candidate subtree.
    pub interior_assigns: u64,
    /// `is_farther` pruning tests evaluated (PS comparator work).
    pub prune_tests: u64,
    /// Max squared centroid movement this iteration (convergence measure).
    pub moved: f32,
    /// Exact objective value if the solver computed one this iteration.
    pub cost: Option<f64>,
    /// Per-tree-depth work histogram (tree-based solvers only; empty for
    /// Lloyd/Elkan).
    pub levels: Vec<LevelWork>,
}

impl IterStats {
    /// Merge counters from a parallel worker.
    pub fn absorb(&mut self, other: &IterStats) {
        self.dist_evals += other.dist_evals;
        self.node_visits += other.node_visits;
        self.leaf_points += other.leaf_points;
        self.interior_assigns += other.interior_assigns;
        self.prune_tests += other.prune_tests;
        self.moved = self.moved.max(other.moved);
        self.cost = match (self.cost, other.cost) {
            (Some(a), Some(b)) => Some(a + b),
            (a, b) => a.or(b),
        };
        if self.levels.len() < other.levels.len() {
            self.levels.resize(other.levels.len(), LevelWork::default());
        }
        for (mine, theirs) in self.levels.iter_mut().zip(other.levels.iter()) {
            mine.absorb(theirs);
        }
    }
}

/// Full-run statistics.
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    pub iters: Vec<IterStats>,
    pub converged: bool,
    /// An [`IterObserver`] (or raw hook) requested a stop before the
    /// convergence test fired; mutually exclusive with `converged`.
    pub early_stopped: bool,
    /// f32 lanes per vector op of the panel backend's kernel tier
    /// (8 = AVX2, 4 = NEON, 0 = scalar/blocked).  Local-process telemetry;
    /// not carried on the remote wire (decodes as 0).
    pub simd_lanes: u32,
    /// Candidates scored through the reduced-precision (i8) shortlist
    /// path during this run.  Local-process telemetry, like `simd_lanes`.
    pub quantized_candidates: u64,
    /// Quantized candidates re-scored in exact f32 (shortlist survivors).
    pub rescored_candidates: u64,
    /// Leaf panel jobs dropped outright by the triangle-inequality bounds
    /// (DESIGN.md §10) — the incumbent center provably still won.
    /// Local-process telemetry; not carried on the remote wire (decodes
    /// as 0).
    pub bound_pruned_points: u64,
    /// Candidate entries removed from surviving leaf jobs by the bounds'
    /// center-center test.  Local-process telemetry, like
    /// `bound_pruned_points`.
    pub bound_pruned_candidates: u64,
    /// Scalar true-distance evaluations spent maintaining the bounds (the
    /// k×k matrix, per-center shifts, on-demand tightenings) — the cost
    /// side of the pruning ledger.  Local-process telemetry.
    pub bounds_matrix_cost: u64,
}

impl RunStats {
    pub fn iterations(&self) -> usize {
        self.iters.len()
    }

    pub fn total_dist_evals(&self) -> u64 {
        self.iters.iter().map(|i| i.dist_evals).sum()
    }

    pub fn total_node_visits(&self) -> u64 {
        self.iters.iter().map(|i| i.node_visits).sum()
    }

    /// Total `is_farther` pruning tests across the run (tree solvers only;
    /// zero for Lloyd/Elkan) — the PS comparator work the hw cost models
    /// charge.
    pub fn total_prune_tests(&self) -> u64 {
        self.iters.iter().map(|i| i.prune_tests).sum()
    }

    /// Total points handled individually at leaves across the run.
    pub fn total_leaf_points(&self) -> u64 {
        self.iters.iter().map(|i| i.leaf_points).sum()
    }

    /// Total points assigned wholesale at pruned interior nodes.
    pub fn total_interior_assigns(&self) -> u64 {
        self.iters.iter().map(|i| i.interior_assigns).sum()
    }
}

/// Extra outputs of the two-level scheme, attached to its [`KmeansResult`]
/// (the result's own `stats` are the level-2 refinement's).  Replaces the
/// old parallel `TwoLevelResult` type: every solver now returns the same
/// result shape, multi-phase solvers just carry more in `ext`.
///
/// Since the shard-plane refactor these vectors are per-*shard* with
/// length P ([`KmeansSpec::shards`](solver::KmeansSpec)); the field names
/// keep the paper's P = 4 "quarter" vocabulary.
#[derive(Clone, Debug)]
pub struct TwoLevelExt {
    /// Per-shard level-1 statistics (these ran independently).
    pub level1_stats: Vec<RunStats>,
    /// Row count of each shard.
    pub quarter_sizes: Vec<usize>,
    /// The merged (post-`Combine`) centroids that seeded level 2.
    pub merged_centroids: Dataset,
}

/// Solver-specific extensions riding on a [`KmeansResult`].
#[derive(Clone, Debug, Default)]
pub struct ResultExt {
    /// Present when the result came from the two-level scheme.
    pub two_level: Option<Box<TwoLevelExt>>,
}

/// Result of a clustering run.
#[derive(Clone, Debug)]
pub struct KmeansResult {
    /// Final centroids, `[k, d]`.
    pub centroids: Dataset,
    /// Final assignment of every point to a centroid index.
    pub assignments: Vec<u32>,
    pub stats: RunStats,
    /// Solver-specific extensions (empty for single-level solvers).
    pub ext: ResultExt,
}

impl KmeansResult {
    /// Exact k-means objective (sum over points of distance to assigned
    /// centroid) — used by tests to compare solvers.
    pub fn objective(&self, data: &Dataset, metric: Metric) -> f64 {
        let mut acc = 0f64;
        for (i, p) in data.iter().enumerate() {
            let c = self.centroids.point(self.assignments[i] as usize);
            acc += metric.dist(p, c) as f64;
        }
        acc
    }

    /// Cluster sizes.
    pub fn sizes(&self) -> Vec<usize> {
        let mut s = vec![0usize; self.centroids.len()];
        for &a in &self.assignments {
            s[a as usize] += 1;
        }
        s
    }
}

/// Convergence test shared by all solvers: max squared centroid movement
/// (in squared-L2, regardless of assignment metric) below `tol`.
pub(crate) fn max_sq_movement(old: &Dataset, new: &Dataset) -> f32 {
    debug_assert_eq!(old.len(), new.len());
    let mut worst = 0f32;
    for i in 0..old.len() {
        let m = metrics::sq_l2(old.point(i), new.point(i));
        if m > worst {
            worst = m;
        }
    }
    worst
}

/// Recompute centroids from per-cluster sums/counts, keeping the previous
/// centroid for empty clusters (the standard Lloyd rule; the paper's
/// updater does the same — an empty cluster's register bank is not
/// refreshed).
pub(crate) fn centroids_from_sums(
    sums: &[f32],
    counts: &[u32],
    prev: &Dataset,
) -> Dataset {
    let k = prev.len();
    let d = prev.dims();
    debug_assert_eq!(sums.len(), k * d);
    let mut out = Vec::with_capacity(k * d);
    for c in 0..k {
        if counts[c] == 0 {
            out.extend_from_slice(prev.point(c));
        } else {
            let inv = 1.0 / counts[c] as f32;
            out.extend(sums[c * d..(c + 1) * d].iter().map(|&s| s * inv));
        }
    }
    Dataset::from_flat(k, d, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iterstats_absorb_merges() {
        let mut a = IterStats {
            dist_evals: 10,
            node_visits: 5,
            moved: 0.5,
            cost: Some(1.0),
            ..Default::default()
        };
        let b = IterStats {
            dist_evals: 7,
            node_visits: 2,
            moved: 0.9,
            cost: Some(2.5),
            leaf_points: 3,
            ..Default::default()
        };
        a.absorb(&b);
        assert_eq!(a.dist_evals, 17);
        assert_eq!(a.node_visits, 7);
        assert_eq!(a.leaf_points, 3);
        assert_eq!(a.moved, 0.9);
        assert_eq!(a.cost, Some(3.5));
    }

    #[test]
    fn centroids_from_sums_handles_empty_clusters() {
        let prev = Dataset::from_flat(2, 2, vec![1.0, 1.0, 9.0, 9.0]);
        let sums = vec![4.0, 6.0, 0.0, 0.0];
        let counts = vec![2, 0];
        let next = centroids_from_sums(&sums, &counts, &prev);
        assert_eq!(next.point(0), &[2.0, 3.0]);
        assert_eq!(next.point(1), &[9.0, 9.0]); // kept
    }

    #[test]
    fn movement_metric() {
        let a = Dataset::from_flat(2, 2, vec![0.0, 0.0, 1.0, 1.0]);
        let b = Dataset::from_flat(2, 2, vec![0.0, 1.0, 1.0, 1.0]);
        assert_eq!(max_sq_movement(&a, &b), 1.0);
        assert_eq!(max_sq_movement(&a, &a), 0.0);
    }
}
