//! The paper's contribution: two-level k-clustering over 4 parallel
//! kd-trees (Alg. 2).
//!
//! Level 1 — `Quarter`: the dataset is split four ways.  Two strategies:
//!
//! - [`Partition::RoundRobin`] (default): rows are dealt out modulo 4, so
//!   each quarter is an i.i.d. sample of the full distribution.  The
//!   paper's `Combine` step ("combine a cluster in each sub-group with
//!   three clusters in other sub-groups with the nearest centroids") is
//!   statistically consistent under this split: the four per-quarter
//!   centroid sets are four noisy estimates of the *same* k centers, and
//!   nearest-matching + count-weighted averaging de-noises them — which is
//!   what makes the paper's "level 2 converges in very few iterations"
//!   claim hold.
//! - [`Partition::KdTop`]: the four grandchild subtrees of the full
//!   kd-tree root (the paper's "dividing the original data-set ... at the
//!   top of the kd-tree" reading).  Spatially coherent quarters make the
//!   *level-1* trees cheaper, but per-quarter centroids then describe
//!   different regions, so the merge is a weaker seed.  Kept as an
//!   ablation (`bench ablate_partition` quantifies the gap).
//!
//! Each quarter gets its own kd-tree and an independent k-cluster
//! filtering run (on one Cortex-A53 core each, in the real system).
//!
//! Merge — `Combine`: the 4×k level-1 centroids are merged back to k by
//! greedy nearest-centroid matching across quarters (one cluster from each
//! quarter per group), count-weighted averaging, exactly the
//! "combine ... with the nearest centroids ... then update" step the paper
//! describes.
//!
//! Level 2: a short filtering run over the *full* dataset tree seeded with
//! the merged centroids — "the second level ... has initial values that
//! are considerably close to the final result", so it converges in a few
//! iterations.
//!
//! This module is the *sequential reference*; `coordinator::` runs the same
//! phases across real worker threads with the PL offload.  Both call the
//! same building blocks so they cannot drift.

use super::filtering::{self, FilterOpts};
use super::init::{init_centroids, Init};
use super::panel::PanelBackend;
use super::{
    IterHook, IterStats, KmeansResult, Metric, Phase, PhasedHook, RunStats, TwoLevelExt,
};
use crate::data::Dataset;
use crate::kdtree::KdTree;

/// Number of level-1 partitions — 4 in the paper (one per Cortex-A53).
pub const QUARTERS: usize = 4;

/// How `Quarter` splits the dataset (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Partition {
    /// Deal rows out modulo 4 (i.i.d. quarters; default).
    RoundRobin,
    /// The four depth-2 subtrees of the full kd-tree (spatial quarters).
    KdTop,
}

impl Partition {
    /// Canonical name (round-trips through [`FromStr`](std::str::FromStr)
    /// — the model artifact serializes specs by these names).
    pub fn name(self) -> &'static str {
        match self {
            Partition::RoundRobin => "round-robin",
            Partition::KdTop => "kd-top",
        }
    }
}

impl std::str::FromStr for Partition {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "round-robin" | "roundrobin" => Ok(Partition::RoundRobin),
            "kd-top" | "kdtop" => Ok(Partition::KdTop),
            other => anyhow::bail!("unknown partition `{other}` (round-robin|kd-top)"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TwoLevelOpts {
    pub metric: Metric,
    pub tol: f32,
    /// Iteration cap for each level-1 run.
    pub level1_max_iters: usize,
    /// Iteration cap for the level-2 refinement.
    pub level2_max_iters: usize,
    pub init: Init,
    pub partition: Partition,
    pub seed: u64,
}

impl Default for TwoLevelOpts {
    fn default() -> Self {
        Self {
            metric: Metric::Euclid,
            tol: 1e-6,
            level1_max_iters: 100,
            level2_max_iters: 100,
            init: Init::UniformSample,
            partition: Partition::RoundRobin,
            seed: 1,
        }
    }
}

/// `Quarter` (round-robin): deal rows out modulo `QUARTERS`.
pub fn quarter_round_robin(data: &Dataset) -> (Vec<Dataset>, Vec<Vec<u32>>) {
    let mut ids: Vec<Vec<u32>> = vec![Vec::with_capacity(data.len() / QUARTERS + 1); QUARTERS];
    for i in 0..data.len() {
        ids[i % QUARTERS].push(i as u32);
    }
    let datasets = ids
        .iter()
        .map(|rows| {
            let rows_usize: Vec<usize> = rows.iter().map(|&r| r as usize).collect();
            data.gather(&rows_usize)
        })
        .collect();
    (datasets, ids)
}

/// `Quarter` (kd-top): the dataset split into `QUARTERS` spatially-coherent
/// parts using the top of a kd-tree.  Returns per-quarter datasets and
/// the original row index of every quartered row.
pub fn quarter(data: &Dataset, tree: &KdTree) -> (Vec<Dataset>, Vec<Vec<u32>>) {
    // The 4 subtrees two levels below the root; if the tree is too shallow
    // (tiny or degenerate data), fall back to contiguous ranges.
    let mut fronts: Vec<u32> = vec![0];
    for _ in 0..2 {
        let mut next = Vec::with_capacity(fronts.len() * 2);
        for &ni in &fronts {
            let n = &tree.nodes[ni as usize];
            if n.is_leaf() {
                next.push(ni);
            } else {
                next.push(n.left);
                next.push(n.right);
            }
        }
        fronts = next;
    }

    if fronts.len() < QUARTERS {
        // Degenerate: pad by splitting contiguous ranges instead.
        let (parts, offsets) = data.split_contiguous(QUARTERS);
        let ids = offsets
            .iter()
            .zip(parts.iter())
            .map(|(&o, p)| (o as u32..(o + p.len()) as u32).collect())
            .collect();
        return (parts, ids);
    }

    let mut datasets = Vec::with_capacity(QUARTERS);
    let mut ids = Vec::with_capacity(QUARTERS);
    for &ni in fronts.iter().take(QUARTERS) {
        let node = &tree.nodes[ni as usize];
        let rows: Vec<u32> = tree.node_points(node).to_vec();
        let rows_usize: Vec<usize> = rows.iter().map(|&r| r as usize).collect();
        datasets.push(data.gather(&rows_usize));
        ids.push(rows);
    }
    (datasets, ids)
}

/// `Combine`: merge `QUARTERS` sets of k centroids down to k by greedy
/// nearest matching (quarter 0's centroids anchor the groups) with
/// count-weighted averaging.
pub fn combine(
    centroids: &[Dataset],
    counts: &[Vec<usize>],
    metric: Metric,
) -> Dataset {
    let q = centroids.len();
    assert!(q >= 1);
    let k = centroids[0].len();
    let d = centroids[0].dims();
    assert!(counts.iter().zip(centroids).all(|(c, ds)| c.len() == ds.len()));

    let mut out = Vec::with_capacity(k * d);
    // Used-markers per non-anchor quarter.
    let mut used: Vec<Vec<bool>> = centroids.iter().map(|c| vec![false; c.len()]).collect();

    for a in 0..k {
        let anchor = centroids[0].point(a);
        let mut wsum: Vec<f64> = anchor
            .iter()
            .map(|&v| v as f64 * counts[0][a] as f64)
            .collect();
        let mut wtot = counts[0][a] as f64;
        for qi in 1..q {
            // Nearest unused centroid of quarter qi to the anchor.
            let mut best: Option<(usize, f32)> = None;
            for c in 0..centroids[qi].len() {
                if used[qi][c] {
                    continue;
                }
                let dd = metric.dist(anchor, centroids[qi].point(c));
                if best.map_or(true, |(_, bd)| dd < bd) {
                    best = Some((c, dd));
                }
            }
            if let Some((c, _)) = best {
                used[qi][c] = true;
                let w = counts[qi][c] as f64;
                for (j, &v) in centroids[qi].point(c).iter().enumerate() {
                    wsum[j] += v as f64 * w;
                }
                wtot += w;
            }
        }
        if wtot <= 0.0 {
            out.extend_from_slice(anchor);
        } else {
            out.extend(wsum.iter().map(|&v| (v / wtot) as f32));
        }
    }
    Dataset::from_flat(k, d, out)
}

/// One filtering phase of the two-level scheme: recursive engine when no
/// backend is injected, level-batched through `backend` otherwise, with
/// the phased hook narrowed to the engine's plain per-iteration hook.
/// Generic over backend and hook so callers reborrow plain `Option::as_mut`
/// references between phases (`&mut dyn …` implements both traits).
fn run_phase<B, H>(
    data: &Dataset,
    tree: &KdTree,
    init: &Dataset,
    fopts: &FilterOpts,
    backend: Option<&mut B>,
    phase: Phase,
    hook: Option<&mut H>,
) -> KmeansResult
where
    B: PanelBackend,
    H: FnMut(Phase, usize, &IterStats, &Dataset) -> bool,
{
    let mut sub;
    let h: Option<IterHook<'_>> = match hook {
        Some(ph) => {
            sub = move |i: usize, st: &IterStats, c: &Dataset| ph(phase, i, st, c);
            Some(&mut sub)
        }
        None => None,
    };
    match backend {
        Some(b) => filtering::run_batched_hooked(data, tree, init, fopts, b, h),
        None => filtering::run_hooked(data, tree, init, fopts, h),
    }
}

/// Run the full two-level algorithm (sequential reference).  The extra
/// outputs (per-quarter stats, merged seed, quarter sizes) ride on the
/// result's [`TwoLevelExt`] extension; the result's own `stats` are the
/// level-2 refinement's.
pub fn run(data: &Dataset, k: usize, opts: &TwoLevelOpts) -> KmeansResult {
    run_ext(data, k, opts, None, None, None)
}

/// [`run`] with the unified-solver substrate injected: an optional
/// pre-built full-dataset kd-tree (avoids a rebuild when the caller's
/// `SolverCtx` already cached one), an optional panel backend (switches
/// every filtering phase to the level-batched engine — the HW/SW split),
/// and an optional phased per-iteration hook.
pub fn run_ext(
    data: &Dataset,
    k: usize,
    opts: &TwoLevelOpts,
    full_tree: Option<&KdTree>,
    mut backend: Option<&mut dyn PanelBackend>,
    mut hook: Option<PhasedHook<'_>>,
) -> KmeansResult {
    assert!(k >= 1 && k <= data.len());
    let built;
    let full_tree = match full_tree {
        Some(t) => t,
        None => {
            built = KdTree::build(data);
            &built
        }
    };
    let (quarters, _ids) = match opts.partition {
        Partition::RoundRobin => quarter_round_robin(data),
        Partition::KdTop => quarter(data, full_tree),
    };
    let quarter_sizes: Vec<usize> = quarters.iter().map(|q| q.len()).collect();
    let fopts_l2 = FilterOpts {
        metric: opts.metric,
        tol: opts.tol,
        max_iters: opts.level2_max_iters,
    };

    // Tiny-data guard: if any quarter can't host k clusters, the two-level
    // scheme degenerates to a plain filtering run (the paper's regime is
    // always n >> 4k).
    if quarters.iter().any(|q| q.len() < k) {
        let init = init_centroids(data, k, opts.init, opts.metric, opts.seed);
        let mut result = run_phase(
            data,
            full_tree,
            &init,
            &fopts_l2,
            backend.as_mut(),
            Phase::Level2,
            hook.as_mut(),
        );
        let merged = result.centroids.clone();
        result.ext.two_level = Some(Box::new(TwoLevelExt {
            level1_stats: vec![RunStats::default(); QUARTERS],
            quarter_sizes,
            merged_centroids: merged,
        }));
        return result;
    }

    // ---- Level 1: independent k-clustering per quarter -------------------
    let fopts = FilterOpts {
        metric: opts.metric,
        tol: opts.tol,
        max_iters: opts.level1_max_iters,
    };
    let mut l1_centroids: Vec<Dataset> = Vec::with_capacity(QUARTERS);
    let mut l1_counts: Vec<Vec<usize>> = Vec::with_capacity(QUARTERS);
    let mut level1_stats = Vec::with_capacity(QUARTERS);
    for (qi, qdata) in quarters.iter().enumerate() {
        let tree = KdTree::build(qdata);
        let init = init_centroids(
            qdata,
            k,
            opts.init,
            opts.metric,
            opts.seed ^ (qi as u64).wrapping_mul(0x9E37_79B9),
        );
        let r = run_phase(
            qdata,
            &tree,
            &init,
            &fopts,
            backend.as_mut(),
            Phase::Level1 { quarter: qi },
            hook.as_mut(),
        );
        l1_counts.push(r.sizes());
        l1_centroids.push(r.centroids);
        level1_stats.push(r.stats);
    }

    // ---- Combine ----------------------------------------------------------
    let merged = combine(&l1_centroids, &l1_counts, opts.metric);

    // ---- Level 2: refine over the full dataset ----------------------------
    let mut result = run_phase(
        data,
        full_tree,
        &merged,
        &fopts_l2,
        backend.as_mut(),
        Phase::Level2,
        hook.as_mut(),
    );
    result.ext.two_level = Some(Box::new(TwoLevelExt {
        level1_stats,
        quarter_sizes,
        merged_centroids: merged,
    }));
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::generate_params;
    use crate::kmeans::lloyd::{self, LloydOpts};

    #[test]
    fn quarter_partitions_everything() {
        let s = generate_params(1000, 3, 4, 0.3, 1.0, 11);
        let tree = KdTree::build(&s.data);
        let (parts, ids) = quarter(&s.data, &tree);
        assert_eq!(parts.len(), QUARTERS);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 1000);
        // ids form a partition of 0..n
        let mut all: Vec<u32> = ids.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..1000).collect::<Vec<u32>>());
        // gathered rows match original data
        for (p, id) in parts.iter().zip(ids.iter()) {
            for (row, &orig) in id.iter().enumerate() {
                assert_eq!(p.point(row), s.data.point(orig as usize));
            }
        }
        // Quarters are spatially coherent: each has a smaller bbox extent
        // than the full data on the first split dimension.
        let (full_min, full_max) = s.data.bounds();
        let full_ext: f32 = full_min
            .iter()
            .zip(&full_max)
            .map(|(a, b)| b - a)
            .fold(0.0, f32::max);
        let mut smaller = 0;
        for p in &parts {
            let (mn, mx) = p.bounds();
            let ext: f32 = mn.iter().zip(&mx).map(|(a, b)| b - a).fold(0.0, f32::max);
            if ext < full_ext * 0.95 {
                smaller += 1;
            }
        }
        assert!(smaller >= 2, "kd-quartering should shrink extents");
    }

    #[test]
    fn quarter_degenerate_small_data() {
        let s = generate_params(3, 2, 1, 0.1, 1.0, 1);
        let tree = KdTree::build(&s.data);
        let (parts, ids) = quarter(&s.data, &tree);
        assert_eq!(parts.len(), QUARTERS);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 3);
        let mut all: Vec<u32> = ids.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2]);
    }

    #[test]
    fn combine_weighted_average() {
        // Two quarters, k=2, trivially matched.
        let c0 = Dataset::from_flat(2, 1, vec![0.0, 10.0]);
        let c1 = Dataset::from_flat(2, 1, vec![2.0, 12.0]);
        let merged = combine(
            &[c0, c1],
            &[vec![1, 3], vec![3, 1]],
            Metric::Euclid,
        );
        // group 0: (0*1 + 2*3)/4 = 1.5 ; group 1: (10*3 + 12*1)/4 = 10.5
        assert_eq!(merged.point(0), &[1.5]);
        assert_eq!(merged.point(1), &[10.5]);
    }

    #[test]
    fn combine_uses_each_centroid_once() {
        // Quarter 1 has both centroids near anchor 0; greedy must not
        // assign the same one twice.
        let c0 = Dataset::from_flat(2, 1, vec![0.0, 1.0]);
        let c1 = Dataset::from_flat(2, 1, vec![0.1, 0.2]);
        let merged = combine(&[c0, c1], &[vec![1, 1], vec![1, 1]], Metric::Euclid);
        // anchor 0 takes 0.1; anchor 1 must take 0.2 (not 0.1 again).
        assert_eq!(merged.point(0), &[0.05]);
        assert_eq!(merged.point(1), &[0.6]);
    }

    #[test]
    fn two_level_recovers_planted_clusters() {
        let s = generate_params(4000, 3, 6, 0.05, 5.0, 17);
        // k-means++ seeding per quarter: uniform seeding can hit a local
        // optimum that misses a planted cluster (true of any Lloyd
        // variant, not a two-level artifact).
        let r = run(
            &s.data,
            6,
            &TwoLevelOpts { seed: 3, init: Init::KmeansPlusPlus, ..Default::default() },
        );
        assert!(r.stats.converged);
        let ext = r.ext.two_level.as_ref().expect("two-level ext attached");
        assert_eq!(ext.quarter_sizes.iter().sum::<usize>(), 4000);
        assert!(ext.level1_stats.iter().all(|s| s.iterations() > 0));
        // Every planted center has a recovered centroid nearby.
        for t in s.true_centroids.iter() {
            let best = r
                .centroids
                .iter()
                .map(|c| Metric::Euclid.dist(c, t))
                .fold(f32::INFINITY, f32::min);
            assert!(best < 0.1, "planted center {t:?} missed (best {best})");
        }
    }

    #[test]
    fn level2_converges_faster_than_cold_start() {
        // The paper's claim: level-2 starts near the answer, so it needs
        // (much) fewer iterations than clustering from scratch.  Judged
        // over several seeds since k-means iteration counts are noisy.
        let mut l2_total = 0usize;
        let mut cold_total = 0usize;
        for seed in [5u64, 6, 7, 8, 9] {
            let s = generate_params(6000, 4, 8, 0.1, 3.0, seed * 13 + 1);
            let r = run(&s.data, 8, &TwoLevelOpts { seed, ..Default::default() });
            let cold_init =
                init_centroids(&s.data, 8, Init::UniformSample, Metric::Euclid, seed);
            let cold = lloyd::run(
                &s.data,
                &cold_init,
                &LloydOpts { tol: 1e-6, max_iters: 100, ..Default::default() },
            );
            l2_total += r.stats.iterations();
            cold_total += cold.stats.iterations();
        }
        assert!(
            l2_total < cold_total,
            "level2 {l2_total} total iters vs cold {cold_total}"
        );
    }

    #[test]
    fn two_level_objective_close_to_lloyd() {
        let s = generate_params(3000, 3, 5, 0.2, 2.0, 29);
        let r = run(&s.data, 5, &TwoLevelOpts { seed: 7, ..Default::default() });
        let init = init_centroids(&s.data, 5, Init::KmeansPlusPlus, Metric::Euclid, 7);
        let l = lloyd::run(&s.data, &init, &LloydOpts::default());
        let obj_t = r.objective(&s.data, Metric::Euclid);
        let obj_l = l.objective(&s.data, Metric::Euclid);
        // Same ballpark (k-means is non-convex; both are local optima).
        assert!(
            obj_t <= obj_l * 1.5,
            "two-level objective {obj_t} far worse than lloyd {obj_l}"
        );
    }

    #[test]
    fn tiny_dataset_falls_back() {
        let s = generate_params(10, 2, 2, 0.1, 1.0, 31);
        let r = run(&s.data, 5, &TwoLevelOpts::default());
        assert_eq!(r.centroids.len(), 5);
        assert_eq!(r.assignments.len(), 10);
        // Fallback leaves level-1 stats empty.
        let ext = r.ext.two_level.as_ref().unwrap();
        assert!(ext.level1_stats.iter().all(|s| s.iterations() == 0));
    }
}
