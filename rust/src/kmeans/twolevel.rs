//! The paper's contribution: two-level k-clustering over P parallel
//! kd-trees (Alg. 2; P = 4 in the paper — one per ZCU102 Cortex-A53).
//!
//! Since the shard-plane refactor this module is a *thin layer over
//! [`super::shard`]*: partitioning is a [`ShardPlan`], the merge is
//! [`shard::combine_hierarchical`], and what remains here is the phase
//! sequencing (level 1 → combine → level 2) plus the legacy 4-way entry
//! points kept as the sequential paper reference.
//!
//! Level 1 — the dataset is split P ways ([`Partition`] strategies; see
//! `shard` module docs for the statistics of each).  Each shard gets its
//! own kd-tree and an independent k-cluster filtering run (on one
//! Cortex-A53 core each, in the real system).
//!
//! Merge — the P×k level-1 centroids are tree-reduced back to k by the
//! count-weighted nearest-centroid merge, exactly the "combine ... with
//! the nearest centroids ... then update" step the paper describes (flat
//! for P ≤ 4, hierarchical above).
//!
//! Level 2: a short filtering run over the *full* dataset tree seeded with
//! the merged centroids — "the second level ... has initial values that
//! are considerably close to the final result", so it converges in a few
//! iterations.
//!
//! This module is the *sequential reference*; `coordinator::` runs the same
//! phases across real worker threads with the PL offload.  Both call the
//! same shard-plane building blocks so they cannot drift.
//!
//! **Deprecated (docs-level):** the fixed 4-way free functions
//! [`quarter`], [`quarter_round_robin`] and [`combine`] survive only as
//! P = 4 wrappers for the paper reference and old call sites; new code
//! should use [`ShardPlan::build`] and [`shard::combine_hierarchical`]
//! directly, or set [`KmeansSpec::shards`](super::solver::KmeansSpec)
//! on the unified solver.

use super::filtering::{self, FilterOpts};
use super::init::{init_centroids, Init};
use super::panel::PanelBackend;
use super::shard::{self, ShardPlan};
use super::{
    IterHook, IterStats, KmeansResult, Metric, Phase, PhasedHook, RunStats, TwoLevelExt,
};
use crate::data::Dataset;
use crate::kdtree::KdTree;

pub use super::shard::Partition;

/// Number of level-1 partitions in the paper's configuration — 4 (one per
/// Cortex-A53).  Legacy alias of [`shard::DEFAULT_SHARDS`]; the general
/// P-way machinery lives in [`super::shard`].
pub const QUARTERS: usize = shard::DEFAULT_SHARDS;

#[derive(Clone, Debug)]
pub struct TwoLevelOpts {
    pub metric: Metric,
    pub tol: f32,
    /// Iteration cap for each level-1 run.
    pub level1_max_iters: usize,
    /// Iteration cap for the level-2 refinement.
    pub level2_max_iters: usize,
    pub init: Init,
    pub partition: Partition,
    pub seed: u64,
    /// Level-1 partition count P (the paper's 4; any P ≥ 1 works — see
    /// [`super::shard`]).
    pub shards: usize,
}

impl Default for TwoLevelOpts {
    fn default() -> Self {
        Self {
            metric: Metric::Euclid,
            tol: 1e-6,
            level1_max_iters: 100,
            level2_max_iters: 100,
            init: Init::UniformSample,
            partition: Partition::RoundRobin,
            seed: 1,
            shards: QUARTERS,
        }
    }
}

/// `Quarter` (round-robin): deal rows out modulo [`QUARTERS`].
/// Legacy 4-way wrapper over [`shard::plan_round_robin`].
pub fn quarter_round_robin(data: &Dataset) -> (Vec<Dataset>, Vec<Vec<u32>>) {
    shard::plan_round_robin(data, QUARTERS)
}

/// `Quarter` (kd-top): the dataset split into [`QUARTERS`]
/// spatially-coherent parts using the top of a kd-tree.  Returns
/// per-quarter datasets and the original row index of every quartered
/// row.  Legacy 4-way wrapper over [`shard::plan_kd_frontier`].
pub fn quarter(data: &Dataset, tree: &KdTree) -> (Vec<Dataset>, Vec<Vec<u32>>) {
    shard::plan_kd_frontier(data, tree, QUARTERS)
}

/// `Combine`: merge P sets of k centroids down to k by greedy nearest
/// matching (set 0's centroids anchor the groups) with count-weighted
/// averaging.  Legacy wrapper over the shard plane's
/// [`shard::combine_level`] (one flat pass — what the paper describes for
/// its four quarters); the P-way production path is
/// [`shard::combine_hierarchical`].
pub fn combine(centroids: &[Dataset], counts: &[Vec<usize>], metric: Metric) -> Dataset {
    shard::combine_level(centroids, counts, metric).0
}

/// One filtering phase of the two-level scheme: recursive engine when no
/// backend is injected, level-batched through `backend` otherwise, with
/// the phased hook narrowed to the engine's plain per-iteration hook.
/// Generic over backend and hook so callers reborrow plain `Option::as_mut`
/// references between phases (`&mut dyn …` implements both traits).
fn run_phase<B, H>(
    data: &Dataset,
    tree: &KdTree,
    init: &Dataset,
    fopts: &FilterOpts,
    backend: Option<&mut B>,
    phase: Phase,
    hook: Option<&mut H>,
) -> KmeansResult
where
    B: PanelBackend,
    H: FnMut(Phase, usize, &IterStats, &Dataset) -> bool,
{
    let mut sub;
    let h: Option<IterHook<'_>> = match hook {
        Some(ph) => {
            sub = move |i: usize, st: &IterStats, c: &Dataset| ph(phase, i, st, c);
            Some(&mut sub)
        }
        None => None,
    };
    match backend {
        Some(b) => filtering::run_batched_hooked(data, tree, init, fopts, b, h),
        None => filtering::run_hooked(data, tree, init, fopts, h),
    }
}

/// Run the full two-level algorithm (sequential reference).  The extra
/// outputs (per-shard stats, merged seed, shard sizes) ride on the
/// result's [`TwoLevelExt`] extension; the result's own `stats` are the
/// level-2 refinement's.
pub fn run(data: &Dataset, k: usize, opts: &TwoLevelOpts) -> KmeansResult {
    run_ext(data, k, opts, None, None, None)
}

/// [`run`] with the unified-solver substrate injected: an optional
/// pre-built full-dataset kd-tree (avoids a rebuild when the caller's
/// `SolverCtx` already cached one), an optional panel backend (switches
/// every filtering phase to the level-batched engine — the HW/SW split),
/// and an optional phased per-iteration hook.
pub fn run_ext(
    data: &Dataset,
    k: usize,
    opts: &TwoLevelOpts,
    full_tree: Option<&KdTree>,
    mut backend: Option<&mut dyn PanelBackend>,
    mut hook: Option<PhasedHook<'_>>,
) -> KmeansResult {
    assert!(k >= 1 && k <= data.len());
    assert!(opts.shards >= 1, "shards must be >= 1");
    let built;
    let full_tree = match full_tree {
        Some(t) => t,
        None => {
            built = KdTree::build(data);
            &built
        }
    };
    let plan = ShardPlan::build(data, opts.shards, opts.partition, Some(full_tree));
    let shard_sizes = plan.sizes();
    let fopts_l2 = FilterOpts {
        metric: opts.metric,
        tol: opts.tol,
        max_iters: opts.level2_max_iters,
    };

    // Tiny-data guard: if any shard can't host k clusters, the two-level
    // scheme degenerates to a plain filtering run (the paper's regime is
    // always n >> P·k).
    if !plan.supports_k(k) {
        let init = init_centroids(data, k, opts.init, opts.metric, opts.seed);
        let mut result = run_phase(
            data,
            full_tree,
            &init,
            &fopts_l2,
            backend.as_mut(),
            Phase::Level2,
            hook.as_mut(),
        );
        let merged = result.centroids.clone();
        result.ext.two_level = Some(Box::new(TwoLevelExt {
            level1_stats: vec![RunStats::default(); plan.shards()],
            quarter_sizes: shard_sizes,
            merged_centroids: merged,
        }));
        return result;
    }

    // ---- Level 1: independent k-clustering per shard ---------------------
    let fopts = FilterOpts {
        metric: opts.metric,
        tol: opts.tol,
        max_iters: opts.level1_max_iters,
    };
    let mut l1_centroids: Vec<Dataset> = Vec::with_capacity(plan.shards());
    let mut l1_counts: Vec<Vec<usize>> = Vec::with_capacity(plan.shards());
    let mut level1_stats = Vec::with_capacity(plan.shards());
    for (qi, qdata) in plan.parts.iter().enumerate() {
        let tree = KdTree::build(qdata);
        let init = init_centroids(
            qdata,
            k,
            opts.init,
            opts.metric,
            shard::shard_seed(opts.seed, qi),
        );
        let r = run_phase(
            qdata,
            &tree,
            &init,
            &fopts,
            backend.as_mut(),
            Phase::Level1 { quarter: qi },
            hook.as_mut(),
        );
        l1_counts.push(r.sizes());
        l1_centroids.push(r.centroids);
        level1_stats.push(r.stats);
    }

    // ---- Combine: tree-reduce P×k centroids to k --------------------------
    let merged = shard::combine_hierarchical(&l1_centroids, &l1_counts, opts.metric);

    // ---- Level 2: refine over the full dataset ----------------------------
    let mut result = run_phase(
        data,
        full_tree,
        &merged,
        &fopts_l2,
        backend.as_mut(),
        Phase::Level2,
        hook.as_mut(),
    );
    result.ext.two_level = Some(Box::new(TwoLevelExt {
        level1_stats,
        quarter_sizes: shard_sizes,
        merged_centroids: merged,
    }));
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::generate_params;
    use crate::kmeans::lloyd::{self, LloydOpts};

    #[test]
    fn quarter_partitions_everything() {
        let s = generate_params(1000, 3, 4, 0.3, 1.0, 11);
        let tree = KdTree::build(&s.data);
        let (parts, ids) = quarter(&s.data, &tree);
        assert_eq!(parts.len(), QUARTERS);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 1000);
        // ids form a partition of 0..n
        let mut all: Vec<u32> = ids.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..1000).collect::<Vec<u32>>());
        // gathered rows match original data
        for (p, id) in parts.iter().zip(ids.iter()) {
            for (row, &orig) in id.iter().enumerate() {
                assert_eq!(p.point(row), s.data.point(orig as usize));
            }
        }
        // Quarters are spatially coherent: each has a smaller bbox extent
        // than the full data on the first split dimension.
        let (full_min, full_max) = s.data.bounds();
        let full_ext: f32 = full_min
            .iter()
            .zip(&full_max)
            .map(|(a, b)| b - a)
            .fold(0.0, f32::max);
        let mut smaller = 0;
        for p in &parts {
            let (mn, mx) = p.bounds();
            let ext: f32 = mn.iter().zip(&mx).map(|(a, b)| b - a).fold(0.0, f32::max);
            if ext < full_ext * 0.95 {
                smaller += 1;
            }
        }
        assert!(smaller >= 2, "kd-quartering should shrink extents");
    }

    #[test]
    fn quarter_degenerate_small_data() {
        let s = generate_params(3, 2, 1, 0.1, 1.0, 1);
        let tree = KdTree::build(&s.data);
        let (parts, ids) = quarter(&s.data, &tree);
        assert_eq!(parts.len(), QUARTERS);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 3);
        let mut all: Vec<u32> = ids.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2]);
    }

    #[test]
    fn combine_weighted_average() {
        // Two quarters, k=2, trivially matched.
        let c0 = Dataset::from_flat(2, 1, vec![0.0, 10.0]);
        let c1 = Dataset::from_flat(2, 1, vec![2.0, 12.0]);
        let merged = combine(
            &[c0, c1],
            &[vec![1, 3], vec![3, 1]],
            Metric::Euclid,
        );
        // group 0: (0*1 + 2*3)/4 = 1.5 ; group 1: (10*3 + 12*1)/4 = 10.5
        assert_eq!(merged.point(0), &[1.5]);
        assert_eq!(merged.point(1), &[10.5]);
    }

    #[test]
    fn combine_uses_each_centroid_once() {
        // Quarter 1 has both centroids near anchor 0; greedy must not
        // assign the same one twice.
        let c0 = Dataset::from_flat(2, 1, vec![0.0, 1.0]);
        let c1 = Dataset::from_flat(2, 1, vec![0.1, 0.2]);
        let merged = combine(&[c0, c1], &[vec![1, 1], vec![1, 1]], Metric::Euclid);
        // anchor 0 takes 0.1; anchor 1 must take 0.2 (not 0.1 again).
        assert_eq!(merged.point(0), &[0.05]);
        assert_eq!(merged.point(1), &[0.6]);
    }

    #[test]
    fn two_level_recovers_planted_clusters() {
        let s = generate_params(4000, 3, 6, 0.05, 5.0, 17);
        // k-means++ seeding per quarter: uniform seeding can hit a local
        // optimum that misses a planted cluster (true of any Lloyd
        // variant, not a two-level artifact).
        let r = run(
            &s.data,
            6,
            &TwoLevelOpts { seed: 3, init: Init::KmeansPlusPlus, ..Default::default() },
        );
        assert!(r.stats.converged);
        let ext = r.ext.two_level.as_ref().expect("two-level ext attached");
        assert_eq!(ext.quarter_sizes.iter().sum::<usize>(), 4000);
        assert!(ext.level1_stats.iter().all(|s| s.iterations() > 0));
        // Every planted center has a recovered centroid nearby.
        for t in s.true_centroids.iter() {
            let best = r
                .centroids
                .iter()
                .map(|c| Metric::Euclid.dist(c, t))
                .fold(f32::INFINITY, f32::min);
            assert!(best < 0.1, "planted center {t:?} missed (best {best})");
        }
    }

    #[test]
    fn level2_converges_faster_than_cold_start() {
        // The paper's claim: level-2 starts near the answer, so it needs
        // (much) fewer iterations than clustering from scratch.  Judged
        // over several seeds since k-means iteration counts are noisy.
        let mut l2_total = 0usize;
        let mut cold_total = 0usize;
        for seed in [5u64, 6, 7, 8, 9] {
            let s = generate_params(6000, 4, 8, 0.1, 3.0, seed * 13 + 1);
            let r = run(&s.data, 8, &TwoLevelOpts { seed, ..Default::default() });
            let cold_init =
                init_centroids(&s.data, 8, Init::UniformSample, Metric::Euclid, seed);
            let cold = lloyd::run(
                &s.data,
                &cold_init,
                &LloydOpts { tol: 1e-6, max_iters: 100, ..Default::default() },
            );
            l2_total += r.stats.iterations();
            cold_total += cold.stats.iterations();
        }
        assert!(
            l2_total < cold_total,
            "level2 {l2_total} total iters vs cold {cold_total}"
        );
    }

    #[test]
    fn two_level_objective_close_to_lloyd() {
        let s = generate_params(3000, 3, 5, 0.2, 2.0, 29);
        let r = run(&s.data, 5, &TwoLevelOpts { seed: 7, ..Default::default() });
        let init = init_centroids(&s.data, 5, Init::KmeansPlusPlus, Metric::Euclid, 7);
        let l = lloyd::run(&s.data, &init, &LloydOpts::default());
        let obj_t = r.objective(&s.data, Metric::Euclid);
        let obj_l = l.objective(&s.data, Metric::Euclid);
        // Same ballpark (k-means is non-convex; both are local optima).
        assert!(
            obj_t <= obj_l * 1.5,
            "two-level objective {obj_t} far worse than lloyd {obj_l}"
        );
    }

    #[test]
    fn tiny_dataset_falls_back() {
        let s = generate_params(10, 2, 2, 0.1, 1.0, 31);
        let r = run(&s.data, 5, &TwoLevelOpts::default());
        assert_eq!(r.centroids.len(), 5);
        assert_eq!(r.assignments.len(), 10);
        // Fallback leaves level-1 stats empty.
        let ext = r.ext.two_level.as_ref().unwrap();
        assert!(ext.level1_stats.iter().all(|s| s.iterations() == 0));
    }

    #[test]
    fn eight_shards_run_end_to_end() {
        let s = generate_params(4000, 3, 5, 0.15, 2.0, 23);
        for partition in [Partition::RoundRobin, Partition::KdTop, Partition::Contiguous] {
            let r = run(
                &s.data,
                5,
                &TwoLevelOpts { shards: 8, partition, seed: 4, ..Default::default() },
            );
            assert_eq!(r.assignments.len(), 4000);
            let ext = r.ext.two_level.as_ref().unwrap();
            assert_eq!(ext.level1_stats.len(), 8, "{partition:?}");
            assert_eq!(ext.quarter_sizes.len(), 8);
            assert_eq!(ext.quarter_sizes.iter().sum::<usize>(), 4000);
            assert!(ext.level1_stats.iter().all(|st| st.iterations() > 0));
        }
    }

    #[test]
    fn one_shard_degenerates_to_chained_filtering() {
        // P=1: level 1 clusters the full dataset, the "merge" of one set
        // is (numerically) itself, and level 2 polishes — so the outcome
        // must essentially match a plain filtering run with the same seed.
        let s = generate_params(2500, 3, 4, 0.2, 2.0, 19);
        let r = run(&s.data, 4, &TwoLevelOpts { shards: 1, seed: 6, ..Default::default() });
        let ext = r.ext.two_level.as_ref().unwrap();
        assert_eq!(ext.quarter_sizes, vec![2500]);
        assert_eq!(ext.level1_stats.len(), 1);
        let tree = KdTree::build(&s.data);
        let init = init_centroids(&s.data, 4, Init::UniformSample, Metric::Euclid, 6);
        let plain = filtering::run(&s.data, &tree, &init, &FilterOpts::default());
        let obj_r = r.objective(&s.data, Metric::Euclid);
        let obj_p = plain.objective(&s.data, Metric::Euclid);
        assert!(
            (obj_r - obj_p).abs() <= 1e-3 * (1.0 + obj_p.abs()),
            "P=1 two-level {obj_r} vs plain filtering {obj_p}"
        );
    }
}
