//! Bounds plane: Elkan/Hamerly-style triangle-inequality work
//! elimination, fused into the *batched* filtering engine and the
//! serving-side [`Predictor`](super::predict::Predictor).
//!
//! The standalone [`super::elkan`] baseline proves the bounds machinery
//! against Lloyd; this module is its fused successor on the panel path:
//! instead of replacing the engine, it shrinks the work the engine sees.
//! Each iteration maintains a k×k half-center-center distance matrix
//! ([`BoundsState::advance`]) plus a per-point upper bound on the true
//! distance to the point's assigned center, and uses them to drop leaf
//! `PanelJobs` outright (the incumbent provably still wins) or shrink
//! their candidate lists before they reach the
//! [`PanelBackend`](super::panel::PanelBackend) seam — so the win
//! multiplies through every kernel tier and every executor.
//!
//! The invariant contract — what makes pruning *exact* under both
//! metrics, the tie rule, and how the bounds interact with the engine's
//! bitwise pins — is documented in DESIGN.md §10; the property tests in
//! `tests/bounds_plane.rs` pin it.
//!
//! Three rules keep this sound:
//!
//! 1. **Bounds are maintained in scalar true-metric arithmetic only**
//!    (`sqrt` of the squared-L2 kernel for Euclid, L1 as-is): panel
//!    kernel outputs never feed a bound, because the blocked/SIMD
//!    kernels' `‖q‖² − 2q·c + ‖c‖²` form carries cancellation error that
//!    is unbounded *relative* to small distances.
//! 2. **Every comparison goes through [`surely_lt`]** — a strict
//!    less-than with [`BOUNDS_SLACK`] relative margin on both sides.
//!    Slack only ever weakens pruning, never correctness, and it makes
//!    exact ties (duplicated centroids included) unprunable, preserving
//!    the repo-wide lowest-index tie rule.
//! 3. **Pruning never reorders surviving work.** Candidate lists keep
//!    their ascending engine order, and points pruned outright have
//!    their accumulator contribution *deferred* to the exact slot the
//!    unpruned schedule would have used (see
//!    `filtering::filter_iteration_batched_bounded`), so bounds-on
//!    centroids are bitwise the bounds-off ones.

use super::metrics::{self, Metric};
use crate::data::Dataset;

/// [`BoundsMode::Auto`] enables the bounds at this many clusters — below
/// it the k×k matrix upkeep costs more than the candidate work it saves
/// (the `bounds_{off,on}_k*` entries in `BENCH_hotpath.json` measure the
/// crossover).
pub const AUTO_MIN_K: usize = 64;

/// Relative slack applied to both sides of every bound comparison
/// ([`surely_lt`]).  Generous on purpose: it absorbs the `sqrt` rounding
/// and the d·ε positive-summation error of the scalar distance kernels,
/// so a pruned candidate is *strictly* worse in real arithmetic.
pub const BOUNDS_SLACK: f32 = 1e-3;

/// Upper bound on k×k matrix entries before [`BoundsMode::Auto`] (and
/// the training-side state) refuses to activate: 1<<24 f32s = 64 MiB.
const MAX_CC_ENTRIES: u64 = 1 << 24;

/// Whether (and when) triangle-inequality pruning runs.  The knob rides
/// on [`KmeansSpec`](super::solver::KmeansSpec),
/// [`Predictor`](super::predict::Predictor), and
/// [`ServeConfig`](crate::serve::ServeConfig); `Off` (the default)
/// leaves every pre-existing code path untouched.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BoundsMode {
    /// No bounds upkeep, no pruning — the legacy path, bit for bit.
    #[default]
    Off,
    /// Enable at `k >= `[`AUTO_MIN_K`] (where the matrix pays for
    /// itself), stay off below.
    Auto,
    /// Always enable (subject only to the k×k memory guard).
    On,
}

impl BoundsMode {
    /// Canonical name (round-trips through
    /// [`FromStr`](std::str::FromStr)).
    pub fn name(self) -> &'static str {
        match self {
            BoundsMode::Off => "off",
            BoundsMode::Auto => "auto",
            BoundsMode::On => "on",
        }
    }

    pub fn all() -> &'static [BoundsMode] {
        &[BoundsMode::Off, BoundsMode::Auto, BoundsMode::On]
    }

    /// Resolve the knob for a concrete cluster count.
    pub fn enabled_for(self, k: usize) -> bool {
        let fits = (k as u64) * (k as u64) <= MAX_CC_ENTRIES;
        match self {
            BoundsMode::Off => false,
            BoundsMode::Auto => k >= AUTO_MIN_K && fits,
            BoundsMode::On => fits,
        }
    }
}

impl std::fmt::Display for BoundsMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for BoundsMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "off" => Ok(BoundsMode::Off),
            "auto" => Ok(BoundsMode::Auto),
            "on" => Ok(BoundsMode::On),
            other => anyhow::bail!("unknown bounds mode `{other}` (off|auto|on)"),
        }
    }
}

/// The *true* distance of the metric — what the triangle inequality
/// holds for.  [`Metric::dist`] returns squared L2 for
/// [`Metric::Euclid`] (the repo-wide convention), which is not a metric;
/// every bound in this module lives in `sqrt` space instead.
#[inline]
pub fn true_dist(metric: Metric, a: &[f32], b: &[f32]) -> f32 {
    match metric {
        Metric::Euclid => metrics::sq_l2(a, b).sqrt(),
        Metric::Manhattan => metrics::l1(a, b),
    }
}

/// Slack-guarded strict less-than over nonnegative true distances:
/// `a` is *surely* below `b` only when the [`BOUNDS_SLACK`] margins on
/// both sides cannot close the gap.  `INFINITY` (an unset upper bound)
/// is never surely below anything.
#[inline]
pub fn surely_lt(a: f32, b: f32) -> bool {
    a.is_finite() && a * (1.0 + BOUNDS_SLACK) < b * (1.0 - BOUNDS_SLACK)
}

/// Lifetime pruning counters, shared by the training state and the
/// predictor (the `bound_*` fields of
/// [`RunStats`](super::RunStats)/`ServeMetrics`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BoundsStats {
    /// Leaf points (training) or queries (predict) whose panel job was
    /// dropped outright — the incumbent center provably still wins.
    pub pruned_points: u64,
    /// Candidate entries removed from surviving panel jobs by the
    /// center-center test.
    pub pruned_candidates: u64,
    /// Scalar true-distance evaluations spent maintaining the bounds
    /// (the k×k matrix, per-center shifts, and on-demand tightenings) —
    /// the cost side of the ledger.
    pub matrix_cost: u64,
}

impl BoundsStats {
    /// Counter delta since an `earlier` snapshot of the same source.
    pub fn delta_from(&self, earlier: &BoundsStats) -> BoundsStats {
        BoundsStats {
            pruned_points: self.pruned_points.saturating_sub(earlier.pruned_points),
            pruned_candidates: self
                .pruned_candidates
                .saturating_sub(earlier.pruned_candidates),
            matrix_cost: self.matrix_cost.saturating_sub(earlier.matrix_cost),
        }
    }

    /// Fold another source's counters into this one.
    pub fn absorb(&mut self, other: &BoundsStats) {
        self.pruned_points += other.pruned_points;
        self.pruned_candidates += other.pruned_candidates;
        self.matrix_cost += other.matrix_cost;
    }
}

/// The center-center geometry of one centroid set: half pairwise true
/// distances (`cc_half[a*k + b] = d(c_a, c_b) / 2`, zero diagonal) and
/// each center's closest-other-center half distance
/// (`s[a] = min_{b≠a} cc_half[a*k + b]`).
pub struct CenterGeometry {
    k: usize,
    cc_half: Vec<f32>,
    s: Vec<f32>,
    /// True-distance evaluations the build spent (k·(k−1)/2).
    cost: u64,
}

impl CenterGeometry {
    /// Compute the geometry of `centroids` under `metric` with scalar
    /// true-distance arithmetic.
    pub fn compute(centroids: &Dataset, metric: Metric) -> Self {
        let k = centroids.len();
        let mut cc_half = vec![0.0f32; k * k];
        let mut cost = 0u64;
        for a in 0..k {
            for b in a + 1..k {
                let h = 0.5 * true_dist(metric, centroids.point(a), centroids.point(b));
                cc_half[a * k + b] = h;
                cc_half[b * k + a] = h;
                cost += 1;
            }
        }
        let mut s = vec![f32::INFINITY; k];
        for a in 0..k {
            for b in 0..k {
                if b != a && cc_half[a * k + b] < s[a] {
                    s[a] = cc_half[a * k + b];
                }
            }
        }
        Self { k, cc_half, s, cost }
    }

    /// Half true distance between centers `a` and `b`.
    #[inline]
    pub fn cc_half(&self, a: usize, b: usize) -> f32 {
        self.cc_half[a * self.k + b]
    }

    /// Half true distance from center `a` to its closest other center.
    #[inline]
    pub fn s(&self, a: usize) -> f32 {
        self.s[a]
    }

    /// True-distance evaluations the build spent.
    pub fn cost(&self) -> u64 {
        self.cost
    }

    /// Keep (in `out`, preserving order) every candidate of `cands` that
    /// the center-center test cannot rule out against pivot `a` with
    /// exact pivot distance `u = d(q, c_a)`: candidate `c` is dropped
    /// only when `u` is [`surely_lt`] `cc_half(a, c)`, which makes
    /// `d(q, c) ≥ 2·cc_half − u > u ≥ d(q, argmin)` strict — the argmin
    /// and everything tied with it always survive, and `a` itself always
    /// survives (zero diagonal).  Returns how many were dropped.
    pub fn filter_candidates(&self, a: usize, u: f32, cands: &[u32], out: &mut Vec<u32>) -> usize {
        out.clear();
        for &c in cands {
            if !surely_lt(u, self.cc_half(a, c as usize)) {
                out.push(c);
            }
        }
        cands.len() - out.len()
    }
}

/// Per-run bounds state for the batched training engine: the current
/// centroid geometry plus a per-point upper bound on the true distance
/// to the point's assigned center, carried across iterations.
///
/// Protocol (driven by `filtering::run_impl` and the session plane's
/// `ShardStepper`): call [`advance`](Self::advance) with each
/// iteration's centroids *before* running the iteration.  The first call
/// only seeds the state ([`active`](Self::active) stays `false` — the
/// assignments a fresh pass sees are not yet meaningful); every later
/// call loosens the uppers by the per-center movement since the previous
/// call and rebuilds the geometry, after which the engine may consult
/// [`prunes_outright`](Self::prunes_outright) /
/// [`tighten`](Self::tighten) / the geometry per leaf point.
pub struct BoundsState {
    /// Centroids of the most recent [`advance`](Self::advance) (flat
    /// k×d), the reference frame of `upper`.
    cur: Vec<f32>,
    geometry: Option<CenterGeometry>,
    /// `upper[i]` bounds the true distance from point `i` to its
    /// currently assigned center; `INFINITY` = unknown.
    upper: Vec<f32>,
    active: bool,
    stats: BoundsStats,
    /// Scratch: the filtered candidate list of the leaf point currently
    /// being pushed.
    pub(crate) filtered: Vec<u32>,
    /// Scratch: accumulator adds for pruned points, deferred to the job
    /// slot the unpruned schedule would have used — `(job index the add
    /// precedes, point id)`, in push order.
    pub(crate) deferred: Vec<(usize, u32)>,
}

impl BoundsState {
    /// Fresh state for an `n`-point dataset: all uppers unknown.
    pub fn new(n: usize) -> Self {
        Self {
            cur: Vec::new(),
            geometry: None,
            upper: vec![f32::INFINITY; n],
            active: false,
            stats: BoundsStats::default(),
            filtered: Vec::new(),
            deferred: Vec::new(),
        }
    }

    /// Whether the engine may prune this iteration (false until the
    /// second [`advance`](Self::advance) — a fresh pass's assignments
    /// are not yet meaningful).
    #[inline]
    pub fn active(&self) -> bool {
        self.active
    }

    /// Lifetime counters.
    pub fn stats(&self) -> BoundsStats {
        self.stats
    }

    /// Move the state to this iteration's `centroids`: loosen every
    /// point's upper bound by its assigned center's movement since the
    /// previous call (`upper[i] += d(prev[a], cur[a])`, `a =
    /// assignments[i]`), then rebuild the center-center geometry.  On
    /// the first call (or after a shape change) the state only seeds
    /// itself and stays inactive.
    pub fn advance(&mut self, centroids: &Dataset, metric: Metric, assignments: &[u32]) {
        let k = centroids.len();
        let d = centroids.dims();
        if self.cur.len() != k * d {
            self.cur.clear();
            self.cur.extend_from_slice(centroids.flat());
            self.geometry = None;
            self.active = false;
            return;
        }
        // Per-center movement since the previous advance, in true-metric
        // units; loosening by it keeps every upper valid for the moved
        // centers (triangle inequality on d(x, c_new) ≤ d(x, c_old) +
        // d(c_old, c_new)).
        let mut shifts = vec![0.0f32; k];
        for (c, shift) in shifts.iter_mut().enumerate() {
            *shift = true_dist(metric, &self.cur[c * d..(c + 1) * d], centroids.point(c));
            self.stats.matrix_cost += 1;
        }
        for (u, &a) in self.upper.iter_mut().zip(assignments) {
            *u += shifts[a as usize]; // INF + x = INF: unknown stays unknown
        }
        self.cur.clear();
        self.cur.extend_from_slice(centroids.flat());
        let geom = CenterGeometry::compute(centroids, metric);
        self.stats.matrix_cost += geom.cost();
        self.geometry = Some(geom);
        self.active = true;
    }

    /// The geometry of the centroids last passed to
    /// [`advance`](Self::advance); `None` until the state is active.
    #[inline]
    pub fn geometry(&self) -> Option<&CenterGeometry> {
        self.geometry.as_ref()
    }

    /// Elkan's lemma 1 with the current (possibly loose) upper: when the
    /// upper bound is surely below half the distance from the assigned
    /// center `a` to its closest other center, no other center can win
    /// strictly or tie — the point's argmin is still `a`.
    #[inline]
    pub fn prunes_outright(&self, point: u32, a: u32) -> bool {
        match &self.geometry {
            Some(g) => surely_lt(self.upper[point as usize], g.s(a as usize)),
            None => false,
        }
    }

    /// Replace the point's upper with the exact true distance to its
    /// assigned center (counted in
    /// [`matrix_cost`](BoundsStats::matrix_cost)) and return it.
    #[inline]
    pub fn tighten(&mut self, point: u32, q: &[f32], center: &[f32], metric: Metric) -> f32 {
        let u = true_dist(metric, q, center);
        self.upper[point as usize] = u;
        self.stats.matrix_cost += 1;
        u
    }

    /// The batched engine's per-leaf-point decision (only called while
    /// [`active`](Self::active)): `true` ⇒ drop the job outright, the
    /// point keeps assignment `a`; `false` ⇒ push the job with the
    /// (possibly shrunk, order-preserving) candidate list left in the
    /// `filtered` scratch.
    ///
    /// Sequence: lemma 1 with the loose upper, then tighten to the exact
    /// `d(q, c_a)` and retest, then the center-center candidate filter.
    /// A one-survivor filtered list counts as an outright prune *only*
    /// when the survivor is `a` itself — when `a` was not in `cands`
    /// (the point's cell no longer carries it) the single survivor still
    /// goes through the kernel so the assignment updates.
    pub(crate) fn leaf_decision(
        &mut self,
        point: u32,
        a: u32,
        q: &[f32],
        center_a: &[f32],
        metric: Metric,
        cands: &[u32],
    ) -> bool {
        if self.prunes_outright(point, a) {
            self.stats.pruned_points += 1;
            return true;
        }
        let u = true_dist(metric, q, center_a);
        self.upper[point as usize] = u;
        self.stats.matrix_cost += 1;
        let Some(geom) = &self.geometry else {
            self.filtered.clear();
            self.filtered.extend_from_slice(cands);
            return false;
        };
        if surely_lt(u, geom.s(a as usize)) {
            self.stats.pruned_points += 1;
            return true;
        }
        let dropped = geom.filter_candidates(a as usize, u, cands, &mut self.filtered);
        self.stats.pruned_candidates += dropped as u64;
        if self.filtered.len() == 1 && self.filtered[0] == a {
            self.stats.pruned_points += 1;
            return true;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_names_round_trip_and_default_is_off() {
        assert_eq!(BoundsMode::default(), BoundsMode::Off);
        for m in BoundsMode::all() {
            assert_eq!(m.name().parse::<BoundsMode>().unwrap(), *m);
            assert_eq!(format!("{m}"), m.name());
        }
        assert!("elkan".parse::<BoundsMode>().is_err());
    }

    #[test]
    fn auto_resolves_at_the_documented_threshold() {
        assert!(!BoundsMode::Off.enabled_for(1 << 10));
        assert!(!BoundsMode::Auto.enabled_for(AUTO_MIN_K - 1));
        assert!(BoundsMode::Auto.enabled_for(AUTO_MIN_K));
        assert!(BoundsMode::On.enabled_for(1));
        // The k×k memory guard refuses absurd k even under On.
        assert!(!BoundsMode::On.enabled_for(1 << 13));
        assert!(!BoundsMode::Auto.enabled_for(1 << 13));
    }

    #[test]
    fn surely_lt_is_strict_and_slack_guarded() {
        assert!(surely_lt(1.0, 2.0));
        assert!(!surely_lt(2.0, 1.0));
        assert!(!surely_lt(1.0, 1.0), "exact ties never prune");
        assert!(!surely_lt(0.0, 0.0), "duplicated centers never prune");
        assert!(surely_lt(0.0, 1.0));
        assert!(
            !surely_lt(1.0, 1.0 + 1e-5),
            "gaps inside the slack margin never prune"
        );
        assert!(!surely_lt(f32::INFINITY, f32::INFINITY));
        assert!(!surely_lt(f32::INFINITY, 1.0), "unset uppers never prune");
    }

    #[test]
    fn true_dist_is_the_metric_not_its_square() {
        let a = [0.0f32, 0.0];
        let b = [3.0f32, 4.0];
        assert_eq!(true_dist(Metric::Euclid, &a, &b), 5.0);
        assert_eq!(true_dist(Metric::Manhattan, &a, &b), 7.0);
    }

    #[test]
    fn geometry_is_symmetric_with_zero_diagonal() {
        let cents = Dataset::from_flat(3, 2, vec![0.0, 0.0, 6.0, 8.0, 0.0, 2.0]);
        let g = CenterGeometry::compute(&cents, Metric::Euclid);
        for a in 0..3 {
            assert_eq!(g.cc_half(a, a), 0.0);
            for b in 0..3 {
                assert_eq!(g.cc_half(a, b), g.cc_half(b, a));
            }
        }
        assert_eq!(g.cc_half(0, 1), 5.0); // d = 10
        assert_eq!(g.cc_half(0, 2), 1.0); // d = 2
        assert_eq!(g.s(0), 1.0);
        assert_eq!(g.s(1), g.cc_half(1, 2));
        assert_eq!(g.cost(), 3);
    }

    #[test]
    fn filter_keeps_pivot_order_and_ties() {
        let cents = Dataset::from_flat(3, 1, vec![0.0, 100.0, 0.5]);
        let g = CenterGeometry::compute(&cents, Metric::Euclid);
        let mut out = Vec::new();
        // Query at 0.3: exact pivot distance to center 0 is 0.3; center 1
        // (cc_half 50) is surely out, center 2 (cc_half 0.25) is not —
        // and indeed the query is *closer* to center 2, so dropping it
        // would be a wrong answer, not just a loose bound.
        let dropped = g.filter_candidates(0, 0.3, &[0, 1, 2], &mut out);
        assert_eq!(dropped, 1);
        assert_eq!(out, vec![0, 2], "order preserved, pivot kept");
        // Duplicated centers: cc_half = 0, nothing ever prunes.
        let dup = Dataset::from_flat(2, 1, vec![4.0, 4.0]);
        let gd = CenterGeometry::compute(&dup, Metric::Euclid);
        assert_eq!(gd.filter_candidates(0, 0.0, &[0, 1], &mut out), 0);
        assert_eq!(out, vec![0, 1]);
    }

    #[test]
    fn state_activates_on_the_second_advance_and_loosens_by_shift() {
        let mut st = BoundsState::new(2);
        let c0 = Dataset::from_flat(2, 1, vec![0.0, 10.0]);
        let assign = vec![0u32, 1u32];
        st.advance(&c0, Metric::Euclid, &assign);
        assert!(!st.active(), "first advance only seeds");
        assert!(st.geometry().is_none());
        assert!(!st.prunes_outright(0, 0), "inactive state never prunes");

        // Tighten point 0 against center 0, then move center 0 by 2.
        let u = st.tighten(0, &[1.0], c0.point(0), Metric::Euclid);
        assert_eq!(u, 1.0);
        let c1 = Dataset::from_flat(2, 1, vec![2.0, 10.0]);
        st.advance(&c1, Metric::Euclid, &assign);
        assert!(st.active());
        assert_eq!(st.upper[0], 3.0, "upper loosened by the center's shift");
        assert_eq!(st.upper[1], f32::INFINITY, "unknown stays unknown");
        // s(0) = half of d(2, 10) = 4: upper 3.0 surely below ⇒ prune.
        assert!(st.prunes_outright(0, 0));
        assert!(!st.prunes_outright(1, 1), "INF upper never prunes");
        let stats = st.stats();
        assert_eq!(stats.matrix_cost, 1 + 2 + 1, "tighten + shifts + matrix");
    }

    #[test]
    fn zero_movement_advance_keeps_tight_uppers() {
        let mut st = BoundsState::new(1);
        let c = Dataset::from_flat(2, 1, vec![0.0, 8.0]);
        let assign = vec![0u32];
        st.advance(&c, Metric::Euclid, &assign);
        st.tighten(0, &[0.5], c.point(0), Metric::Euclid);
        st.advance(&c, Metric::Euclid, &assign);
        assert_eq!(st.upper[0], 0.5, "zero shift leaves the upper tight");
        assert!(st.prunes_outright(0, 0), "fixpoint prunes everything");
    }

    #[test]
    fn stats_delta_and_absorb() {
        let a = BoundsStats {
            pruned_points: 10,
            pruned_candidates: 100,
            matrix_cost: 7,
        };
        let b = BoundsStats {
            pruned_points: 4,
            pruned_candidates: 40,
            matrix_cost: 2,
        };
        let d = a.delta_from(&b);
        assert_eq!(d.pruned_points, 6);
        assert_eq!(d.pruned_candidates, 60);
        assert_eq!(d.matrix_cost, 5);
        let mut acc = b;
        acc.absorb(&d);
        assert_eq!(acc, a);
    }
}
