//! Reduced-precision shortlist backend — the software analogue of the
//! paper's fixed-point PL distance datapath.
//!
//! [`QuantPanels`] scores every (job, candidate) pair through an
//! **i8-quantized** copy of the centroid panel (per-centroid scale /
//! zero-point for squared-L2, one global scale for L1, built once per
//! [`PanelBackend::begin_pass`]), derives a *provable* per-candidate
//! error bound, and only re-scores the candidates whose approximate
//! interval can still contain the minimum — in exact f32, through the
//! same [`Metric::dist`] the scalar oracle uses.
//!
//! ## Why emitted labels stay bitwise-identical to the scalar oracle
//!
//! Every consumer of panel rows (the batched filtering engine, the
//! predictor, the serve tier) takes a **first-wins argmin** over each
//! row.  `QuantPanels` writes:
//!
//! - **survivors** (`approx − bound ≤ min(approx + bound)`): the exact
//!   scalar-oracle distance;
//! - **non-survivors**: `approx + bound`, which is *strictly greater*
//!   than the row's true minimum (proof: for a non-survivor `ns`,
//!   `approx_ns − bound_ns > m = min_c(approx_c + bound_c)`, and the true
//!   nearest `t` has `dist_t ≤ approx_c + bound_c` for every `c`, hence
//!   `dist_t ≤ m < approx_ns + bound_ns`).
//!
//! So the row's first-wins argmin lands on the lowest-index *exact*
//! minimizer: any candidate exactly tied with the minimum satisfies
//! `approx − bound ≤ dist = dist_t ≤ m`, i.e. ties always survive and are
//! compared by their exact values — the oracle's lowest-index tie rule is
//! preserved.  The winner's row value is the exact distance, so scored
//! predictions are exact too.  `tests/model_predict.rs` pins this
//! bitwise, tie cases included.
//!
//! ## Error budget
//!
//! With per-centroid scale `s_c = max_j|c_j − zp_c| / 127` and symmetric
//! query scale `s_q = max_j|q_j| / 127`, each reconstructed coordinate is
//! off by at most half a quantization step, giving
//! `|q·c − q'·c'| ≤ (s_c/2)·Σ|q_j| + (s_q/2)·Σ|c'_j|` for the L2 cross
//! term (doubled in the distance) and `Σ|q−c|` off by at most `d·s` for
//! L1.  The implemented bound inflates the analytic value by 6.25% and
//! adds a `1e-4`-relative float-rounding cushion (the norm decomposition
//! itself rounds at ~`d·2⁻²⁴` relative, two orders below the cushion), so
//! quantization can only ever *widen* the shortlist, never corrupt the
//! argmin.

use super::{dot8, KernelStats, PanelBackend, PanelJobs, PanelSet};
use crate::data::Dataset;
use crate::kmeans::Metric;

/// Relative float-rounding cushion added to every bound (the analytic
/// quantization bound is exact in real arithmetic; this covers the f32
/// evaluation of both the bound and the `‖q‖²−2q·c+‖c‖²` decomposition).
const REL_SLACK: f32 = 1e-4;
/// Multiplicative inflation of the analytic quantization bound.
const BOUND_INFLATE: f32 = 1.0625;
/// Manhattan queries whose quantized magnitude would exceed this are
/// scored exactly instead (saturating f32→i32 casts would break the
/// error bound); ~never hit outside adversarial inputs.
const L1_Q_LIMIT: f32 = 1e8;

/// i8-shortlist panel backend: quantized scoring + exact re-scoring.
///
/// Single-threaded by design — it is the predictor/serve tier's cheap
/// scoring path (each serve dispatcher owns one), and an opt-in solver
/// backend via `SolverCtx::with_backend`.
#[derive(Clone, Debug, Default)]
pub struct QuantPanels {
    d: usize,
    /// k×d quantized centroid panel.
    qc: Vec<i8>,
    /// Per-centroid scale (L2) or `[global]` scale (L1).
    scale: Vec<f32>,
    /// Per-centroid zero point (L2 only).
    zp: Vec<f32>,
    /// Per-centroid Σ|c'_j| of the *reconstructed* centroid (L2 bound).
    l1rec: Vec<f32>,
    /// Per-centroid ‖c‖² for the decomposition (approximate use only).
    cn: Vec<f32>,
    /// Identity of the centroid buffer the tables were built for.
    key: Option<(usize, usize, Metric)>,
    // Per-job scratch (recycled).
    qq: Vec<i32>,
    approx: Vec<f32>,
    bound: Vec<f32>,
    // Lifetime counters (see `KernelStats`).
    quantized: u64,
    rescored: u64,
}

fn centroid_key(centroids: &Dataset, metric: Metric) -> (usize, usize, Metric) {
    (
        centroids.flat().as_ptr() as usize,
        centroids.flat().len(),
        metric,
    )
}

impl QuantPanels {
    pub fn new() -> Self {
        Self::default()
    }

    /// Candidates scored through the i8 path so far (lifetime counter).
    pub fn quantized_candidates(&self) -> u64 {
        self.quantized
    }

    /// Shortlist survivors re-scored in exact f32 so far.
    pub fn rescored_candidates(&self) -> u64 {
        self.rescored
    }

    fn build_tables(&mut self, centroids: &Dataset, metric: Metric) {
        let d = centroids.dims();
        let k = centroids.len();
        self.d = d;
        self.qc.clear();
        self.qc.reserve(k * d);
        self.scale.clear();
        self.zp.clear();
        self.l1rec.clear();
        self.cn.clear();
        match metric {
            Metric::Euclid => {
                for c in centroids.iter() {
                    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                    for &x in c {
                        lo = lo.min(x);
                        hi = hi.max(x);
                    }
                    let zp = 0.5 * (lo + hi);
                    let half = (hi - zp).max(zp - lo).max(0.0);
                    let s = if half > 0.0 { half / 127.0 } else { 1.0 };
                    let mut l1 = 0.0f32;
                    for &x in c {
                        let q = ((x - zp) / s).round().clamp(-127.0, 127.0) as i8;
                        self.qc.push(q);
                        l1 += (zp + s * q as f32).abs();
                    }
                    self.scale.push(s);
                    self.zp.push(zp);
                    self.l1rec.push(l1);
                    self.cn.push(dot8(c, c));
                }
            }
            Metric::Manhattan => {
                let mut max_abs = 0.0f32;
                for &x in centroids.flat() {
                    max_abs = max_abs.max(x.abs());
                }
                let s = if max_abs > 0.0 { max_abs / 127.0 } else { 1.0 };
                self.scale.push(s);
                for &x in centroids.flat() {
                    self.qc.push((x / s).round().clamp(-127.0, 127.0) as i8);
                }
            }
        }
        self.key = Some(centroid_key(centroids, metric));
    }
}

impl PanelBackend for QuantPanels {
    fn begin_pass(&mut self, centroids: &Dataset, metric: Metric) {
        self.key = None;
        self.build_tables(centroids, metric);
    }

    fn panels(
        &mut self,
        jobs: &PanelJobs,
        centroids: &Dataset,
        metric: Metric,
        out: &mut PanelSet,
    ) {
        out.reset_from(jobs);
        if jobs.is_empty() {
            return;
        }
        if self.key != Some(centroid_key(centroids, metric)) {
            self.build_tables(centroids, metric);
        }
        let d = self.d;
        for j in 0..jobs.len() {
            let q = jobs.mid(j);
            let cands = jobs.cands(j);
            let row = out.row_mut(j);
            self.quantized += cands.len() as u64;

            self.approx.clear();
            self.bound.clear();
            match metric {
                Metric::Euclid => {
                    // Symmetric query quantization.
                    let mut max_abs = 0.0f32;
                    let mut l1q = 0.0f32;
                    for &x in q {
                        max_abs = max_abs.max(x.abs());
                        l1q += x.abs();
                    }
                    let sq = if max_abs > 0.0 { max_abs / 127.0 } else { 1.0 };
                    self.qq.clear();
                    let mut sum_q: i32 = 0;
                    for &x in q {
                        let v = (x / sq).round().clamp(-127.0, 127.0) as i32;
                        sum_q += v;
                        self.qq.push(v);
                    }
                    let qn = dot8(q, q);
                    for &c in cands {
                        let ci = c as usize;
                        let crow = &self.qc[ci * d..ci * d + d];
                        let mut dot: i32 = 0;
                        for (a, &b) in self.qq.iter().zip(crow) {
                            dot += a * b as i32;
                        }
                        let sc = self.scale[ci];
                        let cross = sq * self.zp[ci] * sum_q as f32 + sq * sc * dot as f32;
                        let approx = qn - 2.0 * cross + self.cn[ci];
                        let bound = (sc * l1q + sq * self.l1rec[ci]) * BOUND_INFLATE
                            + REL_SLACK * (qn + self.cn[ci] + 1.0);
                        self.approx.push(approx);
                        self.bound.push(bound);
                    }
                }
                Metric::Manhattan => {
                    let s = self.scale[0];
                    let mut max_abs = 0.0f32;
                    for &x in q {
                        max_abs = max_abs.max(x.abs());
                    }
                    if max_abs / s > L1_Q_LIMIT {
                        // Saturation hazard: score everything exactly.
                        for _ in cands {
                            self.approx.push(0.0);
                            self.bound.push(f32::INFINITY);
                        }
                    } else {
                        self.qq.clear();
                        for &x in q {
                            self.qq.push((x / s).round() as i32);
                        }
                        for &c in cands {
                            let ci = c as usize;
                            let crow = &self.qc[ci * d..ci * d + d];
                            let mut sad: i64 = 0;
                            for (a, &b) in self.qq.iter().zip(crow) {
                                sad += (a - b as i32).unsigned_abs() as i64;
                            }
                            let approx = s * sad as f32;
                            let bound = s * d as f32 * BOUND_INFLATE + REL_SLACK * approx + 1e-6;
                            self.approx.push(approx);
                            self.bound.push(bound);
                        }
                    }
                }
            }

            // Shortlist: a candidate survives iff its interval can still
            // contain the minimum.
            let mut m = f32::INFINITY;
            for (a, b) in self.approx.iter().zip(self.bound.iter()) {
                m = m.min(a + b);
            }
            for (slot, &c) in cands.iter().enumerate() {
                if self.approx[slot] - self.bound[slot] <= m {
                    row[slot] = metric.dist(q, centroids.point(c as usize));
                    self.rescored += 1;
                } else {
                    row[slot] = self.approx[slot] + self.bound[slot];
                }
            }
        }
    }

    fn kernel_stats(&self) -> KernelStats {
        KernelStats {
            simd_lanes: 0,
            quantized_candidates: self.quantized,
            rescored_candidates: self.rescored,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{CpuPanels, PanelJobs, PanelSet};
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn random_problem(seed: u64, jobs: usize, d: usize, k: usize) -> (PanelJobs, Dataset) {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let cents = Dataset::from_flat(
            k,
            d,
            (0..k * d).map(|_| rng.uniform_f32(-3.0, 3.0)).collect(),
        );
        let mut batch = PanelJobs::new();
        batch.clear(d);
        let mut mid = vec![0f32; d];
        for _ in 0..jobs {
            for m in mid.iter_mut() {
                *m = rng.uniform_f32(-3.0, 3.0);
            }
            let len = 1 + rng.below_usize(k);
            let mut c: Vec<u32> = (0..k as u32).collect();
            rng.shuffle(&mut c);
            c.truncate(len);
            batch.push(&mid, &c);
        }
        (batch, cents)
    }

    /// First-wins argmin over a row.
    fn argmin(row: &[f32]) -> usize {
        let mut best = 0;
        for (i, &v) in row.iter().enumerate() {
            if v < row[best] {
                best = i;
            }
        }
        best
    }

    #[test]
    fn quant_argmin_matches_oracle_and_winner_value_is_exact() {
        for metric in [Metric::Euclid, Metric::Manhattan] {
            for d in [1usize, 3, 8, 16, 31] {
                let (batch, cents) = random_problem(d as u64 ^ 0x51AD, 80, d, 12);
                let mut exact = PanelSet::new();
                CpuPanels.panels(&batch, &cents, metric, &mut exact);
                let mut q = QuantPanels::new();
                q.begin_pass(&cents, metric);
                let mut got = PanelSet::new();
                q.panels(&batch, &cents, metric, &mut got);
                for j in 0..batch.len() {
                    let (er, gr) = (exact.row(j), got.row(j));
                    let (ea, ga) = (argmin(er), argmin(gr));
                    assert_eq!(ea, ga, "{metric:?} d={d} job {j}");
                    assert_eq!(
                        er[ea].to_bits(),
                        gr[ga].to_bits(),
                        "winner value must be the exact oracle distance"
                    );
                }
            }
        }
    }

    #[test]
    fn duplicated_centroids_keep_lowest_index_tie() {
        // Centroids 0 and 2 are identical; the oracle's first-wins argmin
        // picks 0 — so must the quantized path.
        for metric in [Metric::Euclid, Metric::Manhattan] {
            let cents = Dataset::from_flat(3, 2, vec![1.0, 1.0, 5.0, 5.0, 1.0, 1.0]);
            let mut batch = PanelJobs::new();
            batch.clear(2);
            batch.push(&[1.1, 0.9], &[0, 1, 2]);
            let mut q = QuantPanels::new();
            q.begin_pass(&cents, metric);
            let mut got = PanelSet::new();
            q.panels(&batch, &cents, metric, &mut got);
            assert_eq!(argmin(got.row(0)), 0, "{metric:?}");
        }
    }

    #[test]
    fn counters_accumulate_and_rescore_is_a_subset() {
        let (batch, cents) = random_problem(77, 50, 16, 20);
        let mut q = QuantPanels::new();
        q.begin_pass(&cents, Metric::Euclid);
        let mut out = PanelSet::new();
        q.panels(&batch, &cents, Metric::Euclid, &mut out);
        let s = q.kernel_stats();
        assert_eq!(s.quantized_candidates, batch.total_cands() as u64);
        assert!(s.rescored_candidates >= batch.len() as u64, "≥1 survivor per row");
        assert!(s.rescored_candidates <= s.quantized_candidates);
        // Second pass keeps accumulating.
        q.panels(&batch, &cents, Metric::Euclid, &mut out);
        assert_eq!(q.kernel_stats().quantized_candidates, 2 * s.quantized_candidates);
    }

    #[test]
    fn zero_and_constant_centroids_are_safe() {
        // Degenerate scales (all-zero panel, zero-range rows) must not
        // divide by zero and must stay exact.
        for metric in [Metric::Euclid, Metric::Manhattan] {
            let cents = Dataset::from_flat(2, 3, vec![0.0; 6]);
            let mut batch = PanelJobs::new();
            batch.clear(3);
            batch.push(&[0.5, -0.5, 0.25], &[0, 1]);
            let mut q = QuantPanels::new();
            q.begin_pass(&cents, metric);
            let mut got = PanelSet::new();
            q.panels(&batch, &cents, metric, &mut got);
            assert_eq!(argmin(got.row(0)), 0, "{metric:?}");
            let want = metric.dist(&[0.5, -0.5, 0.25], cents.point(0));
            assert_eq!(got.row(0)[0].to_bits(), want.to_bits());
        }
    }
}
