//! Explicit `core::arch` SIMD row kernels — the [`super::PanelKernel::Simd`]
//! tier of the panel engine.
//!
//! Same arithmetic shape as the `Blocked` tier (`‖q−c‖² = ‖q‖² − 2·q·c +
//! ‖c‖²` with per-pass cached centroid norms; lane-wise `|q−c|`
//! accumulation for L1) but with the inner loops written directly in
//! intrinsics instead of relying on the autovectorizer:
//!
//! - **x86-64**: AVX2 + FMA, 8 f32 lanes, candidates processed in blocks
//!   of four so each 8-lane load of the query feeds four FMA chains (the
//!   horizontal reduction is amortized across the block — that is what
//!   clears the ≥2× bar over `Blocked` at d ≥ 16).
//! - **aarch64**: NEON, 4 f32 lanes, same four-candidate blocking.
//!
//! Feature detection runs **once per process** ([`available`], cached in a
//! `OnceLock`): `is_x86_feature_detected!("avx2")` + `("fma")` on x86-64,
//! unconditional on aarch64 (NEON is baseline), `false` everywhere else
//! **and under Miri** — Miri cannot execute vendor intrinsics, so the Miri
//! job exercises this module's dispatch seam while the rows are computed
//! by the scalar-shaped fallback below (satisfying the "SIMD paths compile
//! out to the scalar oracle under Miri" contract).
//!
//! Every `unsafe` site carries a `// SAFETY:` justification and the whole
//! module sits behind `pallas-lint`'s unsafe-audit allowlist; the
//! tolerance contract (≤ 1e-4 relative vs the scalar oracle, all dims and
//! tails) is pinned by `tests/panel_engine.rs`.

use std::sync::OnceLock;

use super::{dot8, l1_8};
use crate::data::Dataset;

static AVAILABLE: OnceLock<bool> = OnceLock::new();

/// Whether this process can run the SIMD tier.  Detected once, cached.
pub fn available() -> bool {
    *AVAILABLE.get_or_init(detect)
}

/// f32 lanes per vector op of the active SIMD tier (0 when unavailable).
pub fn lanes() -> u32 {
    if !available() {
        return 0;
    }
    if cfg!(target_arch = "x86_64") {
        8
    } else {
        4
    }
}

/// Human-readable description of the feature set this host would need /
/// has — used in the `KernelKind::resolve` error message.
pub fn describe() -> &'static str {
    if cfg!(miri) {
        "intrinsics disabled under Miri"
    } else if cfg!(target_arch = "x86_64") {
        "needs AVX2+FMA"
    } else if cfg!(target_arch = "aarch64") {
        "NEON"
    } else {
        "no SIMD kernel for this architecture"
    }
}

fn detect() -> bool {
    // Miri interprets MIR and cannot execute vendor intrinsics; report
    // the tier unavailable so every Simd/Auto request degrades to the
    // scalar-shaped fallback (dispatch seam still exercised).
    if cfg!(miri) {
        return false;
    }
    #[cfg(target_arch = "x86_64")]
    {
        return std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma");
    }
    #[cfg(target_arch = "aarch64")]
    {
        // NEON is part of the aarch64 baseline ISA.
        return true;
    }
    #[allow(unreachable_code)]
    false
}

// ---------------------------------------------------------------------------
// Dispatch wrappers (safe API)
// ---------------------------------------------------------------------------

/// Squared-L2 row: `row[slot] = max(0, ‖q‖² − 2·q·c + ‖c‖²)` for each
/// candidate, with `‖c‖²` taken from the per-pass `cnorms` cache.
///
/// Runs the intrinsic kernel when [`available`]; otherwise (foreign arch,
/// missing features, Miri) computes the identical decomposition through
/// the portable [`dot8`] path, so calling this with a demoted kernel is
/// still correct — just not vector-wide.
pub(crate) fn euclid_row(
    q: &[f32],
    centroids: &Dataset,
    cands: &[u32],
    cnorms: &[f32],
    row: &mut [f32],
) {
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        if available() {
            // SAFETY: `available()` verified AVX2+FMA via
            // `is_x86_feature_detected!`, which is exactly the feature set
            // `x86::euclid_row_avx2` is compiled for.
            unsafe {
                x86::euclid_row_avx2(q, centroids.flat(), centroids.dims(), cands, cnorms, row);
            }
            return;
        }
    }
    #[cfg(all(target_arch = "aarch64", not(miri)))]
    {
        if available() {
            // SAFETY: on aarch64 NEON is baseline, which is the feature set
            // `neon::euclid_row_neon` is compiled for.
            unsafe {
                neon::euclid_row_neon(q, centroids.flat(), centroids.dims(), cands, cnorms, row);
            }
            return;
        }
    }
    // Portable fallback — the Blocked tier's decomposition, same
    // tolerance contract.
    let qn = dot8(q, q);
    for (slot, &c) in cands.iter().enumerate() {
        let ci = c as usize;
        let d = qn - 2.0 * dot8(q, centroids.point(ci)) + cnorms[ci];
        row[slot] = d.max(0.0);
    }
}

/// L1 row: `row[slot] = Σ|q_j − c_j|` per candidate.  Same dispatch and
/// fallback contract as [`euclid_row`].
pub(crate) fn l1_row(q: &[f32], centroids: &Dataset, cands: &[u32], row: &mut [f32]) {
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        if available() {
            // SAFETY: `available()` verified AVX2+FMA via
            // `is_x86_feature_detected!`; `x86::l1_row_avx2` needs AVX2 only.
            unsafe {
                x86::l1_row_avx2(q, centroids.flat(), centroids.dims(), cands, row);
            }
            return;
        }
    }
    #[cfg(all(target_arch = "aarch64", not(miri)))]
    {
        if available() {
            // SAFETY: on aarch64 NEON is baseline, which is the feature set
            // `neon::l1_row_neon` is compiled for.
            unsafe {
                neon::l1_row_neon(q, centroids.flat(), centroids.dims(), cands, row);
            }
            return;
        }
    }
    for (slot, &c) in cands.iter().enumerate() {
        row[slot] = l1_8(q, centroids.point(c as usize));
    }
}

// ---------------------------------------------------------------------------
// x86-64: AVX2 + FMA
// ---------------------------------------------------------------------------

#[cfg(all(target_arch = "x86_64", not(miri)))]
mod x86 {
    #[allow(clippy::wildcard_imports)]
    use core::arch::x86_64::*;

    /// Horizontal sum of all 8 lanes.
    ///
    // SAFETY: requires AVX (implied by AVX2); callers are
    // `#[target_feature(enable = "avx2", ...)]` functions, so the
    // requirement is inherited.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum256(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps::<1>(v);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps::<0b01>(s, s));
        _mm_cvtss_f32(s)
    }

    /// Squared-L2 rows via `qn − 2·dot + cn`, four candidates per block so
    /// each 8-lane query load feeds four independent FMA chains.
    ///
    // SAFETY: (to call) AVX2+FMA must be available on the executing CPU —
    // guaranteed by the `available()` gate in the dispatch wrapper.  All
    // memory access below is through bounds-checked slice indexing plus
    // unaligned loads on ranges proven in-bounds by the loop conditions
    // (`j + 8 <= d` with every row slice exactly `d` long), so no
    // out-of-bounds pointer is ever formed.
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn euclid_row_avx2(
        q: &[f32],
        flat: &[f32],
        d: usize,
        cands: &[u32],
        cnorms: &[f32],
        row: &mut [f32],
    ) {
        debug_assert_eq!(q.len(), d);
        debug_assert_eq!(row.len(), cands.len());
        let qn = dot_self(q);
        let qp = q.as_ptr();
        let mut i = 0;
        // Four-candidate blocks: one query load, four FMA accumulators.
        while i + 4 <= cands.len() {
            let c0 = row_at(flat, d, cands[i]);
            let c1 = row_at(flat, d, cands[i + 1]);
            let c2 = row_at(flat, d, cands[i + 2]);
            let c3 = row_at(flat, d, cands[i + 3]);
            let mut a0 = _mm256_setzero_ps();
            let mut a1 = _mm256_setzero_ps();
            let mut a2 = _mm256_setzero_ps();
            let mut a3 = _mm256_setzero_ps();
            let mut j = 0;
            while j + 8 <= d {
                // SAFETY: j + 8 <= d and q/c0..c3 are exactly d long, so
                // each unaligned 8-f32 load reads inside its slice.
                let vq = _mm256_loadu_ps(qp.add(j));
                a0 = _mm256_fmadd_ps(vq, _mm256_loadu_ps(c0.as_ptr().add(j)), a0);
                a1 = _mm256_fmadd_ps(vq, _mm256_loadu_ps(c1.as_ptr().add(j)), a1);
                a2 = _mm256_fmadd_ps(vq, _mm256_loadu_ps(c2.as_ptr().add(j)), a2);
                a3 = _mm256_fmadd_ps(vq, _mm256_loadu_ps(c3.as_ptr().add(j)), a3);
                j += 8;
            }
            let mut dot = [hsum256(a0), hsum256(a1), hsum256(a2), hsum256(a3)];
            while j < d {
                let x = q[j];
                dot[0] += x * c0[j];
                dot[1] += x * c1[j];
                dot[2] += x * c2[j];
                dot[3] += x * c3[j];
                j += 1;
            }
            for t in 0..4 {
                let ci = cands[i + t] as usize;
                row[i + t] = (qn - 2.0 * dot[t] + cnorms[ci]).max(0.0);
            }
            i += 4;
        }
        // Remainder candidates, one FMA chain each.
        while i < cands.len() {
            let ci = cands[i] as usize;
            let c = row_at(flat, d, cands[i]);
            let mut acc = _mm256_setzero_ps();
            let mut j = 0;
            while j + 8 <= d {
                // SAFETY: j + 8 <= d with q and c exactly d long.
                let vq = _mm256_loadu_ps(qp.add(j));
                let vc = _mm256_loadu_ps(c.as_ptr().add(j));
                acc = _mm256_fmadd_ps(vq, vc, acc);
                j += 8;
            }
            let mut dot = hsum256(acc);
            while j < d {
                dot += q[j] * c[j];
                j += 1;
            }
            row[i] = (qn - 2.0 * dot + cnorms[ci]).max(0.0);
            i += 1;
        }
    }

    /// `‖q‖²` with the same FMA chain as the cross terms.
    ///
    // SAFETY: (to call) AVX2+FMA required; called only from
    // `euclid_row_avx2`, which carries the same `target_feature` set.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn dot_self(q: &[f32]) -> f32 {
        let mut acc = _mm256_setzero_ps();
        let mut j = 0;
        while j + 8 <= q.len() {
            // SAFETY: j + 8 <= q.len() keeps the 8-f32 load in-bounds.
            let v = _mm256_loadu_ps(q.as_ptr().add(j));
            acc = _mm256_fmadd_ps(v, v, acc);
            j += 8;
        }
        let mut s = hsum256(acc);
        while j < q.len() {
            s += q[j] * q[j];
            j += 1;
        }
        s
    }

    /// Centroid row `c` of the flat k×d panel (safe, bounds-checked).
    #[inline(always)]
    fn row_at(flat: &[f32], d: usize, c: u32) -> &[f32] {
        let start = c as usize * d;
        &flat[start..start + d]
    }

    /// L1 rows: lane-wise `|q−c|` accumulation (abs via sign-bit andnot),
    /// four candidates per block.
    ///
    // SAFETY: (to call) AVX2 must be available on the executing CPU —
    // guaranteed by the `available()` gate (which also proves FMA, a
    // superset of what this kernel needs).  Loads are bounds-proven
    // exactly as in `euclid_row_avx2`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn l1_row_avx2(
        q: &[f32],
        flat: &[f32],
        d: usize,
        cands: &[u32],
        row: &mut [f32],
    ) {
        debug_assert_eq!(q.len(), d);
        debug_assert_eq!(row.len(), cands.len());
        let sign = _mm256_set1_ps(-0.0);
        let qp = q.as_ptr();
        let mut i = 0;
        while i + 4 <= cands.len() {
            let c0 = row_at(flat, d, cands[i]);
            let c1 = row_at(flat, d, cands[i + 1]);
            let c2 = row_at(flat, d, cands[i + 2]);
            let c3 = row_at(flat, d, cands[i + 3]);
            let mut a0 = _mm256_setzero_ps();
            let mut a1 = _mm256_setzero_ps();
            let mut a2 = _mm256_setzero_ps();
            let mut a3 = _mm256_setzero_ps();
            let mut j = 0;
            while j + 8 <= d {
                // SAFETY: j + 8 <= d and all row slices are d long.
                let vq = _mm256_loadu_ps(qp.add(j));
                let v0 = _mm256_loadu_ps(c0.as_ptr().add(j));
                let v1 = _mm256_loadu_ps(c1.as_ptr().add(j));
                let v2 = _mm256_loadu_ps(c2.as_ptr().add(j));
                let v3 = _mm256_loadu_ps(c3.as_ptr().add(j));
                a0 = _mm256_add_ps(a0, _mm256_andnot_ps(sign, _mm256_sub_ps(vq, v0)));
                a1 = _mm256_add_ps(a1, _mm256_andnot_ps(sign, _mm256_sub_ps(vq, v1)));
                a2 = _mm256_add_ps(a2, _mm256_andnot_ps(sign, _mm256_sub_ps(vq, v2)));
                a3 = _mm256_add_ps(a3, _mm256_andnot_ps(sign, _mm256_sub_ps(vq, v3)));
                j += 8;
            }
            let mut sum = [hsum256(a0), hsum256(a1), hsum256(a2), hsum256(a3)];
            while j < d {
                let x = q[j];
                sum[0] += (x - c0[j]).abs();
                sum[1] += (x - c1[j]).abs();
                sum[2] += (x - c2[j]).abs();
                sum[3] += (x - c3[j]).abs();
                j += 1;
            }
            row[i..i + 4].copy_from_slice(&sum);
            i += 4;
        }
        while i < cands.len() {
            let c = row_at(flat, d, cands[i]);
            let mut acc = _mm256_setzero_ps();
            let mut j = 0;
            while j + 8 <= d {
                // SAFETY: j + 8 <= d with q and c exactly d long.
                let vq = _mm256_loadu_ps(qp.add(j));
                let vc = _mm256_loadu_ps(c.as_ptr().add(j));
                acc = _mm256_add_ps(acc, _mm256_andnot_ps(sign, _mm256_sub_ps(vq, vc)));
                j += 8;
            }
            let mut s = hsum256(acc);
            while j < d {
                s += (q[j] - c[j]).abs();
                j += 1;
            }
            row[i] = s;
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// aarch64: NEON
// ---------------------------------------------------------------------------

#[cfg(all(target_arch = "aarch64", not(miri)))]
mod neon {
    #[allow(clippy::wildcard_imports)]
    use core::arch::aarch64::*;

    /// Centroid row `c` of the flat k×d panel (safe, bounds-checked).
    #[inline(always)]
    fn row_at(flat: &[f32], d: usize, c: u32) -> &[f32] {
        let start = c as usize * d;
        &flat[start..start + d]
    }

    /// Squared-L2 rows, four candidates per block, 4 f32 lanes.
    ///
    // SAFETY: (to call) NEON is the aarch64 baseline, so the
    // `target_feature` requirement is met on every aarch64 CPU; loads are
    // through pointers proven in-bounds by `j + 4 <= d` with every slice
    // exactly `d` long.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn euclid_row_neon(
        q: &[f32],
        flat: &[f32],
        d: usize,
        cands: &[u32],
        cnorms: &[f32],
        row: &mut [f32],
    ) {
        debug_assert_eq!(q.len(), d);
        debug_assert_eq!(row.len(), cands.len());
        let qp = q.as_ptr();
        let mut qacc = vdupq_n_f32(0.0);
        let mut j = 0;
        while j + 4 <= d {
            // SAFETY: j + 4 <= d keeps the 4-f32 load inside `q`.
            let v = vld1q_f32(qp.add(j));
            qacc = vfmaq_f32(qacc, v, v);
            j += 4;
        }
        let mut qn = vaddvq_f32(qacc);
        while j < d {
            qn += q[j] * q[j];
            j += 1;
        }

        let mut i = 0;
        while i + 4 <= cands.len() {
            let c0 = row_at(flat, d, cands[i]);
            let c1 = row_at(flat, d, cands[i + 1]);
            let c2 = row_at(flat, d, cands[i + 2]);
            let c3 = row_at(flat, d, cands[i + 3]);
            let mut a0 = vdupq_n_f32(0.0);
            let mut a1 = vdupq_n_f32(0.0);
            let mut a2 = vdupq_n_f32(0.0);
            let mut a3 = vdupq_n_f32(0.0);
            let mut j = 0;
            while j + 4 <= d {
                // SAFETY: j + 4 <= d and all row slices are d long.
                let vq = vld1q_f32(qp.add(j));
                a0 = vfmaq_f32(a0, vq, vld1q_f32(c0.as_ptr().add(j)));
                a1 = vfmaq_f32(a1, vq, vld1q_f32(c1.as_ptr().add(j)));
                a2 = vfmaq_f32(a2, vq, vld1q_f32(c2.as_ptr().add(j)));
                a3 = vfmaq_f32(a3, vq, vld1q_f32(c3.as_ptr().add(j)));
                j += 4;
            }
            let mut dot = [vaddvq_f32(a0), vaddvq_f32(a1), vaddvq_f32(a2), vaddvq_f32(a3)];
            while j < d {
                let x = q[j];
                dot[0] += x * c0[j];
                dot[1] += x * c1[j];
                dot[2] += x * c2[j];
                dot[3] += x * c3[j];
                j += 1;
            }
            for t in 0..4 {
                let ci = cands[i + t] as usize;
                row[i + t] = (qn - 2.0 * dot[t] + cnorms[ci]).max(0.0);
            }
            i += 4;
        }
        while i < cands.len() {
            let ci = cands[i] as usize;
            let c = row_at(flat, d, cands[i]);
            let mut acc = vdupq_n_f32(0.0);
            let mut j = 0;
            while j + 4 <= d {
                // SAFETY: j + 4 <= d with q and c exactly d long.
                let vq = vld1q_f32(qp.add(j));
                let vc = vld1q_f32(c.as_ptr().add(j));
                acc = vfmaq_f32(acc, vq, vc);
                j += 4;
            }
            let mut dot = vaddvq_f32(acc);
            while j < d {
                dot += q[j] * c[j];
                j += 1;
            }
            row[i] = (qn - 2.0 * dot + cnorms[ci]).max(0.0);
            i += 1;
        }
    }

    /// L1 rows via `vabdq_f32` (absolute difference), four candidates per
    /// block.
    ///
    // SAFETY: (to call) NEON is the aarch64 baseline; loads are
    // bounds-proven exactly as in `euclid_row_neon`.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn l1_row_neon(
        q: &[f32],
        flat: &[f32],
        d: usize,
        cands: &[u32],
        row: &mut [f32],
    ) {
        debug_assert_eq!(q.len(), d);
        debug_assert_eq!(row.len(), cands.len());
        let qp = q.as_ptr();
        let mut i = 0;
        while i + 4 <= cands.len() {
            let c0 = row_at(flat, d, cands[i]);
            let c1 = row_at(flat, d, cands[i + 1]);
            let c2 = row_at(flat, d, cands[i + 2]);
            let c3 = row_at(flat, d, cands[i + 3]);
            let mut a0 = vdupq_n_f32(0.0);
            let mut a1 = vdupq_n_f32(0.0);
            let mut a2 = vdupq_n_f32(0.0);
            let mut a3 = vdupq_n_f32(0.0);
            let mut j = 0;
            while j + 4 <= d {
                // SAFETY: j + 4 <= d and all row slices are d long.
                let vq = vld1q_f32(qp.add(j));
                a0 = vaddq_f32(a0, vabdq_f32(vq, vld1q_f32(c0.as_ptr().add(j))));
                a1 = vaddq_f32(a1, vabdq_f32(vq, vld1q_f32(c1.as_ptr().add(j))));
                a2 = vaddq_f32(a2, vabdq_f32(vq, vld1q_f32(c2.as_ptr().add(j))));
                a3 = vaddq_f32(a3, vabdq_f32(vq, vld1q_f32(c3.as_ptr().add(j))));
                j += 4;
            }
            let mut sum = [vaddvq_f32(a0), vaddvq_f32(a1), vaddvq_f32(a2), vaddvq_f32(a3)];
            while j < d {
                let x = q[j];
                sum[0] += (x - c0[j]).abs();
                sum[1] += (x - c1[j]).abs();
                sum[2] += (x - c2[j]).abs();
                sum[3] += (x - c3[j]).abs();
                j += 1;
            }
            row[i..i + 4].copy_from_slice(&sum);
            i += 4;
        }
        while i < cands.len() {
            let c = row_at(flat, d, cands[i]);
            let mut acc = vdupq_n_f32(0.0);
            let mut j = 0;
            while j + 4 <= d {
                // SAFETY: j + 4 <= d with q and c exactly d long.
                let vq = vld1q_f32(qp.add(j));
                let vc = vld1q_f32(c.as_ptr().add(j));
                acc = vaddq_f32(acc, vabdq_f32(vq, vc));
                j += 4;
            }
            let mut s = vaddvq_f32(acc);
            while j < d {
                s += (q[j] - c[j]).abs();
                j += 1;
            }
            row[i] = s;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{CpuPanels, PanelBackend, PanelJobs, PanelKernel, PanelSet, ParCpuPanels};
    use super::*;
    use crate::kmeans::Metric;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn detection_is_stable_and_consistent() {
        assert_eq!(available(), available());
        assert_eq!(lanes() > 0, available());
        assert!(!describe().is_empty());
    }

    #[test]
    fn simd_rows_match_scalar_oracle_all_dims() {
        // Covers lane-width multiples and every tail class for both 8- and
        // 4-lane kernels, plus candidate counts around the 4-block edges.
        for metric in [Metric::Euclid, Metric::Manhattan] {
            for d in [1usize, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 64] {
                for k in [1usize, 2, 3, 4, 5, 9] {
                    let mut rng = Xoshiro256pp::seed_from_u64((d * 31 + k) as u64);
                    let flat: Vec<f32> = (0..k * d).map(|_| rng.uniform_f32(-3.0, 3.0)).collect();
                    let cents = Dataset::from_flat(k, d, flat);
                    let mut jobs = PanelJobs::new();
                    jobs.clear(d);
                    let mid: Vec<f32> = (0..d).map(|_| rng.uniform_f32(-3.0, 3.0)).collect();
                    let cands: Vec<u32> = (0..k as u32).collect();
                    jobs.push(&mid, &cands);
                    let mut want = PanelSet::new();
                    CpuPanels.panels(&jobs, &cents, metric, &mut want);
                    let mut got = PanelSet::new();
                    let mut simd = ParCpuPanels::with_kernel(1, PanelKernel::Simd);
                    simd.begin_pass(&cents, metric);
                    simd.panels(&jobs, &cents, metric, &mut got);
                    for (x, y) in want.dists.iter().zip(got.dists.iter()) {
                        assert!(
                            (x - y).abs() <= 1e-4 * (1.0 + x.abs()),
                            "{metric:?} d={d} k={k}: {x} vs {y}"
                        );
                    }
                }
            }
        }
    }
}
