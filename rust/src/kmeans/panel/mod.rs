//! The distance-panel engine: flat, arena-backed panel batches and the
//! blocked, multi-threaded CPU kernels that fill them.
//!
//! One *job* is a query point (kd-cell midpoint or leaf point) plus a set
//! of candidate centroid indices; a *panel* is the job's distance row
//! (query → every candidate).  The level-batched filtering traversal
//! ([`crate::kmeans::filtering::filter_iteration_batched`]) assembles one
//! job batch per tree level and ships it through a [`PanelBackend`] —
//! the software analogue of the paper's PS→PL BRAM bridge.
//!
//! Everything here is *flat*:
//!
//! - [`PanelJobs`] holds the whole batch in three arenas (`mids` row-major,
//!   candidates + ragged offsets) — no per-job `Vec`s;
//! - [`PanelSet`] holds every distance row in one arena with the same
//!   ragged offsets — allocated once per run and recycled across levels
//!   and iterations (see `FilterScratch`).
//!
//! Backends:
//!
//! - [`CpuPanels`] — the scalar reference: one [`Metric::dist`] call per
//!   (job, candidate), bit-identical to the recursive engine's arithmetic.
//!   This is the semantic oracle the equivalence tests pin.
//! - [`ParCpuPanels`] — the production CPU backend: splits the job list
//!   across `std::thread::scope` workers (each writing a disjoint slice of
//!   the output arena) and, with [`PanelKernel::Blocked`], computes
//!   squared-L2 via the `‖q−c‖² = ‖q‖² − 2·q·c + ‖c‖²` decomposition with
//!   per-pass cached centroid norms and 8-wide manually unrolled inner
//!   loops (the shape the autovectorizer turns into SIMD).  The blocked
//!   kernel matches the scalar one to f32 rounding (≤ ~1e-4 relative),
//!   which the property tests in `tests/panel_engine.rs` enforce.
//! - [`PanelKernel::Simd`] upgrades the blocked kernel's inner loops to
//!   explicit `core::arch` intrinsics ([`simd`]: AVX2/FMA on x86-64, NEON
//!   on aarch64), runtime-detected once per process; see [`KernelKind`]
//!   for the user-facing dispatch seam.
//! - [`quant::QuantPanels`] — the reduced-precision shortlist backend
//!   mirroring the paper's fixed-point PL arithmetic: i8-quantized
//!   centroid panels score every candidate cheaply, survivors are
//!   re-scored in exact f32, so emitted *labels* stay bitwise-identical
//!   to the scalar oracle.

use super::Metric;
use crate::data::Dataset;

pub mod quant;
pub mod simd;

// ---------------------------------------------------------------------------
// Flat batch containers
// ---------------------------------------------------------------------------

/// A flat batch of panel jobs: query points plus ragged candidate lists.
///
/// Arena-backed: `clear` + `push` recycle the allocations, so steady-state
/// traversal allocates nothing per level.  Offsets are `u32` (a single
/// level batch is capped at 2^32 candidate evaluations — far beyond the
/// BRAM-bridge scale this models).
#[derive(Clone, Debug)]
pub struct PanelJobs {
    d: usize,
    mids: Vec<f32>,
    cand: Vec<u32>,
    cand_off: Vec<u32>,
}

impl Default for PanelJobs {
    fn default() -> Self {
        Self::new()
    }
}

impl PanelJobs {
    pub fn new() -> Self {
        Self {
            d: 0,
            mids: Vec::new(),
            cand: Vec::new(),
            cand_off: vec![0],
        }
    }

    /// Reuse an existing (possibly filled) batch for a new set of jobs of
    /// dimensionality `d`.  Keeps the arena capacity.
    pub fn clear(&mut self, d: usize) {
        debug_assert!(d > 0);
        self.d = d;
        self.mids.clear();
        self.cand.clear();
        self.cand_off.clear();
        self.cand_off.push(0);
    }

    /// Rebuild from raw parts (the offload-service wire format).
    pub fn from_parts(d: usize, mids: Vec<f32>, cand: Vec<u32>, cand_off: Vec<u32>) -> Self {
        debug_assert!(!cand_off.is_empty() && cand_off[0] == 0);
        debug_assert_eq!(mids.len(), (cand_off.len() - 1) * d);
        debug_assert_eq!(*cand_off.last().unwrap() as usize, cand.len());
        Self {
            d,
            mids,
            cand,
            cand_off,
        }
    }

    /// Append one job with an explicit query point.
    #[inline]
    pub fn push(&mut self, mid: &[f32], cands: &[u32]) {
        debug_assert_eq!(mid.len(), self.d);
        self.mids.extend_from_slice(mid);
        self.push_cands(cands);
    }

    /// Append one job whose query point is written in place by `fill`
    /// (used for kd-cell midpoints — no temporary buffer).
    #[inline]
    pub fn push_with(&mut self, cands: &[u32], fill: impl FnOnce(&mut [f32])) {
        let start = self.mids.len();
        self.mids.resize(start + self.d, 0.0);
        fill(&mut self.mids[start..]);
        self.push_cands(cands);
    }

    #[inline]
    fn push_cands(&mut self, cands: &[u32]) {
        self.cand.extend_from_slice(cands);
        debug_assert!(self.cand.len() <= u32::MAX as usize);
        self.cand_off.push(self.cand.len() as u32);
    }

    /// Number of jobs in the batch.
    #[inline]
    pub fn len(&self) -> usize {
        self.cand_off.len() - 1
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Query dimensionality.
    #[inline]
    pub fn dims(&self) -> usize {
        self.d
    }

    /// Query point of job `j`.
    #[inline]
    pub fn mid(&self, j: usize) -> &[f32] {
        &self.mids[j * self.d..(j + 1) * self.d]
    }

    /// Candidate centroid rows of job `j`.
    #[inline]
    pub fn cands(&self, j: usize) -> &[u32] {
        &self.cand[self.cand_off[j] as usize..self.cand_off[j + 1] as usize]
    }

    /// Total candidate evaluations across the batch.
    #[inline]
    pub fn total_cands(&self) -> usize {
        *self.cand_off.last().unwrap() as usize
    }

    /// Largest candidate list in the batch.
    pub fn max_cands(&self) -> usize {
        self.cand_off
            .windows(2)
            .map(|w| (w[1] - w[0]) as usize)
            .max()
            .unwrap_or(0)
    }

    /// The flat arenas (wire format for the offload service).
    pub fn parts(&self) -> (usize, &[f32], &[u32], &[u32]) {
        (self.d, &self.mids, &self.cand, &self.cand_off)
    }
}

/// A flat set of distance panels: one arena of distances plus ragged
/// offsets mirroring the job batch's candidate lists.
///
/// `reset_from` re-shapes the set for a new batch while keeping the arena
/// allocation — the whole filtering run reuses a single `PanelSet`.
#[derive(Clone, Debug)]
pub struct PanelSet {
    pub(crate) dists: Vec<f32>,
    pub(crate) offsets: Vec<u32>,
}

impl Default for PanelSet {
    fn default() -> Self {
        Self::new()
    }
}

impl PanelSet {
    pub fn new() -> Self {
        Self {
            dists: Vec::new(),
            offsets: vec![0],
        }
    }

    /// Shape this set for `jobs` (row `j` gets exactly `jobs.cands(j).len()`
    /// slots), recycling the arenas.
    pub fn reset_from(&mut self, jobs: &PanelJobs) {
        let (_, _, _, cand_off) = jobs.parts();
        self.offsets.clear();
        self.offsets.extend_from_slice(cand_off);
        let total = jobs.total_cands();
        // Backends overwrite every slot, so surviving values need no
        // zeroing — only growth pays the fill.
        if self.dists.len() > total {
            self.dists.truncate(total);
        } else {
            self.dists.resize(total, 0.0);
        }
    }

    /// Number of panel rows.
    #[inline]
    pub fn len(&self) -> usize {
        self.offsets.len() - 1
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Distance row of job `j`, aligned with its candidate list.
    #[inline]
    pub fn row(&self, j: usize) -> &[f32] {
        &self.dists[self.offsets[j] as usize..self.offsets[j + 1] as usize]
    }

    /// Mutable distance row of job `j`.
    #[inline]
    pub fn row_mut(&mut self, j: usize) -> &mut [f32] {
        &mut self.dists[self.offsets[j] as usize..self.offsets[j + 1] as usize]
    }
}

// ---------------------------------------------------------------------------
// Backend contract
// ---------------------------------------------------------------------------

/// Distance-panel provider for the batched filtering engine.
///
/// The engine calls [`begin_pass`](PanelBackend::begin_pass) once per
/// filtering iteration (fixed centroids), then
/// [`panels`](PanelBackend::panels) once per tree level.  Backends may
/// precompute per-centroid state (e.g. squared norms) in `begin_pass`;
/// `panels` must only be called after a `begin_pass` with the same
/// centroids/metric.
pub trait PanelBackend {
    /// Per-iteration hook; default is a no-op.
    fn begin_pass(&mut self, _centroids: &Dataset, _metric: Metric) {}

    /// Compute every job's distance panel into `out` (re-shaped by the
    /// implementation via [`PanelSet::reset_from`]).
    fn panels(
        &mut self,
        jobs: &PanelJobs,
        centroids: &Dataset,
        metric: Metric,
        out: &mut PanelSet,
    );

    /// Kernel-tier telemetry: lane width plus lifetime quantize/rescore
    /// counters.  Callers that want per-run numbers snapshot before and
    /// after and subtract ([`KernelStats::delta_from`]).  Default: all
    /// zeros (scalar-tier backends have nothing to report).
    fn kernel_stats(&self) -> KernelStats {
        KernelStats::default()
    }
}

/// Telemetry from the kernel tier of a [`PanelBackend`].
///
/// `simd_lanes` is a gauge (f32 lanes per vector op of the active kernel:
/// 8 for AVX2, 4 for NEON, 0 for scalar/blocked); the candidate counters
/// are lifetime-monotonic for the backend instance.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KernelStats {
    /// f32 lanes per vector op in the active kernel (0 = no SIMD tier).
    pub simd_lanes: u32,
    /// Candidates scored through the reduced-precision (i8) path.
    pub quantized_candidates: u64,
    /// Quantized candidates that survived the shortlist and were
    /// re-scored in exact f32.
    pub rescored_candidates: u64,
}

impl KernelStats {
    /// Counters accumulated since `earlier` (gauge fields are carried,
    /// not subtracted).
    pub fn delta_from(&self, earlier: &KernelStats) -> KernelStats {
        KernelStats {
            simd_lanes: self.simd_lanes,
            quantized_candidates: self
                .quantized_candidates
                .saturating_sub(earlier.quantized_candidates),
            rescored_candidates: self
                .rescored_candidates
                .saturating_sub(earlier.rescored_candidates),
        }
    }
}

// Forwarding impls so trait objects plug into the generic engine entry
// points: `&mut dyn PanelBackend` / `Box<dyn PanelBackend>` are themselves
// backends (what the `solver` layer's injected-backend seam relies on).
impl<B: PanelBackend + ?Sized> PanelBackend for &mut B {
    fn begin_pass(&mut self, centroids: &Dataset, metric: Metric) {
        (**self).begin_pass(centroids, metric);
    }

    fn panels(
        &mut self,
        jobs: &PanelJobs,
        centroids: &Dataset,
        metric: Metric,
        out: &mut PanelSet,
    ) {
        (**self).panels(jobs, centroids, metric, out);
    }

    fn kernel_stats(&self) -> KernelStats {
        (**self).kernel_stats()
    }
}

impl<B: PanelBackend + ?Sized> PanelBackend for Box<B> {
    fn begin_pass(&mut self, centroids: &Dataset, metric: Metric) {
        (**self).begin_pass(centroids, metric);
    }

    fn panels(
        &mut self,
        jobs: &PanelJobs,
        centroids: &Dataset,
        metric: Metric,
        out: &mut PanelSet,
    ) {
        (**self).panels(jobs, centroids, metric, out);
    }

    fn kernel_stats(&self) -> KernelStats {
        (**self).kernel_stats()
    }
}

/// Which inner kernel fills the rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PanelKernel {
    /// One `Metric::dist` per (job, candidate) — bit-identical to the
    /// recursive reference engine.
    Scalar,
    /// Norm-decomposition squared-L2 / 8-wide L1 — equal to `Scalar` up to
    /// f32 rounding (≤ ~1e-4 relative), measurably faster.
    Blocked,
    /// The blocked kernel with explicit `core::arch` inner loops
    /// ([`simd`]): AVX2/FMA on x86-64, NEON on aarch64.  Same arithmetic
    /// shape and tolerance contract as `Blocked`.  Only constructible
    /// where [`simd::available`] is true — [`ParCpuPanels::with_kernel`]
    /// demotes it to `Blocked` otherwise, and [`KernelKind::resolve`]
    /// turns an explicit request on an unsupported host into a clean
    /// error.
    Simd,
}

/// The user-facing kernel-dispatch seam: what `--kernel` parses to and
/// what [`crate::kmeans::solver::KmeansSpec`] carries.  `Scalar`/`Blocked`
/// /`Simd` request that tier explicitly; `Auto` picks the fastest tier the
/// host supports ([`PanelKernel::Simd`] where detected, else `Blocked`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelKind {
    Scalar,
    #[default]
    Blocked,
    Simd,
    Auto,
}

impl KernelKind {
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Blocked => "blocked",
            KernelKind::Simd => "simd",
            KernelKind::Auto => "auto",
        }
    }

    pub fn all() -> [KernelKind; 4] {
        [
            KernelKind::Scalar,
            KernelKind::Blocked,
            KernelKind::Simd,
            KernelKind::Auto,
        ]
    }

    /// Strict resolution for explicit user requests: `Simd` on a host
    /// without the feature set is an error (the CLI surfaces it as such),
    /// never a silent downgrade.  `Auto` always resolves.
    pub fn resolve(self) -> Result<PanelKernel, String> {
        match self {
            KernelKind::Scalar => Ok(PanelKernel::Scalar),
            KernelKind::Blocked => Ok(PanelKernel::Blocked),
            KernelKind::Simd => {
                if simd::available() {
                    Ok(PanelKernel::Simd)
                } else {
                    Err(format!(
                        "kernel `simd` requested but this host has no supported \
                         SIMD feature set ({}); use `auto` to fall back to `blocked`",
                        simd::describe()
                    ))
                }
            }
            KernelKind::Auto => Ok(KernelKind::Auto.effective()),
        }
    }

    /// Lenient resolution for library defaults: `Simd`/`Auto` degrade to
    /// `Blocked` when the host lacks the feature set.
    pub fn effective(self) -> PanelKernel {
        match self {
            KernelKind::Scalar => PanelKernel::Scalar,
            KernelKind::Blocked => PanelKernel::Blocked,
            KernelKind::Simd | KernelKind::Auto => {
                if simd::available() {
                    PanelKernel::Simd
                } else {
                    PanelKernel::Blocked
                }
            }
        }
    }
}

impl std::str::FromStr for KernelKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "scalar" => Ok(KernelKind::Scalar),
            "blocked" => Ok(KernelKind::Blocked),
            "simd" => Ok(KernelKind::Simd),
            "auto" => Ok(KernelKind::Auto),
            other => Err(format!("unknown kernel `{other}` (scalar|blocked|simd|auto)")),
        }
    }
}

impl std::fmt::Display for KernelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Plain-CPU scalar panel backend (software baseline, semantic oracle).
#[derive(Clone, Copy, Debug, Default)]
pub struct CpuPanels;

impl PanelBackend for CpuPanels {
    fn panels(
        &mut self,
        jobs: &PanelJobs,
        centroids: &Dataset,
        metric: Metric,
        out: &mut PanelSet,
    ) {
        out.reset_from(jobs);
        fill_range(
            jobs,
            centroids,
            metric,
            PanelKernel::Scalar,
            &[],
            0,
            jobs.len(),
            &mut out.dists,
            0,
        );
    }
}

/// Multi-threaded CPU panel backend: the job list is split into
/// candidate-count-balanced chunks, one `std::thread::scope` worker per
/// chunk, each writing a disjoint slice of the output arena.
#[derive(Clone, Debug)]
pub struct ParCpuPanels {
    workers: usize,
    kernel: PanelKernel,
    /// Squared centroid norms (Blocked + Euclid only).
    cnorms: Vec<f32>,
    /// Identity (buffer address + length, as usizes so the backend stays
    /// `Send`) of the centroid set `begin_pass` cached norms for; `None`
    /// when nothing is cached.  `panels` reuses the cache only when its
    /// centroids have this exact identity and recomputes otherwise.
    cnorms_key: Option<(usize, usize)>,
}

/// Cache key for a centroid set: buffer address + length.  Distinguishes
/// any two simultaneously-live buffers; a freed-and-reallocated buffer at
/// the same address/length (with `begin_pass` never re-called, violating
/// its documented contract) is the one case it cannot see.
fn centroid_key(centroids: &Dataset) -> (usize, usize) {
    (centroids.flat().as_ptr() as usize, centroids.flat().len())
}

/// Below this many candidate evaluations a batch is filled inline — the
/// spawn overhead would dominate (upper tree levels have 1–2 jobs).
const PAR_MIN_EVALS: usize = 4096;

impl ParCpuPanels {
    /// Blocked kernel across `workers` threads (the production profile).
    pub fn new(workers: usize) -> Self {
        Self::with_kernel(workers, PanelKernel::Blocked)
    }

    /// Scalar kernel across `workers` threads — bit-identical results to
    /// [`CpuPanels`] regardless of thread count (each row's arithmetic is
    /// independent), for consumers that pin exact equivalence.
    pub fn scalar(workers: usize) -> Self {
        Self::with_kernel(workers, PanelKernel::Scalar)
    }

    /// Build with an explicit kernel.  A `Simd` request on a host without
    /// the feature set is demoted to `Blocked` (same arithmetic contract)
    /// — `kernel()` reports the *effective* tier.  Callers that want a
    /// hard error instead go through [`KernelKind::resolve`] first.
    pub fn with_kernel(workers: usize, kernel: PanelKernel) -> Self {
        let kernel = if kernel == PanelKernel::Simd && !simd::available() {
            PanelKernel::Blocked
        } else {
            kernel
        };
        Self {
            workers: workers.max(1),
            kernel,
            cnorms: Vec::new(),
            cnorms_key: None,
        }
    }

    /// Build from the user-facing dispatch seam (lenient: `Simd`/`Auto`
    /// degrade to `Blocked` off-host).
    pub fn with_kind(workers: usize, kind: KernelKind) -> Self {
        Self::with_kernel(workers, kind.effective())
    }

    fn needs_cnorms(&self, metric: Metric) -> bool {
        matches!(self.kernel, PanelKernel::Blocked | PanelKernel::Simd)
            && metric == Metric::Euclid
    }

    fn compute_cnorms(&mut self, centroids: &Dataset) {
        self.cnorms.clear();
        self.cnorms.reserve(centroids.len());
        for c in centroids.iter() {
            self.cnorms.push(dot8(c, c));
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The *effective* kernel (a demoted `Simd` request reads `Blocked`).
    pub fn kernel(&self) -> PanelKernel {
        self.kernel
    }
}

impl PanelBackend for ParCpuPanels {
    /// Caches centroid norms for the pass.  Subsequent `panels` calls
    /// reuse the cache only for this exact centroid buffer — callers that
    /// mutate or replace centroids between passes must call `begin_pass`
    /// again (the batched engine does this every iteration).
    fn begin_pass(&mut self, centroids: &Dataset, metric: Metric) {
        self.cnorms_key = None;
        self.cnorms.clear();
        if self.needs_cnorms(metric) {
            self.compute_cnorms(centroids);
            self.cnorms_key = Some(centroid_key(centroids));
        }
    }

    fn panels(
        &mut self,
        jobs: &PanelJobs,
        centroids: &Dataset,
        metric: Metric,
        out: &mut PanelSet,
    ) {
        out.reset_from(jobs);
        let n = jobs.len();
        if n == 0 {
            return;
        }
        // No begin_pass for this exact centroid buffer → compute fresh
        // norms for this call; a caller that skips begin_pass just loses
        // the per-pass reuse (see `centroid_key` for the one caveat).
        if self.needs_cnorms(metric) && self.cnorms_key != Some(centroid_key(centroids)) {
            self.compute_cnorms(centroids);
            self.cnorms_key = None;
        }
        let total = jobs.total_cands();
        let workers = self.workers.min(n);
        if workers <= 1 || total < PAR_MIN_EVALS {
            fill_range(
                jobs,
                centroids,
                metric,
                self.kernel,
                &self.cnorms,
                0,
                n,
                &mut out.dists,
                0,
            );
            return;
        }

        // Chunk boundaries balanced by candidate evaluations, aligned to
        // whole jobs.
        let (_, _, _, off) = jobs.parts();
        let target = total.div_ceil(workers);
        let mut bounds = Vec::with_capacity(workers + 1);
        bounds.push(0usize);
        let mut acc = 0usize;
        for j in 0..n {
            acc += (off[j + 1] - off[j]) as usize;
            if acc >= target && bounds.len() < workers {
                bounds.push(j + 1);
                acc = 0;
            }
        }
        bounds.push(n);

        let kernel = self.kernel;
        let cnorms = &self.cnorms;
        std::thread::scope(|scope| {
            let mut rest: &mut [f32] = &mut out.dists;
            let mut consumed = 0usize;
            for t in 0..bounds.len() - 1 {
                let (j0, j1) = (bounds[t], bounds[t + 1]);
                if j0 == j1 {
                    continue;
                }
                let end = off[j1] as usize;
                let (seg, tail) = rest.split_at_mut(end - consumed);
                rest = tail;
                let base = consumed;
                consumed = end;
                scope.spawn(move || {
                    fill_range(jobs, centroids, metric, kernel, cnorms, j0, j1, seg, base);
                });
            }
        });
    }

    fn kernel_stats(&self) -> KernelStats {
        KernelStats {
            simd_lanes: if self.kernel == PanelKernel::Simd {
                simd::lanes()
            } else {
                0
            },
            quantized_candidates: 0,
            rescored_candidates: 0,
        }
    }
}

// ---------------------------------------------------------------------------
// Kernels
// ---------------------------------------------------------------------------

/// Fill rows `[j0, j1)` of the batch into `seg`, which is the output arena
/// slice covering exactly those rows (`base` = arena offset of `seg[0]`).
#[allow(clippy::too_many_arguments)]
fn fill_range(
    jobs: &PanelJobs,
    centroids: &Dataset,
    metric: Metric,
    kernel: PanelKernel,
    cnorms: &[f32],
    j0: usize,
    j1: usize,
    seg: &mut [f32],
    base: usize,
) {
    let (_, _, _, off) = jobs.parts();
    for j in j0..j1 {
        let lo = off[j] as usize - base;
        let hi = off[j + 1] as usize - base;
        let row = &mut seg[lo..hi];
        let q = jobs.mid(j);
        let cands = jobs.cands(j);
        match (kernel, metric) {
            (PanelKernel::Scalar, _) => {
                for (slot, &c) in cands.iter().enumerate() {
                    row[slot] = metric.dist(q, centroids.point(c as usize));
                }
            }
            (PanelKernel::Blocked, Metric::Euclid) => {
                euclid_row_blocked(q, centroids, cands, cnorms, row);
            }
            (PanelKernel::Blocked, Metric::Manhattan) => {
                for (slot, &c) in cands.iter().enumerate() {
                    row[slot] = l1_8(q, centroids.point(c as usize));
                }
            }
            (PanelKernel::Simd, Metric::Euclid) => {
                simd::euclid_row(q, centroids, cands, cnorms, row);
            }
            (PanelKernel::Simd, Metric::Manhattan) => {
                simd::l1_row(q, centroids, cands, row);
            }
        }
    }
}

/// Squared-L2 row via the norm decomposition: `‖q‖²` once per job,
/// `‖c‖²` from the per-pass cache, one 8-wide dot product per candidate.
#[inline]
fn euclid_row_blocked(
    q: &[f32],
    centroids: &Dataset,
    cands: &[u32],
    cnorms: &[f32],
    row: &mut [f32],
) {
    let qn = dot8(q, q);
    for (slot, &c) in cands.iter().enumerate() {
        let ci = c as usize;
        let d = qn - 2.0 * dot8(q, centroids.point(ci)) + cnorms[ci];
        // The decomposition can round slightly negative near zero.
        row[slot] = d.max(0.0);
    }
}

/// 8-wide manually unrolled dot product — eight independent accumulator
/// lanes so the autovectorizer emits one FMA vector op per chunk.
#[inline]
pub(crate) fn dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        for lane in 0..8 {
            acc[lane] += xa[lane] * xb[lane];
        }
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        s += x * y;
    }
    s
}

/// 8-wide manually unrolled L1 distance (same lane structure as [`dot8`]).
#[inline]
pub(crate) fn l1_8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        for lane in 0..8 {
            acc[lane] += (xa[lane] - xb[lane]).abs();
        }
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        s += (x - y).abs();
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn random_problem(
        seed: u64,
        jobs: usize,
        d: usize,
        k: usize,
    ) -> (PanelJobs, Dataset) {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let cents = Dataset::from_flat(
            k,
            d,
            (0..k * d).map(|_| rng.uniform_f32(-3.0, 3.0)).collect(),
        );
        let mut batch = PanelJobs::new();
        batch.clear(d);
        let mut mid = vec![0f32; d];
        for _ in 0..jobs {
            for m in mid.iter_mut() {
                *m = rng.uniform_f32(-3.0, 3.0);
            }
            let len = 1 + rng.below_usize(k);
            let mut c: Vec<u32> = (0..k as u32).collect();
            rng.shuffle(&mut c);
            c.truncate(len);
            batch.push(&mid, &c);
        }
        (batch, cents)
    }

    #[test]
    fn panel_jobs_layout() {
        let mut b = PanelJobs::new();
        b.clear(2);
        b.push(&[1.0, 2.0], &[0, 3]);
        b.push_with(&[1], |m| {
            m[0] = 5.0;
            m[1] = 6.0;
        });
        assert_eq!(b.len(), 2);
        assert_eq!(b.dims(), 2);
        assert_eq!(b.mid(0), &[1.0, 2.0]);
        assert_eq!(b.mid(1), &[5.0, 6.0]);
        assert_eq!(b.cands(0), &[0, 3]);
        assert_eq!(b.cands(1), &[1]);
        assert_eq!(b.total_cands(), 3);
        assert_eq!(b.max_cands(), 2);
        // clear recycles.
        b.clear(3);
        assert!(b.is_empty());
        assert_eq!(b.total_cands(), 0);
    }

    #[test]
    fn panel_set_shapes_match_jobs() {
        let (batch, cents) = random_problem(1, 17, 3, 5);
        let mut out = PanelSet::new();
        CpuPanels.panels(&batch, &cents, Metric::Euclid, &mut out);
        assert_eq!(out.len(), batch.len());
        for j in 0..batch.len() {
            assert_eq!(out.row(j).len(), batch.cands(j).len());
            for (slot, &c) in batch.cands(j).iter().enumerate() {
                let want = Metric::Euclid.dist(batch.mid(j), cents.point(c as usize));
                assert_eq!(out.row(j)[slot], want, "scalar backend must be exact");
            }
        }
    }

    #[test]
    fn unrolled_kernels_match_naive() {
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        for len in 1..=33 {
            let a: Vec<f32> = (0..len).map(|_| rng.uniform_f32(-2.0, 2.0)).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.uniform_f32(-2.0, 2.0)).collect();
            let dot: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let l1: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
            assert!((dot8(&a, &b) - dot).abs() < 1e-4 * (1.0 + dot.abs()), "len {len}");
            assert!((l1_8(&a, &b) - l1).abs() < 1e-4 * (1.0 + l1.abs()), "len {len}");
        }
    }

    #[test]
    fn par_scalar_is_bit_identical_to_cpu() {
        for metric in [Metric::Euclid, Metric::Manhattan] {
            let (batch, cents) = random_problem(7, 300, 15, 20);
            let mut a = PanelSet::new();
            let mut b = PanelSet::new();
            CpuPanels.begin_pass(&cents, metric);
            CpuPanels.panels(&batch, &cents, metric, &mut a);
            let mut par = ParCpuPanels::scalar(4);
            par.begin_pass(&cents, metric);
            par.panels(&batch, &cents, metric, &mut b);
            assert_eq!(a.dists, b.dists, "{metric:?}");
            assert_eq!(a.offsets, b.offsets);
        }
    }

    #[test]
    fn blocked_kernel_close_to_scalar() {
        for metric in [Metric::Euclid, Metric::Manhattan] {
            for d in [1usize, 3, 7, 8, 15, 16, 31] {
                let (batch, cents) = random_problem(d as u64 ^ 0xA5, 60, d, 9);
                let mut a = PanelSet::new();
                let mut b = PanelSet::new();
                CpuPanels.panels(&batch, &cents, metric, &mut a);
                let mut blk = ParCpuPanels::with_kernel(3, PanelKernel::Blocked);
                blk.begin_pass(&cents, metric);
                blk.panels(&batch, &cents, metric, &mut b);
                for (x, y) in a.dists.iter().zip(b.dists.iter()) {
                    assert!(
                        (x - y).abs() <= 1e-4 * (1.0 + x.abs()),
                        "{metric:?} d={d}: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn empty_batch_is_fine() {
        let mut b = PanelJobs::new();
        b.clear(4);
        let cents = Dataset::from_flat(2, 4, vec![0.0; 8]);
        let mut out = PanelSet::new();
        let mut par = ParCpuPanels::new(4);
        par.begin_pass(&cents, Metric::Euclid);
        par.panels(&b, &cents, Metric::Euclid, &mut out);
        assert!(out.is_empty());
    }
}
