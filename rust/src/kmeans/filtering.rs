//! The kd-tree filtering algorithm (paper Alg. 1, Kanungo et al. [7]).
//!
//! Two traversal engines over the same math:
//!
//! - [`run`] / [`filter_iteration`] — depth-first recursion, the reference
//!   implementation used by the software baselines and by [13]'s
//!   architecture model.
//! - [`run_batched`] / [`filter_iteration_batched`] — breadth-first by tree
//!   level, where each level's candidate-distance panels are computed
//!   through a [`PanelBackend`] in one batch.  This is the paper's HW/SW
//!   split: traversal, pruning geometry and bookkeeping stay on the "PS"
//!   (this code), while the distance arithmetic ships to the "PL" (the
//!   PJRT-executed Pallas kernel via `runtime::PjrtPanels`, or [`CpuPanels`]
//!   for a software run).  Batching per level is exactly how the paper
//!   sizes its BRAM bridge (section 4.2).
//!
//! Both engines produce identical assignments/centroids up to f32
//! accumulation order (verified against each other and against Lloyd in
//! the tests — the filtering algorithm is *exact*, not approximate).
//!
//! # Panel engine
//!
//! The batched engine's distance arithmetic lives in
//! [`crate::kmeans::panel`].  The shapes on the seam:
//!
//! - [`PanelJobs`] — one tree level's job batch, flat: `mids` is the
//!   `[jobs, d]` row-major query arena (cell midpoints and leaf points),
//!   candidates are a single `u32` arena with ragged offsets.
//! - [`PanelSet`] — the distance panels coming back, one `f32` arena with
//!   the same ragged offsets (`PanelSet { dists, offsets }`).
//! - [`PanelBackend`] — `begin_pass` once per iteration (backends cache
//!   per-centroid state, e.g. squared norms), `panels` once per level.
//!
//! All of it is arena-backed and owned by a [`FilterScratch`], which
//! [`run_batched`] allocates **once per run** and recycles across levels
//! and iterations — the steady-state traversal performs no heap
//! allocation.  Candidate sets in the wave are shared: a split node pushes
//! its surviving candidates once and both children reference the same
//! range.
//!
//! Backends: [`CpuPanels`] (scalar oracle, bit-identical to the recursive
//! engine), [`ParCpuPanels`] (multi-threaded, optionally blocked kernels —
//! the software "PL"), and `runtime::PjrtPanels` / the coordinator's
//! offload service for the real PJRT seam.

use super::bounds::{BoundsMode, BoundsState};
use super::panel::{PanelJobs, PanelSet};
use super::{
    centroids_from_sums, max_sq_movement, IterHook, IterStats, KmeansResult, LevelWork, Metric,
    ResultExt, RunStats,
};
use crate::data::Dataset;
use crate::kdtree::KdTree;

pub use super::panel::quant::QuantPanels;
pub use super::panel::{
    CpuPanels, KernelKind, KernelStats, PanelBackend, PanelKernel, ParCpuPanels,
};

/// Options shared by both engines.
#[derive(Clone, Debug)]
pub struct FilterOpts {
    pub metric: Metric,
    pub tol: f32,
    pub max_iters: usize,
    /// Triangle-inequality bounds pruning (DESIGN.md §10).  Applies to
    /// the *batched* engine only — the recursive reference engine always
    /// runs unpruned; `Off` (the default) leaves the batched engine
    /// bitwise on its legacy path.
    pub bounds: BoundsMode,
}

impl Default for FilterOpts {
    fn default() -> Self {
        Self {
            metric: Metric::Euclid,
            tol: 1e-6,
            max_iters: 100,
            bounds: BoundsMode::Off,
        }
    }
}

/// Accumulators for one filtering pass.
struct Scratch {
    sums: Vec<f32>,
    counts: Vec<u32>,
}

impl Scratch {
    fn new(k: usize, d: usize) -> Self {
        Self {
            sums: vec![0.0; k * d],
            counts: vec![0; k],
        }
    }

    #[inline]
    fn add_point(&mut self, c: usize, p: &[f32], d: usize) {
        let row = &mut self.sums[c * d..(c + 1) * d];
        for (j, &v) in p.iter().enumerate() {
            row[j] += v;
        }
        self.counts[c] += 1;
    }

    #[inline]
    fn add_subtree(&mut self, c: usize, wgt: &[f32], count: u32, d: usize) {
        let row = &mut self.sums[c * d..(c + 1) * d];
        for (j, &v) in wgt.iter().enumerate() {
            row[j] += v;
        }
        self.counts[c] += count;
    }
}

// ---------------------------------------------------------------------------
// Recursive engine (Alg. 1 verbatim)
// ---------------------------------------------------------------------------

/// One filtering pass: returns `(sums, counts, stats)` and writes
/// per-point assignments.
pub fn filter_iteration(
    tree: &KdTree,
    data: &Dataset,
    centroids: &Dataset,
    metric: Metric,
    assignments: &mut [u32],
) -> (Vec<f32>, Vec<u32>, IterStats) {
    let k = centroids.len();
    let d = data.dims();
    let mut scratch = Scratch::new(k, d);
    let mut stats = IterStats::default();
    // §Perf L3-3: candidate sets live in one arena stack (frames are
    // (start, len) ranges) and the midpoint goes into a reused buffer —
    // the recursion allocates nothing per node.
    let mut cand_buf: Vec<u32> = (0..k as u32).collect();
    let mut mid_buf = vec![0f32; d];
    recurse(
        tree,
        0,
        data,
        centroids,
        metric,
        (0, k),
        &mut cand_buf,
        &mut mid_buf,
        &mut scratch,
        &mut stats,
        assignments,
    );
    (scratch.sums, scratch.counts, stats)
}

#[allow(clippy::too_many_arguments)]
fn recurse(
    tree: &KdTree,
    node_idx: u32,
    data: &Dataset,
    centroids: &Dataset,
    metric: Metric,
    cand: (usize, usize),
    cand_buf: &mut Vec<u32>,
    mid_buf: &mut Vec<f32>,
    scratch: &mut Scratch,
    stats: &mut IterStats,
    assignments: &mut [u32],
) {
    let node = &tree.nodes[node_idx as usize];
    let d = data.dims();
    let (cand_start, cand_len) = cand;
    stats.node_visits += 1;
    let depth = node.depth as usize;
    if stats.levels.len() <= depth {
        stats.levels.resize(depth + 1, LevelWork::default());
    }

    if node.is_leaf() {
        // Alg. 1 lines 3-6 (bucketed): nearest candidate per point.
        stats.levels[depth].leaf_jobs += node.len as u64;
        stats.levels[depth].cand_evals += node.len as u64 * cand_len as u64;
        for &pi in tree.node_points(node) {
            let p = data.point(pi as usize);
            let mut best = cand_buf[cand_start];
            let mut best_d = f32::INFINITY;
            for ci in cand_start..cand_start + cand_len {
                let c = cand_buf[ci];
                let dist = metric.dist(p, centroids.point(c as usize));
                if dist < best_d {
                    best_d = dist;
                    best = c;
                }
            }
            stats.dist_evals += cand_len as u64;
            stats.leaf_points += 1;
            scratch.add_point(best as usize, p, d);
            assignments[pi as usize] = best;
        }
        return;
    }

    // Alg. 1 line 7: closest candidate to the cell midpoint.
    node.bbox.midpoint_into(mid_buf);
    let mut z_star = cand_buf[cand_start];
    let mut z_star_d = f32::INFINITY;
    for ci in cand_start..cand_start + cand_len {
        let c = cand_buf[ci];
        let dist = metric.dist(mid_buf, centroids.point(c as usize));
        if dist < z_star_d {
            z_star_d = dist;
            z_star = c;
        }
    }
    stats.dist_evals += cand_len as u64;
    stats.levels[depth].interior_jobs += 1;
    stats.levels[depth].cand_evals += cand_len as u64;

    // Alg. 1 lines 8-11: prune candidates farther than z* from the cell.
    // Survivors are pushed onto the arena top, forming the child frame.
    let keep_start = cand_buf.len();
    for ci in cand_start..cand_start + cand_len {
        let c = cand_buf[ci];
        if c == z_star {
            cand_buf.push(c);
            continue;
        }
        stats.prune_tests += 1;
        stats.levels[depth].prune_tests += 1;
        if !node
            .bbox
            .is_farther(centroids.point(c as usize), centroids.point(z_star as usize), metric)
        {
            cand_buf.push(c);
        }
    }
    let keep_len = cand_buf.len() - keep_start;

    if keep_len == 1 {
        // Alg. 1 lines 12-14: whole subtree belongs to z*.
        scratch.add_subtree(z_star as usize, &node.wgt_cent, node.count, d);
        stats.interior_assigns += node.count as u64;
        for &pi in tree.node_points(node) {
            assignments[pi as usize] = z_star;
        }
    } else {
        let (l, r) = (node.left, node.right);
        recurse(tree, l, data, centroids, metric, (keep_start, keep_len), cand_buf, mid_buf, scratch, stats, assignments);
        recurse(tree, r, data, centroids, metric, (keep_start, keep_len), cand_buf, mid_buf, scratch, stats, assignments);
    }
    // Pop this node's frame.
    cand_buf.truncate(keep_start);
}

// ---------------------------------------------------------------------------
// Level-batched engine (the HW/SW split)
// ---------------------------------------------------------------------------

/// One alive node in the breadth-first wave: the node index plus its
/// candidate range in the wave's candidate arena.  Sibling nodes produced
/// by the same split share one range.
#[derive(Clone, Copy, Debug)]
struct WaveNode {
    node: u32,
    cand_start: u32,
    cand_len: u32,
}

/// What a panel job resolves to on the PS side.
#[derive(Clone, Copy, Debug)]
enum JobKind {
    Interior { wave_slot: u32 },
    LeafPoint { point: u32 },
}

/// Arenas for the level-batched engine, allocated once per run and
/// recycled across tree levels **and** solver iterations (§Panel engine in
/// the module docs).  Steady-state traversal allocates nothing.
#[derive(Debug, Default)]
pub struct FilterScratch {
    jobs: PanelJobs,
    panels: PanelSet,
    kinds: Vec<JobKind>,
    wave: Vec<WaveNode>,
    next_wave: Vec<WaveNode>,
    cand: Vec<u32>,
    next_cand: Vec<u32>,
}

impl FilterScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// One filtering pass, breadth-first, with distance panels computed by
/// `backend` one tree level at a time.  Allocates fresh scratch arenas;
/// iterating callers should use [`filter_iteration_batched_scratch`] (as
/// [`run_batched`] does) to recycle them.
pub fn filter_iteration_batched<B: PanelBackend>(
    tree: &KdTree,
    data: &Dataset,
    centroids: &Dataset,
    metric: Metric,
    backend: &mut B,
    assignments: &mut [u32],
) -> (Vec<f32>, Vec<u32>, IterStats) {
    let mut scratch = FilterScratch::new();
    filter_iteration_batched_scratch(tree, data, centroids, metric, backend, assignments, &mut scratch)
}

/// [`filter_iteration_batched`] with caller-owned arenas.
#[allow(clippy::too_many_arguments)]
pub fn filter_iteration_batched_scratch<B: PanelBackend>(
    tree: &KdTree,
    data: &Dataset,
    centroids: &Dataset,
    metric: Metric,
    backend: &mut B,
    assignments: &mut [u32],
    arena: &mut FilterScratch,
) -> (Vec<f32>, Vec<u32>, IterStats) {
    filter_iteration_batched_bounded(tree, data, centroids, metric, backend, assignments, arena, None)
}

/// [`filter_iteration_batched_scratch`] with optional triangle-inequality
/// bounds (DESIGN.md §10): while the state is
/// [`active`](BoundsState::active), leaf panel jobs whose incumbent
/// center provably still wins are dropped before enqueue, and surviving
/// leaf jobs get their candidate lists shrunk by the center-center test.
/// Pruning is exact — assignments and centroid sums are bitwise the
/// unbounded engine's (pruned points' accumulator adds are deferred to
/// the exact slot the unpruned schedule would have used, and candidate
/// lists keep their order, so f32 accumulation order never changes).
/// The caller owns the protocol: [`BoundsState::advance`] must be called
/// with `centroids` *before* this pass, and `assignments` must hold the
/// previous pass's labels.
#[allow(clippy::too_many_arguments)]
pub fn filter_iteration_batched_bounded<B: PanelBackend>(
    tree: &KdTree,
    data: &Dataset,
    centroids: &Dataset,
    metric: Metric,
    backend: &mut B,
    assignments: &mut [u32],
    arena: &mut FilterScratch,
    mut bounds: Option<&mut BoundsState>,
) -> (Vec<f32>, Vec<u32>, IterStats) {
    let k = centroids.len();
    let d = data.dims();
    let mut scratch = Scratch::new(k, d);
    let mut stats = IterStats::default();

    backend.begin_pass(centroids, metric);

    let FilterScratch {
        jobs,
        panels,
        kinds,
        wave,
        next_wave,
        cand,
        next_cand,
    } = arena;

    // Root wave: every centroid is a candidate.
    wave.clear();
    cand.clear();
    cand.extend(0..k as u32);
    wave.push(WaveNode {
        node: 0,
        cand_start: 0,
        cand_len: k as u32,
    });
    let mut depth = 0usize;

    while !wave.is_empty() {
        if stats.levels.len() <= depth {
            stats.levels.resize(depth + 1, LevelWork::default());
        }

        // Assemble the level's job batch: one midpoint job per interior
        // node, one job per leaf point.  With active bounds, a leaf point
        // whose incumbent provably still wins never becomes a job — its
        // accumulator add is deferred (tagged with the job index it would
        // have had) so the f32 accumulation order stays the unbounded
        // engine's — and surviving leaf jobs may carry a shrunk (still
        // ascending) candidate list.
        jobs.clear(d);
        kinds.clear();
        if let Some(bs) = bounds.as_deref_mut() {
            bs.deferred.clear();
        }
        for (slot, wn) in wave.iter().enumerate() {
            let node = &tree.nodes[wn.node as usize];
            let cands =
                &cand[wn.cand_start as usize..(wn.cand_start + wn.cand_len) as usize];
            stats.node_visits += 1;
            if node.is_leaf() {
                for &pi in tree.node_points(node) {
                    let q = data.point(pi as usize);
                    let filtered = match bounds.as_deref_mut() {
                        Some(bs) if bs.active() => {
                            let a = assignments[pi as usize];
                            if bs.leaf_decision(
                                pi,
                                a,
                                q,
                                centroids.point(a as usize),
                                metric,
                                cands,
                            ) {
                                bs.deferred.push((kinds.len(), pi));
                                continue;
                            }
                            true
                        }
                        _ => false,
                    };
                    if filtered {
                        // Reborrow: the filtered list lives in the bounds
                        // scratch filled by leaf_decision above.
                        if let Some(bs) = bounds.as_deref_mut() {
                            jobs.push(q, &bs.filtered);
                            stats.levels[depth].cand_evals += bs.filtered.len() as u64;
                        }
                    } else {
                        jobs.push(q, cands);
                        stats.levels[depth].cand_evals += cands.len() as u64;
                    }
                    kinds.push(JobKind::LeafPoint { point: pi });
                    stats.levels[depth].leaf_jobs += 1;
                }
            } else {
                jobs.push_with(cands, |mid| node.bbox.midpoint_into(mid));
                kinds.push(JobKind::Interior {
                    wave_slot: slot as u32,
                });
                stats.levels[depth].interior_jobs += 1;
                stats.levels[depth].cand_evals += cands.len() as u64;
            }
        }

        // The offloaded arithmetic: one panel batch for the whole level.
        backend.panels(jobs, centroids, metric, panels);
        debug_assert_eq!(panels.len(), kinds.len());

        // PS-side consumption of the panels.  Deferred adds of
        // bounds-pruned points flush right before the job that would
        // have followed them, bitwise-reproducing the unbounded
        // accumulation order.
        next_wave.clear();
        next_cand.clear();
        let mut def_i = 0usize;
        for (j, kind) in kinds.iter().enumerate() {
            if let Some(bs) = bounds.as_deref_mut() {
                while def_i < bs.deferred.len() && bs.deferred[def_i].0 <= j {
                    let pi = bs.deferred[def_i].1;
                    scratch.add_point(assignments[pi as usize] as usize, data.point(pi as usize), d);
                    stats.leaf_points += 1;
                    def_i += 1;
                }
            }
            let cands = jobs.cands(j);
            let dists = panels.row(j);
            stats.dist_evals += cands.len() as u64;
            // arg-min with first-wins tie-break (matches recursive engine).
            let mut best_slot = 0usize;
            for (s, &dist) in dists.iter().enumerate() {
                if dist < dists[best_slot] {
                    best_slot = s;
                }
            }
            let best = cands[best_slot];

            match *kind {
                JobKind::LeafPoint { point } => {
                    let p = data.point(point as usize);
                    scratch.add_point(best as usize, p, d);
                    assignments[point as usize] = best;
                    stats.leaf_points += 1;
                }
                JobKind::Interior { wave_slot } => {
                    let node_idx = wave[wave_slot as usize].node;
                    let node = &tree.nodes[node_idx as usize];
                    let z_star = best;
                    // Survivors go into the next wave's arena; both
                    // children share the range.
                    let keep_start = next_cand.len();
                    for &c in cands {
                        if c == z_star {
                            next_cand.push(c);
                            continue;
                        }
                        stats.prune_tests += 1;
                        stats.levels[depth].prune_tests += 1;
                        if !node.bbox.is_farther(
                            centroids.point(c as usize),
                            centroids.point(z_star as usize),
                            metric,
                        ) {
                            next_cand.push(c);
                        }
                    }
                    let keep_len = next_cand.len() - keep_start;
                    if keep_len == 1 {
                        next_cand.truncate(keep_start);
                        scratch.add_subtree(z_star as usize, &node.wgt_cent, node.count, d);
                        stats.interior_assigns += node.count as u64;
                        for &pi in tree.node_points(node) {
                            assignments[pi as usize] = z_star;
                        }
                    } else {
                        next_wave.push(WaveNode {
                            node: node.left,
                            cand_start: keep_start as u32,
                            cand_len: keep_len as u32,
                        });
                        next_wave.push(WaveNode {
                            node: node.right,
                            cand_start: keep_start as u32,
                            cand_len: keep_len as u32,
                        });
                    }
                }
            }
        }

        // Pruned points that came after the level's last pushed job.
        if let Some(bs) = bounds.as_deref_mut() {
            while def_i < bs.deferred.len() {
                let pi = bs.deferred[def_i].1;
                scratch.add_point(assignments[pi as usize] as usize, data.point(pi as usize), d);
                stats.leaf_points += 1;
                def_i += 1;
            }
        }

        std::mem::swap(wave, next_wave);
        std::mem::swap(cand, next_cand);
        depth += 1;
    }

    (scratch.sums, scratch.counts, stats)
}

// ---------------------------------------------------------------------------
// Full solver loops
// ---------------------------------------------------------------------------

/// Iterate the recursive engine to convergence.
pub fn run(data: &Dataset, tree: &KdTree, init: &Dataset, opts: &FilterOpts) -> KmeansResult {
    run_impl(data, tree, init, opts, None::<&mut CpuPanels>, None)
}

/// Iterate the batched engine to convergence through `backend`.
pub fn run_batched<B: PanelBackend>(
    data: &Dataset,
    tree: &KdTree,
    init: &Dataset,
    opts: &FilterOpts,
    backend: &mut B,
) -> KmeansResult {
    run_impl(data, tree, init, opts, Some(backend), None)
}

/// [`run`] with a per-iteration hook (the unified solver layer's seam; the
/// hook returning `false` stops the run early).
pub fn run_hooked(
    data: &Dataset,
    tree: &KdTree,
    init: &Dataset,
    opts: &FilterOpts,
    hook: Option<IterHook<'_>>,
) -> KmeansResult {
    run_impl(data, tree, init, opts, None::<&mut CpuPanels>, hook)
}

/// [`run_batched`] with a per-iteration hook.
pub fn run_batched_hooked<B: PanelBackend>(
    data: &Dataset,
    tree: &KdTree,
    init: &Dataset,
    opts: &FilterOpts,
    backend: &mut B,
    hook: Option<IterHook<'_>>,
) -> KmeansResult {
    run_impl(data, tree, init, opts, Some(backend), hook)
}

fn run_impl<B: PanelBackend>(
    data: &Dataset,
    tree: &KdTree,
    init: &Dataset,
    opts: &FilterOpts,
    mut backend: Option<&mut B>,
    mut hook: Option<IterHook<'_>>,
) -> KmeansResult {
    assert_eq!(data.dims(), init.dims());
    let mut centroids = init.clone();
    let mut assignments = vec![0u32; data.len()];
    let mut stats = RunStats::default();
    // Kernel-tier counters are lifetime-monotonic on the backend; delta
    // against this snapshot at the end gives this run's share.
    let kernel_before = backend
        .as_deref_mut()
        .map(|b| b.kernel_stats())
        .unwrap_or_default();
    // One arena set for the whole run — recycled every iteration.
    let mut scratch = FilterScratch::new();
    // Bounds ride the batched engine only (the recursive reference is
    // always unpruned); Off resolves to no state at all.
    let mut bounds_state = if backend.is_some() && opts.bounds.enabled_for(init.len()) {
        Some(BoundsState::new(data.len()))
    } else {
        None
    };

    for _ in 0..opts.max_iters {
        if let Some(bs) = bounds_state.as_mut() {
            bs.advance(&centroids, opts.metric, &assignments);
        }
        let (sums, counts, mut iter_stats) = match backend.as_deref_mut() {
            None => filter_iteration(tree, data, &centroids, opts.metric, &mut assignments),
            Some(b) => filter_iteration_batched_bounded(
                tree,
                data,
                &centroids,
                opts.metric,
                b,
                &mut assignments,
                &mut scratch,
                bounds_state.as_mut(),
            ),
        };
        let next = centroids_from_sums(&sums, &counts, &centroids);
        iter_stats.moved = max_sq_movement(&centroids, &next);
        centroids = next;
        let moved = iter_stats.moved;
        stats.iters.push(iter_stats);
        let go = match hook.as_mut() {
            Some(h) => h(stats.iters.len() - 1, stats.iters.last().unwrap(), &centroids),
            None => true,
        };
        if moved <= opts.tol {
            stats.converged = true;
            break;
        }
        if !go {
            stats.early_stopped = true;
            break;
        }
    }

    if let Some(b) = backend.as_deref_mut() {
        let delta = b.kernel_stats().delta_from(&kernel_before);
        stats.simd_lanes = delta.simd_lanes;
        stats.quantized_candidates = delta.quantized_candidates;
        stats.rescored_candidates = delta.rescored_candidates;
    }
    if let Some(bs) = &bounds_state {
        let b = bs.stats();
        stats.bound_pruned_points = b.pruned_points;
        stats.bound_pruned_candidates = b.pruned_candidates;
        stats.bounds_matrix_cost = b.matrix_cost;
    }

    KmeansResult {
        centroids,
        assignments,
        stats,
        ext: ResultExt::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::generate_params;
    use crate::kmeans::init::{init_centroids, Init};
    use crate::kmeans::lloyd::{self, LloydOpts};
    use crate::kmeans::metrics;
    use crate::util::proptest::proptest;

    fn setup(n: usize, d: usize, k: usize, seed: u64) -> (Dataset, KdTree, Dataset) {
        let s = generate_params(n, d, k, 0.2, 1.0, seed);
        let tree = KdTree::build(&s.data);
        let init = init_centroids(&s.data, k, Init::UniformSample, Metric::Euclid, seed ^ 1);
        (s.data, tree, init)
    }

    /// The filtering algorithm is exact: per-iteration centroids must match
    /// Lloyd's (up to f32 accumulation order).
    #[test]
    fn filtering_matches_lloyd_trajectory() {
        for metric in [Metric::Euclid, Metric::Manhattan] {
            let (data, tree, init) = setup(800, 3, 5, 42);
            let iters = 6;
            let fo = FilterOpts { metric, tol: 0.0, max_iters: iters, ..Default::default() };
            let lo = LloydOpts { metric, tol: 0.0, max_iters: iters, ..Default::default() };
            let rf = run(&data, &tree, &init, &fo);
            let rl = lloyd::run(&data, &init, &lo);
            for (cf, cl) in rf.centroids.iter().zip(rl.centroids.iter()) {
                for (a, b) in cf.iter().zip(cl.iter()) {
                    assert!(
                        (a - b).abs() < 1e-3,
                        "{metric:?}: filtering {a} vs lloyd {b}"
                    );
                }
            }
            // And assignments agree.
            let same = rf
                .assignments
                .iter()
                .zip(rl.assignments.iter())
                .filter(|(a, b)| a == b)
                .count();
            assert!(same >= 798, "assignments diverge: {same}/800 agree");
        }
    }

    #[test]
    fn batched_engine_matches_recursive_single_pass_exactly() {
        // Within one pass from identical centroids, every per-job
        // computation is the same arithmetic — assignments, counts and all
        // work counters must match exactly; sums may differ only in f32
        // accumulation order (DFS vs BFS).
        let (data, tree, init) = setup(600, 4, 6, 7);
        let mut a1 = vec![0u32; 600];
        let mut a2 = vec![0u32; 600];
        let (sums_r, counts_r, st_r) =
            filter_iteration(&tree, &data, &init, Metric::Euclid, &mut a1);
        let (sums_b, counts_b, st_b) = filter_iteration_batched(
            &tree,
            &data,
            &init,
            Metric::Euclid,
            &mut CpuPanels,
            &mut a2,
        );
        assert_eq!(a1, a2);
        assert_eq!(counts_r, counts_b);
        assert_eq!(st_r.dist_evals, st_b.dist_evals);
        assert_eq!(st_r.interior_assigns, st_b.interior_assigns);
        assert_eq!(st_r.leaf_points, st_b.leaf_points);
        assert_eq!(st_r.prune_tests, st_b.prune_tests);
        assert_eq!(st_r.levels, st_b.levels);
        for (x, y) in sums_r.iter().zip(sums_b.iter()) {
            assert!((x - y).abs() <= 1e-3 * (1.0 + y.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn batched_engine_matches_recursive_full_run() {
        // Across iterations the ulp-level sum differences may nudge
        // centroids; trajectories must still stay together.
        let (data, tree, init) = setup(600, 4, 6, 7);
        let opts = FilterOpts { tol: 1e-6, max_iters: 20, ..Default::default() };
        let a = run(&data, &tree, &init, &opts);
        let b = run_batched(&data, &tree, &init, &opts, &mut CpuPanels);
        for (ca, cb) in a.centroids.iter().zip(b.centroids.iter()) {
            for (x, y) in ca.iter().zip(cb.iter()) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        }
        let same = a
            .assignments
            .iter()
            .zip(b.assignments.iter())
            .filter(|(x, y)| x == y)
            .count();
        assert!(same >= 594, "assignments diverge: {same}/600");
    }

    #[test]
    fn filtering_does_less_distance_work_than_lloyd() {
        let (data, tree, init) = setup(4000, 3, 8, 3);
        let opts = FilterOpts { tol: 0.0, max_iters: 4, ..Default::default() };
        let r = run(&data, &tree, &init, &opts);
        let lloyd_work = 4000u64 * 8 * 4;
        let filter_work = r.stats.total_dist_evals();
        assert!(
            filter_work < lloyd_work / 2,
            "filtering should prune >2x: {filter_work} vs {lloyd_work}"
        );
        // And most points get assigned wholesale at interior nodes.
        let last = r.stats.iters.last().unwrap();
        assert!(last.interior_assigns > 2000, "interior assigns {}", last.interior_assigns);
    }

    #[test]
    fn every_point_assigned_and_counts_conserve() {
        let (data, tree, init) = setup(500, 2, 4, 9);
        let mut assignments = vec![u32::MAX; 500];
        let (sums, counts, _) =
            filter_iteration(&tree, &data, &init, Metric::Euclid, &mut assignments);
        assert!(assignments.iter().all(|&a| a < 4));
        assert_eq!(counts.iter().sum::<u32>(), 500);
        // sums equal the sum of points per assigned cluster.
        let d = data.dims();
        let mut expect = vec![0f64; 4 * d];
        for (i, p) in data.iter().enumerate() {
            let c = assignments[i] as usize;
            for j in 0..d {
                expect[c * d + j] += p[j] as f64;
            }
        }
        for (g, e) in sums.iter().zip(expect.iter()) {
            assert!((*g as f64 - e).abs() < 1e-2 * (1.0 + e.abs()), "{g} vs {e}");
        }
    }

    #[test]
    fn level_histogram_consistency() {
        let (data, tree, init) = setup(700, 3, 5, 13);
        let mut assignments = vec![0u32; 700];
        let (_, _, stats) =
            filter_iteration(&tree, &data, &init, Metric::Euclid, &mut assignments);
        let total_cand: u64 = stats.levels.iter().map(|l| l.cand_evals).sum();
        assert_eq!(total_cand, stats.dist_evals);
        let total_leaf: u64 = stats.levels.iter().map(|l| l.leaf_jobs).sum();
        assert_eq!(total_leaf, stats.leaf_points);
        assert!(stats.levels.len() <= tree.depth() + 1);
    }

    #[test]
    fn property_filtering_equals_lloyd_step() {
        proptest(15, |g| {
            let n = g.size(20, 400).max(20);
            let d = g.usize_in(1, 5);
            let k = g.usize_in(1, 6).min(n);
            let metric = *g.pick(&[Metric::Euclid, Metric::Manhattan]);
            let s = generate_params(n, d, k.max(1), g.f32_in(0.05, 0.5), 1.0, g.case as u64);
            let tree = KdTree::build_with(&s.data, g.usize_in(1, 8));
            let init = init_centroids(&s.data, k, Init::UniformSample, metric, g.case as u64 ^ 5);

            // One step of each must produce the same sums/counts.
            let mut a1 = vec![0u32; n];
            let (sums_f, counts_f, _) =
                filter_iteration(&tree, &s.data, &init, metric, &mut a1);
            // Lloyd step by hand.
            let mut sums_l = vec![0f32; k * d];
            let mut counts_l = vec![0u32; k];
            for p in s.data.iter() {
                let (best, _) = metrics::nearest(metric, p, init.flat(), k, d);
                for j in 0..d {
                    sums_l[best * d + j] += p[j];
                }
                counts_l[best] += 1;
            }
            if counts_f != counts_l {
                return Err(format!(
                    "counts disagree (n={n} d={d} k={k} {metric:?}): {counts_f:?} vs {counts_l:?}"
                ));
            }
            for (x, y) in sums_f.iter().zip(sums_l.iter()) {
                if (x - y).abs() > 1e-2 * (1.0 + y.abs()) {
                    return Err(format!("sums disagree: {x} vs {y}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn bounds_on_run_is_bitwise_the_bounds_off_run() {
        // DESIGN.md §10: pruning is exact and never reorders surviving
        // work, so the whole trajectory — labels, centroid bits,
        // iteration count — matches the unbounded engine under the
        // scalar backend, while the counters prove work was eliminated.
        for metric in [Metric::Euclid, Metric::Manhattan] {
            let s = generate_params(900, 3, 8, 0.05, 1.0, 21);
            let tree = KdTree::build(&s.data);
            let init =
                init_centroids(&s.data, 8, Init::UniformSample, metric, 22);
            let off = FilterOpts { metric, tol: 1e-6, max_iters: 12, bounds: BoundsMode::Off };
            let on = FilterOpts { bounds: BoundsMode::On, ..off.clone() };
            let a = run_batched(&s.data, &tree, &init, &off, &mut CpuPanels);
            let b = run_batched(&s.data, &tree, &init, &on, &mut CpuPanels);
            assert_eq!(a.assignments, b.assignments, "{metric:?}");
            for (x, y) in a.centroids.flat().iter().zip(b.centroids.flat()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{metric:?}: centroid bits");
            }
            assert_eq!(a.stats.iterations(), b.stats.iterations(), "{metric:?}");
            assert_eq!(a.stats.converged, b.stats.converged, "{metric:?}");
            assert!(
                b.stats.bound_pruned_points + b.stats.bound_pruned_candidates > 0,
                "{metric:?}: bounds never fired"
            );
            assert!(b.stats.bounds_matrix_cost > 0, "{metric:?}");
            assert_eq!(a.stats.bound_pruned_points, 0, "off mode keeps counters zero");
            assert_eq!(a.stats.bounds_matrix_cost, 0);
            // The ledger's point: pruning eliminates kernel evals.
            assert!(
                b.stats.total_dist_evals() < a.stats.total_dist_evals(),
                "{metric:?}: {} !< {}",
                b.stats.total_dist_evals(),
                a.stats.total_dist_evals()
            );
        }
    }

    #[test]
    fn k_one_short_circuits() {
        let (data, tree, _) = setup(300, 2, 3, 17);
        let init = data.gather(&[0]);
        let r = run(&data, &tree, &init, &FilterOpts::default());
        assert!(r.assignments.iter().all(|&a| a == 0));
        // With one candidate the root prunes immediately: one node visit.
        assert_eq!(r.stats.iters[0].node_visits, 1);
        assert_eq!(r.stats.iters[0].interior_assigns, 300);
    }
}
