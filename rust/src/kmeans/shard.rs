//! The shard plane: P-way partitioning and hierarchical centroid combine.
//!
//! The paper "evolves the SW to naturally divide the classification into
//! smaller data sets, **based on the number of available cores**" — the
//! quartet of the ZCU102 is one instance, not the architecture.  This
//! module is the generalization: a [`ShardPlan`] describes P partitions of
//! the dataset, per-shard solves run independently (the level-1 phase),
//! and [`combine_hierarchical`] tree-reduces the P×k level-1 centroids
//! back to k with the count-weighted nearest-centroid merge.  Everything
//! above it — [`super::twolevel`] (the sequential P=4 paper reference),
//! the [`crate::coordinator`] (threaded system), the `arch`/`hw` cost
//! models and the serving layer — builds on this plane, which is also the
//! seam any future scale-out direction (remote shards, PJRT shard
//! backends) plugs into.
//!
//! Partition strategies ([`Partition`]):
//!
//! - [`Partition::RoundRobin`] (default): rows dealt out modulo P, so
//!   every shard is an i.i.d. sample of the full distribution and the
//!   per-shard centroid sets are P noisy estimates of the *same* k
//!   centers — what makes the merge a strong level-2 seed.
//! - [`Partition::KdTop`]: the P-node frontier of the full kd-tree
//!   (generalizing the paper's "four grandchild subtrees" reading to any
//!   P): the frontier is expanded level by level until it holds ≥ P
//!   nodes, then adjacent smallest neighbors are merged back down to
//!   exactly P spatially-coherent shards.  For P = 4 this reproduces the
//!   legacy quartering bit for bit.
//! - [`Partition::Contiguous`]: plain contiguous row ranges — the
//!   cheapest split (no gather), kept for streaming/ingest-ordered data.
//!
//! Combine: [`combine_level`] is the paper's flat greedy merge (one
//! cluster from each shard per group, count-weighted averaging), extended
//! to also return the merged counts.  [`combine_hierarchical`] reduces P
//! sets with a fan-in-[`COMBINE_FAN_IN`] tree of `combine_level` calls, so
//! P ≫ 4 costs O(P·k²·d) instead of one O(P²·k²) greedy pass over an
//! ever-growing used-set; for P ≤ [`COMBINE_FAN_IN`] it *is* a single
//! flat pass, bitwise identical to the legacy `twolevel::combine`.

use super::bounds::{BoundsMode, BoundsState, BoundsStats};
use super::filtering::{filter_iteration_batched_bounded, FilterScratch};
use super::panel::PanelBackend;
use super::solver::{Algo, IterObserver, KmeansSpec, SolverCtx};
use super::{centroids_from_sums, max_sq_movement, IterStats, KmeansResult, Metric, RunStats};
use crate::data::Dataset;
use crate::kdtree::{KdTree, DEFAULT_LEAF_SIZE};
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;

/// Default shard count — the paper's 4 (one per ZCU102 Cortex-A53).
pub const DEFAULT_SHARDS: usize = 4;

/// Fan-in of the hierarchical combine tree: up to this many centroid sets
/// are merged per `combine_level` call.  4 keeps the P ≤ 4 paper
/// configuration on the exact legacy flat-combine path.
pub const COMBINE_FAN_IN: usize = 4;

/// How a [`ShardPlan`] splits the dataset (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Partition {
    /// Deal rows out modulo P (i.i.d. shards; default).
    RoundRobin,
    /// The P-node frontier of the full kd-tree (spatial shards).
    KdTop,
    /// Contiguous row ranges (no gather; ingest-ordered shards).
    Contiguous,
}

impl Partition {
    /// Canonical name (round-trips through [`FromStr`](std::str::FromStr)
    /// — the model artifact serializes specs by these names).
    pub fn name(self) -> &'static str {
        match self {
            Partition::RoundRobin => "round-robin",
            Partition::KdTop => "kd-top",
            Partition::Contiguous => "contiguous",
        }
    }

    pub fn all() -> &'static [Partition] {
        &[Partition::RoundRobin, Partition::KdTop, Partition::Contiguous]
    }
}

impl std::str::FromStr for Partition {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "round-robin" | "roundrobin" => Ok(Partition::RoundRobin),
            "kd-top" | "kdtop" => Ok(Partition::KdTop),
            "contiguous" => Ok(Partition::Contiguous),
            other => {
                anyhow::bail!("unknown partition `{other}` (round-robin|kd-top|contiguous)")
            }
        }
    }
}

/// Per-shard seed derivation shared by every executor of the plan (the
/// sequential reference and the threaded coordinator must agree so their
/// level-1 solves are bitwise comparable).
#[inline]
pub fn shard_seed(seed: u64, shard: usize) -> u64 {
    seed ^ (shard as u64).wrapping_mul(0x9E37_79B9)
}

/// P partitions of a dataset: the shard datasets plus, for each shard, the
/// original row index of every shard row (so labels can be scattered back).
#[derive(Clone, Debug)]
pub struct ShardPlan {
    pub strategy: Partition,
    /// The shard datasets, `parts.len() == P`.
    pub parts: Vec<Dataset>,
    /// Original row ids per shard (`ids[s][i]` is the dataset row of
    /// `parts[s].point(i)`).
    pub ids: Vec<Vec<u32>>,
}

impl ShardPlan {
    /// Partition `data` into `shards` parts.  [`Partition::KdTop`] uses
    /// `tree` when given (the solver ctx's cached full tree) and builds
    /// one otherwise; the other strategies never touch it.
    pub fn build(
        data: &Dataset,
        shards: usize,
        strategy: Partition,
        tree: Option<&KdTree>,
    ) -> Self {
        assert!(shards >= 1, "shard plan needs >= 1 shard");
        let (parts, ids) = match strategy {
            Partition::RoundRobin => plan_round_robin(data, shards),
            Partition::Contiguous => plan_contiguous(data, shards),
            Partition::KdTop => match tree {
                Some(t) => plan_kd_frontier(data, t, shards),
                None => {
                    let t = KdTree::build(data);
                    plan_kd_frontier(data, &t, shards)
                }
            },
        };
        Self {
            strategy,
            parts,
            ids,
        }
    }

    /// Number of shards in the plan.
    pub fn shards(&self) -> usize {
        self.parts.len()
    }

    /// Row count of each shard.
    pub fn sizes(&self) -> Vec<usize> {
        self.parts.iter().map(|p| p.len()).collect()
    }

    /// Can every shard host `k` clusters?  When not, a two-level run must
    /// fall back to a plain single-level solve.
    pub fn supports_k(&self, k: usize) -> bool {
        self.parts.iter().all(|p| p.len() >= k)
    }
}

/// Round-robin plan: deal rows out modulo `shards`.
pub fn plan_round_robin(data: &Dataset, shards: usize) -> (Vec<Dataset>, Vec<Vec<u32>>) {
    assert!(shards >= 1);
    let mut ids: Vec<Vec<u32>> = vec![Vec::with_capacity(data.len() / shards + 1); shards];
    for i in 0..data.len() {
        ids[i % shards].push(i as u32);
    }
    let datasets = ids
        .iter()
        .map(|rows| {
            let rows_usize: Vec<usize> = rows.iter().map(|&r| r as usize).collect();
            data.gather(&rows_usize)
        })
        .collect();
    (datasets, ids)
}

/// Contiguous plan: `shards` row ranges whose sizes differ by at most one.
pub fn plan_contiguous(data: &Dataset, shards: usize) -> (Vec<Dataset>, Vec<Vec<u32>>) {
    assert!(shards >= 1);
    let (parts, offsets) = data.split_contiguous(shards);
    let ids = offsets
        .iter()
        .zip(parts.iter())
        .map(|(&o, p)| (o as u32..(o + p.len()) as u32).collect())
        .collect();
    (parts, ids)
}

/// kd-frontier plan: expand the tree frontier level by level until it
/// holds at least `shards` nodes (leaves stay), then merge adjacent
/// smallest neighbors back down to exactly `shards` parts.  Falls back to
/// [`plan_contiguous`] when the tree is too shallow to yield `shards`
/// frontier nodes (tiny or degenerate data).
pub fn plan_kd_frontier(
    data: &Dataset,
    tree: &KdTree,
    shards: usize,
) -> (Vec<Dataset>, Vec<Vec<u32>>) {
    assert!(shards >= 1);
    // ceil(log2(shards)) frontier expansions: enough levels for `shards`
    // nodes if nothing bottoms out early.
    let rounds = shards.next_power_of_two().trailing_zeros();
    let mut fronts: Vec<u32> = vec![0];
    for _ in 0..rounds {
        let mut next = Vec::with_capacity(fronts.len() * 2);
        for &ni in &fronts {
            let n = &tree.nodes[ni as usize];
            if n.is_leaf() {
                next.push(ni);
            } else {
                next.push(n.left);
                next.push(n.right);
            }
        }
        fronts = next;
    }

    if fronts.len() < shards {
        // Degenerate: pad by splitting contiguous ranges instead.
        return plan_contiguous(data, shards);
    }

    // Materialize the frontier's row-id lists, then (for non-power-of-two
    // P) fold adjacent smallest neighbors together until exactly P remain —
    // neighbors on the frontier are spatial siblings, so merged shards stay
    // coherent.  For P a power of two (the P = 4 legacy case included)
    // `fronts.len() == shards` already and no folding happens.
    let mut ids: Vec<Vec<u32>> = fronts
        .iter()
        .map(|&ni| tree.node_points(&tree.nodes[ni as usize]).to_vec())
        .collect();
    fold_adjacent_smallest(&mut ids, shards);

    let datasets = ids
        .iter()
        .map(|rows| {
            let rows_usize: Vec<usize> = rows.iter().map(|&r| r as usize).collect();
            data.gather(&rows_usize)
        })
        .collect();
    (datasets, ids)
}

/// Repeatedly merge the adjacent pair with the smallest combined size
/// (leftmost on ties) until exactly `shards` lists remain — the
/// kd-frontier folding rule, now driven by a binary heap with lazy
/// invalidation instead of a full linear re-scan per fold (O(F log F)
/// instead of O(F²) for F frontier nodes).  The merge *sequence* is
/// pinned to the historical scan's output: entries are keyed
/// `(combined size, left position)` so equal-size ties still resolve to
/// the leftmost pair, and stale entries (a neighbor merged or grew) are
/// discarded at pop time because their recorded sum no longer matches
/// the live pair.
fn fold_adjacent_smallest(ids: &mut Vec<Vec<u32>>, shards: usize) {
    let n = ids.len();
    if n <= shards {
        return;
    }
    let mut len: Vec<usize> = ids.iter().map(|v| v.len()).collect();
    // Doubly-linked list over the original positions (`n` = no neighbor);
    // positions never reorder, so "leftmost" stays the original index.
    let mut next: Vec<usize> = (1..=n).collect();
    let mut prev: Vec<usize> = (0..n).map(|i| i.wrapping_sub(1)).collect();
    let mut alive = vec![true; n];
    let mut heap: BinaryHeap<Reverse<(usize, usize)>> = (0..n - 1)
        .map(|i| Reverse((len[i] + len[i + 1], i)))
        .collect();
    let mut remaining = n;
    while remaining > shards {
        let Reverse((sum, left)) = heap.pop().expect("frontier fold heap exhausted");
        if !alive[left] {
            continue;
        }
        let right = next[left];
        if right >= n || len[left] + len[right] != sum {
            continue; // stale: the pair this entry described no longer exists
        }
        let moved = std::mem::take(&mut ids[right]);
        ids[left].extend_from_slice(&moved);
        len[left] += len[right];
        alive[right] = false;
        next[left] = next[right];
        if next[left] < n {
            prev[next[left]] = left;
        }
        remaining -= 1;
        if next[left] < n {
            heap.push(Reverse((len[left] + len[next[left]], left)));
        }
        if prev[left] != usize::MAX {
            heap.push(Reverse((len[prev[left]] + len[left], prev[left])));
        }
    }
    let mut keep = alive.into_iter();
    ids.retain(|_| keep.next().unwrap());
}

/// One flat `Combine` pass: merge up to [`COMBINE_FAN_IN`]-ish sets of k
/// centroids down to k by greedy nearest matching (set 0's centroids
/// anchor the groups) with count-weighted averaging — the paper's
/// "combine a cluster in each sub-group with ... the nearest centroids
/// ... then update".  Also returns each merged centroid's total member
/// count, which is what lets [`combine_hierarchical`] chain passes
/// without losing the weighting.
pub fn combine_level(
    centroids: &[Dataset],
    counts: &[Vec<usize>],
    metric: Metric,
) -> (Dataset, Vec<usize>) {
    let q = centroids.len();
    assert!(q >= 1);
    let k = centroids[0].len();
    let d = centroids[0].dims();
    assert!(counts.iter().zip(centroids).all(|(c, ds)| c.len() == ds.len()));

    let mut out = Vec::with_capacity(k * d);
    let mut out_counts = Vec::with_capacity(k);
    // Used-markers per non-anchor set.
    let mut used: Vec<Vec<bool>> = centroids.iter().map(|c| vec![false; c.len()]).collect();

    for a in 0..k {
        let anchor = centroids[0].point(a);
        let mut wsum: Vec<f64> = anchor
            .iter()
            .map(|&v| v as f64 * counts[0][a] as f64)
            .collect();
        let mut wtot = counts[0][a] as f64;
        let mut ctot = counts[0][a];
        for qi in 1..q {
            // Nearest unused centroid of set qi to the anchor.
            let mut best: Option<(usize, f32)> = None;
            for c in 0..centroids[qi].len() {
                if used[qi][c] {
                    continue;
                }
                let dd = metric.dist(anchor, centroids[qi].point(c));
                if best.map_or(true, |(_, bd)| dd < bd) {
                    best = Some((c, dd));
                }
            }
            if let Some((c, _)) = best {
                used[qi][c] = true;
                let w = counts[qi][c] as f64;
                for (j, &v) in centroids[qi].point(c).iter().enumerate() {
                    wsum[j] += v as f64 * w;
                }
                wtot += w;
                ctot += counts[qi][c];
            }
        }
        if wtot <= 0.0 {
            out.extend_from_slice(anchor);
        } else {
            out.extend(wsum.iter().map(|&v| (v / wtot) as f32));
        }
        out_counts.push(ctot);
    }
    (Dataset::from_flat(k, d, out), out_counts)
}

/// Hierarchical `Combine`: tree-reduce P sets of k centroids to k with a
/// fan-in-[`COMBINE_FAN_IN`] tree of [`combine_level`] passes, carrying
/// merged counts between levels.  For P ≤ [`COMBINE_FAN_IN`] this is one
/// flat pass — bitwise identical to the legacy 4-way
/// [`super::twolevel::combine`]; for larger P the total matching work is
/// O(P·k²·d) instead of the O(P²·k²) a single ever-wider greedy pass
/// would cost.
pub fn combine_hierarchical(
    centroids: &[Dataset],
    counts: &[Vec<usize>],
    metric: Metric,
) -> Dataset {
    assert!(!centroids.is_empty());
    assert_eq!(centroids.len(), counts.len());
    let mut sets: Vec<Dataset> = centroids.to_vec();
    let mut cnts: Vec<Vec<usize>> = counts.to_vec();
    while sets.len() > COMBINE_FAN_IN {
        let groups = sets.len().div_ceil(COMBINE_FAN_IN);
        let mut next_sets = Vec::with_capacity(groups);
        let mut next_cnts = Vec::with_capacity(groups);
        for start in (0..sets.len()).step_by(COMBINE_FAN_IN) {
            let end = (start + COMBINE_FAN_IN).min(sets.len());
            let (merged, merged_counts) =
                combine_level(&sets[start..end], &cnts[start..end], metric);
            next_sets.push(merged);
            next_cnts.push(merged_counts);
        }
        sets = next_sets;
        cnts = next_cnts;
    }
    combine_level(&sets, &cnts, metric).0
}

// ---------------------------------------------------------------------------
// The shard-solve seam: one canonical level-1 solve, many executors
// ---------------------------------------------------------------------------

/// The spec one level-1 shard solve runs under: the caller's spec with the
/// batched filtering engine selected, the per-shard seed derived by
/// [`shard_seed`], and any explicit start centroids stripped (level-1
/// always seeds per shard).  Every executor of a [`ShardPlan`] — the
/// sequential reference, the threaded coordinator, and a remote
/// [`shard-worker`](crate::kmeans::remote) — derives its working spec
/// through this one function, which is what makes their solves bitwise
/// comparable.
pub fn level1_spec(spec: &KmeansSpec, shard: usize) -> KmeansSpec {
    let mut wspec = spec
        .clone()
        .algo(Algo::FilterBatched)
        .seed(shard_seed(spec.seed, shard));
    wspec.start = None;
    wspec
}

/// The canonical level-1 shard solve: build a kd-tree over the shard
/// (sequential build — the caller already owns the parallelism budget),
/// then run `wspec` through the unified solver API with the given panel
/// backend.  Shared verbatim by the coordinator's local executor and the
/// remote worker loop so the two cannot drift: same tree, same engine,
/// same arithmetic ⇒ bit-identical centroids wherever the solve runs.
pub fn solve_level1_shard<'a, B, O>(
    data: &'a Dataset,
    wspec: &KmeansSpec,
    backend: B,
    observer: Option<O>,
) -> KmeansResult
where
    B: PanelBackend + 'a,
    O: IterObserver + 'a,
{
    let tree = Arc::new(KdTree::build_par(data, DEFAULT_LEAF_SIZE, 0));
    let mut ctx = SolverCtx::new(data).with_tree(tree).with_backend(backend);
    if let Some(obs) = observer {
        ctx = ctx.with_observer(obs);
    }
    wspec.solve(&mut ctx)
}

// ---------------------------------------------------------------------------
// Session-mode step primitives
// ---------------------------------------------------------------------------
//
// One-shot mode runs a whole level-1 solve wherever the shard data is
// ([`solve_level1_shard`] above).  Session mode splits that same solve
// across the wire: the *data side* executes single filter iterations
// ([`ShardStepper`]) and the *coordinator side* folds each iteration's
// `(sums, counts)` partials into the next centroid set
// ([`fold_partials`]) — exactly the two halves of the engine's own
// iteration, so composing them reproduces [`solve_level1_shard`] bit for
// bit.  `tests::session_step_composition_matches_oneshot_solve` pins
// that equivalence against the oracle.

/// Dataset-resident half of a session-mode shard solve: the shard slice,
/// its kd-tree, and the recycled per-iteration arenas.  Built once per
/// `LoadShard` (worker-side) or per local session shard
/// (coordinator-side); each [`step`](Self::step) executes exactly one
/// canonical batched filter iteration — the same
/// [`filter_iteration_batched_scratch`](super::filtering::filter_iteration_batched_scratch)
/// call the one-shot engine loops
/// over, with the same tree construction as [`solve_level1_shard`].
pub struct ShardStepper<'a, B: PanelBackend> {
    data: &'a Dataset,
    tree: KdTree,
    metric: Metric,
    backend: B,
    assignments: Vec<u32>,
    scratch: FilterScratch,
    bounds_mode: BoundsMode,
    bounds: Option<BoundsState>,
}

impl<'a, B: PanelBackend> ShardStepper<'a, B> {
    /// Make `data` resident: build its kd-tree (the same parallel build
    /// the one-shot path uses) and allocate the iteration arenas.
    pub fn new(data: &'a Dataset, metric: Metric, backend: B) -> Self {
        Self {
            tree: KdTree::build_par(data, DEFAULT_LEAF_SIZE, 0),
            metric,
            backend,
            assignments: vec![0u32; data.len()],
            scratch: FilterScratch::new(),
            bounds_mode: BoundsMode::Off,
            bounds: None,
            data,
        }
    }

    /// Enable the triangle-inequality bounds tier (DESIGN.md §10) for
    /// subsequent steps.  Bound state is owned per stepper, so a stepper
    /// rebuilt mid-run (recovery) simply restarts from infinite uppers —
    /// looser, never wrong.
    pub fn with_bounds(mut self, mode: BoundsMode) -> Self {
        self.bounds_mode = mode;
        self
    }

    /// One filter iteration against `centroids`: returns the per-center
    /// coordinate sums (k×d flat), member counts, and work counters.
    /// `moved` in the returned stats is left untouched (0) — computing it
    /// needs the *next* centroids, which only the folding side has.
    pub fn step(&mut self, centroids: &Dataset) -> (Vec<f32>, Vec<u32>, IterStats) {
        if self.bounds_mode.enabled_for(centroids.len()) {
            let bs = self
                .bounds
                .get_or_insert_with(|| BoundsState::new(self.data.len()));
            bs.advance(centroids, self.metric, &self.assignments);
        }
        filter_iteration_batched_bounded(
            &self.tree,
            self.data,
            centroids,
            self.metric,
            &mut self.backend,
            &mut self.assignments,
            &mut self.scratch,
            self.bounds.as_mut(),
        )
    }

    /// Cumulative bounds-pruning counters across every step so far (all
    /// zero when bounds never engaged).
    pub fn bounds_stats(&self) -> BoundsStats {
        self.bounds.as_ref().map(|b| b.stats()).unwrap_or_default()
    }

    /// Labels written by the most recent [`step`](Self::step).
    pub fn assignments(&self) -> &[u32] {
        &self.assignments
    }

    /// Resident bytes this stepper pins (slice + tree + arenas, the
    /// dominant terms) — what a worker charges against its residency
    /// budget.
    pub fn resident_bytes(data: &Dataset) -> usize {
        // Slice + assignments + tree (nodes ≈ 2·n/leaf, each carrying a
        // d-dim weighted centroid + bbox) — a deliberate overestimate.
        let point_bytes = data.flat().len() * 4;
        point_bytes * 3 + data.len() * 8
    }
}

/// Coordinator-side half of a session-mode iteration: fold one
/// iteration's `(sums, counts)` partials into the next centroid set and
/// its convergence movement — verbatim the update step of the engine's
/// own loop (`centroids_from_sums` + `max_sq_movement`), which is what
/// keeps a session trajectory bitwise on the one-shot one.
pub fn fold_partials(prev: &Dataset, sums: &[f32], counts: &[u32]) -> (Dataset, f32) {
    let next = centroids_from_sums(sums, counts, prev);
    let moved = max_sq_movement(prev, &next);
    (next, moved)
}

/// What one level-1 shard solve ships back to the combiner — the paper's
/// `(centroid, count)` partials plus the run's work counters.  This is the
/// whole coordinator↔executor contract: shard assignments never travel
/// (level 2 reassigns every point), which is also what keeps the remote
/// wire format small.
#[derive(Clone, Debug)]
pub struct ShardPartial {
    /// The shard's k level-1 centroids.
    pub centroids: Dataset,
    /// Member count of each centroid's cluster.
    pub counts: Vec<usize>,
    /// Per-iteration work counters of the solve.
    pub stats: RunStats,
}

impl ShardPartial {
    /// Distill a full shard-solve result down to the partials the
    /// combiner needs.
    pub fn from_result(r: KmeansResult) -> Self {
        Self {
            counts: r.sizes(),
            centroids: r.centroids,
            stats: r.stats,
        }
    }
}

/// Where a shard solve runs.  The coordinator's scheduler pulls shard
/// indices off a shared counter and hands each to *some* executor — local
/// CPU threads ([`crate::coordinator`]'s `LocalShardExec`) or remote
/// workers over the wire protocol
/// ([`crate::kmeans::remote::RemoteWorker`]) — without caring which;
/// per-shard solves are deterministic, so the mix never changes the
/// result.  `on_iter` receives every iteration's counters (the live
/// metrics feed); a `Err` return means the executor could not produce a
/// partial (e.g. the wire died) and the caller should fall back.
pub trait ShardExecutor: Send {
    /// Human-readable identity for logs ("local", "remote(host:port)").
    fn describe(&self) -> String;

    /// Solve shard `shard` of the plan over `data` under `base_spec`
    /// (executors derive the working spec via [`level1_spec`]).
    fn solve_shard(
        &mut self,
        shard: usize,
        data: &Dataset,
        base_spec: &KmeansSpec,
        on_iter: &mut dyn FnMut(&IterStats),
    ) -> anyhow::Result<ShardPartial>;

    /// Wire-traffic accounting `(bytes_tx, bytes_rx)`; zero for local
    /// executors.
    fn wire_bytes(&self) -> (u64, u64) {
        (0, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::generate_params;

    fn check_partition(parts: &[Dataset], ids: &[Vec<u32>], data: &Dataset, p: usize) {
        assert_eq!(parts.len(), p);
        assert_eq!(ids.len(), p);
        let total: usize = parts.iter().map(|q| q.len()).sum();
        assert_eq!(total, data.len());
        let mut all: Vec<u32> = ids.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..data.len() as u32).collect::<Vec<u32>>());
        for (part, id) in parts.iter().zip(ids.iter()) {
            for (row, &orig) in id.iter().enumerate() {
                assert_eq!(part.point(row), data.point(orig as usize));
            }
        }
    }

    #[test]
    fn every_strategy_partitions_everything_at_many_p() {
        let s = generate_params(1003, 3, 4, 0.3, 1.0, 11);
        let tree = KdTree::build(&s.data);
        for p in [1usize, 2, 3, 4, 5, 7, 8, 16] {
            for strat in Partition::all() {
                let plan = ShardPlan::build(&s.data, p, *strat, Some(&tree));
                assert_eq!(plan.strategy, *strat);
                assert_eq!(plan.shards(), p, "{strat:?} P={p}");
                check_partition(&plan.parts, &plan.ids, &s.data, p);
                assert_eq!(plan.sizes().iter().sum::<usize>(), 1003);
            }
        }
    }

    #[test]
    fn round_robin_shards_are_balanced() {
        let s = generate_params(1000, 2, 2, 0.2, 1.0, 3);
        let plan = ShardPlan::build(&s.data, 8, Partition::RoundRobin, None);
        assert!(plan.sizes().iter().all(|&n| n == 125));
        // Row i lands on shard i % P at position i / P.
        assert_eq!(plan.ids[3][2], 3 + 2 * 8);
    }

    #[test]
    fn contiguous_shards_are_ranges() {
        let s = generate_params(10, 2, 1, 0.2, 1.0, 5);
        let plan = ShardPlan::build(&s.data, 3, Partition::Contiguous, None);
        assert_eq!(plan.sizes(), vec![4, 3, 3]);
        assert_eq!(plan.ids[0], vec![0, 1, 2, 3]);
        assert_eq!(plan.ids[1], vec![4, 5, 6]);
        assert_eq!(plan.ids[2], vec![7, 8, 9]);
    }

    #[test]
    fn kd_frontier_shrinks_extents() {
        // Spatial coherence: most shards span a smaller extent than the
        // full data (same invariant the legacy quartering test pinned).
        let s = generate_params(2000, 3, 4, 0.3, 1.0, 11);
        let tree = KdTree::build(&s.data);
        let (full_min, full_max) = s.data.bounds();
        let full_ext: f32 = full_min
            .iter()
            .zip(&full_max)
            .map(|(a, b)| b - a)
            .fold(0.0, f32::max);
        for p in [4usize, 6, 8] {
            let plan = ShardPlan::build(&s.data, p, Partition::KdTop, Some(&tree));
            let mut smaller = 0;
            for part in &plan.parts {
                let (mn, mx) = part.bounds();
                let ext: f32 = mn.iter().zip(&mx).map(|(a, b)| b - a).fold(0.0, f32::max);
                if ext < full_ext * 0.95 {
                    smaller += 1;
                }
            }
            assert!(smaller >= p / 2, "P={p}: only {smaller} shards shrank");
        }
    }

    #[test]
    fn kd_frontier_degenerate_small_data_falls_back() {
        let s = generate_params(3, 2, 1, 0.1, 1.0, 1);
        let tree = KdTree::build(&s.data);
        let plan = ShardPlan::build(&s.data, 4, Partition::KdTop, Some(&tree));
        check_partition(&plan.parts, &plan.ids, &s.data, 4);
        // 3 points over 4 shards: someone is empty, so k >= 1 two-level
        // runs must fall back.
        assert!(!plan.supports_k(1));
    }

    #[test]
    fn shard_seed_matches_legacy_quarter_seeding() {
        // The coordinator/sequential xor recipe, verbatim.
        for qi in 0..8usize {
            assert_eq!(
                shard_seed(42, qi),
                42 ^ (qi as u64).wrapping_mul(0x9E37_79B9)
            );
        }
        assert_eq!(shard_seed(7, 0), 7);
    }

    #[test]
    fn combine_level_weighted_average_and_counts() {
        let c0 = Dataset::from_flat(2, 1, vec![0.0, 10.0]);
        let c1 = Dataset::from_flat(2, 1, vec![2.0, 12.0]);
        let (merged, counts) =
            combine_level(&[c0, c1], &[vec![1, 3], vec![3, 1]], Metric::Euclid);
        // group 0: (0*1 + 2*3)/4 = 1.5 ; group 1: (10*3 + 12*1)/4 = 10.5
        assert_eq!(merged.point(0), &[1.5]);
        assert_eq!(merged.point(1), &[10.5]);
        assert_eq!(counts, vec![4, 4]);
    }

    #[test]
    fn combine_hierarchical_is_flat_combine_up_to_fan_in() {
        let sets: Vec<Dataset> = (0..COMBINE_FAN_IN)
            .map(|i| {
                Dataset::from_flat(3, 2, (0..6).map(|j| (i * 7 + j) as f32 * 0.31).collect())
            })
            .collect();
        let counts: Vec<Vec<usize>> = (0..COMBINE_FAN_IN)
            .map(|i| vec![i + 1, 2 * i + 1, 3])
            .collect();
        for take in 1..=COMBINE_FAN_IN {
            let flat = combine_level(&sets[..take], &counts[..take], Metric::Euclid).0;
            let tree = combine_hierarchical(&sets[..take], &counts[..take], Metric::Euclid);
            assert_eq!(flat, tree, "P={take} must be the flat greedy pass, bitwise");
        }
    }

    #[test]
    fn combine_hierarchical_composes_exactly_like_manual_chunking() {
        // P=16 reduces as four fan-in-4 groups then one final pass; pin the
        // reduction order so the tree shape is part of the contract.
        let sets: Vec<Dataset> = (0..16)
            .map(|i| {
                Dataset::from_flat(
                    2,
                    2,
                    vec![i as f32, -(i as f32), 100.0 + i as f32, 50.0 - i as f32],
                )
            })
            .collect();
        let counts: Vec<Vec<usize>> = (0..16).map(|i| vec![i + 1, 17 - i]).collect();
        let got = combine_hierarchical(&sets, &counts, Metric::Euclid);
        let mut mids = Vec::new();
        let mut midc = Vec::new();
        for g in 0..4 {
            let (m, c) =
                combine_level(&sets[g * 4..g * 4 + 4], &counts[g * 4..g * 4 + 4], Metric::Euclid);
            mids.push(m);
            midc.push(c);
        }
        let want = combine_level(&mids, &midc, Metric::Euclid).0;
        assert_eq!(got, want);
    }

    #[test]
    fn combine_recovers_planted_centers_at_large_p() {
        // 16 noisy estimates of the same 3 centers; the hierarchical merge
        // should land near the truth.
        let centers = [[0.0f32, 0.0], [10.0, 10.0], [-10.0, 5.0]];
        let mut sets = Vec::new();
        let mut counts = Vec::new();
        for i in 0..16usize {
            let mut flat = Vec::new();
            for (ci, c) in centers.iter().enumerate() {
                // Small deterministic jitter, different per set/center.
                let jx = ((i * 31 + ci * 7) % 13) as f32 * 0.01 - 0.06;
                let jy = ((i * 17 + ci * 11) % 13) as f32 * 0.01 - 0.06;
                flat.push(c[0] + jx);
                flat.push(c[1] + jy);
            }
            sets.push(Dataset::from_flat(3, 2, flat));
            counts.push(vec![50, 60, 70]);
        }
        let merged = combine_hierarchical(&sets, &counts, Metric::Euclid);
        for c in &centers {
            let best = merged
                .iter()
                .map(|m| Metric::Euclid.dist(m, c))
                .fold(f32::INFINITY, f32::min);
            assert!(best < 0.01, "center {c:?} missed (best sq dist {best})");
        }
    }

    #[test]
    fn combine_empty_cluster_keeps_anchor() {
        let c0 = Dataset::from_flat(1, 1, vec![3.5]);
        let c1 = Dataset::from_flat(1, 1, vec![9.0]);
        let (merged, counts) =
            combine_level(&[c0, c1], &[vec![0], vec![0]], Metric::Euclid);
        assert_eq!(merged.point(0), &[3.5]);
        assert_eq!(counts, vec![0]);
    }

    /// The pre-heap folding rule, verbatim: full linear scan for the
    /// smallest adjacent pair (leftmost on ties), merge, repeat.
    fn legacy_fold(mut ids: Vec<Vec<u32>>, shards: usize) -> Vec<Vec<u32>> {
        while ids.len() > shards {
            let mut best = 0usize;
            let mut best_len = usize::MAX;
            for i in 0..ids.len() - 1 {
                let len = ids[i].len() + ids[i + 1].len();
                if len < best_len {
                    best_len = len;
                    best = i;
                }
            }
            let right = ids.remove(best + 1);
            ids[best].extend_from_slice(&right);
        }
        ids
    }

    #[test]
    fn heap_fold_matches_legacy_scan_fold() {
        use crate::util::rng::Xoshiro256pp;
        let mut rng = Xoshiro256pp::seed_from_u64(0xF01D);
        for case in 0..40 {
            let n = 2 + (rng.next_u64() % 30) as usize;
            let mut row = 0u32;
            let lists: Vec<Vec<u32>> = (0..n)
                .map(|_| {
                    // Small sizes force plenty of equal-sum ties.
                    let take = 1 + (rng.next_u64() % 5) as u32;
                    let v: Vec<u32> = (row..row + take).collect();
                    row += take;
                    v
                })
                .collect();
            for target in 1..=n {
                let want = legacy_fold(lists.clone(), target);
                let mut got = lists.clone();
                fold_adjacent_smallest(&mut got, target);
                assert_eq!(got, want, "case {case}: n={n} target={target}");
            }
        }
    }

    #[test]
    fn level1_spec_derives_the_worker_spec() {
        let spec = KmeansSpec::two_level(5)
            .seed(42)
            .start(Dataset::from_flat(5, 1, vec![0.0; 5]));
        let w = level1_spec(&spec, 3);
        assert_eq!(w.algo, Algo::FilterBatched);
        assert_eq!(w.seed, shard_seed(42, 3));
        assert!(w.start.is_none(), "level 1 never inherits explicit starts");
        assert_eq!(w.k, 5);
        // Shard 0 keeps the base seed (xor with 0).
        assert_eq!(level1_spec(&spec, 0).seed, 42);
    }

    #[test]
    fn shard_partial_distills_a_result() {
        let s = generate_params(400, 2, 3, 0.2, 1.0, 9);
        let wspec = level1_spec(&KmeansSpec::two_level(3).seed(4), 1);
        let r = solve_level1_shard(
            &s.data,
            &wspec,
            crate::kmeans::panel::CpuPanels,
            None::<crate::kmeans::solver::IterLog>,
        );
        let iters = r.stats.iterations();
        let p = ShardPartial::from_result(r.clone());
        assert_eq!(p.centroids, r.centroids);
        assert_eq!(p.counts, r.sizes());
        assert_eq!(p.counts.iter().sum::<usize>(), 400);
        assert_eq!(p.stats.iterations(), iters);
    }

    #[test]
    fn partition_names_round_trip() {
        for p in Partition::all() {
            assert_eq!(p.name().parse::<Partition>().unwrap(), *p);
        }
        assert!("octants".parse::<Partition>().is_err());
    }

    /// The session-plane contract: stepping one filter iteration at a
    /// time ([`ShardStepper::step`]) and folding the partials
    /// coordinator-side ([`fold_partials`]) with the engine's own stop
    /// rule reproduces the one-shot [`solve_level1_shard`] oracle bit for
    /// bit — centroids, labels, counts, and iteration count.
    #[test]
    fn session_step_composition_matches_oneshot_solve() {
        use crate::kmeans::panel::CpuPanels;
        for shard in 0..3usize {
            let s = generate_params(700, 3, 4, 0.2, 1.0, 29 + shard as u64);
            let wspec = level1_spec(&KmeansSpec::two_level(4).seed(13), shard);
            let oracle = solve_level1_shard(
                &s.data,
                &wspec,
                CpuPanels,
                None::<crate::kmeans::solver::IterLog>,
            );

            let mut stepper = ShardStepper::new(&s.data, wspec.metric, CpuPanels);
            let mut centroids = wspec.starting_centroids(&s.data);
            let mut iters = 0usize;
            let mut converged = false;
            let mut last_counts: Vec<u32> = Vec::new();
            for _ in 0..wspec.max_iters {
                let (sums, counts, _stats) = stepper.step(&centroids);
                let (next, moved) = fold_partials(&centroids, &sums, &counts);
                centroids = next;
                last_counts = counts;
                iters += 1;
                if moved <= wspec.tol {
                    converged = true;
                    break;
                }
            }

            assert_eq!(converged, oracle.stats.converged, "shard {shard}");
            assert_eq!(iters, oracle.stats.iterations(), "shard {shard}");
            for (a, b) in centroids.flat().iter().zip(oracle.centroids.flat()) {
                assert_eq!(a.to_bits(), b.to_bits(), "shard {shard}: centroid bits");
            }
            assert_eq!(stepper.assignments(), &oracle.assignments[..], "shard {shard}");
            let counts_usize: Vec<usize> = last_counts.iter().map(|&c| c as usize).collect();
            assert_eq!(counts_usize, oracle.sizes(), "shard {shard}");
        }
    }
}
