//! Server half of the remote shard plane: the `shard-worker` loop behind
//! the CLI subcommand of the same name.
//!
//! A [`WorkerServer`] accepts any number of concurrent coordinator
//! connections (one thread each), answers the version handshake, and
//! serves [`Job`](super::protocol::Message::Job) frames by running the
//! *canonical* level-1 shard solve
//! ([`shard::solve_level1_shard`](crate::kmeans::shard::solve_level1_shard))
//! over the scalar-oracle panel backend — the same code path and the same
//! arithmetic as the coordinator's local CPU executor, which is what
//! makes a loopback remote run bit-identical to the in-process shard
//! plane.
//!
//! Hostile peers are survived, not trusted: bad magic, corrupt frames,
//! malformed payloads and out-of-range jobs all produce an error reply
//! and/or a dropped connection, never a panic of the server.  A
//! [`Shutdown`](super::protocol::Message::Shutdown) frame (from any
//! peer — the worker is a loopback/cluster-internal tool, not an
//! authenticated service) ends the accept loop.

use super::protocol::{
    DoneFrame, IterFrame, Message, ShardJob, ERR_BAD_JOB, ERR_VERSION_SKEW, PROTOCOL_VERSION,
};
use super::RetryPolicy;
use crate::kmeans::panel::CpuPanels;
use crate::kmeans::shard::{solve_level1_shard, ShardPartial};
use crate::kmeans::solver::{IterEvent, IterFlow, ObserveFn};
use crate::util::frame::FrameError;
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

/// How a connection ended (drives the accept loop).
enum ConnEnd {
    /// Peer hung up or was dropped for misbehaving.
    Closed,
    /// Peer requested worker shutdown.
    Shutdown,
}

/// A bound (not yet running) shard worker.
pub struct WorkerServer {
    listener: TcpListener,
    local: SocketAddr,
    stop: Arc<AtomicBool>,
}

impl WorkerServer {
    /// Bind `addr` (e.g. `127.0.0.1:7601`; port 0 picks a free port).
    pub fn bind(addr: &str) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        Ok(Self {
            listener,
            local,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The actual bound address (resolves a `:0` bind).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Blocking accept loop.  Returns cleanly when a peer sends a
    /// Shutdown frame; propagates listener-level I/O errors.
    pub fn run(&self) -> anyhow::Result<()> {
        log::info!(
            "shard-worker listening on {} (protocol v{PROTOCOL_VERSION})",
            self.local
        );
        let mut conns: Vec<JoinHandle<()>> = Vec::new();
        // Transient accept failures (ECONNABORTED from a peer resetting
        // mid-handshake, EMFILE under fd pressure) must not take the
        // worker down; only a persistently broken listener is fatal.
        let mut accept_errors = 0u32;
        loop {
            let (stream, peer) = match self.listener.accept() {
                Ok(c) => {
                    accept_errors = 0;
                    c
                }
                Err(e) => {
                    accept_errors += 1;
                    log::warn!("shard-worker: accept failed ({accept_errors} in a row): {e}");
                    if accept_errors >= 16 {
                        return Err(e.into());
                    }
                    std::thread::sleep(std::time::Duration::from_millis(50));
                    continue;
                }
            };
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            conns.retain(|h| !h.is_finished());
            let stop = Arc::clone(&self.stop);
            let local = self.local;
            conns.push(std::thread::spawn(move || {
                match handle_conn(stream) {
                    Ok(ConnEnd::Shutdown) => {
                        log::info!("shard-worker: shutdown requested by {peer}");
                        stop.store(true, Ordering::SeqCst);
                        // Wake the accept loop so it observes the flag.
                        let _ = TcpStream::connect(local);
                    }
                    Ok(ConnEnd::Closed) => {}
                    Err(e) => log::warn!("shard-worker: connection from {peer} failed: {e}"),
                }
            }));
        }
        for c in conns {
            let _ = c.join();
        }
        Ok(())
    }

    /// Bind and run on a background thread (tests and embedders).
    pub fn spawn(addr: &str) -> anyhow::Result<WorkerHandle> {
        let server = Self::bind(addr)?;
        let local = server.local_addr();
        let join = std::thread::Builder::new()
            .name(format!("shard-worker-{local}"))
            .spawn(move || server.run())?;
        Ok(WorkerHandle { local, join })
    }
}

/// A running background [`WorkerServer`].
pub struct WorkerHandle {
    local: SocketAddr,
    join: JoinHandle<anyhow::Result<()>>,
}

impl WorkerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.local
    }

    /// Ask the worker to stop and join its accept loop.
    pub fn shutdown(self) -> anyhow::Result<()> {
        super::client::shutdown_worker(&self.local.to_string())?;
        self.wait()
    }

    /// Join the accept loop without sending anything — for callers that
    /// already delivered a Shutdown frame over their own connection.
    pub fn wait(self) -> anyhow::Result<()> {
        match self.join.join() {
            Ok(r) => r,
            Err(_) => anyhow::bail!("shard-worker accept loop panicked"),
        }
    }
}

/// Serve one coordinator connection: handshake, then a Job loop.
fn handle_conn(mut stream: TcpStream) -> anyhow::Result<ConnEnd> {
    let io_timeout = RetryPolicy::default().io_timeout;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(io_timeout))?;
    stream.set_write_timeout(Some(io_timeout))?;

    // Handshake.  A bare disconnect (the accept-loop wake-up dummy, port
    // scanners) is a normal close; a non-Hello opener is refused.
    let first = match Message::read_from(&mut stream) {
        Ok((m, _)) => m,
        Err(FrameError::Truncated) => return Ok(ConnEnd::Closed),
        Err(e) => return Err(e.into()),
    };
    match first {
        Message::Shutdown => return Ok(ConnEnd::Shutdown),
        Message::Hello { version } if version == PROTOCOL_VERSION => {
            Message::HelloAck {
                version: PROTOCOL_VERSION,
            }
            .write_to(&mut stream)?;
        }
        Message::Hello { version } => {
            Message::Error {
                code: ERR_VERSION_SKEW,
                message: format!(
                    "worker speaks protocol v{PROTOCOL_VERSION}, client sent v{version}"
                ),
            }
            .write_to(&mut stream)?;
            return Ok(ConnEnd::Closed);
        }
        other => {
            Message::Error {
                code: ERR_BAD_JOB,
                message: format!("expected Hello, got {other:?}"),
            }
            .write_to(&mut stream)?;
            return Ok(ConnEnd::Closed);
        }
    }

    // Job loop: one connection serves any number of shard solves.
    loop {
        let msg = match Message::read_from(&mut stream) {
            Ok((m, _)) => m,
            Err(FrameError::Truncated) => return Ok(ConnEnd::Closed),
            Err(e) => return Err(e.into()),
        };
        match msg {
            Message::Shutdown => return Ok(ConnEnd::Shutdown),
            Message::Job(job) => serve_job(&mut stream, *job)?,
            // Health check (v2): answer and keep serving.
            Message::Ping => {
                Message::Pong.write_to(&mut stream)?;
            }
            other => {
                Message::Error {
                    code: ERR_BAD_JOB,
                    message: format!("expected Job, Ping or Shutdown, got {other:?}"),
                }
                .write_to(&mut stream)?;
                return Ok(ConnEnd::Closed);
            }
        }
    }
}

/// Run one shard solve, streaming per-iteration frames, ending in Done.
fn serve_job(stream: &mut TcpStream, job: ShardJob) -> anyhow::Result<()> {
    let n = job.data.len();
    let k = job.spec.k as usize;
    // Range-check before touching the (panicky-by-contract) solver.
    if k < 1 || k > n || job.spec.max_iters < 1 {
        Message::Error {
            code: ERR_BAD_JOB,
            message: format!(
                "unsolvable job: k={k} over n={n} rows, max_iters={}",
                job.spec.max_iters
            ),
        }
        .write_to(stream)?;
        return Ok(());
    }
    log::debug!(
        "shard-worker: solving shard {} (n={n} d={} k={k} seed={})",
        job.shard,
        job.data.dims(),
        job.spec.seed
    );
    let wspec = job.spec.to_spec();
    // Stream every iteration back as it happens; if the coordinator went
    // away mid-solve, stop early and drop the connection.
    let mut io_err: Option<io::Error> = None;
    let result = {
        let observer = ObserveFn(|ev: &IterEvent<'_>| {
            let frame = Message::Iter(Box::new(IterFrame {
                iter: ev.iter as u64,
                stats: ev.stats.clone(),
                centroids: ev.centroids.clone(),
            }));
            match frame.write_to(&mut *stream) {
                Ok(_) => IterFlow::Continue,
                Err(e) => {
                    io_err = Some(e);
                    IterFlow::Stop
                }
            }
        });
        // CpuPanels: the scalar oracle — bitwise the coordinator's local
        // CPU executor.
        solve_level1_shard(&job.data, &wspec, CpuPanels, Some(observer))
    };
    if let Some(e) = io_err {
        return Err(e.into());
    }
    let partial = ShardPartial::from_result(result);
    Message::Done(Box::new(DoneFrame {
        centroids: partial.centroids,
        counts: partial.counts,
        stats: partial.stats,
    }))
    .write_to(stream)?;
    Ok(())
}
