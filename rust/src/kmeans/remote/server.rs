//! Server half of the remote shard plane: the `shard-worker` loop behind
//! the CLI subcommand of the same name.
//!
//! A [`WorkerServer`] accepts any number of concurrent coordinator
//! connections (one thread each), answers the version handshake, and
//! serves [`Job`](super::protocol::Message::Job) frames by running the
//! *canonical* level-1 shard solve
//! ([`shard::solve_level1_shard`](crate::kmeans::shard::solve_level1_shard))
//! over the scalar-oracle panel backend — the same code path and the same
//! arithmetic as the coordinator's local CPU executor, which is what
//! makes a loopback remote run bit-identical to the in-process shard
//! plane.
//!
//! Since protocol v3 a connection can also host a **session**: the
//! coordinator ships the shard once (`LoadShard`, crc-checked and
//! acked), then each iteration exchanges only `Centroids` → `Partials`
//! frames while the worker runs the canonical filter iteration
//! ([`filter_iteration_batched_scratch`]) over its resident copy.
//! Resident shards are bounded per connection ([`MAX_RESIDENT_BYTES`])
//! and dropped on `Release`, `EndSession`, or disconnect — a worker
//! never leaks a dataset past the connection that loaded it.
//!
//! Hostile peers are survived, not trusted: bad magic, corrupt frames,
//! malformed payloads and out-of-range jobs all produce an error reply
//! and/or a dropped connection, never a panic of the server.  A
//! [`Shutdown`](super::protocol::Message::Shutdown) frame (from any
//! peer — the worker is a loopback/cluster-internal tool, not an
//! authenticated service) ends the accept loop.

use super::protocol::{
    dataset_checksum, DoneFrame, IterFrame, LoadShardFrame, Message, PartialsFrame, ShardJob,
    ERR_BAD_CHECKSUM, ERR_BAD_JOB, ERR_NO_SHARD, ERR_RESIDENT_LIMIT, ERR_VERSION_SKEW,
    PROTOCOL_VERSION,
};
use super::RetryPolicy;
use crate::data::Dataset;
use crate::kdtree::{KdTree, DEFAULT_LEAF_SIZE};
use crate::kmeans::filtering::{filter_iteration_batched_scratch, FilterScratch};
use crate::kmeans::panel::{CpuPanels, KernelKind, ParCpuPanels};
use crate::kmeans::shard::{solve_level1_shard, ShardPartial, ShardStepper};
use crate::kmeans::solver::{IterEvent, IterFlow, ObserveFn};
use crate::kmeans::Metric;
use crate::util::frame::FrameError;
use std::collections::HashMap;
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Default per-connection cap on resident session state.  Generous for
/// the shard sizes this plane targets (a 256 MiB budget fits ~20M f32×8d
/// points at the [`ShardStepper::resident_bytes`] accounting) while
/// keeping a misbehaving coordinator from OOMing the worker box.
pub const MAX_RESIDENT_BYTES: usize = 256 << 20;

/// How a connection ended (drives the accept loop).
enum ConnEnd {
    /// Peer hung up or was dropped for misbehaving.
    Closed,
    /// Peer requested worker shutdown.
    Shutdown,
}

/// A bound (not yet running) shard worker.
pub struct WorkerServer {
    listener: TcpListener,
    local: SocketAddr,
    stop: Arc<AtomicBool>,
    resident_limit: usize,
    kernel: KernelKind,
}

impl WorkerServer {
    /// Bind `addr` (e.g. `127.0.0.1:7601`; port 0 picks a free port).
    pub fn bind(addr: &str) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        Ok(Self {
            listener,
            local,
            stop: Arc::new(AtomicBool::new(false)),
            resident_limit: MAX_RESIDENT_BYTES,
            kernel: KernelKind::Scalar,
        })
    }

    /// Override the per-connection resident-memory budget (tests shrink
    /// it to exercise the `ERR_RESIDENT_LIMIT` refusal path cheaply).
    pub fn with_resident_limit(mut self, bytes: usize) -> Self {
        self.resident_limit = bytes;
        self
    }

    /// Pick the distance-kernel tier this worker solves with.  The
    /// default is `Scalar` — the oracle arithmetic, bitwise the
    /// coordinator's local executor — so the cross-process parity pins in
    /// `tests/remote_worker.rs` hold regardless of host SIMD support.
    /// This knob is worker-local: no wire-protocol change, the
    /// coordinator never learns (or needs to know) the tier.
    pub fn with_kernel(mut self, kind: KernelKind) -> Self {
        self.kernel = kind;
        self
    }

    /// The actual bound address (resolves a `:0` bind).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Blocking accept loop.  Returns cleanly when a peer sends a
    /// Shutdown frame; propagates listener-level I/O errors.
    pub fn run(&self) -> anyhow::Result<()> {
        log::info!(
            "shard-worker listening on {} (protocol v{PROTOCOL_VERSION})",
            self.local
        );
        let mut conns: Vec<JoinHandle<()>> = Vec::new();
        // Transient accept failures (ECONNABORTED from a peer resetting
        // mid-handshake, EMFILE under fd pressure) must not take the
        // worker down; only a persistently broken listener is fatal.
        let mut accept_errors = 0u32;
        loop {
            let (stream, peer) = match self.listener.accept() {
                Ok(c) => {
                    accept_errors = 0;
                    c
                }
                Err(e) => {
                    accept_errors += 1;
                    log::warn!("shard-worker: accept failed ({accept_errors} in a row): {e}");
                    if accept_errors >= 16 {
                        return Err(e.into());
                    }
                    std::thread::sleep(std::time::Duration::from_millis(50));
                    continue;
                }
            };
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            conns.retain(|h| !h.is_finished());
            let stop = Arc::clone(&self.stop);
            let local = self.local;
            let resident_limit = self.resident_limit;
            let kernel = self.kernel;
            conns.push(std::thread::spawn(move || {
                match handle_conn(stream, resident_limit, kernel) {
                    Ok(ConnEnd::Shutdown) => {
                        log::info!("shard-worker: shutdown requested by {peer}");
                        stop.store(true, Ordering::SeqCst);
                        // Wake the accept loop so it observes the flag.
                        let _ = TcpStream::connect(local);
                    }
                    Ok(ConnEnd::Closed) => {}
                    Err(e) => log::warn!("shard-worker: connection from {peer} failed: {e}"),
                }
            }));
        }
        for c in conns {
            let _ = c.join();
        }
        Ok(())
    }

    /// Bind and run on a background thread (tests and embedders).
    pub fn spawn(addr: &str) -> anyhow::Result<WorkerHandle> {
        Self::bind(addr)?.spawn_bound()
    }

    /// Like [`spawn`](Self::spawn) with a shrunken resident budget.
    pub fn spawn_with_resident_limit(addr: &str, bytes: usize) -> anyhow::Result<WorkerHandle> {
        Self::bind(addr)?.with_resident_limit(bytes).spawn_bound()
    }

    fn spawn_bound(self) -> anyhow::Result<WorkerHandle> {
        let server = self;
        let local = server.local_addr();
        let join = std::thread::Builder::new()
            .name(format!("shard-worker-{local}"))
            .spawn(move || server.run())?;
        Ok(WorkerHandle { local, join })
    }
}

/// A running background [`WorkerServer`].
pub struct WorkerHandle {
    local: SocketAddr,
    join: JoinHandle<anyhow::Result<()>>,
}

impl WorkerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.local
    }

    /// Ask the worker to stop and join its accept loop.
    pub fn shutdown(self) -> anyhow::Result<()> {
        super::client::shutdown_worker(&self.local.to_string())?;
        self.wait()
    }

    /// Join the accept loop without sending anything — for callers that
    /// already delivered a Shutdown frame over their own connection.
    pub fn wait(self) -> anyhow::Result<()> {
        match self.join.join() {
            Ok(r) => r,
            Err(_) => anyhow::bail!("shard-worker accept loop panicked"),
        }
    }
}

/// One dataset held resident for a session (protocol v3).  Everything a
/// [`ShardStepper`] owns, flattened so the map can own the dataset and
/// the iteration state side by side.
struct Resident {
    data: Dataset,
    tree: KdTree,
    metric: Metric,
    assignments: Vec<u32>,
    scratch: FilterScratch,
    bytes: usize,
}

impl Resident {
    fn load(data: Dataset, metric: Metric) -> Self {
        let bytes = ShardStepper::<CpuPanels>::resident_bytes(&data);
        let tree = KdTree::build_par(&data, DEFAULT_LEAF_SIZE, 0);
        let assignments = vec![0u32; data.len()];
        Self {
            data,
            tree,
            metric,
            assignments,
            scratch: FilterScratch::new(),
            bytes,
        }
    }
}

/// Serve one coordinator connection: handshake, then a Job loop.
fn handle_conn(
    mut stream: TcpStream,
    resident_limit: usize,
    kernel: KernelKind,
) -> anyhow::Result<ConnEnd> {
    let io_timeout = RetryPolicy::default().io_timeout;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(io_timeout))?;
    stream.set_write_timeout(Some(io_timeout))?;

    // Handshake.  A bare disconnect (the accept-loop wake-up dummy, port
    // scanners) is a normal close; a non-Hello opener is refused.
    let first = match Message::read_from(&mut stream) {
        Ok((m, _)) => m,
        Err(FrameError::Truncated) => return Ok(ConnEnd::Closed),
        Err(e) => return Err(e.into()),
    };
    match first {
        Message::Shutdown => return Ok(ConnEnd::Shutdown),
        Message::Hello { version } if version == PROTOCOL_VERSION => {
            Message::HelloAck {
                version: PROTOCOL_VERSION,
            }
            .write_to(&mut stream)?;
        }
        Message::Hello { version } => {
            Message::Error {
                code: ERR_VERSION_SKEW,
                message: format!(
                    "worker speaks protocol v{PROTOCOL_VERSION}, client sent v{version}"
                ),
            }
            .write_to(&mut stream)?;
            return Ok(ConnEnd::Closed);
        }
        other => {
            Message::Error {
                code: ERR_BAD_JOB,
                message: format!("expected Hello, got {other:?}"),
            }
            .write_to(&mut stream)?;
            return Ok(ConnEnd::Closed);
        }
    }

    // Job loop: one connection serves any number of one-shot shard
    // solves and/or session frames.  Resident shards live exactly as
    // long as this scope — disconnect (any return path) drops them.
    let mut resident: HashMap<u32, Resident> = HashMap::new();
    loop {
        let msg = match Message::read_from(&mut stream) {
            Ok((m, _)) => m,
            Err(FrameError::Truncated) => return Ok(ConnEnd::Closed),
            Err(e) => return Err(e.into()),
        };
        match msg {
            Message::Shutdown => return Ok(ConnEnd::Shutdown),
            Message::Job(job) => serve_job(&mut stream, *job, kernel)?,
            // Health check (v2): answer and keep serving.
            Message::Ping => {
                Message::Pong.write_to(&mut stream)?;
            }
            // Session plane (v3).
            Message::LoadShard(frame) => {
                serve_load_shard(&mut stream, *frame, &mut resident, resident_limit)?;
            }
            Message::Centroids(frame) => {
                let reply = match resident.get_mut(&frame.shard) {
                    None => Message::Error {
                        code: ERR_NO_SHARD,
                        message: format!("shard {} is not resident on this connection", frame.shard),
                    },
                    Some(r) if frame.centroids.dims() != r.data.dims()
                        || frame.centroids.is_empty()
                        || frame.centroids.len() > r.data.len() =>
                    {
                        Message::Error {
                            code: ERR_BAD_JOB,
                            message: format!(
                                "centroids [{}, {}] do not fit resident shard [{}, {}]",
                                frame.centroids.len(),
                                frame.centroids.dims(),
                                r.data.len(),
                                r.data.dims()
                            ),
                        }
                    }
                    Some(r) => {
                        // Scalar (the default) is bit-identical to
                        // `CpuPanels` — the pinned cross-process parity.
                        let mut backend = ParCpuPanels::with_kind(1, kernel);
                        let (sums, counts, stats) = filter_iteration_batched_scratch(
                            &r.tree,
                            &r.data,
                            &frame.centroids,
                            r.metric,
                            &mut backend,
                            &mut r.assignments,
                            &mut r.scratch,
                        );
                        let sums = Dataset::from_flat(frame.centroids.len(), r.data.dims(), sums);
                        Message::Partials(Box::new(PartialsFrame {
                            shard: frame.shard,
                            iter: frame.iter,
                            sums,
                            counts,
                            stats,
                        }))
                    }
                };
                reply.write_to(&mut stream)?;
            }
            // Release is idempotent: retried frames after a reconnect
            // must not error.
            Message::Release { shard } => {
                resident.remove(&shard);
                Message::Released { shard }.write_to(&mut stream)?;
            }
            // Drop all session state but keep the connection — the peer
            // may still run one-shot jobs (or a fresh session) on it.
            Message::EndSession => {
                resident.clear();
            }
            other => {
                Message::Error {
                    code: ERR_BAD_JOB,
                    message: format!("expected Job, session frame, Ping or Shutdown, got {other:?}"),
                }
                .write_to(&mut stream)?;
                return Ok(ConnEnd::Closed);
            }
        }
    }
}

/// Admit (or refuse) a `LoadShard`: checksum, budget, then residency.
fn serve_load_shard(
    stream: &mut TcpStream,
    frame: LoadShardFrame,
    resident: &mut HashMap<u32, Resident>,
    resident_limit: usize,
) -> anyhow::Result<()> {
    if frame.data.is_empty() {
        Message::Error {
            code: ERR_BAD_JOB,
            message: format!("refusing empty shard {}", frame.shard),
        }
        .write_to(stream)?;
        return Ok(());
    }
    let got = dataset_checksum(&frame.data);
    if got != frame.checksum {
        Message::Error {
            code: ERR_BAD_CHECKSUM,
            message: format!(
                "shard {} checksum mismatch: frame says {:#010x}, payload hashes to {got:#010x}",
                frame.shard, frame.checksum
            ),
        }
        .write_to(stream)?;
        return Ok(());
    }
    // Re-loading the same shard id replaces it (reconnect/recovery), so
    // its old footprint does not count against the budget.
    let held: usize = resident
        .iter()
        .filter(|(id, _)| **id != frame.shard)
        .map(|(_, r)| r.bytes)
        .sum();
    let incoming = ShardStepper::<CpuPanels>::resident_bytes(&frame.data);
    if held + incoming > resident_limit {
        Message::Error {
            code: ERR_RESIDENT_LIMIT,
            message: format!(
                "shard {} needs {incoming} resident bytes; {held} of {resident_limit} already held",
                frame.shard
            ),
        }
        .write_to(stream)?;
        return Ok(());
    }
    log::debug!(
        "shard-worker: shard {} resident (n={} d={} {incoming} bytes)",
        frame.shard,
        frame.data.len(),
        frame.data.dims()
    );
    let checksum = frame.checksum;
    let shard = frame.shard;
    resident.insert(shard, Resident::load(frame.data, frame.metric));
    Message::LoadAck { shard, checksum }.write_to(stream)?;
    Ok(())
}

/// Run one shard solve, streaming per-iteration frames, ending in Done.
fn serve_job(stream: &mut TcpStream, job: ShardJob, kernel: KernelKind) -> anyhow::Result<()> {
    let n = job.data.len();
    let k = job.spec.k as usize;
    // Range-check before touching the (panicky-by-contract) solver.
    if k < 1 || k > n || job.spec.max_iters < 1 {
        Message::Error {
            code: ERR_BAD_JOB,
            message: format!(
                "unsolvable job: k={k} over n={n} rows, max_iters={}",
                job.spec.max_iters
            ),
        }
        .write_to(stream)?;
        return Ok(());
    }
    log::debug!(
        "shard-worker: solving shard {} (n={n} d={} k={k} seed={})",
        job.shard,
        job.data.dims(),
        job.spec.seed
    );
    let wspec = job.spec.to_spec();
    // Stream every iteration back as it happens; if the coordinator went
    // away mid-solve, stop early and drop the connection.
    let mut io_err: Option<io::Error> = None;
    let result = {
        let observer = ObserveFn(|ev: &IterEvent<'_>| {
            let frame = Message::Iter(Box::new(IterFrame {
                iter: ev.iter as u64,
                stats: ev.stats.clone(),
                centroids: ev.centroids.clone(),
            }));
            match frame.write_to(&mut *stream) {
                Ok(_) => IterFlow::Continue,
                Err(e) => {
                    io_err = Some(e);
                    IterFlow::Stop
                }
            }
        });
        // The default Scalar tier is the oracle arithmetic — bitwise the
        // coordinator's local CPU executor (`ParCpuPanels::scalar` is
        // pinned bit-identical to `CpuPanels`).
        solve_level1_shard(
            &job.data,
            &wspec,
            ParCpuPanels::with_kind(1, kernel),
            Some(observer),
        )
    };
    if let Some(e) = io_err {
        return Err(e.into());
    }
    let partial = ShardPartial::from_result(result);
    Message::Done(Box::new(DoneFrame {
        centroids: partial.centroids,
        counts: partial.counts,
        stats: partial.stats,
    }))
    .write_to(stream)?;
    Ok(())
}
