//! Message layer of the remote shard plane: typed frames over the
//! [`util::frame`](crate::util::frame) codec.
//!
//! Conversation shape (client = coordinator, server = `shard-worker`):
//!
//! ```text
//! client → Hello{version}            server → HelloAck{version}
//!                                           | Error{VersionSkew}
//! client → Ping                      server → Pong        (health check)
//! client → Job{shard, spec, slice}   server → Iter{..} × iterations
//!                                            Done{centroids, counts, stats}
//!                                           | Error{BadJob | Internal}
//! …(more Pings / Jobs on the same connection)…
//! client → Shutdown                  server exits its accept loop
//! ```
//!
//! Session mode (v3) makes the worker **dataset-resident**: the shard
//! slice crosses the wire once, then each iteration moves only O(k·d):
//!
//! ```text
//! client → LoadShard{shard, metric,  server → LoadAck{shard, checksum}
//!            checksum, slice}                | Error{BadChecksum |
//!                                                    ResidentLimit}
//! per iteration:
//! client → Centroids{shard, iter,    server → Partials{shard, iter,
//!            centroids}                        sums, counts, stats}
//!                                           | Error{NoShard | Internal}
//! client → Release{shard}            server → Released{shard}   (drops it)
//! client → EndSession                server drops every resident shard,
//!                                    keeps the connection for one-shot use
//! ```
//!
//! The *coordinator* runs the global Lloyd/filtering loop in session
//! mode; the worker executes exactly one canonical filter iteration per
//! `Centroids` frame over its resident slice.  Resident state is
//! per-connection and dropped on disconnect.
//!
//! All numeric fields are little-endian; every f32/f64 travels as exact
//! IEEE bits, which is what lets a loopback remote run reproduce the
//! in-process shard plane bit for bit.  Decoders never panic on hostile
//! payloads — every length is bounds-checked against the frame.
//!
//! This module is a `pallas-lint` panic-hygiene surface: production code
//! here must stay free of `unwrap`/`expect`/panicking macros and
//! unchecked indexing.  The clippy denies below backstop the custom lint.

#![deny(clippy::unwrap_used, clippy::expect_used)]

use crate::data::Dataset;
use crate::kmeans::init::Init;
use crate::kmeans::solver::{Algo, KmeansSpec};
use crate::kmeans::{IterStats, LevelWork, Metric, RunStats};
use crate::util::frame::{read_frame, write_frame, ByteReader, ByteWriter, FrameError};
use std::io::{self, Read, Write};

/// Wire protocol version; the handshake requires an exact match (the
/// format has no negotiation — a skewed peer is told so and dropped).
/// v2 added the `Ping`/`Pong` health-check frames; v3 added the session
/// plane (`LoadShard`/`LoadAck`/`Centroids`/`Partials`/`Release`/
/// `Released`/`EndSession`).
pub const PROTOCOL_VERSION: u32 = 3;

// Frame kinds.
pub const KIND_HELLO: u8 = 1;
pub const KIND_HELLO_ACK: u8 = 2;
pub const KIND_JOB: u8 = 3;
pub const KIND_ITER: u8 = 4;
pub const KIND_DONE: u8 = 5;
pub const KIND_ERROR: u8 = 6;
pub const KIND_SHUTDOWN: u8 = 7;
pub const KIND_PING: u8 = 8;
pub const KIND_PONG: u8 = 9;
// Session plane (v3).
pub const KIND_LOAD_SHARD: u8 = 10;
pub const KIND_LOAD_ACK: u8 = 11;
pub const KIND_CENTROIDS: u8 = 12;
pub const KIND_PARTIALS: u8 = 13;
pub const KIND_RELEASE: u8 = 14;
pub const KIND_RELEASED: u8 = 15;
pub const KIND_END_SESSION: u8 = 16;

// Error codes carried by [`Message::Error`].
pub const ERR_VERSION_SKEW: u8 = 1;
pub const ERR_BAD_JOB: u8 = 2;
pub const ERR_INTERNAL: u8 = 3;
/// A `Centroids`/`Release` frame named a shard this connection never
/// loaded (or already released).
pub const ERR_NO_SHARD: u8 = 4;
/// Loading this shard would exceed the worker's resident-memory budget.
pub const ERR_RESIDENT_LIMIT: u8 = 5;
/// The `LoadShard` payload's checksum does not match its data bytes.
pub const ERR_BAD_CHECKSUM: u8 = 6;

/// The solver knobs a level-1 shard solve needs — the spec snapshot of
/// the handshake's Job frames.  Deliberately *not* the whole
/// [`KmeansSpec`]: partition/shards/level-2 fields are coordinator-side
/// concerns, the seed arrives already shard-derived
/// ([`shard_seed`](crate::kmeans::shard::shard_seed)'d by the client),
/// and `track_cost` is omitted on purpose — the filtering engine behind
/// every level-1 solve has no cost tracking (`FilterOpts` carries none),
/// so the flag is dead weight locally and remotely alike.  If a future
/// engine grows it, bump [`PROTOCOL_VERSION`] and add the field.
#[derive(Clone, Debug, PartialEq)]
pub struct WireSpec {
    pub k: u32,
    pub metric: Metric,
    /// Exact bits of the convergence tolerance.
    pub tol: f32,
    pub max_iters: u64,
    pub init: Init,
    pub seed: u64,
}

impl WireSpec {
    /// Snapshot the fields of an (already [`level1_spec`]-derived)
    /// working spec.
    ///
    /// [`level1_spec`]: crate::kmeans::shard::level1_spec
    pub fn from_spec(spec: &KmeansSpec) -> Self {
        Self {
            k: spec.k as u32,
            metric: spec.metric,
            tol: spec.tol,
            max_iters: spec.max_iters as u64,
            init: spec.init,
            seed: spec.seed,
        }
    }

    /// Rebuild the working spec a worker runs: always the batched
    /// filtering engine (the panel backend is injected worker-side).
    pub fn to_spec(&self) -> KmeansSpec {
        KmeansSpec::new(self.k as usize)
            .algo(Algo::FilterBatched)
            .metric(self.metric)
            .tol(self.tol)
            .max_iters(self.max_iters as usize)
            .init(self.init)
            .seed(self.seed)
            .workers(1)
    }
}

/// One shard solve request.
#[derive(Clone, Debug)]
pub struct ShardJob {
    /// Shard index within the coordinator's plan (for logs/accounting).
    pub shard: u32,
    pub spec: WireSpec,
    /// The shard's rows, exact bits.
    pub data: Dataset,
}

/// One streamed iteration of a running shard solve: the post-update
/// centroids plus that iteration's work counters (the coordinator's live
/// metrics feed).  The coordinator currently consumes only `stats`;
/// centroids ride along by design (k×d×4 bytes — small next to the
/// solve) so progress UIs / checkpointing consumers can subscribe
/// without a protocol bump.
#[derive(Clone, Debug)]
pub struct IterFrame {
    pub iter: u64,
    pub stats: IterStats,
    pub centroids: Dataset,
}

/// Terminal frame of a shard solve: the `(centroids, counts)` partials
/// the combiner consumes plus the full run statistics.
#[derive(Clone, Debug)]
pub struct DoneFrame {
    pub centroids: Dataset,
    pub counts: Vec<usize>,
    pub stats: RunStats,
}

/// Session-mode shard upload (v3): the one O(n/P) transfer of a
/// session.  The checksum is [`dataset_checksum`] over the slice's exact
/// f32 bits — the worker recomputes it before accepting residency, so a
/// corrupted upload can never silently seed a whole session of wrong
/// partials.
#[derive(Clone, Debug)]
pub struct LoadShardFrame {
    pub shard: u32,
    /// Distance metric every iteration of this session will use (fixed at
    /// load so the per-iteration frames stay minimal).
    pub metric: Metric,
    /// [`dataset_checksum`] of `data`, verified worker-side.
    pub checksum: u32,
    /// The shard's rows, exact bits.
    pub data: Dataset,
}

/// Session-mode per-iteration broadcast (v3): just the current k×d
/// centroids — the steady-state O(k·d) downlink.
#[derive(Clone, Debug)]
pub struct CentroidsFrame {
    pub shard: u32,
    /// Iteration index, echoed back in the matching [`PartialsFrame`] so
    /// the coordinator can detect a desynced worker.
    pub iter: u64,
    pub centroids: Dataset,
}

/// Session-mode per-iteration reduce (v3): one filter iteration's
/// per-center sums (k×d, exact bits), member counts and work counters.
/// The coordinator folds these through the same update step the
/// in-process engine uses, so the trajectory is bitwise-identical.
#[derive(Clone, Debug)]
pub struct PartialsFrame {
    pub shard: u32,
    /// Echo of the driving [`CentroidsFrame`]'s iteration index.
    pub iter: u64,
    /// Per-center coordinate sums as a k×d dataset (exact f32 bits).
    pub sums: Dataset,
    /// Per-center member counts (same k as `sums`).
    pub counts: Vec<u32>,
    pub stats: IterStats,
}

/// Every message the protocol speaks.
#[derive(Clone, Debug)]
pub enum Message {
    Hello { version: u32 },
    HelloAck { version: u32 },
    Job(Box<ShardJob>),
    Iter(Box<IterFrame>),
    Done(Box<DoneFrame>),
    Error { code: u8, message: String },
    Shutdown,
    /// Health-check request (v2): empty payload, answered with [`Pong`].
    ///
    /// [`Pong`]: Message::Pong
    Ping,
    /// Health-check reply (v2): empty payload.
    Pong,
    /// Session upload (v3): make a shard resident on this connection.
    LoadShard(Box<LoadShardFrame>),
    /// Residency granted (v3): echoes the shard and verified checksum.
    LoadAck { shard: u32, checksum: u32 },
    /// Per-iteration centroid broadcast (v3).
    Centroids(Box<CentroidsFrame>),
    /// Per-iteration partial reduce (v3).
    Partials(Box<PartialsFrame>),
    /// Drop one resident shard (v3).
    Release { shard: u32 },
    /// Residency dropped (v3): echoes the released shard.
    Released { shard: u32 },
    /// Drop every resident shard on this connection (v3); the connection
    /// stays open for one-shot jobs or a fresh session.
    EndSession,
}

/// Checksum of a dataset's exact f32 bit content (the integrity tag of
/// [`LoadShardFrame`]).  Shape is deliberately excluded: the frame codec
/// already validates `n × d == len`, this guards the payload bits.
pub fn dataset_checksum(d: &Dataset) -> u32 {
    let mut bytes = Vec::with_capacity(d.flat().len() * 4);
    for v in d.flat() {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    crate::util::frame::crc32(&bytes)
}

// ---------------------------------------------------------------------------
// Field codecs
// ---------------------------------------------------------------------------

fn put_metric(w: &mut ByteWriter, m: Metric) {
    w.put_u8(match m {
        Metric::Euclid => 0,
        Metric::Manhattan => 1,
    });
}

fn take_metric(r: &mut ByteReader<'_>) -> Result<Metric, FrameError> {
    match r.take_u8()? {
        0 => Ok(Metric::Euclid),
        1 => Ok(Metric::Manhattan),
        _ => Err(FrameError::Malformed("unknown metric tag")),
    }
}

fn put_init(w: &mut ByteWriter, i: Init) {
    w.put_u8(match i {
        Init::UniformSample => 0,
        Init::KmeansPlusPlus => 1,
    });
}

fn take_init(r: &mut ByteReader<'_>) -> Result<Init, FrameError> {
    match r.take_u8()? {
        0 => Ok(Init::UniformSample),
        1 => Ok(Init::KmeansPlusPlus),
        _ => Err(FrameError::Malformed("unknown init tag")),
    }
}

fn put_dataset(w: &mut ByteWriter, d: &Dataset) {
    w.put_u32(d.len() as u32);
    w.put_u32(d.dims() as u32);
    w.put_f32_slice(d.flat());
}

fn take_dataset(r: &mut ByteReader<'_>) -> Result<Dataset, FrameError> {
    let n = r.take_u32()? as usize;
    let d = r.take_u32()? as usize;
    let flat = r.take_f32_vec()?;
    if d == 0 || flat.len() != n.saturating_mul(d) {
        return Err(FrameError::Malformed("dataset shape/length mismatch"));
    }
    Ok(Dataset::from_flat(n, d, flat))
}

fn put_iter_stats(w: &mut ByteWriter, s: &IterStats) {
    w.put_u64(s.dist_evals);
    w.put_u64(s.node_visits);
    w.put_u64(s.leaf_points);
    w.put_u64(s.interior_assigns);
    w.put_u64(s.prune_tests);
    w.put_f32_bits(s.moved);
    match s.cost {
        Some(c) => {
            w.put_u8(1);
            w.put_f64_bits(c);
        }
        None => w.put_u8(0),
    }
    w.put_u32(s.levels.len() as u32);
    for l in &s.levels {
        w.put_u64(l.interior_jobs);
        w.put_u64(l.leaf_jobs);
        w.put_u64(l.cand_evals);
        w.put_u64(l.prune_tests);
    }
}

fn take_iter_stats(r: &mut ByteReader<'_>) -> Result<IterStats, FrameError> {
    let mut s = IterStats {
        dist_evals: r.take_u64()?,
        node_visits: r.take_u64()?,
        leaf_points: r.take_u64()?,
        interior_assigns: r.take_u64()?,
        prune_tests: r.take_u64()?,
        moved: r.take_f32_bits()?,
        cost: None,
        levels: Vec::new(),
    };
    if r.take_u8()? != 0 {
        s.cost = Some(r.take_f64_bits()?);
    }
    let nlevels = r.take_u32()? as usize;
    if r.remaining() < nlevels.saturating_mul(32) {
        return Err(FrameError::Malformed("level histogram length"));
    }
    s.levels.reserve(nlevels);
    for _ in 0..nlevels {
        s.levels.push(LevelWork {
            interior_jobs: r.take_u64()?,
            leaf_jobs: r.take_u64()?,
            cand_evals: r.take_u64()?,
            prune_tests: r.take_u64()?,
        });
    }
    Ok(s)
}

fn put_run_stats(w: &mut ByteWriter, s: &RunStats) {
    w.put_u8(s.converged as u8);
    w.put_u8(s.early_stopped as u8);
    w.put_u32(s.iters.len() as u32);
    for it in &s.iters {
        put_iter_stats(w, it);
    }
}

fn take_run_stats(r: &mut ByteReader<'_>) -> Result<RunStats, FrameError> {
    let converged = r.take_u8()? != 0;
    let early_stopped = r.take_u8()? != 0;
    let n = r.take_u32()? as usize;
    // Each iteration costs >= 49 payload bytes; bound before reserving.
    if r.remaining() < n.saturating_mul(49) {
        return Err(FrameError::Malformed("iteration list length"));
    }
    let mut iters = Vec::with_capacity(n);
    for _ in 0..n {
        iters.push(take_iter_stats(r)?);
    }
    Ok(RunStats {
        iters,
        converged,
        early_stopped,
        // Kernel-tier telemetry is local-process only — the wire format
        // does not carry it, so decoded stats read zero.
        ..RunStats::default()
    })
}

// ---------------------------------------------------------------------------
// Message encode/decode
// ---------------------------------------------------------------------------

/// Encode a Job frame from *borrowed* parts — the client-side hot path
/// uses this so the shard slice is serialized straight from the plan's
/// dataset without an intermediate clone into a [`ShardJob`].
pub fn encode_job(shard: u32, spec: &WireSpec, data: &Dataset) -> (u8, Vec<u8>) {
    let mut w = ByteWriter::with_capacity(40 + data.flat().len() * 4);
    w.put_u32(shard);
    w.put_u32(spec.k);
    put_metric(&mut w, spec.metric);
    w.put_f32_bits(spec.tol);
    w.put_u64(spec.max_iters);
    put_init(&mut w, spec.init);
    w.put_u64(spec.seed);
    put_dataset(&mut w, data);
    (KIND_JOB, w.into_vec())
}

impl Message {
    /// `(frame kind, payload)` of this message.
    pub fn encode(&self) -> (u8, Vec<u8>) {
        let mut w = ByteWriter::new();
        let kind = match self {
            Message::Hello { version } => {
                w.put_u32(*version);
                KIND_HELLO
            }
            Message::HelloAck { version } => {
                w.put_u32(*version);
                KIND_HELLO_ACK
            }
            // Serialized straight from the borrowed parts — the hot path
            // (`encode_job`) never clones the shard slice.
            Message::Job(job) => return encode_job(job.shard, &job.spec, &job.data),
            Message::Iter(it) => {
                w.put_u64(it.iter);
                put_iter_stats(&mut w, &it.stats);
                put_dataset(&mut w, &it.centroids);
                KIND_ITER
            }
            Message::Done(done) => {
                put_dataset(&mut w, &done.centroids);
                w.put_u32(done.counts.len() as u32);
                for &c in &done.counts {
                    w.put_u64(c as u64);
                }
                put_run_stats(&mut w, &done.stats);
                KIND_DONE
            }
            Message::Error { code, message } => {
                w.put_u8(*code);
                w.put_str(message);
                KIND_ERROR
            }
            Message::Shutdown => KIND_SHUTDOWN,
            Message::Ping => KIND_PING,
            Message::Pong => KIND_PONG,
            Message::LoadShard(ls) => {
                w.put_u32(ls.shard);
                put_metric(&mut w, ls.metric);
                w.put_u32(ls.checksum);
                put_dataset(&mut w, &ls.data);
                KIND_LOAD_SHARD
            }
            Message::LoadAck { shard, checksum } => {
                w.put_u32(*shard);
                w.put_u32(*checksum);
                KIND_LOAD_ACK
            }
            Message::Centroids(c) => {
                w.put_u32(c.shard);
                w.put_u64(c.iter);
                put_dataset(&mut w, &c.centroids);
                KIND_CENTROIDS
            }
            Message::Partials(p) => {
                w.put_u32(p.shard);
                w.put_u64(p.iter);
                put_dataset(&mut w, &p.sums);
                w.put_u32(p.counts.len() as u32);
                for &c in &p.counts {
                    w.put_u32(c);
                }
                put_iter_stats(&mut w, &p.stats);
                KIND_PARTIALS
            }
            Message::Release { shard } => {
                w.put_u32(*shard);
                KIND_RELEASE
            }
            Message::Released { shard } => {
                w.put_u32(*shard);
                KIND_RELEASED
            }
            Message::EndSession => KIND_END_SESSION,
        };
        (kind, w.into_vec())
    }

    /// Decode a frame's payload.  Unknown kinds and malformed payloads
    /// are errors, never panics.
    pub fn decode(kind: u8, payload: &[u8]) -> Result<Message, FrameError> {
        let mut r = ByteReader::new(payload);
        let msg = match kind {
            KIND_HELLO => Message::Hello {
                version: r.take_u32()?,
            },
            KIND_HELLO_ACK => Message::HelloAck {
                version: r.take_u32()?,
            },
            KIND_JOB => {
                let shard = r.take_u32()?;
                let k = r.take_u32()?;
                let metric = take_metric(&mut r)?;
                let tol = r.take_f32_bits()?;
                let max_iters = r.take_u64()?;
                let init = take_init(&mut r)?;
                let seed = r.take_u64()?;
                let data = take_dataset(&mut r)?;
                Message::Job(Box::new(ShardJob {
                    shard,
                    spec: WireSpec {
                        k,
                        metric,
                        tol,
                        max_iters,
                        init,
                        seed,
                    },
                    data,
                }))
            }
            KIND_ITER => {
                let iter = r.take_u64()?;
                let stats = take_iter_stats(&mut r)?;
                let centroids = take_dataset(&mut r)?;
                Message::Iter(Box::new(IterFrame {
                    iter,
                    stats,
                    centroids,
                }))
            }
            KIND_DONE => {
                let centroids = take_dataset(&mut r)?;
                let n = r.take_u32()? as usize;
                if r.remaining() < n.saturating_mul(8) {
                    return Err(FrameError::Malformed("count list length"));
                }
                let mut counts = Vec::with_capacity(n);
                for _ in 0..n {
                    counts.push(r.take_u64()? as usize);
                }
                let stats = take_run_stats(&mut r)?;
                Message::Done(Box::new(DoneFrame {
                    centroids,
                    counts,
                    stats,
                }))
            }
            KIND_ERROR => Message::Error {
                code: r.take_u8()?,
                message: r.take_str()?,
            },
            KIND_SHUTDOWN => Message::Shutdown,
            KIND_PING => Message::Ping,
            KIND_PONG => Message::Pong,
            KIND_LOAD_SHARD => {
                let shard = r.take_u32()?;
                let metric = take_metric(&mut r)?;
                let checksum = r.take_u32()?;
                let data = take_dataset(&mut r)?;
                Message::LoadShard(Box::new(LoadShardFrame {
                    shard,
                    metric,
                    checksum,
                    data,
                }))
            }
            KIND_LOAD_ACK => Message::LoadAck {
                shard: r.take_u32()?,
                checksum: r.take_u32()?,
            },
            KIND_CENTROIDS => {
                let shard = r.take_u32()?;
                let iter = r.take_u64()?;
                let centroids = take_dataset(&mut r)?;
                Message::Centroids(Box::new(CentroidsFrame {
                    shard,
                    iter,
                    centroids,
                }))
            }
            KIND_PARTIALS => {
                let shard = r.take_u32()?;
                let iter = r.take_u64()?;
                let sums = take_dataset(&mut r)?;
                let n = r.take_u32()? as usize;
                if n != sums.len() {
                    return Err(FrameError::Malformed("partials count/sum shape mismatch"));
                }
                if r.remaining() < n.saturating_mul(4) {
                    return Err(FrameError::Malformed("partials count list length"));
                }
                let mut counts = Vec::with_capacity(n);
                for _ in 0..n {
                    counts.push(r.take_u32()?);
                }
                let stats = take_iter_stats(&mut r)?;
                Message::Partials(Box::new(PartialsFrame {
                    shard,
                    iter,
                    sums,
                    counts,
                    stats,
                }))
            }
            KIND_RELEASE => Message::Release {
                shard: r.take_u32()?,
            },
            KIND_RELEASED => Message::Released {
                shard: r.take_u32()?,
            },
            KIND_END_SESSION => Message::EndSession,
            _ => return Err(FrameError::Malformed("unknown frame kind")),
        };
        r.finish()?;
        Ok(msg)
    }

    /// Frame and send this message; returns bytes put on the wire.
    pub fn write_to(&self, w: &mut impl Write) -> io::Result<usize> {
        let (kind, payload) = self.encode();
        write_frame(w, kind, &payload)
    }

    /// Read and decode one message; returns it with its wire byte count.
    pub fn read_from(r: &mut impl Read) -> Result<(Message, usize), FrameError> {
        let (kind, payload, n) = read_frame(r)?;
        Ok((Message::decode(kind, &payload)?, n))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::data::synthetic::generate_params;
    use std::io::Cursor;

    fn round_trip(msg: &Message) -> Message {
        let mut wire = Vec::new();
        let tx = msg.write_to(&mut wire).unwrap();
        assert_eq!(tx, wire.len());
        let (back, rx) = Message::read_from(&mut Cursor::new(&wire)).unwrap();
        assert_eq!(rx, tx);
        back
    }

    #[test]
    fn handshake_messages_round_trip() {
        match round_trip(&Message::Hello {
            version: PROTOCOL_VERSION,
        }) {
            Message::Hello { version } => assert_eq!(version, PROTOCOL_VERSION),
            other => panic!("{other:?}"),
        }
        match round_trip(&Message::Error {
            code: ERR_VERSION_SKEW,
            message: "speak v1".into(),
        }) {
            Message::Error { code, message } => {
                assert_eq!(code, ERR_VERSION_SKEW);
                assert_eq!(message, "speak v1");
            }
            other => panic!("{other:?}"),
        }
        assert!(matches!(round_trip(&Message::Shutdown), Message::Shutdown));
    }

    #[test]
    fn ping_pong_round_trip_with_empty_payloads() {
        assert!(matches!(round_trip(&Message::Ping), Message::Ping));
        assert!(matches!(round_trip(&Message::Pong), Message::Pong));
        // Empty payload is part of the contract: a bloated health check
        // would tax the between-jobs path.
        assert!(Message::Ping.encode().1.is_empty());
        assert!(Message::Pong.encode().1.is_empty());
        // A Ping/Pong with trailing bytes is malformed, not ignored.
        assert!(Message::decode(KIND_PING, &[0]).is_err());
        assert!(Message::decode(KIND_PONG, &[0]).is_err());
    }

    #[test]
    fn job_round_trips_exact_bits() {
        let s = generate_params(37, 5, 3, 0.2, 1.0, 8);
        let spec = WireSpec {
            k: 3,
            metric: Metric::Manhattan,
            tol: 1e-6,
            max_iters: 100,
            init: Init::KmeansPlusPlus,
            seed: u64::MAX - 5,
        };
        let job = Message::Job(Box::new(ShardJob {
            shard: 2,
            spec: spec.clone(),
            data: s.data.clone(),
        }));
        match round_trip(&job) {
            Message::Job(j) => {
                assert_eq!(j.shard, 2);
                assert_eq!(j.spec, spec);
                // Bitwise dataset equality.
                assert_eq!(j.data.flat().len(), s.data.flat().len());
                for (a, b) in j.data.flat().iter().zip(s.data.flat()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn wire_spec_maps_onto_the_solver_spec() {
        let spec = WireSpec {
            k: 7,
            metric: Metric::Euclid,
            tol: 3.5e-5,
            max_iters: 41,
            init: Init::UniformSample,
            seed: 99,
        };
        let k = spec.to_spec();
        assert_eq!(k.k, 7);
        assert_eq!(k.algo, Algo::FilterBatched);
        assert_eq!(k.tol.to_bits(), 3.5e-5f32.to_bits());
        assert_eq!(k.max_iters, 41);
        assert_eq!(k.seed, 99);
        assert_eq!(WireSpec::from_spec(&k), spec);
    }

    #[test]
    fn done_round_trips_stats_and_counts() {
        let stats = RunStats {
            converged: true,
            early_stopped: false,
            iters: vec![
                IterStats {
                    dist_evals: 123,
                    node_visits: 45,
                    leaf_points: 6,
                    interior_assigns: 7,
                    prune_tests: 89,
                    moved: 0.25,
                    cost: Some(1.5),
                    levels: vec![LevelWork {
                        interior_jobs: 1,
                        leaf_jobs: 2,
                        cand_evals: 3,
                        prune_tests: 4,
                    }],
                },
                IterStats::default(),
            ],
            ..RunStats::default()
        };
        let done = Message::Done(Box::new(DoneFrame {
            centroids: Dataset::from_flat(2, 2, vec![1.0, -0.0, f32::MIN_POSITIVE, 4.0]),
            counts: vec![10, 20],
            stats,
        }));
        match round_trip(&done) {
            Message::Done(d) => {
                assert_eq!(d.counts, vec![10, 20]);
                assert!(d.stats.converged);
                assert_eq!(d.stats.iters.len(), 2);
                assert_eq!(d.stats.iters[0].dist_evals, 123);
                assert_eq!(d.stats.iters[0].cost, Some(1.5));
                assert_eq!(d.stats.iters[0].levels.len(), 1);
                assert_eq!(d.centroids.point(0)[1].to_bits(), (-0.0f32).to_bits());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn malformed_payloads_are_rejected_without_panic() {
        // Unknown kind.
        assert!(Message::decode(99, &[]).is_err());
        // Truncated payloads for every kind.
        for kind in [
            KIND_HELLO,
            KIND_HELLO_ACK,
            KIND_JOB,
            KIND_ITER,
            KIND_DONE,
            KIND_ERROR,
        ] {
            assert!(Message::decode(kind, &[1, 2]).is_err(), "kind {kind}");
        }
        // Trailing garbage after a valid message body.
        let (kind, mut payload) = Message::Hello { version: 1 }.encode();
        payload.push(0);
        assert!(Message::decode(kind, &payload).is_err());
        // Bad enum tags inside a job are refused.
        let s = generate_params(5, 2, 1, 0.2, 1.0, 3);
        let (kind, mut payload) = Message::Job(Box::new(ShardJob {
            shard: 0,
            spec: WireSpec {
                k: 1,
                metric: Metric::Euclid,
                tol: 0.0,
                max_iters: 1,
                init: Init::UniformSample,
                seed: 0,
            },
            data: s.data.clone(),
        }))
        .encode();
        payload[8] = 9; // metric tag byte
        assert!(Message::decode(kind, &payload).is_err());
    }

    #[test]
    fn session_frames_round_trip_exact_bits() {
        let s = generate_params(23, 4, 3, 0.2, 1.0, 17);
        let sum = dataset_checksum(&s.data);
        match round_trip(&Message::LoadShard(Box::new(LoadShardFrame {
            shard: 3,
            metric: Metric::Manhattan,
            checksum: sum,
            data: s.data.clone(),
        }))) {
            Message::LoadShard(ls) => {
                assert_eq!(ls.shard, 3);
                assert_eq!(ls.metric, Metric::Manhattan);
                assert_eq!(ls.checksum, sum);
                for (a, b) in ls.data.flat().iter().zip(s.data.flat()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                // The checksum still validates against the decoded bits.
                assert_eq!(dataset_checksum(&ls.data), sum);
            }
            other => panic!("{other:?}"),
        }
        match round_trip(&Message::LoadAck {
            shard: 3,
            checksum: sum,
        }) {
            Message::LoadAck { shard, checksum } => {
                assert_eq!((shard, checksum), (3, sum));
            }
            other => panic!("{other:?}"),
        }
        let cents = Dataset::from_flat(2, 2, vec![-0.0, 1.5, f32::MIN_POSITIVE, -3.25]);
        match round_trip(&Message::Centroids(Box::new(CentroidsFrame {
            shard: 1,
            iter: 41,
            centroids: cents.clone(),
        }))) {
            Message::Centroids(c) => {
                assert_eq!((c.shard, c.iter), (1, 41));
                for (a, b) in c.centroids.flat().iter().zip(cents.flat()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("{other:?}"),
        }
        match round_trip(&Message::Partials(Box::new(PartialsFrame {
            shard: 1,
            iter: 41,
            sums: cents.clone(),
            counts: vec![7, 0],
            stats: IterStats {
                dist_evals: 9,
                moved: -0.0,
                ..Default::default()
            },
        }))) {
            Message::Partials(p) => {
                assert_eq!((p.shard, p.iter), (1, 41));
                assert_eq!(p.counts, vec![7, 0]);
                assert_eq!(p.stats.dist_evals, 9);
                assert_eq!(p.stats.moved.to_bits(), (-0.0f32).to_bits());
                for (a, b) in p.sums.flat().iter().zip(cents.flat()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("{other:?}"),
        }
        match round_trip(&Message::Release { shard: 2 }) {
            Message::Release { shard } => assert_eq!(shard, 2),
            other => panic!("{other:?}"),
        }
        match round_trip(&Message::Released { shard: 2 }) {
            Message::Released { shard } => assert_eq!(shard, 2),
            other => panic!("{other:?}"),
        }
        assert!(matches!(round_trip(&Message::EndSession), Message::EndSession));
        // EndSession is an empty frame, like the other control kinds.
        assert!(Message::EndSession.encode().1.is_empty());
        assert!(Message::decode(KIND_END_SESSION, &[0]).is_err());
    }

    #[test]
    fn malformed_session_payloads_are_rejected() {
        for kind in [
            KIND_LOAD_SHARD,
            KIND_LOAD_ACK,
            KIND_CENTROIDS,
            KIND_PARTIALS,
            KIND_RELEASE,
            KIND_RELEASED,
        ] {
            assert!(Message::decode(kind, &[1, 2]).is_err(), "kind {kind}");
        }
        // A Partials frame whose count list disagrees with its sums shape
        // is refused outright.
        let (kind, payload) = Message::Partials(Box::new(PartialsFrame {
            shard: 0,
            iter: 0,
            sums: Dataset::from_flat(2, 2, vec![0.0; 4]),
            counts: vec![1, 2],
            stats: IterStats::default(),
        }))
        .encode();
        let mut bad = payload.clone();
        // counts-length word sits after shard(4) + iter(8) + sums dataset
        // (n:4 + d:4 + len:4 + 4 floats:16 = 28).
        bad[40] = 9;
        assert!(Message::decode(kind, &bad).is_err());
        // Checksums are order- and bit-sensitive.
        let a = dataset_checksum(&Dataset::from_flat(2, 1, vec![1.0, 2.0]));
        let b = dataset_checksum(&Dataset::from_flat(2, 1, vec![2.0, 1.0]));
        let c = dataset_checksum(&Dataset::from_flat(2, 1, vec![1.0, -2.0]));
        assert_ne!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn every_iter_stat_field_survives_the_wire() {
        // Catches a codec that forgets a field: absorb-equality on a
        // fully-populated IterStats.
        let mut w = ByteWriter::new();
        let s = IterStats {
            dist_evals: 1,
            node_visits: 2,
            leaf_points: 3,
            interior_assigns: 4,
            prune_tests: 5,
            moved: -0.0,
            cost: None,
            levels: vec![LevelWork::default(); 3],
        };
        put_iter_stats(&mut w, &s);
        let buf = w.into_vec();
        let mut r = ByteReader::new(&buf);
        let back = take_iter_stats(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(back, s);
        assert_eq!(back.moved.to_bits(), (-0.0f32).to_bits());
    }
}
