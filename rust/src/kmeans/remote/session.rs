//! The session plane (protocol v3): dataset-resident remote workers.
//!
//! The one-shot plane ([`client`](super::client)) re-ships the whole
//! O(n/P) shard slice inside every `Job` frame; workers forget
//! everything between solves.  This module implements the paper's
//! load-once architecture instead: each shard is uploaded **once**
//! (`LoadShard`, crc-checked and acked), after which every global Lloyd
//! iteration exchanges only a `Centroids` broadcast (O(k·d) down) and a
//! `Partials` reduce (O(k·d) up) — per-center sums, member counts, and
//! the iteration's work counters.
//!
//! The coordinator side is [`run_session`]: it owns the *global*
//! iteration state of every shard (initial centroids, fold, stop rule),
//! while workers are pure functions from `(resident shard, centroids)`
//! to partial sums via the canonical
//! [`filter_iteration_batched_scratch`](crate::kmeans::filtering::filter_iteration_batched_scratch)
//! pass.  Folding happens through [`fold_partials`] with exactly the
//! engine's own update/stop ordering, so a session run is **bitwise
//! identical** to the one-shot [`solve_level1_shard`] oracle
//! (`shard::tests::session_step_composition_matches_oneshot_solve` pins
//! the composition; `rust/tests/remote_session.rs` pins the loopback).
//!
//! **Failure semantics.** Because every step is a pure function of the
//! driver-owned centroids, recovery is stateless re-execution — no
//! exactly-once bookkeeping beyond "fold each (shard, iter) once", which
//! the driver enforces structurally.  A dead connection climbs the PR-6
//! ladder: reconnect the same endpoint and re-load
//! ([`SessionMetrics::shard_reloads`]), re-load on another live session
//! connection, and
//! finally a local [`ShardStepper`] fallback
//! ([`SessionMetrics::remote_fallbacks`]).  Whatever rung answers, the
//! folded partials carry the same IEEE bits.
//!
//! **No per-iteration Ping.** Unlike the one-shot path (which fronts
//! every job upload with a Ping/Pong health check), a session implies
//! liveness through its per-iteration exchange; [`RemoteWorker::ping`]
//! exists for *idle* connections only.

use super::client::{RemoteShardPool, RemoteWorker};
use super::protocol::{dataset_checksum, CentroidsFrame, LoadShardFrame, Message, PartialsFrame};
use super::WireCounters;
use crate::data::Dataset;
use crate::kmeans::panel::CpuPanels;
use crate::kmeans::shard::{fold_partials, level1_spec, ShardPartial, ShardStepper};
use crate::kmeans::solver::KmeansSpec;
use crate::kmeans::{IterStats, RunStats};
use std::sync::Arc;
use std::time::Instant;

/// What one session-mode level-1 phase did — folded into `CoordMetrics`
/// by the coordinator.
#[derive(Clone, Debug, Default)]
pub struct SessionMetrics {
    /// Connections that hosted at least one resident shard.
    pub sessions: u64,
    /// `Centroids` frames sent (one per remote shard per iteration).
    pub centroid_bcasts: u64,
    /// `Partials` frames received and folded.
    pub partials_rx: u64,
    /// Steady-state wire bytes: `Centroids` out / `Partials` in only —
    /// the O(k·d) traffic the plane exists to minimize.  `LoadShard`
    /// uploads count into `remote_bytes_tx`, not here.
    pub session_bytes_tx: u64,
    pub session_bytes_rx: u64,
    /// Shard uploads beyond the first (recovery re-loads, on the same
    /// endpoint after a reconnect or on another live connection).
    pub shard_reloads: u64,
    /// Shards (or endpoints) that exhausted every remote rung.
    pub remote_fallbacks: u64,
    /// Shards whose final home was a remote connection.
    pub remote_shards: u64,
    /// Connections established at session start.
    pub remote_workers: usize,
    /// Endpoints that never produced a usable connection.
    pub remote_failed_endpoints: Vec<String>,
    /// Whole-connection traffic (handshakes, loads, releases included).
    pub remote_bytes_tx: u64,
    pub remote_bytes_rx: u64,
}

/// Where a shard's next step executes.
enum Home<'a> {
    /// Resident on `conns[i]`.
    Remote(usize),
    /// Stepped in-process (no remotes, or the fallback rung).
    Local(Box<ShardStepper<'a, CpuPanels>>),
}

/// Driver-owned global state of one shard's level-1 solve.
struct ShardState<'a> {
    part: &'a Dataset,
    wspec: KmeansSpec,
    centroids: Dataset,
    stats: RunStats,
    /// Member counts of the last folded iteration — identical to the
    /// one-shot result's `sizes()` (the final assignments are the last
    /// filter pass's).
    last_counts: Vec<u32>,
    done: bool,
    released: bool,
    home: Home<'a>,
}

impl<'a> ShardState<'a> {
    /// Apply one iteration's partials with the engine's exact ordering:
    /// fold, record movement, push stats, then test tolerance against
    /// the iteration cap (`run_impl` semantics — convergence wins ties).
    fn fold(
        &mut self,
        si: usize,
        sums: &[f32],
        counts: Vec<u32>,
        mut st: IterStats,
        on_iter: &mut dyn FnMut(usize, &IterStats),
    ) {
        let (next, moved) = fold_partials(&self.centroids, sums, &counts);
        st.moved = moved;
        self.centroids = next;
        self.last_counts = counts;
        self.stats.iters.push(st);
        if let Some(last) = self.stats.iters.last() {
            on_iter(si, last);
        }
        if moved <= self.wspec.tol {
            self.stats.converged = true;
            self.done = true;
        } else if self.stats.iters.len() >= self.wspec.max_iters {
            self.done = true;
        }
    }
}

/// One session connection of the run.
struct SessionConn {
    worker: RemoteWorker,
    alive: bool,
    /// Acked at least one `LoadShard` (drives the `sessions` counter).
    hosted: bool,
}

/// How a `LoadShard` attempt ended.
enum LoadOutcome {
    Loaded,
    /// The worker answered with a protocol refusal (checksum, resident
    /// budget, bad shard) — the connection itself is still healthy.
    Refused,
    /// Transport-level failure or desync: stop trusting the connection.
    Dead,
}

/// Upload one shard and wait for its ack.
fn load_on(conn: &mut SessionConn, si: usize, st: &ShardState<'_>) -> LoadOutcome {
    let checksum = dataset_checksum(st.part);
    let frame = LoadShardFrame {
        shard: si as u32,
        metric: st.wspec.metric,
        checksum,
        data: st.part.clone(),
    };
    if let Err(e) = conn.worker.send(&Message::LoadShard(Box::new(frame))) {
        log::warn!("shard {si}: LoadShard to {} failed: {e}", conn.worker.addr());
        return LoadOutcome::Dead;
    }
    let deadline = Instant::now() + conn.worker.policy().job_deadline;
    match conn.worker.recv_by(deadline) {
        Ok(Message::LoadAck { shard, checksum: ack }) if shard == si as u32 && ack == checksum => {
            LoadOutcome::Loaded
        }
        Ok(Message::Error { code, message }) => {
            log::warn!(
                "shard {si}: {} refused the load (code {code}): {message}",
                conn.worker.addr()
            );
            LoadOutcome::Refused
        }
        Ok(other) => {
            log::warn!("shard {si}: {} sent {other:?} instead of a LoadAck", conn.worker.addr());
            LoadOutcome::Dead
        }
        Err(e) => {
            log::warn!("shard {si}: LoadAck from {} failed: {e}", conn.worker.addr());
            LoadOutcome::Dead
        }
    }
}

/// Send one `Centroids` broadcast (the O(k·d) downlink of a step).
fn send_centroids(
    conn: &mut SessionConn,
    si: usize,
    st: &ShardState<'_>,
    m: &mut SessionMetrics,
) -> bool {
    let frame = CentroidsFrame {
        shard: si as u32,
        iter: st.stats.iters.len() as u64,
        centroids: st.centroids.clone(),
    };
    let (tx0, _) = conn.worker.traffic();
    let sent = conn.worker.send(&Message::Centroids(Box::new(frame)));
    let (tx1, _) = conn.worker.traffic();
    m.session_bytes_tx += tx1 - tx0;
    match sent {
        Ok(()) => {
            m.centroid_bcasts += 1;
            true
        }
        Err(e) => {
            log::warn!("shard {si}: Centroids to {} failed: {e}", conn.worker.addr());
            false
        }
    }
}

/// Receive, validate and fold one `Partials` reply.
fn recv_fold(
    conn: &mut SessionConn,
    si: usize,
    st: &mut ShardState<'_>,
    m: &mut SessionMetrics,
    on_iter: &mut dyn FnMut(usize, &IterStats),
) -> bool {
    let expect_iter = st.stats.iters.len() as u64;
    let deadline = Instant::now() + conn.worker.policy().job_deadline;
    let (_, rx0) = conn.worker.traffic();
    let got = conn.worker.recv_by(deadline);
    let (_, rx1) = conn.worker.traffic();
    m.session_bytes_rx += rx1 - rx0;
    let shaped = |p: &PartialsFrame| {
        p.shard == si as u32
            && p.iter == expect_iter
            && p.sums.len() == st.wspec.k
            && p.sums.dims() == st.part.dims()
            && p.counts.len() == st.wspec.k
    };
    match got {
        Ok(Message::Partials(p)) if shaped(&p) => {
            m.partials_rx += 1;
            let PartialsFrame { sums, counts, stats, .. } = *p;
            st.fold(si, sums.flat(), counts, stats, on_iter);
            true
        }
        Ok(Message::Error { code, message }) => {
            log::warn!(
                "shard {si}: {} failed the step (code {code}): {message}",
                conn.worker.addr()
            );
            false
        }
        Ok(other) => {
            log::warn!(
                "shard {si}: {} answered the step with {other:?}",
                conn.worker.addr()
            );
            false
        }
        Err(e) => {
            log::warn!("shard {si}: Partials from {} failed: {e}", conn.worker.addr());
            false
        }
    }
}

/// One full remote step (broadcast + reduce) — the recovery path's
/// re-execution of an iteration that a dead connection swallowed.
fn step_via_conn(
    conn: &mut SessionConn,
    si: usize,
    st: &mut ShardState<'_>,
    m: &mut SessionMetrics,
    on_iter: &mut dyn FnMut(usize, &IterStats),
) -> bool {
    send_centroids(conn, si, st, m) && recv_fold(conn, si, st, m, on_iter)
}

/// Free one finished shard's resident memory.
fn release_on(conn: &mut SessionConn, si: usize) -> bool {
    if conn.worker.send(&Message::Release { shard: si as u32 }).is_err() {
        return false;
    }
    let deadline = Instant::now() + conn.worker.policy().io_timeout;
    matches!(
        conn.worker.recv_by(deadline),
        Ok(Message::Released { shard }) if shard == si as u32
    )
}

/// The degradation ladder for a shard whose step this round was lost:
/// revive + re-load the home connection, re-load on another live
/// connection, then fall back to a local stepper.  The step is re-run on
/// whatever rung answers; since it is a pure function of the current
/// centroids, the folded result is bitwise what the dead worker would
/// have returned.
fn recover_and_step<'a>(
    si: usize,
    states: &mut [ShardState<'a>],
    conns: &mut [SessionConn],
    m: &mut SessionMetrics,
    on_iter: &mut dyn FnMut(usize, &IterStats),
    revive_failed: &mut Vec<usize>,
) {
    let home_ci = match states[si].home {
        Home::Remote(ci) => Some(ci),
        Home::Local(_) => None,
    };
    if let Some(ci) = home_ci {
        // Rung 1: the home endpoint, reconnected if its stream died.
        if !conns[ci].alive && !revive_failed.contains(&ci) {
            match conns[ci].worker.reconnect() {
                Ok(()) => conns[ci].alive = true,
                Err(e) => {
                    log::warn!("session reconnect to {} failed: {e}", conns[ci].worker.addr());
                    revive_failed.push(ci);
                }
            }
        }
        if conns[ci].alive {
            if matches!(load_on(&mut conns[ci], si, &states[si]), LoadOutcome::Loaded) {
                m.shard_reloads += 1;
                if step_via_conn(&mut conns[ci], si, &mut states[si], m, on_iter) {
                    return;
                }
            }
            conns[ci].alive = false;
        }
        // Rung 2: any other live session connection.
        for cj in 0..conns.len() {
            if cj == ci || !conns[cj].alive {
                continue;
            }
            match load_on(&mut conns[cj], si, &states[si]) {
                LoadOutcome::Loaded => {
                    m.shard_reloads += 1;
                    if !conns[cj].hosted {
                        conns[cj].hosted = true;
                        m.sessions += 1;
                    }
                    states[si].home = Home::Remote(cj);
                    log::info!("shard {si} re-loaded onto {}", conns[cj].worker.addr());
                    if step_via_conn(&mut conns[cj], si, &mut states[si], m, on_iter) {
                        return;
                    }
                    conns[cj].alive = false;
                }
                LoadOutcome::Refused => {}
                LoadOutcome::Dead => conns[cj].alive = false,
            }
        }
    }
    // Rung 3: local fallback for the rest of the run.
    m.remote_fallbacks += 1;
    log::warn!("shard {si}: session remotes exhausted, stepping locally from here on");
    let part = states[si].part;
    let metric = states[si].wspec.metric;
    let mut stepper =
        Box::new(ShardStepper::new(part, metric, CpuPanels).with_bounds(states[si].wspec.bounds));
    let (sums, counts, st) = stepper.step(&states[si].centroids);
    states[si].home = Home::Local(stepper);
    states[si].fold(si, &sums, counts, st, on_iter);
}

/// Run every shard's level-1 solve in session mode and return the same
/// [`ShardPartial`]s (same bits, same order) the one-shot executor fleet
/// would have produced.
///
/// `on_iter(shard, stats)` streams each folded iteration to the
/// coordinator's live counters.  An empty `pool` degrades to pure local
/// stepping (no fallback counted — there was nothing to fall back from).
pub fn run_session(
    parts: &[Dataset],
    spec: &KmeansSpec,
    pool: &RemoteShardPool,
    wire: &Arc<WireCounters>,
    on_iter: &mut dyn FnMut(usize, &IterStats),
) -> (Vec<ShardPartial>, SessionMetrics) {
    let mut m = SessionMetrics::default();
    let (workers, failed) = if pool.is_empty() {
        (Vec::new(), Vec::new())
    } else {
        pool.connect_all_with(wire)
    };
    m.remote_workers = workers.len();
    m.remote_fallbacks += failed.len() as u64;
    m.remote_failed_endpoints = failed;
    let mut conns: Vec<SessionConn> = workers
        .into_iter()
        .map(|worker| SessionConn {
            worker,
            alive: true,
            hosted: false,
        })
        .collect();

    let mut states: Vec<ShardState<'_>> = parts
        .iter()
        .enumerate()
        .map(|(si, part)| {
            let wspec = level1_spec(spec, si);
            let centroids = wspec.starting_centroids(part);
            ShardState {
                part,
                wspec,
                centroids,
                stats: RunStats::default(),
                last_counts: Vec::new(),
                done: false,
                released: false,
                home: Home::Remote(usize::MAX),
            }
        })
        .collect();

    // ---- Load phase: place each shard, round-robin over connections.
    for si in 0..states.len() {
        let mut placed = false;
        if !conns.is_empty() {
            let start = si % conns.len();
            for off in 0..conns.len() {
                let ci = (start + off) % conns.len();
                if !conns[ci].alive {
                    continue;
                }
                match load_on(&mut conns[ci], si, &states[si]) {
                    LoadOutcome::Loaded => {
                        if !conns[ci].hosted {
                            conns[ci].hosted = true;
                            m.sessions += 1;
                        }
                        states[si].home = Home::Remote(ci);
                        placed = true;
                        break;
                    }
                    LoadOutcome::Refused => continue,
                    LoadOutcome::Dead => {
                        conns[ci].alive = false;
                        continue;
                    }
                }
            }
        }
        if !placed {
            if !conns.is_empty() {
                m.remote_fallbacks += 1;
            }
            let part = states[si].part;
            let metric = states[si].wspec.metric;
            let bounds = states[si].wspec.bounds;
            states[si].home = Home::Local(Box::new(
                ShardStepper::new(part, metric, CpuPanels).with_bounds(bounds),
            ));
        }
    }

    // ---- Iteration rounds: lockstep over all unconverged shards.
    loop {
        let active: Vec<usize> = states
            .iter()
            .enumerate()
            .filter(|(_, s)| !s.done)
            .map(|(i, _)| i)
            .collect();
        if active.is_empty() {
            break;
        }
        let mut folded = vec![false; states.len()];
        let mut sent = vec![false; states.len()];

        // A) Pipeline the centroid broadcasts: every live connection gets
        //    all of its shards' frames before any reply is awaited, so
        //    the workers compute concurrently.
        for &si in &active {
            if let Home::Remote(ci) = states[si].home {
                if conns[ci].alive {
                    if send_centroids(&mut conns[ci], si, &states[si], &mut m) {
                        sent[si] = true;
                    } else {
                        conns[ci].alive = false;
                    }
                }
            }
        }

        // B) Step local shards while the remotes work.
        for &si in &active {
            let st = &mut states[si];
            let step = match &mut st.home {
                Home::Local(stepper) => Some(stepper.step(&st.centroids)),
                Home::Remote(_) => None,
            };
            if let Some((sums, counts, is)) = step {
                st.fold(si, &sums, counts, is, on_iter);
                folded[si] = true;
            }
        }

        // C) Collect partials in send order (one server thread per
        //    connection answers in request order).
        for &si in &active {
            if folded[si] || !sent[si] {
                continue;
            }
            if let Home::Remote(ci) = states[si].home {
                if !conns[ci].alive {
                    continue;
                }
                if recv_fold(&mut conns[ci], si, &mut states[si], &mut m, on_iter) {
                    folded[si] = true;
                } else {
                    conns[ci].alive = false;
                }
            }
        }

        // D) Anything still pending lost its step to a dead connection:
        //    climb the ladder and re-run the step.  Rung 3 is local and
        //    infallible, so every shard folds exactly once per round.
        let mut revive_failed: Vec<usize> = Vec::new();
        for &si in &active {
            if folded[si] {
                continue;
            }
            recover_and_step(si, &mut states, &mut conns, &mut m, on_iter, &mut revive_failed);
        }

        // E) Release finished shards promptly — the worker's resident
        //    budget frees as the fleet converges, not at session end.
        for &si in &active {
            if !states[si].done || states[si].released {
                continue;
            }
            if let Home::Remote(ci) = states[si].home {
                if conns[ci].alive && !release_on(&mut conns[ci], si) {
                    conns[ci].alive = false;
                }
            }
            states[si].released = true;
        }
    }

    // ---- Teardown: drop whatever residency is left, tally traffic.
    for c in conns.iter_mut() {
        if c.alive {
            let _ = c.worker.send(&Message::EndSession);
        }
        let (tx, rx) = c.worker.traffic();
        m.remote_bytes_tx += tx;
        m.remote_bytes_rx += rx;
    }
    for st in &states {
        if matches!(st.home, Home::Remote(_)) {
            m.remote_shards += 1;
        }
    }
    let partials = states
        .into_iter()
        .map(|st| {
            let mut stats = st.stats;
            // Bounds counters are local-process telemetry: fold them in
            // for shards that ran (or fell back) on a local stepper —
            // remote partials carry none on the wire.
            if let Home::Local(stepper) = &st.home {
                let bs = stepper.bounds_stats();
                stats.bound_pruned_points += bs.pruned_points;
                stats.bound_pruned_candidates += bs.pruned_candidates;
                stats.bounds_matrix_cost += bs.matrix_cost;
            }
            ShardPartial {
                centroids: st.centroids,
                counts: st.last_counts.iter().map(|&c| c as usize).collect(),
                stats,
            }
        })
        .collect();
    (partials, m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::generate_params;
    use crate::kmeans::shard::{solve_level1_shard, ShardPartial, ShardPlan};

    /// With no remotes at all, the driver is a pure-local lockstep loop —
    /// and must still reproduce the one-shot oracle bit for bit (this
    /// pins the driver's fold/stop ordering independently of any wire).
    #[test]
    fn local_session_matches_oneshot_partials() {
        let s = generate_params(2000, 3, 4, 0.2, 1.0, 17);
        let spec = KmeansSpec::two_level(4).seed(7).shards(4);
        let plan = ShardPlan::build(&s.data, spec.shards, spec.partition, None);
        let wire = Arc::new(WireCounters::default());
        let mut seen: Vec<(usize, u64)> = Vec::new();
        let (partials, m) = run_session(
            &plan.parts,
            &spec,
            &RemoteShardPool::default(),
            &wire,
            &mut |si, st| seen.push((si, st.dist_evals)),
        );
        assert_eq!(partials.len(), 4);
        assert_eq!(m.sessions, 0);
        assert_eq!(m.remote_fallbacks, 0, "no pool, no fallback");
        assert_eq!(m.session_bytes_tx + m.session_bytes_rx, 0);
        let mut streamed = 0usize;
        for (si, part) in plan.parts.iter().enumerate() {
            let wspec = level1_spec(&spec, si);
            let oracle = solve_level1_shard(
                part,
                &wspec,
                CpuPanels,
                None::<crate::kmeans::solver::IterLog>,
            );
            let oracle = ShardPartial::from_result(oracle);
            assert_eq!(partials[si].centroids, oracle.centroids, "shard {si}");
            assert_eq!(partials[si].counts, oracle.counts, "shard {si}");
            assert_eq!(
                partials[si].stats.iterations(),
                oracle.stats.iterations(),
                "shard {si}"
            );
            assert_eq!(partials[si].stats.converged, oracle.stats.converged);
            streamed += oracle.stats.iterations();
        }
        assert_eq!(seen.len(), streamed, "every folded iteration streamed once");
    }
}
