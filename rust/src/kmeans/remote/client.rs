//! Client half of the remote shard plane: a [`RemoteWorker`] is one
//! handshaken connection to a `shard-worker`, and a [`RemoteShardPool`]
//! is the set of endpoints the coordinator may spread level-1 solves
//! over.
//!
//! A `RemoteWorker` implements the coordinator's shard-solve seam
//! ([`ShardExecutor`]), so the work-pulling scheduler treats it exactly
//! like a local thread.  Unlike a local thread, the wire can fail — so
//! every operation is governed by a [`RetryPolicy`]: connects retry
//! with seeded exponential backoff, each shard job carries a wall-clock
//! deadline shared across *all* of its attempts (a hung worker costs at
//! most `job_deadline`), and any mid-solve failure triggers a
//! reconnect-and-retry before the error is surfaced to the coordinator's
//! degradation ladder.  Recovery work is tallied in [`WireCounters`],
//! which the coordinator folds into `CoordMetrics`.

use super::protocol::{self, DoneFrame, Message, WireSpec, PROTOCOL_VERSION};
use super::RetryPolicy;
use crate::data::Dataset;
use crate::kmeans::shard::{level1_spec, ShardExecutor, ShardPartial};
use crate::kmeans::solver::KmeansSpec;
use crate::kmeans::IterStats;
use crate::util::frame::{write_frame, FrameError};
use crate::util::rng::Xoshiro256pp;
use std::io;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Shared tally of the recovery work a run's remote connections did.
/// One instance is shared by every worker of a coordinated run (it is
/// updated from the puller threads, hence atomics; Relaxed is enough —
/// these are counters, not synchronization).
#[derive(Debug, Default)]
pub struct WireCounters {
    /// Re-attempts of a failed operation (connects and jobs alike).
    pub retries: AtomicU64,
    /// Operations that hit a read/deadline timeout.
    pub timeouts: AtomicU64,
    /// Fresh dial+handshake cycles performed to replace a dead stream.
    pub reconnects: AtomicU64,
}

impl WireCounters {
    /// `(retries, timeouts, reconnects)` snapshot.
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.retries.load(Ordering::Relaxed),
            self.timeouts.load(Ordering::Relaxed),
            self.reconnects.load(Ordering::Relaxed),
        )
    }
}

/// `true` when a frame error is a socket-timeout, i.e. the peer is silent
/// rather than wrong.  Read timeouts surface as `WouldBlock` on Unix and
/// `TimedOut` on Windows.
fn is_timeout(e: &FrameError) -> bool {
    matches!(
        e,
        FrameError::Io(io) if matches!(io.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
    )
}

/// One live, version-checked connection to a `shard-worker`.
pub struct RemoteWorker {
    addr: String,
    stream: TcpStream,
    bytes_tx: u64,
    bytes_rx: u64,
    policy: RetryPolicy,
    counters: Arc<WireCounters>,
    /// Per-worker jitter stream: seeded from `(policy seed, addr)`, so
    /// the backoff schedule of a run is reproducible.
    jitter: Xoshiro256pp,
}

impl RemoteWorker {
    /// Connect and handshake under the default [`RetryPolicy`].  Any
    /// terminal failure — unresolvable address, refused connection,
    /// version skew, a peer that does not speak the protocol — is an
    /// error the caller treats as "this endpoint is unavailable".
    pub fn connect(addr: &str) -> anyhow::Result<Self> {
        Self::connect_with(addr, &RetryPolicy::default(), Arc::new(WireCounters::default()))
    }

    /// Connect and handshake, retrying per `policy` with seeded backoff.
    /// Every attempt dials, handshakes, *and* health-checks (Ping/Pong)
    /// — a worker that accepts TCP but won't answer protocol traffic is
    /// caught here, not mid-job.
    pub fn connect_with(
        addr: &str,
        policy: &RetryPolicy,
        counters: Arc<WireCounters>,
    ) -> anyhow::Result<Self> {
        let mut jitter = Xoshiro256pp::seed_from_u64(policy.jitter_seed(addr));
        let attempts = policy.max_attempts.max(1);
        let mut last: Option<anyhow::Error> = None;
        for attempt in 1..=attempts {
            if attempt > 1 {
                counters.retries.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(policy.backoff(attempt - 1, jitter.next_f64()));
            }
            match Self::dial_once(addr, policy, &counters) {
                Ok((stream, tx, rx)) => {
                    return Ok(Self {
                        addr: addr.to_string(),
                        stream,
                        bytes_tx: tx,
                        bytes_rx: rx,
                        policy: policy.clone(),
                        counters,
                        jitter,
                    });
                }
                Err(e) => {
                    log::debug!("connect attempt {attempt}/{attempts} to {addr} failed: {e}");
                    last = Some(e);
                }
            }
        }
        Err(last.unwrap_or_else(|| anyhow::anyhow!("`{addr}`: no connect attempts made")))
    }

    /// One dial + handshake + health check.  Returns the ready stream
    /// and the handshake's wire bytes `(tx, rx)`.
    fn dial_once(
        addr: &str,
        policy: &RetryPolicy,
        counters: &WireCounters,
    ) -> anyhow::Result<(TcpStream, u64, u64)> {
        let sock = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| anyhow::anyhow!("`{addr}` resolves to no address"))?;
        let mut stream = TcpStream::connect_timeout(&sock, policy.connect_timeout)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(policy.io_timeout))?;
        stream.set_write_timeout(Some(policy.io_timeout))?;
        let mut tx = 0u64;
        let mut rx = 0u64;
        tx += Message::Hello {
            version: PROTOCOL_VERSION,
        }
        .write_to(&mut stream)? as u64;
        let (ack, n) = Self::read_classified(&mut stream, counters)?;
        rx += n as u64;
        match ack {
            Message::HelloAck { version } if version == PROTOCOL_VERSION => {}
            Message::HelloAck { version } => {
                anyhow::bail!("worker {addr} acked protocol v{version}, want v{PROTOCOL_VERSION}")
            }
            Message::Error { code, message } => {
                anyhow::bail!("worker {addr} refused the handshake (code {code}): {message}")
            }
            other => anyhow::bail!("worker {addr} sent {other:?} instead of a handshake ack"),
        }
        tx += Message::Ping.write_to(&mut stream)? as u64;
        let (pong, n) = Self::read_classified(&mut stream, counters)?;
        rx += n as u64;
        match pong {
            Message::Pong => Ok((stream, tx, rx)),
            other => anyhow::bail!("worker {addr} answered the health check with {other:?}"),
        }
    }

    /// Read one message, folding socket timeouts into the timeout tally.
    fn read_classified(
        stream: &mut TcpStream,
        counters: &WireCounters,
    ) -> anyhow::Result<(Message, usize)> {
        match Message::read_from(stream) {
            Ok(ok) => Ok(ok),
            Err(e) => {
                if is_timeout(&e) {
                    counters.timeouts.fetch_add(1, Ordering::Relaxed);
                }
                Err(e.into())
            }
        }
    }

    /// The endpoint this connection was dialed to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// `(bytes sent, bytes received)` over this connection's lifetime
    /// (reconnects included).
    pub fn traffic(&self) -> (u64, u64) {
        (self.bytes_tx, self.bytes_rx)
    }

    /// The shared recovery tally this worker reports into.
    pub fn counters(&self) -> &Arc<WireCounters> {
        &self.counters
    }

    pub(crate) fn send(&mut self, msg: &Message) -> anyhow::Result<()> {
        self.bytes_tx += msg.write_to(&mut self.stream)? as u64;
        Ok(())
    }

    pub(crate) fn policy(&self) -> &RetryPolicy {
        &self.policy
    }

    /// Explicit health check — Ping, wait for Pong.  The session plane
    /// calls this only on an *idle* connection (e.g. while other shards
    /// still converge): during the per-iteration Centroids/Partials
    /// exchange liveness is implied and no Ping is sent.
    pub fn ping(&mut self) -> anyhow::Result<()> {
        self.send(&Message::Ping)?;
        match self.recv_by(Instant::now() + self.policy.io_timeout)? {
            Message::Pong => Ok(()),
            other => anyhow::bail!("worker {} answered Ping with {other:?}", self.addr),
        }
    }

    /// Read one message with the job deadline enforced: the socket read
    /// timeout is clamped to the remaining budget, so a silent peer
    /// costs at most `min(io_timeout, remaining)` per read and never
    /// more than the deadline overall.
    pub(crate) fn recv_by(&mut self, deadline: Instant) -> anyhow::Result<Message> {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            self.counters.timeouts.fetch_add(1, Ordering::Relaxed);
            anyhow::bail!("worker {}: job deadline exceeded", self.addr);
        }
        let per_read = self
            .policy
            .io_timeout
            .min(remaining)
            .max(Duration::from_millis(1));
        self.stream.set_read_timeout(Some(per_read))?;
        match Message::read_from(&mut self.stream) {
            Ok((msg, n)) => {
                self.bytes_rx += n as u64;
                Ok(msg)
            }
            Err(e) => {
                if is_timeout(&e) {
                    self.counters.timeouts.fetch_add(1, Ordering::Relaxed);
                    anyhow::bail!("worker {}: read timed out ({e})", self.addr);
                }
                Err(e.into())
            }
        }
    }

    /// Tear down the dead stream and dial a fresh one.
    pub(crate) fn reconnect(&mut self) -> anyhow::Result<()> {
        self.counters.reconnects.fetch_add(1, Ordering::Relaxed);
        let (stream, tx, rx) = Self::dial_once(&self.addr, &self.policy, &self.counters)?;
        self.stream = stream;
        self.bytes_tx += tx;
        self.bytes_rx += rx;
        Ok(())
    }

    /// Ship one shard solve and stream its iterations, retrying on
    /// transient failure.  `wspec` must already be the worker-side spec
    /// ([`level1_spec`]); `on_iter` receives each iteration's counters
    /// as the frames arrive (replayed iterations of a retried attempt
    /// are forwarded once, not twice).
    ///
    /// The wall-clock deadline is taken **once**, up front, and shared
    /// by every retry attempt: however the attempts go, a hung worker
    /// costs at most `policy.job_deadline` before the coordinator's
    /// ladder takes over.
    pub fn solve(
        &mut self,
        shard: usize,
        data: &Dataset,
        wspec: &KmeansSpec,
        on_iter: &mut dyn FnMut(&IterStats),
    ) -> anyhow::Result<ShardPartial> {
        let deadline = Instant::now() + self.policy.job_deadline;
        let attempts = self.policy.max_attempts.max(1);
        let mut streamed = 0u64;
        let mut last: Option<anyhow::Error> = None;
        for attempt in 1..=attempts {
            if attempt > 1 {
                let remaining = deadline.saturating_duration_since(Instant::now());
                if remaining.is_zero() {
                    break;
                }
                self.counters.retries.fetch_add(1, Ordering::Relaxed);
                let pause = self.policy.backoff(attempt - 1, self.jitter.next_f64());
                std::thread::sleep(pause.min(remaining));
                // Any failure leaves the old stream desynced (a stray
                // Iter/Done frame could arrive later); always start the
                // retry on a fresh connection.
                if let Err(e) = self.reconnect() {
                    log::debug!(
                        "shard {shard}: reconnect to {} failed on attempt {attempt}/{attempts}: {e}",
                        self.addr
                    );
                    last = Some(e);
                    continue;
                }
            }
            match self.solve_once(shard, data, wspec, on_iter, deadline, &mut streamed) {
                Ok(partial) => return Ok(partial),
                Err(e) => {
                    log::warn!(
                        "shard {shard} attempt {attempt}/{attempts} on {} failed: {e}",
                        self.addr
                    );
                    last = Some(e);
                }
            }
        }
        Err(last
            .unwrap_or_else(|| anyhow::anyhow!("shard {shard}: retry budget exhausted on {}", self.addr)))
    }

    fn solve_once(
        &mut self,
        shard: usize,
        data: &Dataset,
        wspec: &KmeansSpec,
        on_iter: &mut dyn FnMut(&IterStats),
        deadline: Instant,
        streamed: &mut u64,
    ) -> anyhow::Result<ShardPartial> {
        // Health check before the upload: a hung worker is detected for
        // the price of a Pong, not of shipping the whole shard slice.
        // One-shot mode only — the session plane ships the shard once
        // and gets per-iteration liveness for free, so it never Pings a
        // busy connection (see `remote::session`).
        self.send(&Message::Ping)?;
        match self.recv_by(deadline)? {
            Message::Pong => {}
            other => anyhow::bail!(
                "worker {} answered the pre-job health check with {other:?}",
                self.addr
            ),
        }
        // Borrowed-parts encode: the shard slice serializes straight from
        // the plan's dataset, no intermediate clone.
        let (kind, payload) = protocol::encode_job(shard as u32, &WireSpec::from_spec(wspec), data);
        self.bytes_tx += write_frame(&mut self.stream, kind, &payload)? as u64;
        let mut seen = 0u64;
        loop {
            match self.recv_by(deadline)? {
                Message::Iter(frame) => {
                    seen += 1;
                    // Forward only iterations the observer has not seen
                    // from an earlier attempt of this same job.
                    if seen > *streamed {
                        on_iter(&frame.stats);
                        *streamed = seen;
                    }
                }
                Message::Done(done) => {
                    let DoneFrame {
                        centroids,
                        counts,
                        stats,
                    } = *done;
                    anyhow::ensure!(
                        centroids.len() == wspec.k && counts.len() == wspec.k,
                        "worker {} returned {} centroids / {} counts for k={}",
                        self.addr,
                        centroids.len(),
                        counts.len(),
                        wspec.k
                    );
                    return Ok(ShardPartial {
                        centroids,
                        counts,
                        stats,
                    });
                }
                Message::Error { code, message } => {
                    anyhow::bail!(
                        "worker {} failed shard {shard} (code {code}): {message}",
                        self.addr
                    )
                }
                other => anyhow::bail!(
                    "worker {} sent {other:?} mid-solve of shard {shard}",
                    self.addr
                ),
            }
        }
    }

    /// Politely tell the worker process to exit its accept loop.
    pub fn request_shutdown(mut self) -> anyhow::Result<()> {
        self.send(&Message::Shutdown)
    }
}

impl ShardExecutor for RemoteWorker {
    fn describe(&self) -> String {
        format!("remote({})", self.addr)
    }

    fn solve_shard(
        &mut self,
        shard: usize,
        data: &Dataset,
        base_spec: &KmeansSpec,
        on_iter: &mut dyn FnMut(&IterStats),
    ) -> anyhow::Result<ShardPartial> {
        let wspec = level1_spec(base_spec, shard);
        self.solve(shard, data, &wspec, on_iter)
    }

    fn wire_bytes(&self) -> (u64, u64) {
        self.traffic()
    }
}

/// Connect, handshake and immediately request worker shutdown — the
/// teardown tool tests and scripts use to stop a `shard-worker`.
pub fn shutdown_worker(addr: &str) -> anyhow::Result<()> {
    RemoteWorker::connect(addr)?.request_shutdown()
}

/// The set of `shard-worker` endpoints a coordinated run may use
/// (`--remote host:port`, repeatable; the same endpoint may appear more
/// than once to open multiple connections to one worker), plus the
/// [`RetryPolicy`] every connection operates under.
#[derive(Clone, Debug, Default)]
pub struct RemoteShardPool {
    endpoints: Vec<String>,
    policy: RetryPolicy,
}

impl RemoteShardPool {
    pub fn new(endpoints: Vec<String>) -> Self {
        Self {
            endpoints,
            policy: RetryPolicy::default(),
        }
    }

    /// Replace the pool's retry policy (builder style).
    pub fn with_policy(mut self, policy: RetryPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn policy(&self) -> &RetryPolicy {
        &self.policy
    }

    pub fn endpoints(&self) -> &[String] {
        &self.endpoints
    }

    pub fn is_empty(&self) -> bool {
        self.endpoints.is_empty()
    }

    /// Dial every endpoint under a throwaway counter set.
    pub fn connect_all(&self) -> (Vec<RemoteWorker>, Vec<String>) {
        self.connect_all_with(&Arc::new(WireCounters::default()))
    }

    /// Dial every endpoint.  Unreachable/refusing/skewed endpoints are
    /// logged and returned by name — the coordinator surfaces the failed
    /// list in `CoordMetrics` so dead fleet members are diagnosable, and
    /// falls back to local threads for the capacity they would have
    /// provided.
    pub fn connect_all_with(
        &self,
        counters: &Arc<WireCounters>,
    ) -> (Vec<RemoteWorker>, Vec<String>) {
        let mut workers = Vec::with_capacity(self.endpoints.len());
        let mut failed = Vec::new();
        for ep in &self.endpoints {
            match RemoteWorker::connect_with(ep, &self.policy, Arc::clone(counters)) {
                Ok(w) => workers.push(w),
                Err(e) => {
                    log::warn!("remote shard worker {ep} unavailable, falling back local: {e}");
                    failed.push(ep.clone());
                }
            }
        }
        (workers, failed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_tracks_endpoints() {
        let pool = RemoteShardPool::new(vec!["a:1".into(), "b:2".into(), "a:1".into()]);
        assert_eq!(pool.endpoints().len(), 3);
        assert!(!pool.is_empty());
        assert!(RemoteShardPool::default().is_empty());
    }

    #[test]
    fn connect_to_dead_endpoint_fails_cleanly() {
        // Port 1 on loopback: refused (or at worst filtered — the
        // connect timeout still bounds it).  Either way: Err, no panic.
        // A single attempt keeps the test fast; the retry loop itself is
        // pinned by the chaos tests.
        let policy = RetryPolicy {
            max_attempts: 1,
            connect_timeout: Duration::from_millis(800),
            ..RetryPolicy::default()
        };
        let counters = Arc::new(WireCounters::default());
        assert!(RemoteWorker::connect_with("127.0.0.1:1", &policy, Arc::clone(&counters)).is_err());
        assert!(
            RemoteWorker::connect_with("not-a-host-name.invalid:99", &policy, counters).is_err()
        );
        let (workers, failed) = RemoteShardPool::new(vec!["127.0.0.1:1".into()])
            .with_policy(policy)
            .connect_all();
        assert!(workers.is_empty());
        assert_eq!(failed, vec!["127.0.0.1:1".to_string()]);
    }

    #[test]
    fn failed_connect_attempts_are_counted() {
        let policy = RetryPolicy {
            max_attempts: 3,
            backoff_base: Duration::from_millis(1),
            connect_timeout: Duration::from_millis(500),
            ..RetryPolicy::default()
        };
        let counters = Arc::new(WireCounters::default());
        assert!(RemoteWorker::connect_with("127.0.0.1:1", &policy, Arc::clone(&counters)).is_err());
        let (retries, _timeouts, _reconnects) = counters.snapshot();
        assert_eq!(retries, 2, "3 attempts = 2 retries");
    }
}
