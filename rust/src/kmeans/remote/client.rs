//! Client half of the remote shard plane: a [`RemoteWorker`] is one
//! handshaken connection to a `shard-worker`, and a [`RemoteShardPool`]
//! is the set of endpoints the coordinator may spread level-1 solves
//! over.
//!
//! A `RemoteWorker` implements the coordinator's shard-solve seam
//! ([`ShardExecutor`]), so the work-pulling scheduler treats it exactly
//! like a local thread; any wire failure surfaces as an `Err`, which the
//! coordinator answers by re-solving the shard locally and counting the
//! fallback in `CoordMetrics`.

use super::protocol::{self, DoneFrame, Message, WireSpec, PROTOCOL_VERSION};
use super::{CONNECT_TIMEOUT, IO_TIMEOUT};
use crate::data::Dataset;
use crate::kmeans::shard::{level1_spec, ShardExecutor, ShardPartial};
use crate::kmeans::solver::KmeansSpec;
use crate::kmeans::IterStats;
use crate::util::frame::write_frame;
use std::net::{TcpStream, ToSocketAddrs};

/// One live, version-checked connection to a `shard-worker`.
pub struct RemoteWorker {
    addr: String,
    stream: TcpStream,
    bytes_tx: u64,
    bytes_rx: u64,
}

impl RemoteWorker {
    /// Connect and handshake.  Any failure — unresolvable address,
    /// refused connection, version skew, a peer that does not speak the
    /// protocol — is an error the caller treats as "this endpoint is
    /// unavailable".
    pub fn connect(addr: &str) -> anyhow::Result<Self> {
        let sock = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| anyhow::anyhow!("`{addr}` resolves to no address"))?;
        let stream = TcpStream::connect_timeout(&sock, CONNECT_TIMEOUT)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(IO_TIMEOUT))?;
        stream.set_write_timeout(Some(IO_TIMEOUT))?;
        let mut worker = Self {
            addr: addr.to_string(),
            stream,
            bytes_tx: 0,
            bytes_rx: 0,
        };
        worker.send(&Message::Hello {
            version: PROTOCOL_VERSION,
        })?;
        match worker.recv()? {
            Message::HelloAck { version } if version == PROTOCOL_VERSION => Ok(worker),
            Message::HelloAck { version } => {
                anyhow::bail!("worker {addr} acked protocol v{version}, want v{PROTOCOL_VERSION}")
            }
            Message::Error { code, message } => {
                anyhow::bail!("worker {addr} refused the handshake (code {code}): {message}")
            }
            other => anyhow::bail!("worker {addr} sent {other:?} instead of a handshake ack"),
        }
    }

    /// The endpoint this connection was dialed to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// `(bytes sent, bytes received)` over this connection's lifetime.
    pub fn traffic(&self) -> (u64, u64) {
        (self.bytes_tx, self.bytes_rx)
    }

    fn send(&mut self, msg: &Message) -> anyhow::Result<()> {
        self.bytes_tx += msg.write_to(&mut self.stream)? as u64;
        Ok(())
    }

    fn recv(&mut self) -> anyhow::Result<Message> {
        let (msg, n) = Message::read_from(&mut self.stream)?;
        self.bytes_rx += n as u64;
        Ok(msg)
    }

    /// Ship one shard solve and stream its iterations.  `wspec` must
    /// already be the worker-side spec ([`level1_spec`]); `on_iter`
    /// receives each iteration's counters as the frames arrive.
    pub fn solve(
        &mut self,
        shard: usize,
        data: &Dataset,
        wspec: &KmeansSpec,
        on_iter: &mut dyn FnMut(&IterStats),
    ) -> anyhow::Result<ShardPartial> {
        // Borrowed-parts encode: the shard slice serializes straight from
        // the plan's dataset, no intermediate clone.
        let (kind, payload) =
            protocol::encode_job(shard as u32, &WireSpec::from_spec(wspec), data);
        self.bytes_tx += write_frame(&mut self.stream, kind, &payload)? as u64;
        loop {
            match self.recv()? {
                Message::Iter(frame) => on_iter(&frame.stats),
                Message::Done(done) => {
                    let DoneFrame {
                        centroids,
                        counts,
                        stats,
                    } = *done;
                    anyhow::ensure!(
                        centroids.len() == wspec.k && counts.len() == wspec.k,
                        "worker {} returned {} centroids / {} counts for k={}",
                        self.addr,
                        centroids.len(),
                        counts.len(),
                        wspec.k
                    );
                    return Ok(ShardPartial {
                        centroids,
                        counts,
                        stats,
                    });
                }
                Message::Error { code, message } => {
                    anyhow::bail!(
                        "worker {} failed shard {shard} (code {code}): {message}",
                        self.addr
                    )
                }
                other => anyhow::bail!(
                    "worker {} sent {other:?} mid-solve of shard {shard}",
                    self.addr
                ),
            }
        }
    }

    /// Politely tell the worker process to exit its accept loop.
    pub fn request_shutdown(mut self) -> anyhow::Result<()> {
        self.send(&Message::Shutdown)
    }
}

impl ShardExecutor for RemoteWorker {
    fn describe(&self) -> String {
        format!("remote({})", self.addr)
    }

    fn solve_shard(
        &mut self,
        shard: usize,
        data: &Dataset,
        base_spec: &KmeansSpec,
        on_iter: &mut dyn FnMut(&IterStats),
    ) -> anyhow::Result<ShardPartial> {
        let wspec = level1_spec(base_spec, shard);
        self.solve(shard, data, &wspec, on_iter)
    }

    fn wire_bytes(&self) -> (u64, u64) {
        self.traffic()
    }
}

/// Connect, handshake and immediately request worker shutdown — the
/// teardown tool tests and scripts use to stop a `shard-worker`.
pub fn shutdown_worker(addr: &str) -> anyhow::Result<()> {
    RemoteWorker::connect(addr)?.request_shutdown()
}

/// The set of `shard-worker` endpoints a coordinated run may use
/// (`--remote host:port`, repeatable; the same endpoint may appear more
/// than once to open multiple connections to one worker).
#[derive(Clone, Debug, Default)]
pub struct RemoteShardPool {
    endpoints: Vec<String>,
}

impl RemoteShardPool {
    pub fn new(endpoints: Vec<String>) -> Self {
        Self { endpoints }
    }

    pub fn endpoints(&self) -> &[String] {
        &self.endpoints
    }

    pub fn is_empty(&self) -> bool {
        self.endpoints.is_empty()
    }

    /// Dial every endpoint.  Unreachable/refusing/skewed endpoints are
    /// logged and *counted*, not fatal — the coordinator falls back to
    /// local threads for the capacity they would have provided.
    pub fn connect_all(&self) -> (Vec<RemoteWorker>, u64) {
        let mut workers = Vec::with_capacity(self.endpoints.len());
        let mut failures = 0u64;
        for ep in &self.endpoints {
            match RemoteWorker::connect(ep) {
                Ok(w) => workers.push(w),
                Err(e) => {
                    failures += 1;
                    log::warn!("remote shard worker {ep} unavailable, falling back local: {e}");
                }
            }
        }
        (workers, failures)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_tracks_endpoints() {
        let pool = RemoteShardPool::new(vec!["a:1".into(), "b:2".into(), "a:1".into()]);
        assert_eq!(pool.endpoints().len(), 3);
        assert!(!pool.is_empty());
        assert!(RemoteShardPool::default().is_empty());
    }

    #[test]
    fn connect_to_dead_endpoint_fails_cleanly() {
        // Port 1 on loopback: refused (or at worst filtered — the
        // connect timeout still bounds it).  Either way: Err, no panic.
        assert!(RemoteWorker::connect("127.0.0.1:1").is_err());
        assert!(RemoteWorker::connect("not-a-host-name.invalid:99").is_err());
        let (workers, failures) =
            RemoteShardPool::new(vec!["127.0.0.1:1".into()]).connect_all();
        assert!(workers.is_empty());
        assert_eq!(failures, 1);
    }
}
