//! The remote shard plane: level-1 shard solves over a wire protocol.
//!
//! The paper's architecture distributes level-1 filtering across
//! independent cores and merges their `(centroid, count)` partials
//! centrally; the shard plane ([`crate::kmeans::shard`]) already
//! abstracts *where* a shard solves via [`ShardExecutor`].  This module
//! takes that seam across a socket:
//!
//! - [`protocol`] — versioned, length-prefixed, checksummed binary
//!   frames ([`crate::util::frame`]): a `Hello`/`HelloAck` handshake,
//!   `Job` frames carrying a spec snapshot plus the shard slice in exact
//!   f32 bits, streamed per-iteration frames, and a terminal
//!   `Done { centroids, counts, stats }` — the paper's partial-sums
//!   exchange, literally.
//! - [`server`] — the `shard-worker` accept loop behind the CLI
//!   subcommand: each connection is served on its own thread, each job
//!   runs the *canonical* shard solve over the scalar-oracle panels.
//! - [`client`] — [`RemoteWorker`] (one connection, implements
//!   [`ShardExecutor`]) and [`RemoteShardPool`] (the `--remote`
//!   endpoints of a run).
//!
//! **Bitwise parity.** Worker and coordinator share one solve function
//! and the wire carries exact IEEE bits, so a loopback remote run of P
//! shards produces *byte-identical* centroids and assignments to the
//! in-process shard plane (`rust/tests/remote_shard.rs` pins this).
//!
//! **Failure semantics.** Every wire failure is contained: endpoints
//! that refuse/skew at connect time and connections that die mid-solve
//! both fall back to a local solve of the affected shard, counted in
//! `CoordMetrics::remote_fallbacks` — a dead worker costs throughput,
//! never the run.
//!
//! [`ShardExecutor`]: crate::kmeans::shard::ShardExecutor

pub mod client;
pub mod protocol;
pub mod server;

pub use client::{shutdown_worker, RemoteShardPool, RemoteWorker};
pub use protocol::PROTOCOL_VERSION;
pub use server::{WorkerHandle, WorkerServer};

use std::time::Duration;

/// Dial timeout for coordinator → worker connections.
pub const CONNECT_TIMEOUT: Duration = Duration::from_secs(5);

/// Per-read/write socket timeout on both sides.  Generous — a shard
/// solve streams a frame per iteration, so silence this long means a
/// dead peer, not a slow one.
pub const IO_TIMEOUT: Duration = Duration::from_secs(120);
