//! The remote shard plane: level-1 shard solves over a wire protocol.
//!
//! The paper's architecture distributes level-1 filtering across
//! independent cores and merges their `(centroid, count)` partials
//! centrally; the shard plane ([`crate::kmeans::shard`]) already
//! abstracts *where* a shard solves via [`ShardExecutor`].  This module
//! takes that seam across a socket:
//!
//! - [`protocol`] — versioned, length-prefixed, checksummed binary
//!   frames ([`crate::util::frame`]): a `Hello`/`HelloAck` handshake,
//!   `Job` frames carrying a spec snapshot plus the shard slice in exact
//!   f32 bits, streamed per-iteration frames, and a terminal
//!   `Done { centroids, counts, stats }` — the paper's partial-sums
//!   exchange, literally.
//! - [`server`] — the `shard-worker` accept loop behind the CLI
//!   subcommand: each connection is served on its own thread, each job
//!   runs the *canonical* shard solve over the scalar-oracle panels.
//! - [`client`] — [`RemoteWorker`] (one connection, implements
//!   [`ShardExecutor`]) and [`RemoteShardPool`] (the `--remote`
//!   endpoints of a run).
//! - [`session`] — the v3 **session plane**: workers hold shards
//!   *resident* (`LoadShard` once, checksummed), the coordinator runs
//!   the global iteration loop, and the steady-state wire carries only
//!   O(k·d) `Centroids`/`Partials` frames per iteration instead of the
//!   one-shot plane's O(n/P) re-uploads (`cluster --session`).
//!
//! **Bitwise parity.** Worker and coordinator share one solve function
//! and the wire carries exact IEEE bits, so a loopback remote run of P
//! shards produces *byte-identical* centroids and assignments to the
//! in-process shard plane (`rust/tests/remote_shard.rs` pins this).
//!
//! **Failure semantics.** Every wire failure is contained and every
//! recovery step is bounded by a [`RetryPolicy`]: a failed operation is
//! retried against the same worker with exponential backoff (seeded
//! jitter, so runs are reproducible), a still-dead worker's shard is
//! rescheduled on another live remote, and only then does the shard
//! fall back to a local solve.  A hung worker costs at most the per-job
//! deadline, never an unbounded stall.  Whatever path recovery takes,
//! the result is bitwise-identical — the shard seed is a pure function
//! of `(base seed, shard index)`, so retries cannot change the answer.
//! DESIGN.md §6 tabulates fault → detection → action → metric.
//!
//! [`ShardExecutor`]: crate::kmeans::shard::ShardExecutor

pub mod client;
pub mod protocol;
pub mod server;
pub mod session;

pub use client::{shutdown_worker, RemoteShardPool, RemoteWorker, WireCounters};
pub use protocol::PROTOCOL_VERSION;
pub use server::{WorkerHandle, WorkerServer};
pub use session::{run_session, SessionMetrics};

use crate::util::rng::SplitMix64;
use std::time::Duration;

/// Bounds every remote operation: dial/handshake attempts, socket IO,
/// and whole-job deadlines, plus how failures are retried.
///
/// Replaces the former `CONNECT_TIMEOUT`/`IO_TIMEOUT` constants (the
/// defaults mirror them).  Backoff between attempts is exponential with
/// **seeded** jitter — two runs with the same policy seed sleep the same
/// schedule, which is what keeps chaos tests deterministic.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Attempts per operation (connect or job), including the first.
    pub max_attempts: u32,
    /// Base backoff before the second attempt; doubles per attempt.
    pub backoff_base: Duration,
    /// Backoff growth cap.
    pub backoff_max: Duration,
    /// TCP dial timeout per connect attempt.
    pub connect_timeout: Duration,
    /// Per-read/write socket timeout.  Generous — a shard solve streams
    /// a frame per iteration, so silence this long means a dead peer.
    pub io_timeout: Duration,
    /// Total wall-clock budget for one shard job across *all* retry
    /// attempts — the bound on what a hung worker can cost.
    pub job_deadline: Duration,
    /// Seed for backoff jitter (mixed per worker address).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 3,
            backoff_base: Duration::from_millis(100),
            backoff_max: Duration::from_secs(5),
            connect_timeout: Duration::from_secs(5),
            io_timeout: Duration::from_secs(120),
            job_deadline: Duration::from_secs(120),
            seed: 0x5EED_FA17,
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry attempt `attempt` (1-based: the sleep after
    /// the first failure is `backoff(1)`), jittered into `[50%, 100%]`
    /// of the exponential step by `jitter` (a per-worker rng draw).
    pub fn backoff(&self, attempt: u32, jitter: f64) -> Duration {
        let exp = self
            .backoff_base
            .saturating_mul(1u32 << attempt.min(16).saturating_sub(1));
        let capped = exp.min(self.backoff_max);
        capped.mul_f64(0.5 + 0.5 * jitter.clamp(0.0, 1.0))
    }

    /// Deterministic jitter seed for one worker address: same policy
    /// seed + same address → same backoff schedule.
    pub fn jitter_seed(&self, addr: &str) -> u64 {
        let mut h = self.seed;
        for &b in addr.as_bytes() {
            // FNV-ish fold, then SplitMix to spread the bits.
            h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
        SplitMix64::new(h).next_u64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_exponentially_and_respects_the_cap() {
        let p = RetryPolicy::default();
        let full = |a| p.backoff(a, 1.0);
        assert!(full(2) >= full(1));
        assert!(full(3) >= full(2));
        // Far-out attempts saturate at backoff_max.
        assert!(full(20) <= p.backoff_max);
        assert!(full(20) >= p.backoff_max.mul_f64(0.99));
        // jitter=0.0 halves the step, never zeroes it.
        assert!(p.backoff(1, 0.0) >= p.backoff_base.mul_f64(0.49));
        assert!(p.backoff(1, 0.0) <= p.backoff_base.mul_f64(0.51));
    }

    #[test]
    fn jitter_seed_is_stable_and_address_dependent() {
        let p = RetryPolicy::default();
        assert_eq!(p.jitter_seed("a:1"), p.jitter_seed("a:1"));
        assert_ne!(p.jitter_seed("a:1"), p.jitter_seed("b:2"));
        let p2 = RetryPolicy {
            seed: p.seed ^ 1,
            ..RetryPolicy::default()
        };
        assert_ne!(p.jitter_seed("a:1"), p2.jitter_seed("a:1"));
    }
}
