//! Batched inference against a trained [`KmeansModel`]: the fit/predict
//! split's "predict" half.
//!
//! A [`Predictor`] answers assign/score queries in *batches* through the
//! same [`PanelBackend`] seam the training hot path uses: each query
//! point becomes one panel job (query × candidate centroids), the whole
//! batch ships through the backend's flat arenas, and the arg-min over
//! each returned distance row is the label.  That means inference rides
//! the identical blocked/multi-threaded kernels (or the PJRT "PL") as
//! training — the serving story of the paper's PS→PL dispatch.  The
//! kernel tier is selectable ([`Predictor::with_kernel_kind`]): scalar
//! oracle, blocked, or explicit SIMD; [`Predictor::quantized`] instead
//! routes panels through the i8 shortlist + exact-f32-rescore backend,
//! which keeps labels bitwise-identical to the scalar oracle.
//!
//! For large `k` the candidate lists can be pruned through a kd-tree
//! built over the *centroids* (KPynq-style assignment-time pruning): a
//! greedy descent yields an upper bound, then every subtree whose
//! bounding-box lower bound ([`BBox::min_dist`](crate::kdtree::bbox::BBox::min_dist)) beats the (slightly
//! inflated) bound contributes candidates.  The shortlist provably
//! contains every *scalar-arithmetic* global minimizer, and candidates
//! are sorted ascending before paneling, so with the scalar kernel
//! (the default) pruned and unpruned labels are **identical** —
//! including lowest-index tie-breaking, matching
//! [`crate::kmeans::metrics::nearest`].  Under the blocked kernel the
//! panel arithmetic differs from the scalar bound arithmetic by f32
//! rounding (≤ ~1e-4 relative), so a near-exact tie can resolve
//! differently with pruning on vs off; the assigned *distance* still
//! agrees to that tolerance.
//!
//! Orthogonally, [`Predictor::bounds`] layers the triangle-inequality
//! bounds tier (DESIGN.md §10) on top: a one-time k×k center-center
//! half-distance matrix lets each query drop candidates `c` with
//! `d(q, pivot) < ½·d(pivot, c)` — provably not the nearest — *before*
//! paneling.  Survivors are still scored by the configured kernel (a
//! query is never answered from the bound alone), so under the scalar
//! and quantized kernels labels **and** distances stay bitwise-identical
//! to bounds-off, lowest-index ties included.  Under the blocked/SIMD
//! kernels per-candidate values depend on lane position, so — exactly as
//! with the kd prune above — a near-exact tie can resolve differently;
//! the assigned distance agrees to kernel rounding.

use super::bounds::{true_dist, BoundsMode, BoundsStats, CenterGeometry};
use super::model::KmeansModel;
use super::panel::quant::QuantPanels;
use super::panel::{KernelKind, KernelStats, PanelBackend, PanelJobs, PanelSet, ParCpuPanels};
use super::Metric;
use crate::data::Dataset;
use crate::kdtree::KdTree;

/// Auto-prune threshold: below this many centroids a flat panel over all
/// of `k` beats tree bookkeeping.
pub const PRUNE_MIN_K: usize = 32;

/// Leaf bucket size of the centroid kd-tree (small: k is small).
const CENTROID_LEAF: usize = 4;

/// Jobs per internal chunk — bounds the panel arenas for huge query sets
/// while leaving per-row arithmetic untouched (labels are chunk-invariant).
const ASSIGN_CHUNK: usize = 8192;

/// Relative slack on the branch-and-bound upper bound, absorbing f32
/// summation-order differences between [`Metric::dist`]'s unrolled kernel
/// and the plain [`BBox::min_dist`](crate::kdtree::bbox::BBox::min_dist)
/// loop.  Only ever *widens* the
/// shortlist, so exactness is preserved.
const BOUND_SLACK: f32 = 1e-5;

/// Batched assign/score engine over a trained model.
pub struct Predictor<'m> {
    model: &'m KmeansModel,
    backend: Box<dyn PanelBackend + Send + 'm>,
    /// kd-tree over the centroids when pruning is active.
    tree: Option<KdTree>,
    /// k×k half-distance matrix when the bounds tier is active.
    geometry: Option<CenterGeometry>,
    bstats: BoundsStats,
    // Recycled arenas (steady-state predict allocates nothing per batch).
    jobs: PanelJobs,
    panels: PanelSet,
    all_cands: Vec<u32>,
    shortlist: Vec<u32>,
    bounds_list: Vec<u32>,
    stack: Vec<u32>,
}

impl<'m> Predictor<'m> {
    /// Default predictor: scalar panel kernel across the machine's cores —
    /// the *oracle* arithmetic, so labels are bit-identical to
    /// [`crate::kmeans::metrics::nearest`] over the model centroids
    /// regardless of worker count.  Pruning auto-enables at
    /// [`PRUNE_MIN_K`] centroids.
    pub fn new(model: &'m KmeansModel) -> Self {
        let workers = std::thread::available_parallelism()
            .map(|c| c.get())
            .unwrap_or(1)
            .min(8);
        Self::with_backend(model, ParCpuPanels::scalar(workers))
    }

    /// Predictor over an explicit panel backend (blocked CPU kernel, PJRT,
    /// the coordinator's offload handle — anything on the seam).
    pub fn with_backend(model: &'m KmeansModel, backend: impl PanelBackend + Send + 'm) -> Self {
        let mut p = Self {
            model,
            backend: Box::new(backend),
            tree: None,
            geometry: None,
            bstats: BoundsStats::default(),
            jobs: PanelJobs::new(),
            panels: PanelSet::new(),
            all_cands: (0..model.k() as u32).collect(),
            shortlist: Vec::new(),
            bounds_list: Vec::new(),
            stack: Vec::new(),
        };
        if model.k() >= PRUNE_MIN_K {
            p = p.prune(true);
        }
        p
    }

    /// Predictor over the [`KernelKind`]-selected CPU tier (lenient
    /// resolution: SIMD demotes to blocked on hosts without AVX2/FMA or
    /// NEON — callers that must know use [`KernelKind::resolve`] first).
    pub fn with_kernel_kind(model: &'m KmeansModel, workers: usize, kind: KernelKind) -> Self {
        Self::with_backend(model, ParCpuPanels::with_kind(workers, kind))
    }

    /// Predictor over the reduced-precision shortlist backend: i8 panels
    /// score every candidate cheaply, survivors re-score in exact f32, so
    /// labels stay bitwise-identical to the scalar oracle (including
    /// lowest-index ties — see [`QuantPanels`]'s bound proof).
    pub fn quantized(model: &'m KmeansModel) -> Self {
        Self::with_backend(model, QuantPanels::new())
    }

    /// Lifetime kernel telemetry from the underlying panel backend
    /// (SIMD lane width, quantized/re-scored candidate counters).
    pub fn kernel_stats(&self) -> KernelStats {
        self.backend.kernel_stats()
    }

    /// Force the centroid kd-tree prune on or off (overrides the
    /// [`PRUNE_MIN_K`] auto rule).
    pub fn prune(mut self, on: bool) -> Self {
        self.tree = if on {
            Some(KdTree::build_with(&self.model.centroids, CENTROID_LEAF))
        } else {
            None
        };
        self
    }

    /// Select the triangle-inequality bounds tier (DESIGN.md §10).  The
    /// center-center matrix is computed once, here; its cost lands in
    /// [`bounds_stats`](Self::bounds_stats)'s `matrix_cost`.
    /// [`BoundsMode::Auto`] engages at large k, [`BoundsMode::On`]
    /// whenever the matrix fits the memory guard.
    pub fn bounds(mut self, mode: BoundsMode) -> Self {
        self.geometry = if mode.enabled_for(self.model.k()) {
            let geom = CenterGeometry::compute(&self.model.centroids, self.model.metric);
            self.bstats.matrix_cost += geom.cost();
            Some(geom)
        } else {
            None
        };
        self
    }

    pub fn model(&self) -> &'m KmeansModel {
        self.model
    }

    pub fn pruning(&self) -> bool {
        self.tree.is_some()
    }

    /// Is the bounds tier actually filtering (mode resolved to active)?
    pub fn bounding(&self) -> bool {
        self.geometry.is_some()
    }

    /// Lifetime bounds-pruning counters: queries whose candidate list the
    /// bounds collapsed to a single (still kernel-scored) survivor,
    /// candidates dropped, and true-distance evals spent maintaining the
    /// bounds.  All zero when the tier is off.
    pub fn bounds_stats(&self) -> BoundsStats {
        self.bstats
    }

    /// Labels for a batch of query points.
    pub fn assign(&mut self, queries: &Dataset) -> Vec<u32> {
        let mut labels = Vec::with_capacity(queries.len());
        self.assign_into(queries, &mut labels, None);
        labels
    }

    /// Labels plus the distance to the assigned centroid (squared-L2 for
    /// [`Metric::Euclid`], per the repo-wide convention).
    pub fn assign_scored(&mut self, queries: &Dataset) -> (Vec<u32>, Vec<f32>) {
        let mut labels = Vec::with_capacity(queries.len());
        let mut dists = Vec::with_capacity(queries.len());
        self.assign_into(queries, &mut labels, Some(&mut dists));
        (labels, dists)
    }

    /// Exact k-means objective of the model on `data` (sum of assigned
    /// distances) — the serving-side quality probe.
    pub fn objective(&mut self, data: &Dataset) -> f64 {
        let (_, dists) = self.assign_scored(data);
        dists.iter().map(|&d| d as f64).sum()
    }

    fn assign_into(
        &mut self,
        queries: &Dataset,
        labels: &mut Vec<u32>,
        mut dists: Option<&mut Vec<f32>>,
    ) {
        assert_eq!(
            queries.dims(),
            self.model.dims(),
            "query dims {} != model dims {}",
            queries.dims(),
            self.model.dims()
        );
        let d = self.model.dims();
        let cents = &self.model.centroids;
        let metric = self.model.metric;
        self.backend.begin_pass(cents, metric);

        let n = queries.len();
        let mut start = 0usize;
        while start < n {
            let take = (n - start).min(ASSIGN_CHUNK);
            self.jobs.clear(d);
            for i in start..start + take {
                let q = queries.point(i);
                let cands: &[u32] = match &self.tree {
                    Some(tree) => {
                        centroid_shortlist(
                            tree,
                            cents,
                            q,
                            metric,
                            &mut self.shortlist,
                            &mut self.stack,
                        );
                        // Ascending order ⇒ first-wins arg-min over the
                        // shortlist picks the lowest-index global minimum.
                        self.shortlist.sort_unstable();
                        &self.shortlist
                    }
                    None => &self.all_cands,
                };
                match &self.geometry {
                    Some(geom) => {
                        // Pivot on the first (lowest-index) candidate: its
                        // exact true distance rules out every candidate the
                        // center-center test puts surely farther.  The
                        // survivors — always including the argmin and its
                        // ties, in unchanged order — still go through the
                        // kernel, even when only one is left, so distances
                        // stay kernel-computed.
                        let g = cands[0] as usize;
                        let u = true_dist(metric, q, cents.point(g));
                        self.bstats.matrix_cost += 1;
                        let dropped =
                            geom.filter_candidates(g, u, cands, &mut self.bounds_list);
                        self.bstats.pruned_candidates += dropped as u64;
                        if self.bounds_list.len() == 1 {
                            self.bstats.pruned_points += 1;
                        }
                        self.jobs.push(q, &self.bounds_list);
                    }
                    None => self.jobs.push(q, cands),
                }
            }
            self.backend.panels(&self.jobs, cents, metric, &mut self.panels);
            for j in 0..take {
                let row = self.panels.row(j);
                let cands = self.jobs.cands(j);
                let mut best_slot = 0usize;
                let mut best_d = f32::INFINITY;
                for (slot, &dd) in row.iter().enumerate() {
                    if dd < best_d {
                        best_d = dd;
                        best_slot = slot;
                    }
                }
                labels.push(cands[best_slot]);
                if let Some(out) = dists.as_mut() {
                    out.push(best_d);
                }
            }
            start += take;
        }
    }
}

/// Collect into `out` every centroid index whose subtree lower bound does
/// not exceed the greedy-descent upper bound.  Guarantees every global
/// nearest centroid of `q` is included (see module docs).
fn centroid_shortlist(
    tree: &KdTree,
    cents: &Dataset,
    q: &[f32],
    metric: Metric,
    out: &mut Vec<u32>,
    stack: &mut Vec<u32>,
) {
    // Phase 1: greedy descent to the most promising leaf for an upper
    // bound (a true distance to some centroid — never an underestimate).
    let mut ni = 0usize;
    loop {
        let node = &tree.nodes[ni];
        if node.is_leaf() {
            break;
        }
        let l = &tree.nodes[node.left as usize];
        let r = &tree.nodes[node.right as usize];
        ni = if l.bbox.min_dist(q, metric) <= r.bbox.min_dist(q, metric) {
            node.left as usize
        } else {
            node.right as usize
        };
    }
    let mut ub = f32::INFINITY;
    for &i in tree.node_points(&tree.nodes[ni]) {
        let dd = metric.dist(q, cents.point(i as usize));
        if dd < ub {
            ub = dd;
        }
    }
    let bound = ub * (1.0 + BOUND_SLACK);

    // Phase 2: gather every subtree that can still hold a minimizer.
    out.clear();
    stack.clear();
    stack.push(0);
    while let Some(x) = stack.pop() {
        let node = &tree.nodes[x as usize];
        if node.bbox.min_dist(q, metric) > bound {
            continue;
        }
        if node.is_leaf() {
            out.extend_from_slice(tree.node_points(node));
        } else {
            stack.push(node.left);
            stack.push(node.right);
        }
    }
    debug_assert!(!out.is_empty());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::generate_params;
    use crate::kmeans::metrics::nearest;
    use crate::kmeans::panel::PanelKernel;
    use crate::kmeans::solver::{KmeansSpec, SolverCtx};

    fn model(metric: Metric, k: usize, d: usize, seed: u64) -> KmeansModel {
        let s = generate_params(400 + 8 * k, d, k, 0.2, 2.0, seed);
        KmeansSpec::new(k)
            .metric(metric)
            .seed(seed)
            .fit(&mut SolverCtx::new(&s.data))
    }

    #[test]
    fn assign_matches_scalar_nearest_exactly() {
        for metric in [Metric::Euclid, Metric::Manhattan] {
            let m = model(metric, 6, 5, 3);
            let q = generate_params(500, 5, 6, 0.4, 2.0, 99).data;
            let labels = Predictor::new(&m).assign(&q);
            for (i, p) in q.iter().enumerate() {
                let (want, _) = nearest(metric, p, m.centroids.flat(), m.k(), m.dims());
                assert_eq!(labels[i] as usize, want, "{metric:?} point {i}");
            }
        }
    }

    #[test]
    fn prune_is_label_identical_to_full_argmin_for_scalar_kernel() {
        for metric in [Metric::Euclid, Metric::Manhattan] {
            let m = model(metric, 48, 4, 7);
            let q = generate_params(600, 4, 8, 0.5, 2.0, 55).data;
            let full = Predictor::with_backend(&m, ParCpuPanels::scalar(2))
                .prune(false)
                .assign(&q);
            let mut pruned_pred =
                Predictor::with_backend(&m, ParCpuPanels::scalar(2)).prune(true);
            assert!(pruned_pred.pruning());
            let pruned = pruned_pred.assign(&q);
            assert_eq!(full, pruned, "{metric:?}");
        }
    }

    #[test]
    fn prune_under_blocked_kernel_agrees_to_rounding() {
        // The shortlist bound uses scalar arithmetic while the blocked
        // kernel rounds differently, so labels may flip only on
        // near-exact ties — assigned distances must agree to f32
        // rounding either way (see module docs).
        for metric in [Metric::Euclid, Metric::Manhattan] {
            let m = model(metric, 48, 4, 7);
            let q = generate_params(600, 4, 8, 0.5, 2.0, 55).data;
            let blocked = ParCpuPanels::with_kernel(2, PanelKernel::Blocked);
            let (_, full_d) = Predictor::with_backend(&m, blocked.clone())
                .prune(false)
                .assign_scored(&q);
            let (_, pruned_d) = Predictor::with_backend(&m, blocked)
                .prune(true)
                .assign_scored(&q);
            for (i, (a, b)) in full_d.iter().zip(pruned_d.iter()).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-4 * (1.0 + a.abs()),
                    "{metric:?} point {i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn auto_prune_kicks_in_at_threshold() {
        let small = model(Metric::Euclid, 4, 3, 1);
        assert!(!Predictor::new(&small).pruning());
        let big = model(Metric::Euclid, PRUNE_MIN_K, 3, 1);
        assert!(Predictor::new(&big).pruning());
    }

    #[test]
    fn scored_distances_are_the_assigned_distances() {
        let m = model(Metric::Euclid, 5, 3, 9);
        let q = generate_params(300, 3, 5, 0.3, 1.0, 21).data;
        let mut p = Predictor::new(&m);
        let (labels, dists) = p.assign_scored(&q);
        for i in 0..q.len() {
            let want = Metric::Euclid.dist(q.point(i), m.centroids.point(labels[i] as usize));
            assert_eq!(dists[i], want, "point {i}");
        }
        // Objective is the sum of those distances.
        let obj = p.objective(&q);
        let want: f64 = dists.iter().map(|&x| x as f64).sum();
        assert!((obj - want).abs() <= 1e-9 * (1.0 + want.abs()));
    }

    #[test]
    fn chunking_is_invisible() {
        // More queries than one chunk: labels must match the per-point oracle.
        let m = model(Metric::Euclid, 3, 2, 4);
        let q = generate_params(ASSIGN_CHUNK + 37, 2, 3, 0.4, 1.0, 13).data;
        let labels = Predictor::new(&m).assign(&q);
        assert_eq!(labels.len(), q.len());
        for (i, p) in q.iter().enumerate().step_by(997) {
            let (want, _) = nearest(Metric::Euclid, p, m.centroids.flat(), 3, 2);
            assert_eq!(labels[i] as usize, want);
        }
    }

    #[test]
    fn empty_query_batch_is_fine() {
        let m = model(Metric::Euclid, 3, 2, 6);
        let q = Dataset::from_flat(0, 2, vec![]);
        let (labels, dists) = Predictor::new(&m).assign_scored(&q);
        assert!(labels.is_empty() && dists.is_empty());
    }

    #[test]
    #[should_panic(expected = "query dims")]
    fn dim_mismatch_panics() {
        let m = model(Metric::Euclid, 3, 2, 8);
        let q = Dataset::from_flat(1, 3, vec![0.0; 3]);
        let _ = Predictor::new(&m).assign(&q);
    }
}
