//! Conventional Lloyd k-means (paper section 2).
//!
//! This is the algorithmic content of three of the paper's comparison
//! points: the software-only solution, the "conventional FPGA-based"
//! implementation (same work, PL-speed arithmetic) and the multi-core
//! no-filtering architecture of [17].  The solver is instrumented so each
//! iteration reports exactly `n * k` distance evaluations — the hardware
//! models turn those counters into cycles.

use super::{
    centroids_from_sums, max_sq_movement, metrics, IterHook, IterStats, KmeansResult, Metric,
    ResultExt, RunStats,
};
use crate::data::Dataset;

/// Tunable knobs for a Lloyd run.
#[derive(Clone, Debug)]
pub struct LloydOpts {
    pub metric: Metric,
    /// Stop when max squared centroid movement drops below this.
    pub tol: f32,
    pub max_iters: usize,
    /// Also accumulate the exact objective each iteration.
    pub track_cost: bool,
}

impl Default for LloydOpts {
    fn default() -> Self {
        Self {
            metric: Metric::Euclid,
            tol: 1e-6,
            max_iters: 100,
            track_cost: false,
        }
    }
}

/// Run Lloyd's algorithm from the given initial centroids.
pub fn run(data: &Dataset, init: &Dataset, opts: &LloydOpts) -> KmeansResult {
    run_hooked(data, init, opts, None)
}

/// [`run`] with a per-iteration hook (what the unified solver layer calls;
/// the hook returning `false` stops the run early).
pub fn run_hooked(
    data: &Dataset,
    init: &Dataset,
    opts: &LloydOpts,
    mut hook: Option<IterHook<'_>>,
) -> KmeansResult {
    assert_eq!(data.dims(), init.dims());
    let n = data.len();
    let d = data.dims();
    let k = init.len();
    let mut centroids = init.clone();
    let mut assignments = vec![0u32; n];
    let mut stats = RunStats::default();

    let mut sums = vec![0f32; k * d];
    let mut counts = vec![0u32; k];

    for _ in 0..opts.max_iters {
        sums.iter_mut().for_each(|s| *s = 0.0);
        counts.iter_mut().for_each(|c| *c = 0);
        let mut cost = 0f64;

        // Assignment + accumulation in one pass (the paper's PL does the
        // same: distance, compare, update pipelines back to back).
        for (i, p) in data.iter().enumerate() {
            let (best, best_d) =
                metrics::nearest(opts.metric, p, centroids.flat(), k, d);
            assignments[i] = best as u32;
            let row = &mut sums[best * d..(best + 1) * d];
            for (j, &v) in p.iter().enumerate() {
                row[j] += v;
            }
            counts[best] += 1;
            if opts.track_cost {
                cost += best_d as f64;
            }
        }

        let next = centroids_from_sums(&sums, &counts, &centroids);
        let moved = max_sq_movement(&centroids, &next);
        centroids = next;

        stats.iters.push(IterStats {
            dist_evals: (n as u64) * (k as u64),
            leaf_points: n as u64,
            moved,
            cost: opts.track_cost.then_some(cost),
            ..Default::default()
        });

        let go = match hook.as_mut() {
            Some(h) => h(stats.iters.len() - 1, stats.iters.last().unwrap(), &centroids),
            None => true,
        };
        if moved <= opts.tol {
            stats.converged = true;
            break;
        }
        if !go {
            stats.early_stopped = true;
            break;
        }
    }

    KmeansResult {
        centroids,
        assignments,
        stats,
        ext: ResultExt::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::generate_params;
    use crate::kmeans::init::{init_centroids, Init};

    fn well_separated() -> (crate::data::synthetic::Synthetic, Dataset) {
        let s = generate_params(600, 2, 3, 0.05, 5.0, 7);
        let init = init_centroids(&s.data, 3, Init::KmeansPlusPlus, Metric::Euclid, 3);
        (s, init)
    }

    #[test]
    fn converges_and_recovers_planted_centroids() {
        let (s, init) = well_separated();
        let r = run(&s.data, &init, &LloydOpts::default());
        assert!(r.stats.converged, "did not converge");
        assert!(r.stats.iterations() < 50);
        // Each recovered centroid is near some planted center.
        for c in r.centroids.iter() {
            let best = s
                .true_centroids
                .iter()
                .map(|t| metrics::sq_l2(c, t))
                .fold(f32::INFINITY, f32::min);
            assert!(best < 0.05, "centroid {c:?} far from any planted center");
        }
    }

    #[test]
    fn counts_exact_work() {
        let (s, init) = well_separated();
        // tol = 0 can still converge early once movement is exactly 0.
        let r = run(&s.data, &init, &LloydOpts { max_iters: 5, tol: 0.0, ..Default::default() });
        assert!(r.stats.iterations() >= 1 && r.stats.iterations() <= 5);
        for it in &r.stats.iters {
            assert_eq!(it.dist_evals, 600 * 3);
            assert_eq!(it.leaf_points, 600);
            assert_eq!(it.node_visits, 0);
        }
        if r.stats.iterations() < 5 {
            assert!(r.stats.converged);
            assert_eq!(r.stats.iters.last().unwrap().moved, 0.0);
        }
    }

    #[test]
    fn cost_is_monotone_nonincreasing() {
        let s = generate_params(500, 4, 6, 0.3, 1.0, 21);
        let init = init_centroids(&s.data, 6, Init::UniformSample, Metric::Euclid, 9);
        let r = run(
            &s.data,
            &init,
            &LloydOpts {
                track_cost: true,
                max_iters: 40,
                ..Default::default()
            },
        );
        let costs: Vec<f64> = r.stats.iters.iter().map(|i| i.cost.unwrap()).collect();
        for w in costs.windows(2) {
            assert!(
                w[1] <= w[0] * (1.0 + 1e-6),
                "objective increased: {} -> {}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn manhattan_metric_runs() {
        let s = generate_params(300, 3, 4, 0.2, 1.0, 5);
        let init = init_centroids(&s.data, 4, Init::UniformSample, Metric::Manhattan, 2);
        let r = run(
            &s.data,
            &init,
            &LloydOpts {
                metric: Metric::Manhattan,
                ..Default::default()
            },
        );
        assert_eq!(r.centroids.len(), 4);
        assert_eq!(r.assignments.len(), 300);
        assert!(r.sizes().iter().sum::<usize>() == 300);
    }

    #[test]
    fn k_equals_one_assigns_everything() {
        let s = generate_params(100, 2, 2, 0.5, 1.0, 8);
        let init = s.data.gather(&[0]);
        let r = run(&s.data, &init, &LloydOpts::default());
        assert!(r.assignments.iter().all(|&a| a == 0));
        // Centroid converges to the global mean.
        let mut mean = vec![0f32; 2];
        for p in s.data.iter() {
            mean[0] += p[0];
            mean[1] += p[1];
        }
        mean.iter_mut().for_each(|m| *m /= 100.0);
        assert!(metrics::sq_l2(r.centroids.point(0), &mean) < 1e-6);
    }

    #[test]
    fn respects_max_iters() {
        let s = generate_params(200, 2, 4, 0.4, 1.0, 10);
        let init = init_centroids(&s.data, 4, Init::UniformSample, Metric::Euclid, 4);
        let r = run(
            &s.data,
            &init,
            &LloydOpts {
                max_iters: 2,
                tol: 0.0,
                ..Default::default()
            },
        );
        assert_eq!(r.stats.iterations(), 2);
        assert!(!r.stats.converged);
    }
}
