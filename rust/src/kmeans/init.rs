//! Centroid initialization.
//!
//! The paper (section 5) distributes initial centroids "between data points
//! uniformly" and Alg. 2 invokes Lloyd-style seeding per quarter; we
//! provide uniform point sampling (the paper's method, default) plus
//! k-means++ [Arthur & Vassilvitskii] as an extension for ablations.

use super::Metric;
use crate::data::Dataset;
use crate::util::rng::Xoshiro256pp;

/// Initialization strategies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Init {
    /// k distinct points sampled uniformly (the paper's scheme).
    UniformSample,
    /// k-means++ D²-weighted seeding (extension).
    KmeansPlusPlus,
}

impl Init {
    /// Canonical name (round-trips through [`FromStr`](std::str::FromStr)
    /// — the model artifact serializes specs by these names).
    pub fn name(self) -> &'static str {
        match self {
            Init::UniformSample => "uniform",
            Init::KmeansPlusPlus => "kmeans++",
        }
    }
}

impl std::str::FromStr for Init {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "uniform" | "uniform-sample" => Ok(Init::UniformSample),
            "kmeans++" | "kpp" | "plusplus" => Ok(Init::KmeansPlusPlus),
            other => anyhow::bail!("unknown init `{other}` (uniform|kmeans++)"),
        }
    }
}

/// Pick `k` initial centroids from `data`.
pub fn init_centroids(
    data: &Dataset,
    k: usize,
    method: Init,
    metric: Metric,
    seed: u64,
) -> Dataset {
    assert!(k >= 1 && k <= data.len(), "k={} n={}", k, data.len());
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    match method {
        Init::UniformSample => {
            let idx = rng.sample_indices(data.len(), k);
            data.gather(&idx)
        }
        Init::KmeansPlusPlus => kpp(data, k, metric, &mut rng),
    }
}

fn kpp(data: &Dataset, k: usize, metric: Metric, rng: &mut Xoshiro256pp) -> Dataset {
    let n = data.len();
    let d = data.dims();
    let mut chosen: Vec<usize> = Vec::with_capacity(k);
    chosen.push(rng.below_usize(n));
    // Distance of each point to the nearest chosen centroid so far.
    let mut best: Vec<f32> = (0..n)
        .map(|i| metric.dist(data.point(i), data.point(chosen[0])))
        .collect();
    while chosen.len() < k {
        let total: f64 = best.iter().map(|&b| b as f64).sum();
        let next = if total <= 0.0 {
            // All remaining mass is zero (duplicate points): fall back to
            // uniform choice among not-yet-chosen indices.
            let mut i = rng.below_usize(n);
            while chosen.contains(&i) && chosen.len() < n {
                i = (i + 1) % n;
            }
            i
        } else {
            let mut target = rng.next_f64() * total;
            let mut pick = n - 1;
            for (i, &b) in best.iter().enumerate() {
                target -= b as f64;
                if target <= 0.0 {
                    pick = i;
                    break;
                }
            }
            pick
        };
        chosen.push(next);
        let np = data.point(next).to_vec();
        for i in 0..n {
            let dd = metric.dist(data.point(i), &np);
            if dd < best[i] {
                best[i] = dd;
            }
        }
    }
    let _ = d;
    data.gather(&chosen)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::generate_params;

    #[test]
    fn uniform_sample_picks_distinct_data_points() {
        let s = generate_params(200, 3, 4, 0.2, 1.0, 1);
        let c = init_centroids(&s.data, 10, Init::UniformSample, Metric::Euclid, 7);
        assert_eq!(c.len(), 10);
        assert_eq!(c.dims(), 3);
        // Every centroid is an actual data point.
        for cent in c.iter() {
            assert!(s.data.iter().any(|p| p == cent));
        }
        // Distinct rows (sampling without replacement).
        for i in 0..10 {
            for j in i + 1..10 {
                assert_ne!(c.point(i), c.point(j));
            }
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let s = generate_params(100, 2, 3, 0.1, 1.0, 2);
        let a = init_centroids(&s.data, 5, Init::UniformSample, Metric::Euclid, 3);
        let b = init_centroids(&s.data, 5, Init::UniformSample, Metric::Euclid, 3);
        assert_eq!(a, b);
        let c = init_centroids(&s.data, 5, Init::KmeansPlusPlus, Metric::Euclid, 3);
        let d2 = init_centroids(&s.data, 5, Init::KmeansPlusPlus, Metric::Euclid, 3);
        assert_eq!(c, d2);
    }

    #[test]
    fn kpp_spreads_over_clusters() {
        // Four well-separated planted clusters: k-means++ should seed in at
        // least 3 distinct ones almost surely.
        let s = generate_params(400, 2, 4, 0.01, 10.0, 5);
        let c = init_centroids(&s.data, 4, Init::KmeansPlusPlus, Metric::Euclid, 11);
        let mut hit = std::collections::BTreeSet::new();
        for cent in c.iter() {
            // nearest planted center
            let mut best = (0usize, f32::INFINITY);
            for (i, tc) in s.true_centroids.iter().enumerate() {
                let d = Metric::Euclid.dist(cent, tc);
                if d < best.1 {
                    best = (i, d);
                }
            }
            hit.insert(best.0);
        }
        assert!(hit.len() >= 3, "k-means++ hit only {hit:?}");
    }

    #[test]
    fn kpp_handles_duplicate_points() {
        let data = Dataset::from_flat(6, 1, vec![1.0; 6]);
        let c = init_centroids(&data, 3, Init::KmeansPlusPlus, Metric::Euclid, 1);
        assert_eq!(c.len(), 3);
    }

    #[test]
    #[should_panic]
    fn k_larger_than_n_panics() {
        let data = Dataset::from_flat(2, 1, vec![0.0, 1.0]);
        init_centroids(&data, 3, Init::UniformSample, Metric::Euclid, 1);
    }
}
