//! The unified solver API: one spec, one trait, pluggable backends and
//! per-iteration observers across all four k-means engines.
//!
//! The paper's framing is that one clustering problem can be driven
//! through interchangeable execution strategies — software-only Lloyd,
//! triangle-inequality Elkan, kd-tree filtering (recursive or
//! level-batched/offloaded), and the two-level multi-core scheme.  This
//! module makes that framing literal:
//!
//! - [`KmeansSpec`] — one builder owning every knob the five old option
//!   structs duplicated (`k`, metric, tolerance, iteration caps, init,
//!   seed, partition, workers) plus the [`Algo`] selection;
//! - [`Solver`] — `fn run(&mut self, ctx: &mut SolverCtx) -> KmeansResult`,
//!   implemented by one adapter per engine ([`LloydSolver`],
//!   [`ElkanSolver`], [`FilterSolver`], [`BatchedFilterSolver`],
//!   [`TwoLevelSolver`]);
//! - [`SolverCtx`] — the shared substrate the old free-function
//!   signatures threaded by hand: the dataset, a lazily-built-and-cached
//!   [`KdTree`] (built once, shared across solvers via [`Arc`]), an
//!   injected [`PanelBackend`] (CPU scalar, `ParCpuPanels`, or PJRT
//!   through the coordinator's offload service), and an [`IterObserver`]
//!   subscription.
//!
//! Observers receive every iteration's [`IterStats`] (plus phase and
//! post-update centroids) and can stop a run early — this is the hook the
//! coordinator's worker loop and any future serving path subscribe to for
//! live logging and metrics streaming.
//!
//! ```no_run
//! # use muchswift::data::synthetic::generate_params;
//! # use muchswift::kmeans::solver::{Algo, KmeansSpec, SolverCtx};
//! let s = generate_params(10_000, 3, 8, 0.1, 2.0, 7);
//! let spec = KmeansSpec::new(8).algo(Algo::FilterBatched).tol(1e-6).seed(1);
//! let result = spec.solve(&mut SolverCtx::new(&s.data));
//! assert!(result.stats.converged);
//! ```

use super::bounds::BoundsMode;
use super::elkan::{self, ElkanOpts};
use super::filtering::{self, FilterOpts};
use super::init::{init_centroids, Init};
use super::lloyd::{self, LloydOpts};
use super::panel::{KernelKind, PanelBackend, ParCpuPanels};
use super::twolevel::{self, Partition, TwoLevelOpts, QUARTERS};
use super::{IterStats, KmeansResult, Metric, Phase};
use crate::data::Dataset;
use crate::kdtree::KdTree;
use std::str::FromStr;
use std::sync::Arc;

// ---------------------------------------------------------------------------
// Algorithm selection
// ---------------------------------------------------------------------------

/// The interchangeable execution strategies (paper sections 2–4).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    /// Conventional Lloyd iteration (the software / unoptimized-FPGA work).
    Lloyd,
    /// Triangle-inequality accelerated Lloyd (Elkan [8]).
    Elkan,
    /// kd-tree filtering, depth-first recursive engine (Alg. 1).
    Filter,
    /// kd-tree filtering, level-batched engine with panel offload — the
    /// HW/SW split; honors an injected [`PanelBackend`].
    FilterBatched,
    /// The paper's two-level 4-way scheme (Alg. 2).
    TwoLevel,
}

impl Algo {
    pub fn name(self) -> &'static str {
        match self {
            Algo::Lloyd => "lloyd",
            Algo::Elkan => "elkan",
            Algo::Filter => "filter",
            Algo::FilterBatched => "filter-batched",
            Algo::TwoLevel => "two-level",
        }
    }

    pub fn all() -> &'static [Algo] {
        &[
            Algo::Lloyd,
            Algo::Elkan,
            Algo::Filter,
            Algo::FilterBatched,
            Algo::TwoLevel,
        ]
    }

    /// Does this strategy traverse a kd-tree (and therefore charge
    /// `node_visits`/`prune_tests` work counters)?
    pub fn uses_tree(self) -> bool {
        matches!(self, Algo::Filter | Algo::FilterBatched | Algo::TwoLevel)
    }
}

impl FromStr for Algo {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "lloyd" => Algo::Lloyd,
            "elkan" => Algo::Elkan,
            "filter" | "filtering" => Algo::Filter,
            "filter-batched" | "batched" => Algo::FilterBatched,
            "two-level" | "twolevel" => Algo::TwoLevel,
            other => anyhow::bail!(
                "unknown algo `{other}` (lloyd|elkan|filter|filter-batched|two-level)"
            ),
        })
    }
}

// ---------------------------------------------------------------------------
// The spec
// ---------------------------------------------------------------------------

/// One clustering problem, fully specified.  Replaces the per-engine
/// option structs (`LloydOpts`/`ElkanOpts`/`FilterOpts`/`TwoLevelOpts`/
/// the old `CoordinatorOpts`) at every call site outside `kmeans/`; the
/// engine-level structs survive only as internal knob carriers the
/// adapters map onto.
#[derive(Clone, Debug)]
pub struct KmeansSpec {
    pub k: usize,
    pub algo: Algo,
    pub metric: Metric,
    /// Stop when max squared centroid movement drops below this.
    pub tol: f32,
    /// Iteration cap for the main loop (level-1 cap for [`Algo::TwoLevel`]).
    pub max_iters: usize,
    /// Iteration cap for the two-level refinement phase.
    pub level2_max_iters: usize,
    pub init: Init,
    /// Shard partition strategy ([`Algo::TwoLevel`] only).
    pub partition: Partition,
    /// Level-1 shard count P ([`Algo::TwoLevel`] only; the paper's 4 by
    /// default).  The shard plane ([`super::shard`]) partitions the data
    /// P ways and tree-reduces the P×k level-1 centroids back to k.
    pub shards: usize,
    pub seed: u64,
    /// Worker threads for the default panel backend (and the coordinator's
    /// level-2 fan-out).
    pub workers: usize,
    /// Also accumulate the exact objective each iteration (Lloyd only).
    pub track_cost: bool,
    /// Distance-kernel tier for the default panel backend.  `None` keeps
    /// the legacy choice (blocked when `workers > 1`, scalar otherwise) so
    /// every bitwise-parity pin on the defaults stays intact; `Some(kind)`
    /// resolves leniently via [`KernelKind::effective`] (SIMD demotes to
    /// blocked on hosts without AVX2/FMA or NEON).
    pub kernel: Option<KernelKind>,
    /// Triangle-inequality bounds tier for the batched filtering engine
    /// (DESIGN.md §10).  [`BoundsMode::Off`] (the default) leaves every
    /// engine bitwise on its legacy path; `Auto` enables pruning at
    /// large k; `On` forces it.  Only [`Algo::FilterBatched`] — and the
    /// shard/session planes built on it — honors the knob; the other
    /// engines ignore it.
    pub bounds: BoundsMode,
    /// Explicit initial centroids; overrides `init`/`seed` seeding.
    /// Ignored by [`Algo::TwoLevel`], which seeds per quarter.
    pub start: Option<Dataset>,
}

impl KmeansSpec {
    /// A spec with the repo-wide defaults (Lloyd, squared-L2, `tol = 1e-6`,
    /// 100 iterations, uniform seeding, round-robin quarters, 4 workers).
    pub fn new(k: usize) -> Self {
        Self {
            k,
            algo: Algo::Lloyd,
            metric: Metric::Euclid,
            tol: 1e-6,
            max_iters: 100,
            level2_max_iters: 100,
            init: Init::UniformSample,
            partition: Partition::RoundRobin,
            shards: QUARTERS,
            seed: 1,
            workers: QUARTERS,
            track_cost: false,
            kernel: None,
            bounds: BoundsMode::Off,
            start: None,
        }
    }

    /// Shorthand for the paper's configuration: [`Algo::TwoLevel`].
    pub fn two_level(k: usize) -> Self {
        Self::new(k).algo(Algo::TwoLevel)
    }

    pub fn algo(mut self, algo: Algo) -> Self {
        self.algo = algo;
        self
    }

    pub fn metric(mut self, metric: Metric) -> Self {
        self.metric = metric;
        self
    }

    pub fn tol(mut self, tol: f32) -> Self {
        self.tol = tol;
        self
    }

    pub fn max_iters(mut self, max_iters: usize) -> Self {
        self.max_iters = max_iters;
        self
    }

    pub fn level2_max_iters(mut self, cap: usize) -> Self {
        self.level2_max_iters = cap;
        self
    }

    pub fn init(mut self, init: Init) -> Self {
        self.init = init;
        self
    }

    pub fn partition(mut self, partition: Partition) -> Self {
        self.partition = partition;
        self
    }

    /// Level-1 shard count P for [`Algo::TwoLevel`] (validated ≥ 1 by
    /// [`validate`](Self::validate); shards that end up smaller than `k`
    /// trigger the plain-filtering fallback).
    pub fn shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    pub fn track_cost(mut self, track: bool) -> Self {
        self.track_cost = track;
        self
    }

    /// Pin the distance-kernel tier for the default panel backend.
    pub fn kernel(mut self, kind: KernelKind) -> Self {
        self.kernel = Some(kind);
        self
    }

    /// Set the triangle-inequality bounds tier for the batched engine.
    pub fn bounds(mut self, bounds: BoundsMode) -> Self {
        self.bounds = bounds;
        self
    }

    /// Start from these centroids instead of seeding from `init`/`seed`.
    pub fn start(mut self, centroids: Dataset) -> Self {
        self.start = Some(centroids);
        self
    }

    /// Panics (like the engines always did) if the spec cannot run on
    /// `data`.
    pub fn validate(&self, data: &Dataset) {
        assert!(
            self.k >= 1 && self.k <= data.len(),
            "k out of range (k={} n={})",
            self.k,
            data.len()
        );
        assert!(self.max_iters >= 1, "max_iters must be >= 1");
        assert!(self.shards >= 1, "shards must be >= 1");
        if let Some(start) = &self.start {
            assert_eq!(start.len(), self.k, "start centroids must have k rows");
            assert_eq!(start.dims(), data.dims(), "start centroid dims mismatch");
        }
    }

    /// The initial centroids this spec resolves to over `data`.
    pub fn starting_centroids(&self, data: &Dataset) -> Dataset {
        match &self.start {
            Some(c) => c.clone(),
            None => init_centroids(data, self.k, self.init, self.metric, self.seed),
        }
    }

    /// Panel backend used when the ctx has none injected.  With no
    /// explicit kernel: scalar (oracle, bit-identical to the recursive
    /// engine) for one worker, the blocked multi-threaded kernel
    /// otherwise.  An explicit [`KernelKind`] overrides that choice.
    fn default_panels(&self) -> ParCpuPanels {
        match self.kernel {
            Some(kind) => ParCpuPanels::with_kind(self.workers, kind),
            None if self.workers > 1 => ParCpuPanels::new(self.workers),
            None => ParCpuPanels::scalar(1),
        }
    }

    /// The [`Solver`] adapter for this spec's [`Algo`].
    pub fn solver(&self) -> Box<dyn Solver> {
        match self.algo {
            Algo::Lloyd => Box::new(LloydSolver { spec: self.clone() }),
            Algo::Elkan => Box::new(ElkanSolver { spec: self.clone() }),
            Algo::Filter => Box::new(FilterSolver { spec: self.clone() }),
            Algo::FilterBatched => Box::new(BatchedFilterSolver { spec: self.clone() }),
            Algo::TwoLevel => Box::new(TwoLevelSolver { spec: self.clone() }),
        }
    }

    /// Run this spec's solver in `ctx`.
    pub fn solve(&self, ctx: &mut SolverCtx<'_>) -> KmeansResult {
        self.solver().run(ctx)
    }

    /// Train and package: solve in `ctx`, then freeze the outcome into a
    /// [`KmeansModel`](super::model::KmeansModel) artifact (centroids +
    /// metric + spec snapshot + train stats, including the exact training
    /// objective).  This is the fit half of the fit/predict split — pair
    /// it with [`Predictor`](super::predict::Predictor) for inference.
    pub fn fit(&self, ctx: &mut SolverCtx<'_>) -> super::model::KmeansModel {
        let result = self.solve(ctx);
        super::model::KmeansModel::from_fit(ctx.data(), &result, self)
    }
}

// ---------------------------------------------------------------------------
// Observers
// ---------------------------------------------------------------------------

/// What an observer tells the solver after each iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IterFlow {
    Continue,
    /// Stop the current phase's iteration loop after this iteration
    /// (recorded as `RunStats::early_stopped`, not convergence).
    Stop,
}

/// One iteration's observation: the work counters the hardware models
/// consume plus where in the solve it happened.
#[derive(Debug)]
pub struct IterEvent<'a> {
    pub algo: Algo,
    pub phase: Phase,
    /// Iteration index within the phase.
    pub iter: usize,
    pub stats: &'a IterStats,
    /// Centroids after this iteration's update step.
    pub centroids: &'a Dataset,
}

/// Per-iteration subscription: live logging, metrics streaming, early
/// stop.  Implement it on a struct, or wrap a closure in [`ObserveFn`]
/// (or use [`SolverCtx::observe`]).
pub trait IterObserver {
    fn on_iter(&mut self, ev: &IterEvent<'_>) -> IterFlow;
}

/// `&mut O` observes wherever `O` does — lets callers keep ownership of a
/// stateful observer (e.g. an [`IterTally`]) across a solve.
impl<O: IterObserver + ?Sized> IterObserver for &mut O {
    fn on_iter(&mut self, ev: &IterEvent<'_>) -> IterFlow {
        (**self).on_iter(ev)
    }
}

/// Closure adapter for [`IterObserver`].
pub struct ObserveFn<F>(pub F);

impl<F> IterObserver for ObserveFn<F>
where
    F: FnMut(&IterEvent<'_>) -> IterFlow,
{
    fn on_iter(&mut self, ev: &IterEvent<'_>) -> IterFlow {
        (self.0)(ev)
    }
}

/// Observer that logs every iteration at debug level.
#[derive(Clone, Copy, Debug, Default)]
pub struct IterLog;

impl IterObserver for IterLog {
    fn on_iter(&mut self, ev: &IterEvent<'_>) -> IterFlow {
        log::debug!(
            "{} {:?} iter {}: dist_evals={} node_visits={} moved={:.3e}",
            ev.algo.name(),
            ev.phase,
            ev.iter,
            ev.stats.dist_evals,
            ev.stats.node_visits,
            ev.stats.moved
        );
        IterFlow::Continue
    }
}

/// Observer that tallies the event stream (tests, live metrics) and can
/// stop a run after a fixed number of events.
#[derive(Clone, Debug, Default)]
pub struct IterTally {
    pub events: usize,
    pub dist_evals: u64,
    pub last_moved: f32,
    /// Phase of every event, in arrival order.
    pub phases: Vec<Phase>,
    /// Request a stop once this many events have been seen.
    pub stop_after: Option<usize>,
}

impl IterObserver for IterTally {
    fn on_iter(&mut self, ev: &IterEvent<'_>) -> IterFlow {
        self.events += 1;
        self.dist_evals += ev.stats.dist_evals;
        self.last_moved = ev.stats.moved;
        self.phases.push(ev.phase);
        match self.stop_after {
            Some(cap) if self.events >= cap => IterFlow::Stop,
            _ => IterFlow::Continue,
        }
    }
}

// ---------------------------------------------------------------------------
// The context
// ---------------------------------------------------------------------------

/// The shared substrate a solver runs against: the dataset, a cached
/// kd-tree, an optional injected panel backend, and an optional observer.
/// Reusable across solves — the tree survives, so running Lloyd then
/// filtering then two-level over the same ctx builds the tree once.
pub struct SolverCtx<'a> {
    data: &'a Dataset,
    tree: Option<Arc<KdTree>>,
    backend: Option<Box<dyn PanelBackend + 'a>>,
    observer: Option<Box<dyn IterObserver + 'a>>,
}

impl<'a> SolverCtx<'a> {
    pub fn new(data: &'a Dataset) -> Self {
        Self {
            data,
            tree: None,
            backend: None,
            observer: None,
        }
    }

    pub fn data(&self) -> &'a Dataset {
        self.data
    }

    /// Inject a pre-built kd-tree (e.g. shared across quarters/solvers).
    pub fn with_tree(mut self, tree: Arc<KdTree>) -> Self {
        self.tree = Some(tree);
        self
    }

    /// Inject the panel backend batched solvers compute distances through.
    pub fn with_backend(mut self, backend: impl PanelBackend + 'a) -> Self {
        self.backend = Some(Box::new(backend));
        self
    }

    /// Subscribe an observer to every iteration of subsequent solves.
    pub fn with_observer(mut self, observer: impl IterObserver + 'a) -> Self {
        self.observer = Some(Box::new(observer));
        self
    }

    /// [`with_observer`](Self::with_observer) sugar for closures.
    pub fn observe(self, f: impl FnMut(&IterEvent<'_>) -> IterFlow + 'a) -> Self {
        self.with_observer(ObserveFn(f))
    }

    pub fn has_backend(&self) -> bool {
        self.backend.is_some()
    }

    /// The full-dataset kd-tree, built on first use and cached.
    pub fn tree(&mut self) -> Arc<KdTree> {
        if self.tree.is_none() {
            self.tree = Some(Arc::new(KdTree::build(self.data)));
        }
        Arc::clone(self.tree.as_ref().unwrap())
    }
}

// ---------------------------------------------------------------------------
// The trait + adapters
// ---------------------------------------------------------------------------

/// One execution strategy, runnable against a [`SolverCtx`].
pub trait Solver {
    fn run(&mut self, ctx: &mut SolverCtx<'_>) -> KmeansResult;
}

pub struct LloydSolver {
    pub spec: KmeansSpec,
}

impl Solver for LloydSolver {
    fn run(&mut self, ctx: &mut SolverCtx<'_>) -> KmeansResult {
        let spec = &self.spec;
        spec.validate(ctx.data);
        let data = ctx.data;
        let init = spec.starting_centroids(data);
        let opts = LloydOpts {
            metric: spec.metric,
            tol: spec.tol,
            max_iters: spec.max_iters,
            track_cost: spec.track_cost,
        };
        match ctx.observer.as_mut() {
            Some(obs) => {
                let mut hook = |i: usize, st: &IterStats, c: &Dataset| -> bool {
                    obs.on_iter(&IterEvent {
                        algo: Algo::Lloyd,
                        phase: Phase::Main,
                        iter: i,
                        stats: st,
                        centroids: c,
                    }) == IterFlow::Continue
                };
                lloyd::run_hooked(data, &init, &opts, Some(&mut hook))
            }
            None => lloyd::run_hooked(data, &init, &opts, None),
        }
    }
}

pub struct ElkanSolver {
    pub spec: KmeansSpec,
}

impl Solver for ElkanSolver {
    fn run(&mut self, ctx: &mut SolverCtx<'_>) -> KmeansResult {
        let spec = &self.spec;
        spec.validate(ctx.data);
        let data = ctx.data;
        let init = spec.starting_centroids(data);
        let opts = ElkanOpts {
            metric: spec.metric,
            tol: spec.tol,
            max_iters: spec.max_iters,
        };
        match ctx.observer.as_mut() {
            Some(obs) => {
                let mut hook = |i: usize, st: &IterStats, c: &Dataset| -> bool {
                    obs.on_iter(&IterEvent {
                        algo: Algo::Elkan,
                        phase: Phase::Main,
                        iter: i,
                        stats: st,
                        centroids: c,
                    }) == IterFlow::Continue
                };
                elkan::run_hooked(data, &init, &opts, Some(&mut hook))
            }
            None => elkan::run_hooked(data, &init, &opts, None),
        }
    }
}

pub struct FilterSolver {
    pub spec: KmeansSpec,
}

impl Solver for FilterSolver {
    fn run(&mut self, ctx: &mut SolverCtx<'_>) -> KmeansResult {
        let spec = &self.spec;
        spec.validate(ctx.data);
        let data = ctx.data;
        let tree = ctx.tree();
        let init = spec.starting_centroids(data);
        let opts = FilterOpts {
            metric: spec.metric,
            tol: spec.tol,
            max_iters: spec.max_iters,
            // The recursive engine assigns whole subtrees wholesale and
            // never builds panel jobs, so point-level bounds do not apply.
            bounds: BoundsMode::Off,
        };
        match ctx.observer.as_mut() {
            Some(obs) => {
                let mut hook = |i: usize, st: &IterStats, c: &Dataset| -> bool {
                    obs.on_iter(&IterEvent {
                        algo: Algo::Filter,
                        phase: Phase::Main,
                        iter: i,
                        stats: st,
                        centroids: c,
                    }) == IterFlow::Continue
                };
                filtering::run_hooked(data, &tree, &init, &opts, Some(&mut hook))
            }
            None => filtering::run_hooked(data, &tree, &init, &opts, None),
        }
    }
}

pub struct BatchedFilterSolver {
    pub spec: KmeansSpec,
}

impl Solver for BatchedFilterSolver {
    fn run(&mut self, ctx: &mut SolverCtx<'_>) -> KmeansResult {
        let spec = &self.spec;
        spec.validate(ctx.data);
        let data = ctx.data;
        let tree = ctx.tree();
        let init = spec.starting_centroids(data);
        let opts = FilterOpts {
            metric: spec.metric,
            tol: spec.tol,
            max_iters: spec.max_iters,
            bounds: spec.bounds,
        };
        let mut fallback: Option<ParCpuPanels> = None;
        let mut backend: &mut dyn PanelBackend = match ctx.backend.as_mut() {
            Some(b) => &mut **b,
            None => fallback.insert(spec.default_panels()),
        };
        match ctx.observer.as_mut() {
            Some(obs) => {
                let mut hook = |i: usize, st: &IterStats, c: &Dataset| -> bool {
                    obs.on_iter(&IterEvent {
                        algo: Algo::FilterBatched,
                        phase: Phase::Main,
                        iter: i,
                        stats: st,
                        centroids: c,
                    }) == IterFlow::Continue
                };
                filtering::run_batched_hooked(data, &tree, &init, &opts, &mut backend, Some(&mut hook))
            }
            None => filtering::run_batched_hooked(data, &tree, &init, &opts, &mut backend, None),
        }
    }
}

pub struct TwoLevelSolver {
    pub spec: KmeansSpec,
}

impl Solver for TwoLevelSolver {
    fn run(&mut self, ctx: &mut SolverCtx<'_>) -> KmeansResult {
        let spec = &self.spec;
        spec.validate(ctx.data);
        let data = ctx.data;
        let tree = ctx.tree();
        let opts = TwoLevelOpts {
            metric: spec.metric,
            tol: spec.tol,
            level1_max_iters: spec.max_iters,
            level2_max_iters: spec.level2_max_iters,
            init: spec.init,
            partition: spec.partition,
            seed: spec.seed,
            shards: spec.shards,
        };
        let backend: Option<&mut dyn PanelBackend> = match ctx.backend.as_mut() {
            Some(b) => Some(&mut **b),
            None => None,
        };
        match ctx.observer.as_mut() {
            Some(obs) => {
                let mut hook = |ph: Phase, i: usize, st: &IterStats, c: &Dataset| -> bool {
                    obs.on_iter(&IterEvent {
                        algo: Algo::TwoLevel,
                        phase: ph,
                        iter: i,
                        stats: st,
                        centroids: c,
                    }) == IterFlow::Continue
                };
                twolevel::run_ext(data, spec.k, &opts, Some(&*tree), backend, Some(&mut hook))
            }
            None => twolevel::run_ext(data, spec.k, &opts, Some(&*tree), backend, None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::generate_params;
    use crate::kmeans::filtering::FilterOpts;
    use crate::kmeans::lloyd::LloydOpts;

    #[test]
    fn algo_names_round_trip() {
        for a in Algo::all() {
            assert_eq!(a.name().parse::<Algo>().unwrap(), *a);
        }
        assert!("gpu".parse::<Algo>().is_err());
    }

    #[test]
    fn spec_builder_sets_fields() {
        let spec = KmeansSpec::new(7)
            .algo(Algo::Elkan)
            .metric(Metric::Manhattan)
            .tol(1e-4)
            .max_iters(17)
            .level2_max_iters(3)
            .init(Init::KmeansPlusPlus)
            .partition(Partition::KdTop)
            .shards(6)
            .seed(99)
            .workers(2)
            .track_cost(true)
            .kernel(KernelKind::Auto)
            .bounds(BoundsMode::Auto);
        assert_eq!(spec.k, 7);
        assert_eq!(spec.algo, Algo::Elkan);
        assert_eq!(spec.metric, Metric::Manhattan);
        assert_eq!(spec.tol, 1e-4);
        assert_eq!(spec.max_iters, 17);
        assert_eq!(spec.level2_max_iters, 3);
        assert_eq!(spec.init, Init::KmeansPlusPlus);
        assert_eq!(spec.partition, Partition::KdTop);
        assert_eq!(spec.shards, 6);
        assert_eq!(spec.seed, 99);
        assert_eq!(spec.workers, 2);
        assert!(spec.track_cost);
        assert_eq!(spec.kernel, Some(KernelKind::Auto));
        assert_eq!(spec.bounds, BoundsMode::Auto);
        assert_eq!(KmeansSpec::new(2).kernel, None);
        assert_eq!(KmeansSpec::new(2).bounds, BoundsMode::Off);
    }

    #[test]
    fn lloyd_solver_matches_engine_exactly() {
        let s = generate_params(600, 3, 4, 0.2, 1.0, 11);
        let spec = KmeansSpec::new(4).seed(5);
        let a = spec.solve(&mut SolverCtx::new(&s.data));
        let init = init_centroids(&s.data, 4, Init::UniformSample, Metric::Euclid, 5);
        let b = lloyd::run(&s.data, &init, &LloydOpts::default());
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.assignments, b.assignments);
        assert_eq!(a.stats.iterations(), b.stats.iterations());
    }

    #[test]
    fn filter_solver_matches_engine_exactly() {
        let s = generate_params(700, 3, 5, 0.2, 1.0, 13);
        let spec = KmeansSpec::new(5).algo(Algo::Filter).seed(4);
        let a = spec.solve(&mut SolverCtx::new(&s.data));
        let tree = KdTree::build(&s.data);
        let init = init_centroids(&s.data, 5, Init::UniformSample, Metric::Euclid, 4);
        let b = filtering::run(&s.data, &tree, &init, &FilterOpts::default());
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.assignments, b.assignments);
    }

    #[test]
    fn batched_solver_honors_injected_backend() {
        let s = generate_params(800, 4, 5, 0.2, 1.0, 3);
        let spec = KmeansSpec::new(5).algo(Algo::FilterBatched).seed(8);
        // Scalar injected backend == recursive reference trajectory.
        let a = spec.solve(
            &mut SolverCtx::new(&s.data).with_backend(ParCpuPanels::scalar(2)),
        );
        let b = spec.clone().algo(Algo::Filter).solve(&mut SolverCtx::new(&s.data));
        let oa = a.objective(&s.data, Metric::Euclid);
        let ob = b.objective(&s.data, Metric::Euclid);
        assert!((oa - ob).abs() <= 1e-3 * (1.0 + ob.abs()), "{oa} vs {ob}");
        // And the default (no injection) path also runs.
        let c = spec.solve(&mut SolverCtx::new(&s.data));
        assert_eq!(c.assignments.len(), 800);
    }

    #[test]
    fn two_level_solver_matches_sequential_reference() {
        let s = generate_params(3000, 3, 5, 0.15, 2.0, 33);
        let spec = KmeansSpec::two_level(5).seed(9);
        let a = spec.solve(&mut SolverCtx::new(&s.data));
        let b = twolevel::run(
            &s.data,
            5,
            &TwoLevelOpts {
                seed: 9,
                ..Default::default()
            },
        );
        assert_eq!(a.centroids, b.centroids);
        let ea = a.ext.two_level.as_ref().unwrap();
        let eb = b.ext.two_level.as_ref().unwrap();
        assert_eq!(ea.quarter_sizes, eb.quarter_sizes);
        assert_eq!(
            ea.level1_stats.iter().map(|s| s.iterations()).collect::<Vec<_>>(),
            eb.level1_stats.iter().map(|s| s.iterations()).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn ctx_tree_is_built_once_and_shared() {
        let s = generate_params(500, 2, 3, 0.2, 1.0, 7);
        let mut ctx = SolverCtx::new(&s.data);
        let t1 = ctx.tree();
        let t2 = ctx.tree();
        assert!(Arc::ptr_eq(&t1, &t2));
        // Solvers run against the cached tree without rebuilding.
        let spec = KmeansSpec::new(3).algo(Algo::Filter).seed(2);
        let r = spec.solve(&mut ctx);
        assert_eq!(r.assignments.len(), 500);
        assert!(Arc::ptr_eq(&t1, &ctx.tree()));
    }

    #[test]
    fn observer_sees_every_iteration() {
        let s = generate_params(600, 3, 4, 0.3, 1.0, 19);
        let spec = KmeansSpec::new(4).seed(6);
        let mut tally = IterTally::default();
        let r;
        {
            let mut ctx = SolverCtx::new(&s.data).with_observer(&mut tally);
            r = spec.solve(&mut ctx);
        }
        assert_eq!(tally.events, r.stats.iterations());
        assert_eq!(tally.dist_evals, r.stats.total_dist_evals());
        assert_eq!(tally.last_moved, r.stats.iters.last().unwrap().moved);
        assert!(tally.phases.iter().all(|p| *p == Phase::Main));
    }

    #[test]
    fn observer_can_stop_early() {
        let s = generate_params(800, 3, 5, 0.4, 1.0, 23);
        let spec = KmeansSpec::new(5).seed(3).tol(0.0).max_iters(50);
        let mut tally = IterTally {
            stop_after: Some(2),
            ..Default::default()
        };
        let r;
        {
            let mut ctx = SolverCtx::new(&s.data).with_observer(&mut tally);
            r = spec.solve(&mut ctx);
        }
        assert_eq!(r.stats.iterations(), 2);
        assert!(r.stats.early_stopped);
        assert!(!r.stats.converged);
    }

    #[test]
    fn closure_observer_and_two_level_phases() {
        let s = generate_params(2000, 2, 3, 0.15, 2.0, 41);
        let spec = KmeansSpec::two_level(3).seed(12);
        let events = std::cell::RefCell::new(Vec::new());
        let r = spec.solve(&mut SolverCtx::new(&s.data).observe(|ev: &IterEvent| {
            events.borrow_mut().push(ev.phase);
            IterFlow::Continue
        }));
        let events = events.into_inner();
        assert!(!events.is_empty());
        // All four quarters and the refinement phase report in.
        for q in 0..QUARTERS {
            assert!(
                events.contains(&Phase::Level1 { quarter: q }),
                "no events for quarter {q}: {events:?}"
            );
        }
        assert!(events.contains(&Phase::Level2));
        let l2_events = events.iter().filter(|p| **p == Phase::Level2).count();
        assert_eq!(l2_events, r.stats.iterations());
    }

    #[test]
    #[should_panic(expected = "k out of range")]
    fn oversized_k_is_rejected() {
        let s = generate_params(10, 2, 2, 0.2, 1.0, 1);
        let _ = KmeansSpec::new(11).solve(&mut SolverCtx::new(&s.data));
    }

    #[test]
    #[should_panic(expected = "shards must be >= 1")]
    fn zero_shards_is_rejected() {
        let s = generate_params(100, 2, 2, 0.2, 1.0, 1);
        let _ = KmeansSpec::two_level(2).shards(0).solve(&mut SolverCtx::new(&s.data));
    }

    #[test]
    fn spec_defaults_to_the_paper_quartet() {
        assert_eq!(KmeansSpec::new(3).shards, QUARTERS);
    }

    #[test]
    fn two_level_solver_honors_shards() {
        let s = generate_params(2400, 3, 4, 0.2, 2.0, 21);
        let r = KmeansSpec::two_level(4)
            .shards(8)
            .seed(2)
            .solve(&mut SolverCtx::new(&s.data));
        let ext = r.ext.two_level.as_ref().unwrap();
        assert_eq!(ext.level1_stats.len(), 8);
        assert_eq!(ext.quarter_sizes, vec![300; 8]);
        // shards(P) with P > n/k collapses to the plain-filtering fallback
        // rather than failing.
        let r = KmeansSpec::two_level(4)
            .shards(2400)
            .seed(2)
            .solve(&mut SolverCtx::new(&s.data));
        assert_eq!(r.assignments.len(), 2400);
        let ext = r.ext.two_level.as_ref().unwrap();
        assert!(ext.level1_stats.iter().all(|st| st.iterations() == 0));
    }
}
