//! Distance metrics.
//!
//! The paper's PL pipelines compute Manhattan distance (section 4 item 2);
//! the kd-tree filtering analysis of Kanungo et al. [7] is stated for
//! Euclidean.  Both are supported everywhere; Euclidean distances are kept
//! *squared* end to end (monotone for arg-min and for the filtering
//! `isFarther` test, and it spares the PL/kernel a sqrt — same trick the
//! paper's fixed-point datapath uses).

use std::str::FromStr;

/// Supported distance metrics.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Metric {
    /// Squared L2.
    Euclid,
    /// L1.
    Manhattan,
}

impl Metric {
    pub fn name(self) -> &'static str {
        match self {
            Metric::Euclid => "euclid",
            Metric::Manhattan => "manhattan",
        }
    }

    /// Distance between two equal-length vectors.
    #[inline]
    pub fn dist(self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        match self {
            Metric::Euclid => sq_l2(a, b),
            Metric::Manhattan => l1(a, b),
        }
    }
}

impl FromStr for Metric {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "euclid" | "euclidean" | "l2" => Ok(Metric::Euclid),
            "manhattan" | "l1" => Ok(Metric::Manhattan),
            other => anyhow::bail!("unknown metric `{other}` (euclid|manhattan)"),
        }
    }
}

/// Squared Euclidean distance. 4-way unrolled: this is the software
/// baseline's inner loop, and the unroll is what a compiler would emit for
/// the A53's dual-issue FPU — keeping the *software* cost model honest.
#[inline]
pub fn sq_l2(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let base = i * 4;
        for lane in 0..4 {
            let d = a[base + lane] - b[base + lane];
            acc[lane] += d * d;
        }
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

/// L1 (Manhattan) distance, same unroll structure.
#[inline]
pub fn l1(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let base = i * 4;
        for lane in 0..4 {
            acc[lane] += (a[base + lane] - b[base + lane]).abs();
        }
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += (a[i] - b[i]).abs();
    }
    s
}

/// Index and distance of the nearest centroid (first wins ties — matches
/// the kernel's arg-min).
#[inline]
pub fn nearest(metric: Metric, p: &[f32], centroids: &[f32], k: usize, d: usize) -> (usize, f32) {
    debug_assert_eq!(centroids.len(), k * d);
    let mut best = 0usize;
    let mut best_d = f32::INFINITY;
    for c in 0..k {
        let dist = metric.dist(p, &centroids[c * d..(c + 1) * d]);
        if dist < best_d {
            best_d = dist;
            best = c;
        }
    }
    (best, best_d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hand_values() {
        assert_eq!(sq_l2(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
        assert_eq!(l1(&[0.0, 0.0], &[3.0, -4.0]), 7.0);
        assert_eq!(Metric::Euclid.dist(&[1.0], &[1.0]), 0.0);
        assert_eq!(Metric::Manhattan.dist(&[1.0], &[-1.0]), 2.0);
    }

    #[test]
    fn unroll_matches_naive_for_odd_lengths() {
        for len in 1..=13 {
            let a: Vec<f32> = (0..len).map(|i| i as f32 * 0.7 - 2.0).collect();
            let b: Vec<f32> = (0..len).map(|i| (i as f32).sin()).collect();
            let naive_l2: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
            let naive_l1: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
            assert!((sq_l2(&a, &b) - naive_l2).abs() < 1e-4, "len {len}");
            assert!((l1(&a, &b) - naive_l1).abs() < 1e-4, "len {len}");
        }
    }

    #[test]
    fn nearest_picks_minimum_and_breaks_ties_low() {
        let cents = [0.0f32, 0.0, 10.0, 0.0, 0.0, 0.0]; // c0 == c2
        let (i, d) = nearest(Metric::Euclid, &[1.0, 0.0], &cents, 3, 2);
        assert_eq!(i, 0);
        assert_eq!(d, 1.0);
    }

    #[test]
    fn metric_parsing() {
        assert_eq!("euclid".parse::<Metric>().unwrap(), Metric::Euclid);
        assert_eq!("l2".parse::<Metric>().unwrap(), Metric::Euclid);
        assert_eq!("manhattan".parse::<Metric>().unwrap(), Metric::Manhattan);
        assert!("chebyshev".parse::<Metric>().is_err());
        assert_eq!(Metric::Euclid.name(), "euclid");
    }
}
