//! The trained-model artifact: the fit/predict split's "fit" output.
//!
//! A [`KmeansModel`] is what survives a training run: the final centroids
//! (as a [`Dataset`]), the assignment metric, a snapshot of the
//! [`KmeansSpec`] that produced it, and summary training statistics.  It
//! is the serving-side contract — [`crate::kmeans::predict::Predictor`]
//! and [`crate::serve::ClusterService`] consume models, never live
//! `KmeansResult`s.
//!
//! Persistence goes through the in-tree [`crate::util::json`] writer
//! (the offline crate set has no serde) with an explicit
//! [`MODEL_FORMAT_VERSION`].  Round-trip is lossless: f32 centroid
//! components widen exactly to f64, the JSON writer emits shortest
//! round-trip decimal for f64, and loading narrows back — so
//! `save` → `load` reproduces the centroid buffer *bitwise* (the
//! guarantee `tests/model_predict.rs` pins, and what makes loaded-model
//! predictions identical to in-memory ones).  The `seed` is carried as a
//! string so full-width `u64` values survive the f64 number pipeline.

use super::solver::KmeansSpec;
use super::{KmeansResult, Metric};
use crate::data::Dataset;
use crate::util::json::Json;
use std::path::Path;

/// Version tag written into every model file; bump on schema change.
pub const MODEL_FORMAT_VERSION: usize = 1;

/// The `"kind"` discriminator in the JSON header.
const MODEL_KIND: &str = "kmeans-model";

/// Summary statistics of the training run that produced a model.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainStats {
    /// Training-set size.
    pub n: usize,
    /// Iterations of the main loop (level-2 for the two-level scheme).
    pub iterations: usize,
    pub converged: bool,
    pub early_stopped: bool,
    /// Total distance evaluations, including level-1 work for two-level.
    pub dist_evals: u64,
    /// Exact k-means objective of the final model on the training set.
    pub objective: Option<f64>,
}

/// A trained clustering model: centroids + metric + provenance.
#[derive(Clone, Debug)]
pub struct KmeansModel {
    /// Final centroids, `[k, d]`.
    pub centroids: Dataset,
    /// Metric assignments were (and must be) computed under.
    pub metric: Metric,
    /// Snapshot of the spec that trained this model (its `start` seeds are
    /// not persisted — a loaded spec re-fits from `init`/`seed`).
    pub spec: KmeansSpec,
    pub train: TrainStats,
}

impl KmeansModel {
    /// Build the artifact from a finished solve.  Computes the exact
    /// objective of the final centroids over `data` (one O(n·k·d) pass),
    /// so the artifact carries its own quality evidence.
    pub fn from_fit(data: &Dataset, result: &KmeansResult, spec: &KmeansSpec) -> Self {
        // Whole-run distance work: the result's own stats cover only the
        // level-2 refinement for two-level — fold level-1 in, same as the
        // CLI report.
        let mut dist_evals = result.stats.total_dist_evals();
        if let Some(ext) = &result.ext.two_level {
            for l1 in &ext.level1_stats {
                dist_evals += l1.total_dist_evals();
            }
        }
        Self {
            centroids: result.centroids.clone(),
            metric: spec.metric,
            spec: spec.clone(),
            train: TrainStats {
                n: data.len(),
                iterations: result.stats.iterations(),
                converged: result.stats.converged,
                early_stopped: result.stats.early_stopped,
                dist_evals,
                objective: Some(result.objective(data, spec.metric)),
            },
        }
    }

    /// Number of clusters.
    #[inline]
    pub fn k(&self) -> usize {
        self.centroids.len()
    }

    /// Dimensionality the model expects of query points.
    #[inline]
    pub fn dims(&self) -> usize {
        self.centroids.dims()
    }

    // -- serialization ------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let cents: Vec<Json> = self
            .centroids
            .flat()
            .iter()
            .map(|&v| Json::num(v as f64))
            .collect();
        Json::obj(vec![
            ("format_version", Json::num(MODEL_FORMAT_VERSION as f64)),
            ("kind", Json::str(MODEL_KIND)),
            ("k", Json::num(self.k() as f64)),
            ("d", Json::num(self.dims() as f64)),
            ("metric", Json::str(self.metric.name())),
            ("centroids", Json::Arr(cents)),
            ("spec", spec_to_json(&self.spec)),
            ("train", train_to_json(&self.train)),
        ])
    }

    pub fn from_json(root: &Json) -> anyhow::Result<Self> {
        let version = root
            .req("format_version")?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("bad format_version"))?;
        anyhow::ensure!(
            version == MODEL_FORMAT_VERSION,
            "unsupported model format version {version} (this build reads {MODEL_FORMAT_VERSION})"
        );
        let kind = root.req("kind")?.as_str().unwrap_or_default();
        anyhow::ensure!(kind == MODEL_KIND, "not a kmeans model file (kind=`{kind}`)");
        let k = root.req("k")?.as_usize().ok_or_else(|| anyhow::anyhow!("bad k"))?;
        let d = root.req("d")?.as_usize().ok_or_else(|| anyhow::anyhow!("bad d"))?;
        anyhow::ensure!(k >= 1 && d >= 1, "degenerate model shape k={k} d={d}");
        let metric: Metric = root
            .req("metric")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("metric must be a string"))?
            .parse()?;
        let arr = root
            .req("centroids")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("centroids must be an array"))?;
        anyhow::ensure!(
            arr.len() == k * d,
            "centroid buffer length {} != k*d = {}",
            arr.len(),
            k * d
        );
        let mut flat = Vec::with_capacity(k * d);
        for v in arr {
            let x = v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("non-numeric centroid component"))?
                as f32;
            anyhow::ensure!(x.is_finite(), "non-finite centroid component");
            flat.push(x);
        }
        let spec = spec_from_json(root.req("spec")?)?;
        anyhow::ensure!(spec.metric == metric, "spec/model metric disagree");
        let train = train_from_json(root.req("train")?)?;
        Ok(Self {
            centroids: Dataset::from_flat(k, d, flat),
            metric,
            spec,
            train,
        })
    }

    /// Write the model to `path` (single JSON document).
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.centroids.flat().iter().all(|v| v.is_finite()),
            "refusing to save a model with non-finite centroids"
        );
        std::fs::write(path, format!("{}\n", self.to_json()))
            .map_err(|e| anyhow::anyhow!("cannot write model {}: {e}", path.display()))
    }

    /// Load a model saved by [`save`](Self::save).
    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read model {}: {e}", path.display()))?;
        let root = Json::parse(&src)?;
        Self::from_json(&root)
    }
}

fn spec_to_json(spec: &KmeansSpec) -> Json {
    let mut fields = vec![
        ("algo", Json::str(spec.algo.name())),
        ("k", Json::num(spec.k as f64)),
        ("metric", Json::str(spec.metric.name())),
        ("tol", Json::num(spec.tol as f64)),
        ("max_iters", Json::num(spec.max_iters as f64)),
        ("level2_max_iters", Json::num(spec.level2_max_iters as f64)),
        ("init", Json::str(spec.init.name())),
        ("partition", Json::str(spec.partition.name())),
        ("shards", Json::num(spec.shards as f64)),
        // Stringly so full-width u64 seeds survive the f64 number path.
        ("seed", Json::str(spec.seed.to_string())),
        ("workers", Json::num(spec.workers as f64)),
        ("track_cost", Json::Bool(spec.track_cost)),
    ];
    // `kernel` is additive like `shards`: written only when the spec pins
    // a tier, so documents from pre-kernel builds stay byte-identical.
    if let Some(kind) = spec.kernel {
        fields.push(("kernel", Json::str(kind.name())));
    }
    // `bounds` follows the same additive rule: only non-default modes are
    // written, so every pre-bounds document stays byte-identical.
    if spec.bounds != crate::kmeans::bounds::BoundsMode::Off {
        fields.push(("bounds", Json::str(spec.bounds.name())));
    }
    Json::obj(fields)
}

fn spec_from_json(j: &Json) -> anyhow::Result<KmeansSpec> {
    let req_str = |key: &str| -> anyhow::Result<&str> {
        j.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("spec field `{key}` must be a string"))
    };
    let req_usize = |key: &str| -> anyhow::Result<usize> {
        j.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("spec field `{key}` must be a non-negative integer"))
    };
    let seed: u64 = req_str("seed")?
        .parse()
        .map_err(|e| anyhow::anyhow!("bad spec seed: {e}"))?;
    let tol = j
        .req("tol")?
        .as_f64()
        .ok_or_else(|| anyhow::anyhow!("spec field `tol` must be a number"))? as f32;
    // `shards` is additive (format v1 stayed): files written before the
    // shard plane default to the paper's quartet.
    let shards = match j.get("shards") {
        Some(v) => v
            .as_usize()
            .filter(|&p| p >= 1)
            .ok_or_else(|| anyhow::anyhow!("spec field `shards` must be a positive integer"))?,
        None => crate::kmeans::shard::DEFAULT_SHARDS,
    };
    let mut spec = KmeansSpec::new(req_usize("k")?)
        .algo(req_str("algo")?.parse()?)
        .metric(req_str("metric")?.parse()?)
        .tol(tol)
        .max_iters(req_usize("max_iters")?)
        .level2_max_iters(req_usize("level2_max_iters")?)
        .init(req_str("init")?.parse()?)
        .partition(req_str("partition")?.parse()?)
        .shards(shards)
        .seed(seed)
        .workers(req_usize("workers")?)
        .track_cost(j.req("track_cost")?.as_bool().unwrap_or(false));
    // Absent `kernel` means "legacy default", not an error: the key only
    // exists in documents whose spec pinned a tier explicitly.
    if let Some(v) = j.get("kernel") {
        let name = v
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("spec field `kernel` must be a string"))?;
        let kind = name
            .parse()
            .map_err(|e| anyhow::anyhow!("bad spec kernel: {e}"))?;
        spec = spec.kernel(kind);
    }
    // Absent `bounds` means `Off` (the pre-bounds default).
    if let Some(v) = j.get("bounds") {
        let name = v
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("spec field `bounds` must be a string"))?;
        let mode = name
            .parse()
            .map_err(|e| anyhow::anyhow!("bad spec bounds: {e}"))?;
        spec = spec.bounds(mode);
    }
    Ok(spec)
}

fn train_to_json(t: &TrainStats) -> Json {
    Json::obj(vec![
        ("n", Json::num(t.n as f64)),
        ("iterations", Json::num(t.iterations as f64)),
        ("converged", Json::Bool(t.converged)),
        ("early_stopped", Json::Bool(t.early_stopped)),
        ("dist_evals", Json::num(t.dist_evals as f64)),
        (
            "objective",
            match t.objective {
                Some(o) => Json::num(o),
                None => Json::Null,
            },
        ),
    ])
}

fn train_from_json(j: &Json) -> anyhow::Result<TrainStats> {
    let req_usize = |key: &str| -> anyhow::Result<usize> {
        j.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("train field `{key}` must be a non-negative integer"))
    };
    Ok(TrainStats {
        n: req_usize("n")?,
        iterations: req_usize("iterations")?,
        converged: j.req("converged")?.as_bool().unwrap_or(false),
        early_stopped: j.req("early_stopped")?.as_bool().unwrap_or(false),
        dist_evals: req_usize("dist_evals")? as u64,
        objective: match j.req("objective")? {
            Json::Null => None,
            v => Some(
                v.as_f64()
                    .ok_or_else(|| anyhow::anyhow!("train objective must be a number or null"))?,
            ),
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::generate_params;
    use crate::kmeans::init::Init;
    use crate::kmeans::solver::{Algo, SolverCtx};
    use crate::kmeans::twolevel::Partition;

    fn fitted(metric: Metric) -> (crate::data::synthetic::Synthetic, KmeansModel) {
        let s = generate_params(600, 3, 4, 0.1, 2.0, 11);
        let spec = KmeansSpec::new(4)
            .metric(metric)
            .init(Init::KmeansPlusPlus)
            .partition(Partition::KdTop)
            .seed(5)
            .tol(1e-6);
        let model = spec.fit(&mut SolverCtx::new(&s.data));
        (s, model)
    }

    #[test]
    fn fit_produces_consistent_artifact() {
        let (s, model) = fitted(Metric::Euclid);
        assert_eq!(model.k(), 4);
        assert_eq!(model.dims(), 3);
        assert_eq!(model.train.n, 600);
        assert!(model.train.iterations >= 1);
        assert!(model.train.dist_evals > 0);
        let obj = model.train.objective.unwrap();
        assert!(obj.is_finite() && obj >= 0.0);
        // The recorded objective is the final centroids' objective.
        let mut acc = 0f64;
        for p in s.data.iter() {
            let best = model
                .centroids
                .iter()
                .map(|c| model.metric.dist(p, c) as f64)
                .fold(f64::INFINITY, f64::min);
            acc += best;
        }
        assert!((acc - obj).abs() <= 1e-6 * (1.0 + obj.abs()), "{acc} vs {obj}");
    }

    #[test]
    fn json_round_trip_is_bitwise_for_both_metrics() {
        for metric in [Metric::Euclid, Metric::Manhattan] {
            let (_, model) = fitted(metric);
            let back = KmeansModel::from_json(&Json::parse(&model.to_json().to_string()).unwrap())
                .unwrap();
            // The round-trip guarantee: centroid buffer is bit-identical.
            assert_eq!(model.centroids, back.centroids, "{metric:?}");
            assert_eq!(model.metric, back.metric);
            assert_eq!(model.train, back.train);
            assert_eq!(model.spec.k, back.spec.k);
            assert_eq!(model.spec.algo, back.spec.algo);
            assert_eq!(model.spec.metric, back.spec.metric);
            assert_eq!(model.spec.tol, back.spec.tol);
            assert_eq!(model.spec.init, back.spec.init);
            assert_eq!(model.spec.partition, back.spec.partition);
            assert_eq!(model.spec.shards, back.spec.shards);
            assert_eq!(model.spec.seed, back.spec.seed);
            assert_eq!(model.spec.workers, back.spec.workers);
        }
    }

    #[test]
    fn shards_round_trips_and_defaults_when_absent() {
        let (_, mut model) = fitted(Metric::Euclid);
        model.spec.shards = 16;
        let back =
            KmeansModel::from_json(&Json::parse(&model.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.spec.shards, 16);
        // Pre-shard-plane documents carry no `shards` key: default to 4.
        let doc = model.to_json().to_string().replace("\"shards\":16,", "");
        assert!(!doc.contains("shards"));
        let back = KmeansModel::from_json(&Json::parse(&doc).unwrap()).unwrap();
        assert_eq!(back.spec.shards, 4);
        // Zero shards is rejected, not deferred to a later panic.
        let doc = model.to_json().to_string().replace("\"shards\":16,", "\"shards\":0,");
        assert!(KmeansModel::from_json(&Json::parse(&doc).unwrap()).is_err());
    }

    #[test]
    fn kernel_round_trips_and_is_optional() {
        use crate::kmeans::panel::KernelKind;
        let (_, mut model) = fitted(Metric::Euclid);
        // Default specs carry no `kernel` key at all (additive format).
        assert!(!model.to_json().to_string().contains("kernel"));
        let back =
            KmeansModel::from_json(&Json::parse(&model.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.spec.kernel, None);
        // A pinned tier survives the round trip.
        model.spec.kernel = Some(KernelKind::Simd);
        let back =
            KmeansModel::from_json(&Json::parse(&model.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.spec.kernel, Some(KernelKind::Simd));
        // Unknown tiers are rejected at load, not deferred to a panic.
        let doc = model.to_json().to_string().replace("\"kernel\":\"simd\"", "\"kernel\":\"warp\"");
        assert!(KmeansModel::from_json(&Json::parse(&doc).unwrap()).is_err());
    }

    #[test]
    fn seed_survives_full_u64_width() {
        let (_, mut model) = fitted(Metric::Euclid);
        model.spec.seed = u64::MAX - 7;
        let back =
            KmeansModel::from_json(&Json::parse(&model.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.spec.seed, u64::MAX - 7);
    }

    #[test]
    fn save_load_file_round_trip() {
        let (_, model) = fitted(Metric::Manhattan);
        let dir = std::env::temp_dir().join("muchswift_model_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.json");
        model.save(&path).unwrap();
        let back = KmeansModel::load(&path).unwrap();
        assert_eq!(model.centroids, back.centroids);
        assert_eq!(model.metric, back.metric);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_corrupt_documents() {
        let (_, model) = fitted(Metric::Euclid);
        let good = model.to_json().to_string();
        // Wrong version.
        let bad = good.replace("\"format_version\":1", "\"format_version\":9");
        assert!(KmeansModel::from_json(&Json::parse(&bad).unwrap()).is_err());
        // Wrong kind.
        let bad = good.replace("kmeans-model", "resnet");
        assert!(KmeansModel::from_json(&Json::parse(&bad).unwrap()).is_err());
        // Truncated centroid buffer (k*d mismatch).
        let bad = good.replace("\"k\":4", "\"k\":5");
        assert!(KmeansModel::from_json(&Json::parse(&bad).unwrap()).is_err());
        // Not JSON at all.
        assert!(KmeansModel::load(Path::new("/nonexistent/model.json")).is_err());
    }

    #[test]
    fn two_level_fit_folds_level1_work() {
        let s = generate_params(2000, 3, 4, 0.1, 2.0, 3);
        let spec = KmeansSpec::two_level(4).seed(2);
        let mut ctx = SolverCtx::new(&s.data);
        let r = spec.solve(&mut ctx);
        let model = KmeansModel::from_fit(&s.data, &r, &spec);
        assert_eq!(model.spec.algo, Algo::TwoLevel);
        // dist_evals covers level-1 + level-2, so it exceeds the result's
        // own (level-2-only) total.
        assert!(model.train.dist_evals > r.stats.total_dist_evals());
    }
}
