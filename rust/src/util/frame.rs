//! Length-prefixed binary frame codec with integrity checksums — the
//! transport substrate of the remote shard plane (`kmeans::remote`).
//!
//! A frame on the wire is:
//!
//! ```text
//! magic   u32 le   FRAME_MAGIC ("MSWF") — rejects non-protocol peers fast
//! kind    u8       message discriminant (owned by the protocol layer)
//! len     u32 le   payload byte length (<= MAX_FRAME_LEN)
//! payload len bytes
//! crc     u32 le   CRC-32 (IEEE) over kind + len + payload
//! ```
//!
//! The codec is deliberately paranoid: bad magic, oversized lengths,
//! truncated streams and checksum mismatches are all *errors*, never
//! panics — a worker must survive a port scanner, and a coordinator must
//! survive a half-dead worker.  Payload encoding/decoding goes through
//! [`ByteWriter`]/[`ByteReader`], which keep every multi-byte value
//! little-endian and every f32/f64 as exact IEEE bits (the remote shard
//! plane's bitwise-parity guarantee rides on this).
//!
//! This module is a `pallas-lint` panic-hygiene surface: production code
//! here must not contain `unwrap`/`expect`/panicking macros or unchecked
//! indexing — hostile bytes must only ever surface as [`FrameError`].
//! The clippy denies below backstop the custom lint.

#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::fmt;
use std::io::{self, Read, Write};

/// Frame preamble: `"MSWF"` little-endian (MUCH-SWIFT wire format).
pub const FRAME_MAGIC: u32 = u32::from_le_bytes(*b"MSWF");

/// Upper bound on a single frame's payload (256 MiB).  A shard slice of
/// the largest workload the repo benches (1M × 15 f32) is ~60 MB; anything
/// past this bound is a corrupt or hostile length prefix, not data.
pub const MAX_FRAME_LEN: u32 = 256 * 1024 * 1024;

/// Bytes of framing overhead around a payload (magic + kind + len + crc).
pub const FRAME_OVERHEAD: usize = 4 + 1 + 4 + 4;

/// Everything that can go wrong reading a frame.  `Io` covers transport
/// failures; the rest are protocol violations the reader refuses cleanly.
#[derive(Debug)]
pub enum FrameError {
    Io(io::Error),
    /// The stream did not start with [`FRAME_MAGIC`].
    BadMagic(u32),
    /// Declared payload length exceeds [`MAX_FRAME_LEN`].
    Oversized(u32),
    /// The stream ended inside a frame.
    Truncated,
    /// CRC mismatch between header+payload and the trailer.
    BadChecksum { want: u32, got: u32 },
    /// A payload decoder ran past the end or hit an invalid encoding.
    Malformed(&'static str),
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "frame io error: {e}"),
            FrameError::BadMagic(m) => {
                write!(f, "bad frame magic {m:#010x} (want {FRAME_MAGIC:#010x})")
            }
            FrameError::Oversized(n) => {
                write!(f, "frame payload of {n} bytes exceeds the {MAX_FRAME_LEN} cap")
            }
            FrameError::Truncated => write!(f, "stream ended inside a frame"),
            FrameError::BadChecksum { want, got } => {
                write!(f, "frame checksum mismatch (want {want:#010x}, got {got:#010x})")
            }
            FrameError::Malformed(what) => write!(f, "malformed frame payload: {what}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<io::Error> for FrameError {
    fn from(e: io::Error) -> Self {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            FrameError::Truncated
        } else {
            FrameError::Io(e)
        }
    }
}

// ---------------------------------------------------------------------------
// CRC-32 (IEEE 802.3), table built at compile time
// ---------------------------------------------------------------------------

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut bit = 0;
        while bit < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            bit += 1;
        }
        // pallas-lint: allow(panic-hygiene) i is bounded by the `while i < 256` guard, table has 256 slots
        table[i] = c;
        i += 1;
    }
    table
}

const CRC_TABLE: [u32; 256] = crc_table();

/// CRC-32 (IEEE) of `bytes` — the frame trailer checksum.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc::new();
    c.update(bytes);
    c.finish()
}

/// Streaming CRC over multiple slices (header then payload) without
/// concatenating them.
struct Crc(u32);

impl Crc {
    fn new() -> Self {
        Crc(!0)
    }

    fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            // pallas-lint: allow(panic-hygiene) index is masked to 0..=255, CRC_TABLE has 256 entries
            self.0 = CRC_TABLE[((self.0 ^ b as u32) & 0xFF) as usize] ^ (self.0 >> 8);
        }
    }

    fn finish(self) -> u32 {
        !self.0
    }
}

// ---------------------------------------------------------------------------
// Frame read/write
// ---------------------------------------------------------------------------

/// Write one frame.  Returns the total bytes put on the wire (payload +
/// [`FRAME_OVERHEAD`]) for traffic accounting.  An over-cap payload is
/// an `InvalidInput` *error*, not a panic — on the client it must
/// surface as a counted local fallback, never abort the run.
pub fn write_frame(w: &mut impl Write, kind: u8, payload: &[u8]) -> io::Result<usize> {
    if payload.len() > MAX_FRAME_LEN as usize {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!(
                "frame payload of {} bytes exceeds the {MAX_FRAME_LEN}-byte cap",
                payload.len()
            ),
        ));
    }
    let len = payload.len() as u32;
    let mut crc = Crc::new();
    crc.update(&[kind]);
    crc.update(&len.to_le_bytes());
    crc.update(payload);
    w.write_all(&FRAME_MAGIC.to_le_bytes())?;
    w.write_all(&[kind])?;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(payload)?;
    w.write_all(&crc.finish().to_le_bytes())?;
    w.flush()?;
    Ok(payload.len() + FRAME_OVERHEAD)
}

/// Read one frame, validating magic, length bound and checksum.  Returns
/// `(kind, payload, wire_bytes)`.  Never panics on hostile input.
pub fn read_frame(r: &mut impl Read) -> Result<(u8, Vec<u8>, usize), FrameError> {
    let mut head = [0u8; 9];
    r.read_exact(&mut head)?;
    let mut hr = ByteReader::new(&head);
    let magic = hr.take_u32()?;
    if magic != FRAME_MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    let kind = hr.take_u8()?;
    let len = hr.take_u32()?;
    if len > MAX_FRAME_LEN {
        return Err(FrameError::Oversized(len));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    let mut trailer = [0u8; 4];
    r.read_exact(&mut trailer)?;
    let got = u32::from_le_bytes(trailer);
    let mut crc = Crc::new();
    // Byte-identical to hashing head[4..]: kind, then the len prefix.
    crc.update(&[kind]);
    crc.update(&len.to_le_bytes());
    crc.update(&payload);
    let want = crc.finish();
    if want != got {
        return Err(FrameError::BadChecksum { want, got });
    }
    Ok((kind, payload, len as usize + FRAME_OVERHEAD))
}

// ---------------------------------------------------------------------------
// Payload cursors
// ---------------------------------------------------------------------------

/// Little-endian payload builder.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        Self {
            buf: Vec::with_capacity(cap),
        }
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Exact IEEE bits — the bitwise-parity carrier for f32 data.
    pub fn put_f32_bits(&mut self, v: f32) {
        self.put_u32(v.to_bits());
    }

    pub fn put_f64_bits(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    /// Length-prefixed UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Length-prefixed f32 vector, exact bits.
    pub fn put_f32_slice(&mut self, vs: &[f32]) {
        self.put_u32(vs.len() as u32);
        for &v in vs {
            self.put_f32_bits(v);
        }
    }

    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// Little-endian payload cursor; every `take_*` bounds-checks and returns
/// [`FrameError::Malformed`] instead of panicking.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], FrameError> {
        let end = self.pos.checked_add(n).ok_or(FrameError::Malformed(what))?;
        let s = self.buf.get(self.pos..end).ok_or(FrameError::Malformed(what))?;
        self.pos = end;
        Ok(s)
    }

    pub fn take_u8(&mut self) -> Result<u8, FrameError> {
        let b = self.take(1, "u8")?;
        b.first().copied().ok_or(FrameError::Malformed("u8"))
    }

    pub fn take_u32(&mut self) -> Result<u32, FrameError> {
        let b: [u8; 4] = self
            .take(4, "u32")?
            .try_into()
            .map_err(|_| FrameError::Malformed("u32"))?;
        Ok(u32::from_le_bytes(b))
    }

    pub fn take_u64(&mut self) -> Result<u64, FrameError> {
        let b: [u8; 8] = self
            .take(8, "u64")?
            .try_into()
            .map_err(|_| FrameError::Malformed("u64"))?;
        Ok(u64::from_le_bytes(b))
    }

    pub fn take_f32_bits(&mut self) -> Result<f32, FrameError> {
        Ok(f32::from_bits(self.take_u32()?))
    }

    pub fn take_f64_bits(&mut self) -> Result<f64, FrameError> {
        Ok(f64::from_bits(self.take_u64()?))
    }

    pub fn take_str(&mut self) -> Result<String, FrameError> {
        let n = self.take_u32()? as usize;
        let b = self.take(n, "string bytes")?;
        String::from_utf8(b.to_vec()).map_err(|_| FrameError::Malformed("non-utf8 string"))
    }

    pub fn take_f32_vec(&mut self) -> Result<Vec<f32>, FrameError> {
        let n = self.take_u32()? as usize;
        // Bound the allocation by what the payload can actually hold.
        if self.remaining() < n.saturating_mul(4) {
            return Err(FrameError::Malformed("f32 vector length"));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.take_f32_bits()?);
        }
        Ok(out)
    }

    /// Decoders call this last: trailing garbage is a protocol violation.
    pub fn finish(&self) -> Result<(), FrameError> {
        if self.remaining() != 0 {
            return Err(FrameError::Malformed("trailing bytes"));
        }
        Ok(())
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;
    use std::io::Cursor;

    #[test]
    fn crc32_known_vectors() {
        // The classic IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn frame_round_trips_random_payloads() {
        let mut rng = Xoshiro256pp::seed_from_u64(0xF4A3);
        // Miri interprets every byte; a smaller sweep keeps the CI Miri
        // job inside its time budget while native runs keep full depth.
        let cases = if cfg!(miri) { 8 } else { 50 };
        let max_len = if cfg!(miri) { 256 } else { 4096 };
        for case in 0..cases {
            let len = (rng.next_u64() % max_len) as usize;
            let payload: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let kind = (case % 7) as u8;
            let mut wire = Vec::new();
            let tx = write_frame(&mut wire, kind, &payload).unwrap();
            assert_eq!(tx, wire.len());
            assert_eq!(tx, payload.len() + FRAME_OVERHEAD);
            let (k, p, rx) = read_frame(&mut Cursor::new(&wire)).unwrap();
            assert_eq!(k, kind);
            assert_eq!(p, payload);
            assert_eq!(rx, tx);
        }
    }

    #[test]
    fn back_to_back_frames_read_in_order() {
        let mut wire = Vec::new();
        write_frame(&mut wire, 1, b"first").unwrap();
        write_frame(&mut wire, 2, b"second").unwrap();
        let mut cur = Cursor::new(&wire);
        let (k1, p1, _) = read_frame(&mut cur).unwrap();
        let (k2, p2, _) = read_frame(&mut cur).unwrap();
        assert_eq!((k1, p1.as_slice()), (1, &b"first"[..]));
        assert_eq!((k2, p2.as_slice()), (2, &b"second"[..]));
    }

    #[test]
    fn truncated_frames_error_not_panic() {
        let mut wire = Vec::new();
        write_frame(&mut wire, 3, b"some payload bytes").unwrap();
        // Every proper prefix must fail cleanly with Truncated.
        for cut in 0..wire.len() {
            let err = read_frame(&mut Cursor::new(&wire[..cut])).unwrap_err();
            assert!(
                matches!(err, FrameError::Truncated),
                "cut at {cut}: {err}"
            );
        }
    }

    #[test]
    fn bad_magic_rejected() {
        let mut wire = Vec::new();
        write_frame(&mut wire, 0, b"x").unwrap();
        wire[0] ^= 0xFF;
        let err = read_frame(&mut Cursor::new(&wire)).unwrap_err();
        assert!(matches!(err, FrameError::BadMagic(_)), "{err}");
        // An HTTP-ish stream is also just bad magic.
        let err = read_frame(&mut Cursor::new(b"GET / HTTP/1.1\r\n")).unwrap_err();
        assert!(matches!(err, FrameError::BadMagic(_)), "{err}");
    }

    #[test]
    fn oversized_length_rejected_before_allocation() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&FRAME_MAGIC.to_le_bytes());
        wire.push(1);
        wire.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut Cursor::new(&wire)).unwrap_err();
        assert!(matches!(err, FrameError::Oversized(n) if n == u32::MAX), "{err}");
    }

    #[test]
    fn oversized_write_is_an_error_not_a_panic() {
        // The write side must refuse cleanly too: on the coordinator a
        // too-large shard slice has to become a local fallback, not a
        // panic in a puller thread.
        let huge = vec![0u8; MAX_FRAME_LEN as usize + 1];
        let mut sink = Vec::new();
        let err = write_frame(&mut sink, 1, &huge).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidInput);
        assert!(sink.is_empty(), "nothing may reach the wire");
    }

    #[test]
    fn corruption_anywhere_fails_the_checksum() {
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let n = if cfg!(miri) { 48 } else { 256 };
        let payload: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        let mut clean = Vec::new();
        write_frame(&mut clean, 5, &payload).unwrap();
        // Flip one byte at a time past the magic (magic corruption is the
        // BadMagic case; kind/len/payload/crc corruption is checksum or,
        // for the length field, oversize/truncation).
        for i in 4..clean.len() {
            let mut wire = clean.clone();
            wire[i] ^= 0x40;
            let res = read_frame(&mut Cursor::new(&wire));
            assert!(res.is_err(), "flip at {i} was accepted");
        }
    }

    #[test]
    fn byte_cursor_round_trips_exact_bits() {
        let mut w = ByteWriter::new();
        w.put_u8(9);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX - 1);
        w.put_f32_bits(-0.0);
        w.put_f32_bits(f32::NAN);
        w.put_f64_bits(1.0 / 3.0);
        w.put_str("héllo");
        w.put_f32_slice(&[1.5, -2.25, 3.0e-40]);
        let buf = w.into_vec();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.take_u8().unwrap(), 9);
        assert_eq!(r.take_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.take_u64().unwrap(), u64::MAX - 1);
        let z = r.take_f32_bits().unwrap();
        assert_eq!(z.to_bits(), (-0.0f32).to_bits());
        assert!(r.take_f32_bits().unwrap().is_nan());
        assert_eq!(r.take_f64_bits().unwrap(), 1.0 / 3.0);
        assert_eq!(r.take_str().unwrap(), "héllo");
        let vs = r.take_f32_vec().unwrap();
        assert_eq!(vs, vec![1.5, -2.25, 3.0e-40]);
        r.finish().unwrap();
    }

    #[test]
    fn byte_cursor_rejects_short_and_trailing() {
        let mut r = ByteReader::new(&[1, 2]);
        assert!(matches!(r.take_u32(), Err(FrameError::Malformed(_))));
        // Lying length prefixes are bounded by the buffer.
        let mut w = ByteWriter::new();
        w.put_u32(1_000_000);
        let buf = w.into_vec();
        let mut r = ByteReader::new(&buf);
        assert!(matches!(r.take_f32_vec(), Err(FrameError::Malformed(_))));
        let mut r = ByteReader::new(&buf);
        assert!(matches!(r.take_str(), Err(FrameError::Malformed(_))));
        // Trailing garbage is flagged by finish().
        let mut r = ByteReader::new(&[0]);
        assert!(r.finish().is_err());
        r.take_u8().unwrap();
        r.finish().unwrap();
    }
}
