//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so this module provides the two
//! generators the rest of the crate needs: [`SplitMix64`] for seeding and
//! [`Xoshiro256pp`] (xoshiro256++, Blackman & Vigna) as the workhorse
//! stream, plus uniform/normal helpers used by the synthetic dataset
//! generator (the paper evaluates on normal-distributed clusters with
//! varying standard deviation).
//!
//! Everything here is deterministic given the seed — experiments are
//! reproducible bit-for-bit, which EXPERIMENTS.md relies on.

/// SplitMix64: used to expand a single `u64` seed into generator state.
///
/// Reference: Steele, Lea, Flood — "Fast splittable pseudorandom number
/// generators", OOPSLA 2014.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 — fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 per the reference implementation's guidance.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// The jump function: equivalent to 2^128 `next_u64` calls. Used to
    /// give each of the four coordinator workers a non-overlapping stream.
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] = [
            0x180EC6D33CFD0ABA,
            0xD5A61266F0C9392C,
            0xA9582618E03FC9AA,
            0x39ABDC4529B1661C,
        ];
        let mut s = [0u64; 4];
        for j in JUMP {
            for b in 0..64 {
                if (j & (1u64 << b)) != 0 {
                    s[0] ^= self.s[0];
                    s[1] ^= self.s[1];
                    s[2] ^= self.s[2];
                    s[3] ^= self.s[3];
                }
                self.next_u64();
            }
        }
        self.s = s;
    }

    /// Uniform in `[0, 1)` with 53 bits of mantissa entropy.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in `[0, n)` via Lemire's unbiased method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box–Muller (polar form avoided; the plain form
    /// keeps the stream consumption deterministic: exactly 2 draws/sample).
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        // Guard u1 away from 0 so ln() stays finite.
        let u1 = (self.next_f64()).max(1e-300);
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Normal with mean/stddev.
    #[inline]
    pub fn normal(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal_f32()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below_usize(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference outputs for seed 1234567 (checked against the
        // published C implementation).
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_seeds() {
        let mut a = Xoshiro256pp::seed_from_u64(42);
        let mut b = Xoshiro256pp::seed_from_u64(42);
        let mut c = Xoshiro256pp::seed_from_u64(43);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Xoshiro256pp::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
            let y = r.uniform_f32(-3.0, 5.0);
            assert!((-3.0..5.0).contains(&y));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Xoshiro256pp::seed_from_u64(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256pp::seed_from_u64(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.normal(2.0, 3.0) as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
        assert!((var - 9.0).abs() < 0.2, "var {var}");
    }

    #[test]
    fn jump_decorrelates_streams() {
        let mut a = Xoshiro256pp::seed_from_u64(5);
        let mut b = a.clone();
        b.jump();
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::seed_from_u64(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Xoshiro256pp::seed_from_u64(1);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 20);
        assert!(t.iter().all(|&i| i < 50));
    }
}
