//! Small statistics helpers for the bench harness and experiment reports:
//! mean/median/MAD, geometric mean (for speedup aggregation, as the paper's
//! "on average 8.5x/12x/210x" figures are ratio averages), and a simple
//! online accumulator.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1); 0 for len < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (averaging the middle pair for even lengths); 0 for empty.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Median absolute deviation — the robust spread measure the bench harness
/// reports alongside the median.
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = median(xs);
    let dev: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&dev)
}

/// Geometric mean; requires strictly positive inputs, 0 for empty.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    assert!(
        xs.iter().all(|&x| x > 0.0),
        "geomean requires positive inputs"
    );
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Percentile via linear interpolation, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// [`percentile`] over an already-ascending-sorted slice — callers
/// reading several percentiles sort once and use this.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (rank - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// Online mean/min/max/count accumulator (used by coordinator metrics).
#[derive(Clone, Debug, Default)]
pub struct Accum {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Accum {
    pub fn add(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        self.sum += x;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn merge(&mut self, other: &Accum) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Human-readable engineering formatting for cycle counts / times.
pub fn eng(x: f64) -> String {
    let ax = x.abs();
    if ax >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if ax >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if ax >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{x:.2}")
    }
}

/// Seconds with adaptive units.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.2909944).abs() < 1e-6);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(mad(&[1.0, 1.0, 2.0, 2.0, 4.0]), 1.0);
    }

    #[test]
    fn geomean_of_ratios() {
        let r = geomean(&[2.0, 8.0]);
        assert!((r - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 0.02);
        // The pre-sorted form agrees with the sorting form.
        let unsorted = [5.0, 1.0, 9.0, 3.0];
        let mut sorted = unsorted.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [0.0, 25.0, 50.0, 90.0, 100.0] {
            assert_eq!(percentile(&unsorted, p), percentile_sorted(&sorted, p));
        }
        assert_eq!(percentile_sorted(&[], 50.0), 0.0);
    }

    #[test]
    fn accum_and_merge() {
        let mut a = Accum::default();
        for x in [3.0, 1.0, 2.0] {
            a.add(x);
        }
        assert_eq!(a.n, 3);
        assert_eq!(a.min, 1.0);
        assert_eq!(a.max, 3.0);
        assert_eq!(a.mean(), 2.0);

        let mut b = Accum::default();
        b.add(10.0);
        a.merge(&b);
        assert_eq!(a.n, 4);
        assert_eq!(a.max, 10.0);

        let mut c = Accum::default();
        c.merge(&a);
        assert_eq!(c.n, 4);
    }

    #[test]
    fn formatting() {
        assert_eq!(eng(1234.0), "1.23k");
        assert_eq!(eng(2.5e7), "25.00M");
        assert_eq!(eng(3.1e9), "3.10G");
        assert_eq!(eng(12.0), "12.00");
        assert_eq!(fmt_secs(2.0), "2.000 s");
        assert_eq!(fmt_secs(0.0025), "2.500 ms");
        assert_eq!(fmt_secs(2.5e-6), "2.500 µs");
        assert_eq!(fmt_secs(5e-9), "5.0 ns");
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(mad(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
