//! Utility substrates.
//!
//! The build environment is fully offline with a small vendored crate set
//! (no `rand`, `serde`, `clap`, `proptest`, `criterion`), so this module
//! implements the pieces the rest of the crate needs from scratch — each
//! documented in DESIGN.md under "Offline-toolchain substitutions".

pub mod bench;
pub mod cli;
pub mod fault;
pub mod frame;
pub mod json;
pub mod logger;
pub mod proptest;
pub mod rng;
pub mod stats;
