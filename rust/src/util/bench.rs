//! Minimal benchmarking harness (the offline crate set has no
//! `criterion`).  `cargo bench` targets use `harness = false` and call
//! [`Bench`]: warmup, adaptive iteration count targeting a wall-time
//! budget, median + MAD + min reporting, and a machine-readable line for
//! EXPERIMENTS.md extraction.

use super::json::Json;
use super::stats::{fmt_secs, mad, median};
use std::path::Path;
use std::time::{Duration, Instant};

/// Benchmark runner configuration.
#[derive(Clone, Debug)]
pub struct Bench {
    /// Target total measurement time per benchmark.
    pub budget: Duration,
    /// Minimum measured samples.
    pub min_samples: usize,
    /// Maximum measured samples.
    pub max_samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            budget: Duration::from_secs(3),
            min_samples: 5,
            max_samples: 100,
        }
    }
}

/// One benchmark's result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub median_s: f64,
    pub mad_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "bench {:<40} median {:>12}  mad {:>12}  min {:>12}  ({} samples)",
            self.name,
            fmt_secs(self.median_s),
            fmt_secs(self.mad_s),
            fmt_secs(self.min_s),
            self.samples
        )
    }

    /// Machine-readable form (times in integer nanoseconds).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("median_ns", Json::num((self.median_s * 1e9).round())),
            ("mad_ns", Json::num((self.mad_s * 1e9).round())),
            ("min_ns", Json::num((self.min_s * 1e9).round())),
            ("samples", Json::num(self.samples as f64)),
        ])
    }
}

/// Write a bench run as a machine-readable JSON report (name → stats) —
/// the perf-trajectory artifact `benches/hotpath.rs` checks in as
/// `BENCH_hotpath.json`.
pub fn write_json(path: &Path, results: &[BenchResult]) -> std::io::Result<()> {
    let benches = Json::Obj(
        results
            .iter()
            .map(|r| (r.name.clone(), r.to_json()))
            .collect(),
    );
    let root = Json::obj(vec![
        ("format_version", Json::num(1.0)),
        ("unit", Json::str("ns")),
        // A real measured report; the checked-in schema placeholder says
        // `true` here, and CI fails the bench-smoke step if that marker
        // survives the run.
        ("placeholder", Json::Bool(false)),
        ("benches", benches),
    ]);
    std::fs::write(path, format!("{root}\n"))
}

/// Measurement-budget override from `MUCHSWIFT_BENCH_BUDGET_MS` (the CI
/// smoke run sets 200 ms), falling back to `default`.
pub fn env_budget(default: Duration) -> Duration {
    parse_budget_ms(std::env::var("MUCHSWIFT_BENCH_BUDGET_MS").ok().as_deref(), default)
}

/// Pure parsing core of [`env_budget`] (unit-testable without touching
/// the process environment, which is unsafe to mutate in threaded tests).
fn parse_budget_ms(val: Option<&str>, default: Duration) -> Duration {
    val.and_then(|s| s.parse::<u64>().ok())
        .map(Duration::from_millis)
        .unwrap_or(default)
}

impl Bench {
    /// Quick profile for slow end-to-end benches.
    pub fn quick() -> Self {
        Self {
            budget: Duration::from_secs(2),
            min_samples: 3,
            max_samples: 20,
        }
    }

    /// Measure `f`, which performs one unit of work per call.  The return
    /// value of `f` is black-boxed to keep the optimizer honest.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // Warmup: one call, then estimate per-call cost.
        let t0 = Instant::now();
        std::hint::black_box(f());
        let first = t0.elapsed();

        let est = first.max(Duration::from_nanos(100));
        let planned = (self.budget.as_secs_f64() / est.as_secs_f64()).ceil() as usize;
        let samples = planned.clamp(self.min_samples, self.max_samples);

        let mut times = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t = Instant::now();
            std::hint::black_box(f());
            times.push(t.elapsed().as_secs_f64());
        }
        let r = BenchResult {
            name: name.to_string(),
            samples,
            median_s: median(&times),
            mad_s: mad(&times),
            min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
        };
        println!("{}", r.line());
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let b = Bench {
            budget: Duration::from_millis(50),
            min_samples: 3,
            max_samples: 10,
        };
        let r = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.samples >= 3 && r.samples <= 10);
        assert!(r.median_s > 0.0);
        assert!(r.min_s <= r.median_s);
        assert!(r.line().contains("spin"));
    }

    #[test]
    fn json_report_is_parseable() {
        let r = BenchResult {
            name: "unit_bench".into(),
            samples: 3,
            median_s: 1.5e-3,
            mad_s: 1e-5,
            min_s: 1.4e-3,
        };
        let dir = std::env::temp_dir().join("muchswift_bench_json");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("report.json");
        write_json(&path, &[r]).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("format_version").unwrap().as_usize().unwrap(), 1);
        // Measured reports clear the placeholder marker CI gates on.
        assert!(!parsed.get("placeholder").unwrap().as_bool().unwrap());
        let b = parsed.get("benches").unwrap().get("unit_bench").unwrap();
        assert_eq!(b.get("median_ns").unwrap().as_f64().unwrap(), 1.5e6);
        assert_eq!(b.get("samples").unwrap().as_usize().unwrap(), 3);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn budget_parsing_reads_override_and_falls_back() {
        // Exercises the pure core — mutating the real environment from a
        // threaded test harness races glibc's getenv.
        let default = Duration::from_millis(123);
        assert_eq!(parse_budget_ms(Some("57"), default), Duration::from_millis(57));
        assert_eq!(parse_budget_ms(Some("not-a-number"), default), default);
        assert_eq!(parse_budget_ms(Some(""), default), default);
        assert_eq!(parse_budget_ms(None, default), default);
    }

    #[test]
    fn fast_functions_hit_max_samples() {
        let b = Bench {
            budget: Duration::from_millis(20),
            min_samples: 2,
            max_samples: 7,
        };
        let r = b.run("noop", || 1 + 1);
        assert_eq!(r.samples, 7);
    }
}
