//! Minimal benchmarking harness (the offline crate set has no
//! `criterion`).  `cargo bench` targets use `harness = false` and call
//! [`Bench`]: warmup, adaptive iteration count targeting a wall-time
//! budget, median + MAD + min reporting, and a machine-readable line for
//! EXPERIMENTS.md extraction.

use super::stats::{fmt_secs, mad, median};
use std::time::{Duration, Instant};

/// Benchmark runner configuration.
#[derive(Clone, Debug)]
pub struct Bench {
    /// Target total measurement time per benchmark.
    pub budget: Duration,
    /// Minimum measured samples.
    pub min_samples: usize,
    /// Maximum measured samples.
    pub max_samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            budget: Duration::from_secs(3),
            min_samples: 5,
            max_samples: 100,
        }
    }
}

/// One benchmark's result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub median_s: f64,
    pub mad_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "bench {:<40} median {:>12}  mad {:>12}  min {:>12}  ({} samples)",
            self.name,
            fmt_secs(self.median_s),
            fmt_secs(self.mad_s),
            fmt_secs(self.min_s),
            self.samples
        )
    }
}

impl Bench {
    /// Quick profile for slow end-to-end benches.
    pub fn quick() -> Self {
        Self {
            budget: Duration::from_secs(2),
            min_samples: 3,
            max_samples: 20,
        }
    }

    /// Measure `f`, which performs one unit of work per call.  The return
    /// value of `f` is black-boxed to keep the optimizer honest.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // Warmup: one call, then estimate per-call cost.
        let t0 = Instant::now();
        std::hint::black_box(f());
        let first = t0.elapsed();

        let est = first.max(Duration::from_nanos(100));
        let planned = (self.budget.as_secs_f64() / est.as_secs_f64()).ceil() as usize;
        let samples = planned.clamp(self.min_samples, self.max_samples);

        let mut times = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t = Instant::now();
            std::hint::black_box(f());
            times.push(t.elapsed().as_secs_f64());
        }
        let r = BenchResult {
            name: name.to_string(),
            samples,
            median_s: median(&times),
            mad_s: mad(&times),
            min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
        };
        println!("{}", r.line());
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let b = Bench {
            budget: Duration::from_millis(50),
            min_samples: 3,
            max_samples: 10,
        };
        let r = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.samples >= 3 && r.samples <= 10);
        assert!(r.median_s > 0.0);
        assert!(r.min_s <= r.median_s);
        assert!(r.line().contains("spin"));
    }

    #[test]
    fn fast_functions_hit_max_samples() {
        let b = Bench {
            budget: Duration::from_millis(20),
            min_samples: 2,
            max_samples: 7,
        };
        let r = b.run("noop", || 1 + 1);
        assert_eq!(r.samples, 7);
    }
}
