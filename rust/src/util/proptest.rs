//! Seeded property-testing runner (the offline crate set has no `proptest`).
//!
//! Provides the shape the coordinator/kd-tree invariant tests need:
//! a deterministic RNG per case, a configurable case count, and on failure a
//! "shrinking-lite" pass that retries the failing case with progressively
//! smaller size hints so the reported counterexample is small.
//!
//! ```ignore
//! proptest(64, |g| {
//!     let n = g.size(1, 500);
//!     let pts = g.vec_f32(n * 2, -10.0, 10.0);
//!     // ... assert invariant, returning Err(String) on violation
//!     Ok(())
//! });
//! ```

use super::rng::Xoshiro256pp;

/// Per-case generator handed to the property closure.
pub struct Gen {
    pub rng: Xoshiro256pp,
    /// Size multiplier in (0, 1]; shrink passes lower it.
    pub scale: f64,
    pub case: usize,
}

impl Gen {
    /// A size in `[lo, hi]`, scaled down during shrinking.
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = hi - lo;
        let scaled = ((span as f64) * self.scale).ceil() as usize;
        lo + self.rng.below_usize(scaled.max(1).min(span + 1))
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_f32(lo, hi)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below_usize(hi - lo + 1)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below_usize(xs.len())]
    }
}

/// Run `cases` random cases of `prop` with a fixed master seed.
///
/// Panics with the case seed and message on the first failure, after
/// attempting to reproduce it at smaller sizes (shrinking-lite): the
/// smallest scale that still fails is what gets reported.
pub fn proptest<F>(cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    proptest_seeded(0xC0FFEE, cases, prop)
}

/// Like [`proptest`] but with an explicit master seed (so a failing seed
/// printed by a previous run can be replayed directly).
pub fn proptest_seeded<F>(master_seed: u64, cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = master_seed ^ ((case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let run = |scale: f64| -> Result<(), String> {
            let mut g = Gen {
                rng: Xoshiro256pp::seed_from_u64(case_seed),
                scale,
                case,
            };
            prop(&mut g)
        };
        if let Err(first_msg) = run(1.0) {
            // Shrinking-lite: same seed, smaller size hints.
            let mut best = (1.0, first_msg);
            for &scale in &[0.5, 0.25, 0.1, 0.05, 0.01] {
                if let Err(msg) = run(scale) {
                    best = (scale, msg);
                }
            }
            panic!(
                "property failed (case {case}, seed {case_seed:#x}, scale {}): {}",
                best.0, best.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::RefCell::new(&mut count);
        proptest(32, |g| {
            **counter.borrow_mut() += 1;
            let n = g.size(1, 100);
            if n >= 1 && n <= 100 {
                Ok(())
            } else {
                Err(format!("size out of bounds: {n}"))
            }
        });
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        proptest(16, |g| {
            let n = g.size(1, 1000);
            if n < 900 {
                Ok(())
            } else {
                Err(format!("n too big: {n}"))
            }
        });
    }

    #[test]
    fn shrinking_reduces_reported_size() {
        // Capture the panic message and check the scale went below 1.
        let result = std::panic::catch_unwind(|| {
            proptest_seeded(7, 8, |g| {
                let n = g.size(1, 10_000);
                if n == 0 {
                    Ok(())
                } else {
                    Err(format!("always fails, n={n}"))
                }
            });
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("expected failure"),
        };
        assert!(msg.contains("scale 0.01"), "expected smallest scale: {msg}");
    }

    #[test]
    fn gen_helpers_in_bounds() {
        proptest(64, |g| {
            let v = g.f32_in(-2.0, 2.0);
            if !(-2.0..2.0).contains(&v) {
                return Err(format!("f32_in out of range: {v}"));
            }
            let u = g.usize_in(3, 9);
            if !(3..=9).contains(&u) {
                return Err(format!("usize_in out of range: {u}"));
            }
            let xs = g.vec_f32(10, 0.0, 1.0);
            if xs.len() != 10 {
                return Err("vec len".into());
            }
            let choice = *g.pick(&[1, 2, 3]);
            if !(1..=3).contains(&choice) {
                return Err("pick".into());
            }
            Ok(())
        });
    }
}
