//! Tiny declarative CLI argument parser (the offline crate set has no
//! `clap`).  Supports subcommands, `--flag`, `--key value` / `--key=value`,
//! typed accessors with defaults, and auto-generated `--help` text.

use std::collections::BTreeMap;

#[derive(Debug)]
pub enum CliError {
    UnknownOption(String),
    MissingValue(String),
    BadValue(String, String, String),
    UnexpectedPositional(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownOption(o) => write!(f, "unknown option `{o}` (try --help)"),
            CliError::MissingValue(o) => write!(f, "option `--{o}` expects a value"),
            CliError::BadValue(o, v, why) => {
                write!(f, "invalid value for `--{o}`: `{v}` ({why})")
            }
            CliError::UnexpectedPositional(a) => {
                write!(f, "unexpected positional argument `{a}`")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// One declared option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
    /// Repeatable `--key value` collecting into a list (zero or more).
    pub is_multi: bool,
}

/// A declarative command: name, description, options.
#[derive(Clone, Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
    pub positional: Option<(&'static str, &'static str)>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            opts: Vec::new(),
            positional: None,
        }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: Some(default),
            is_flag: false,
            is_multi: false,
        });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: false,
            is_multi: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: true,
            is_multi: false,
        });
        self
    }

    /// A repeatable `--key value` option: every occurrence appends to a
    /// list read back with [`Matches::all`] (zero occurrences = empty).
    pub fn multi(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: false,
            is_multi: true,
        });
        self
    }

    pub fn pos(mut self, name: &'static str, help: &'static str) -> Self {
        self.positional = Some((name, help));
        self
    }

    /// Parse `args` (without the program/subcommand prefix).
    pub fn parse(&self, args: &[String]) -> Result<Matches, CliError> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: BTreeMap<String, bool> = BTreeMap::new();
        let mut multis: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut positional = None;

        for o in &self.opts {
            if o.is_flag {
                flags.insert(o.name.to_string(), false);
            } else if o.is_multi {
                multis.insert(o.name.to_string(), Vec::new());
            } else if let Some(d) = o.default {
                values.insert(o.name.to_string(), d.to_string());
            }
        }

        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| CliError::UnknownOption(a.clone()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(CliError::BadValue(
                            key.clone(),
                            inline.unwrap(),
                            "flag takes no value".into(),
                        ));
                    }
                    flags.insert(key, true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(key.clone()))?
                        }
                    };
                    if spec.is_multi {
                        multis.get_mut(&key).expect("multi pre-seeded").push(v);
                    } else {
                        values.insert(key, v);
                    }
                }
            } else if self.positional.is_some() && positional.is_none() {
                positional = Some(a.clone());
            } else {
                return Err(CliError::UnexpectedPositional(a.clone()));
            }
            i += 1;
        }

        // Required options (no default; multis are zero-or-more) must be
        // present.
        for o in &self.opts {
            if !o.is_flag && !o.is_multi && o.default.is_none() && !values.contains_key(o.name) {
                return Err(CliError::MissingValue(o.name.to_string()));
            }
        }

        Ok(Matches {
            command: self.name,
            values,
            flags,
            multis,
            positional,
        })
    }

    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        if let Some((p, h)) = self.positional {
            s.push_str(&format!("  <{p}>  {h}\n"));
        }
        for o in &self.opts {
            let d = match (o.is_flag, o.is_multi, o.default) {
                (true, _, _) => "".to_string(),
                (_, true, _) => " [repeatable]".to_string(),
                (_, _, Some(d)) => format!(" [default: {d}]"),
                (_, _, None) => " [required]".to_string(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, d));
        }
        s
    }
}

/// Parsed results with typed accessors.
#[derive(Debug)]
pub struct Matches {
    pub command: &'static str,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    multis: BTreeMap<String, Vec<String>>,
    pub positional: Option<String>,
}

impl Matches {
    pub fn str(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    /// Every value a repeatable option collected, in argument order.
    pub fn all(&self, name: &str) -> &[String] {
        self.multis
            .get(name)
            .unwrap_or_else(|| panic!("multi option --{name} not declared"))
    }

    pub fn flag(&self, name: &str) -> bool {
        *self
            .flags
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} not declared"))
    }

    pub fn parse_as<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.str(name);
        raw.parse::<T>()
            .map_err(|e| CliError::BadValue(name.to_string(), raw.to_string(), e.to_string()))
    }

    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        self.parse_as(name)
    }

    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        self.parse_as(name)
    }

    pub fn u64(&self, name: &str) -> Result<u64, CliError> {
        self.parse_as(name)
    }

    /// Comma-separated list of T.
    pub fn list<T: std::str::FromStr>(&self, name: &str) -> Result<Vec<T>, CliError>
    where
        T::Err: std::fmt::Display,
    {
        self.str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim().parse::<T>().map_err(|e| {
                    CliError::BadValue(name.to_string(), s.to_string(), e.to_string())
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("cluster", "run clustering")
            .opt("n", "1000", "number of points")
            .opt("k", "8", "clusters")
            .req("arch", "architecture")
            .flag("verbose", "chatty output")
            .pos("input", "input file")
    }

    #[test]
    fn defaults_and_overrides() {
        let m = cmd().parse(&args(&["--arch", "muchswift"])).unwrap();
        assert_eq!(m.usize("n").unwrap(), 1000);
        assert_eq!(m.str("arch"), "muchswift");
        assert!(!m.flag("verbose"));

        let m = cmd()
            .parse(&args(&["--arch=sw", "--n", "42", "--verbose", "file.csv"]))
            .unwrap();
        assert_eq!(m.usize("n").unwrap(), 42);
        assert!(m.flag("verbose"));
        assert_eq!(m.positional.as_deref(), Some("file.csv"));
    }

    #[test]
    fn error_cases() {
        assert!(matches!(
            cmd().parse(&args(&["--arch", "x", "--bogus"])),
            Err(CliError::UnknownOption(_))
        ));
        assert!(matches!(
            cmd().parse(&args(&[])),
            Err(CliError::MissingValue(_))
        ));
        assert!(matches!(
            cmd().parse(&args(&["--arch"])),
            Err(CliError::MissingValue(_))
        ));
        assert!(matches!(
            cmd().parse(&args(&["--arch", "x", "--n", "abc"]))
                .and_then(|m| m.usize("n")),
            Err(CliError::BadValue(..))
        ));
        assert!(matches!(
            cmd().parse(&args(&["--arch", "x", "a.csv", "b.csv"])),
            Err(CliError::UnexpectedPositional(_))
        ));
    }

    #[test]
    fn cluster_like_command_negative_paths() {
        // Mirrors the real `cluster` surface (typed numeric opts + a
        // flag) so the solver-spec flags have parse-level coverage.
        let c = Command::new("cluster", "unified solver")
            .opt("algo", "two-level", "algorithm")
            .opt("tol", "1e-6", "tolerance")
            .opt("max-iters", "100", "iteration cap")
            .opt("workers", "4", "threads")
            .flag("trace", "stream iterations");
        // A flag given a value via `=` is rejected.
        assert!(matches!(
            c.parse(&args(&["--trace=yes"])),
            Err(CliError::BadValue(..))
        ));
        // Dangling value at end of args.
        assert!(matches!(
            c.parse(&args(&["--tol"])),
            Err(CliError::MissingValue(_))
        ));
        // Non-numeric values surface as BadValue from the typed accessors.
        let m = c.parse(&args(&["--max-iters", "many"])).unwrap();
        assert!(matches!(m.usize("max-iters"), Err(CliError::BadValue(..))));
        let m = c.parse(&args(&["--tol", "tiny"])).unwrap();
        assert!(matches!(m.f64("tol"), Err(CliError::BadValue(..))));
        // Misspelled option names don't silently fall through.
        assert!(matches!(
            c.parse(&args(&["--algos", "lloyd"])),
            Err(CliError::UnknownOption(_))
        ));
        // Defaults survive partial overrides.
        let m = c.parse(&args(&["--workers", "2"])).unwrap();
        assert_eq!(m.usize("workers").unwrap(), 2);
        assert_eq!(m.str("algo"), "two-level");
        assert!((m.f64("tol").unwrap() - 1e-6).abs() < 1e-12);
        assert!(!m.flag("trace"));
    }

    #[test]
    fn metric_and_solver_enum_values_negative_paths() {
        // Test-only reach up into `kmeans` (production code in `util`
        // never imports it): pins that every enum the `cluster`/`fit`/
        // `predict` surfaces parse through `parse_as` rejects bad values
        // as `BadValue` with the offending token, not a panic.
        use crate::kmeans::init::Init;
        use crate::kmeans::solver::Algo;
        use crate::kmeans::twolevel::Partition;
        use crate::kmeans::Metric;
        let c = Command::new("fit", "fit/predict surface")
            .opt("metric", "euclid", "euclid|l2|manhattan|l1")
            .opt("algo", "lloyd", "algorithm")
            .opt("init", "uniform", "seeding")
            .opt("partition", "round-robin", "quartering")
            .opt("out", "", "labels path");
        // The l1/l2 aliases the CLI documents parse to the right metrics.
        let m = c.parse(&args(&["--metric", "l2"])).unwrap();
        assert_eq!(m.parse_as::<Metric>("metric").unwrap(), Metric::Euclid);
        let m = c.parse(&args(&["--metric=l1"])).unwrap();
        assert_eq!(m.parse_as::<Metric>("metric").unwrap(), Metric::Manhattan);
        // Bad metric: BadValue carrying option name, token and reason.
        let m = c.parse(&args(&["--metric", "cosine"])).unwrap();
        match m.parse_as::<Metric>("metric") {
            Err(CliError::BadValue(name, val, why)) => {
                assert_eq!(name, "metric");
                assert_eq!(val, "cosine");
                assert!(why.contains("unknown metric"), "{why}");
            }
            other => panic!("expected BadValue, got {other:?}"),
        }
        // Same shape for the other solver enums.
        let m = c.parse(&args(&["--algo", "gpu"])).unwrap();
        assert!(matches!(m.parse_as::<Algo>("algo"), Err(CliError::BadValue(..))));
        let m = c.parse(&args(&["--init", "random"])).unwrap();
        assert!(matches!(m.parse_as::<Init>("init"), Err(CliError::BadValue(..))));
        let m = c.parse(&args(&["--partition", "octants"])).unwrap();
        assert!(matches!(
            m.parse_as::<Partition>("partition"),
            Err(CliError::BadValue(..))
        ));
        // The empty-string default for --out (the "skip" sentinel) survives.
        let m = c.parse(&args(&[])).unwrap();
        assert_eq!(m.str("out"), "");
    }

    #[test]
    fn shards_option_negative_paths() {
        // Mirrors the `cluster`/`fit` --shards surface: the parser hands
        // main.rs a usize (0 included — the P >= 1 and P <= n range checks
        // live at the command layer, exercised by the binary round-trip
        // tests), and non-numeric / negative tokens surface as BadValue.
        let c = Command::new("cluster", "unified solver")
            .opt("shards", "4", "level-1 shard count P (1 <= P <= n)");
        let m = c.parse(&args(&[])).unwrap();
        assert_eq!(m.usize("shards").unwrap(), 4, "defaults to the paper quartet");
        let m = c.parse(&args(&["--shards", "16"])).unwrap();
        assert_eq!(m.usize("shards").unwrap(), 16);
        // P=0 parses (range-checked downstream against n).
        let m = c.parse(&args(&["--shards", "0"])).unwrap();
        assert_eq!(m.usize("shards").unwrap(), 0);
        // Negative and non-numeric P are BadValue with the offending token.
        let m = c.parse(&args(&["--shards", "-4"])).unwrap();
        match m.usize("shards") {
            Err(CliError::BadValue(name, val, _)) => {
                assert_eq!(name, "shards");
                assert_eq!(val, "-4");
            }
            other => panic!("expected BadValue, got {other:?}"),
        }
        let m = c.parse(&args(&["--shards", "four"])).unwrap();
        assert!(matches!(m.usize("shards"), Err(CliError::BadValue(..))));
        // Dangling value.
        assert!(matches!(
            c.parse(&args(&["--shards"])),
            Err(CliError::MissingValue(_))
        ));
        // The contiguous partition name the shard plane added parses.
        use crate::kmeans::twolevel::Partition;
        let c = Command::new("cluster", "partitions")
            .opt("partition", "round-robin", "round-robin|kd-top|contiguous");
        let m = c.parse(&args(&["--partition", "contiguous"])).unwrap();
        assert_eq!(
            m.parse_as::<Partition>("partition").unwrap(),
            Partition::Contiguous
        );
    }

    #[test]
    fn multi_options_accumulate_in_order() {
        // Mirrors the `cluster --remote host:port` surface.
        let c = Command::new("cluster", "unified solver")
            .opt("shards", "4", "level-1 shard count")
            .multi("remote", "shard-worker endpoint (host:port)");
        // Zero occurrences: empty list, not an error.
        let m = c.parse(&args(&[])).unwrap();
        assert!(m.all("remote").is_empty());
        // Repeats accumulate in argument order; `=` form mixes in.
        let m = c
            .parse(&args(&[
                "--remote",
                "127.0.0.1:7601",
                "--shards",
                "8",
                "--remote=127.0.0.1:7602",
                "--remote",
                "127.0.0.1:7601",
            ]))
            .unwrap();
        assert_eq!(
            m.all("remote"),
            &[
                "127.0.0.1:7601".to_string(),
                "127.0.0.1:7602".to_string(),
                "127.0.0.1:7601".to_string()
            ]
        );
        assert_eq!(m.usize("shards").unwrap(), 8);
        // Dangling value still errors.
        assert!(matches!(
            c.parse(&args(&["--remote"])),
            Err(CliError::MissingValue(_))
        ));
        // Help marks it repeatable.
        assert!(c.help().contains("[repeatable]"), "{}", c.help());
    }

    #[test]
    fn lists() {
        let c = Command::new("x", "y").opt("ks", "2,4,8", "cluster sweep");
        let m = c.parse(&args(&[])).unwrap();
        assert_eq!(m.list::<usize>("ks").unwrap(), vec![2, 4, 8]);
        let m = c.parse(&args(&["--ks", "1, 3 ,5"])).unwrap();
        assert_eq!(m.list::<usize>("ks").unwrap(), vec![1, 3, 5]);
    }

    #[test]
    fn kernel_option_negative_paths() {
        // Mirrors the `predict`/`shard-worker` --kernel surface: all four
        // tier names parse, anything else is BadValue carrying the
        // offending token, and strict `resolve()` cleanly rejects `simd`
        // on hosts without a supported feature set instead of silently
        // demoting (the fail-fast path the binary takes before touching
        // the filesystem or binding a socket).
        use crate::kmeans::panel::{KernelKind, PanelKernel};
        let c = Command::new("predict", "assign against a model")
            .opt("kernel", "scalar", "scalar|blocked|simd|auto panel kernel");
        let m = c.parse(&args(&[])).unwrap();
        assert_eq!(m.parse_as::<KernelKind>("kernel").unwrap(), KernelKind::Scalar);
        for (tok, want) in [
            ("blocked", KernelKind::Blocked),
            ("simd", KernelKind::Simd),
            ("auto", KernelKind::Auto),
        ] {
            let m = c.parse(&args(&["--kernel", tok])).unwrap();
            assert_eq!(m.parse_as::<KernelKind>("kernel").unwrap(), want);
        }
        let m = c.parse(&args(&["--kernel", "warp"])).unwrap();
        match m.parse_as::<KernelKind>("kernel") {
            Err(CliError::BadValue(name, val, why)) => {
                assert_eq!(name, "kernel");
                assert_eq!(val, "warp");
                assert!(why.contains("unknown kernel"), "{why}");
            }
            other => panic!("expected BadValue, got {other:?}"),
        }
        // Strict resolve: the portable tiers always succeed, `auto` never
        // fails (it demotes), and `simd` either resolves to the SIMD
        // kernel or errors naming the tier so the operator sees why.
        assert_eq!(KernelKind::Scalar.resolve(), Ok(PanelKernel::Scalar));
        assert_eq!(KernelKind::Blocked.resolve(), Ok(PanelKernel::Blocked));
        assert!(KernelKind::Auto.resolve().is_ok());
        match KernelKind::Simd.resolve() {
            Ok(k) => assert_eq!(k, PanelKernel::Simd),
            Err(why) => assert!(why.contains("simd"), "{why}"),
        }
    }

    #[test]
    fn help_mentions_everything() {
        let h = cmd().help();
        for needle in ["--n", "--arch", "--verbose", "<input>", "required", "default: 1000"] {
            assert!(h.contains(needle), "help missing {needle}: {h}");
        }
    }
}
