//! Minimal JSON parser / writer.
//!
//! The offline crate set has no `serde`/`serde_json`; this module covers
//! what the crate needs: parsing `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) and emitting experiment reports.  It is a
//! strict, recursive-descent implementation of RFC 8259 minus some escape
//! exotica (`\u` surrogate pairs are handled; bignums are parsed as f64).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.  Object keys keep sorted order via `BTreeMap`,
/// which also makes emitted JSON deterministic for golden tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: src.as_bytes(),
            pos: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` access that returns `None` for non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Required-field access with a readable error.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field `{key}`"))
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("lone low surrogate"));
                        } else {
                            out.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = match c {
                        0x00..=0x7F => 0,
                        0xC0..=0xDF => 1,
                        0xE0..=0xEF => 2,
                        0xF0..=0xF7 => 3,
                        _ => return Err(self.err("invalid utf-8 byte")),
                    };
                    if len == 0 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        for _ in 0..len {
                            self.bump().ok_or_else(|| self.err("truncated utf-8"))?;
                        }
                        let s = std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    /// Compact serialization (deterministic: object keys are sorted).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write_into(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    fn write_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                // `-0.0` must not take the integer fast path (`as i64`
                // yields 0, destroying the sign bit the model artifact's
                // bitwise round-trip guarantee relies on); "-0" is valid
                // JSON and parses back to -0.0.
                if n.fract() == 0.0 && n.abs() < 1e15 && !(*n == 0.0 && n.is_sign_negative()) {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => escape_into(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_into(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(k, out);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }

    /// Convenience constructors for report emission.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-2.5e3").unwrap(), Json::Num(-2500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64().unwrap(), 2.0);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\Aé");
        // surrogate pair: U+1F600
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn parse_errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#""\ud800x""#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"n":-3,"o":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
    }

    #[test]
    fn negative_zero_survives_round_trip() {
        let v = Json::Num(-0.0);
        assert_eq!(v.to_string(), "-0");
        let back = Json::parse("-0").unwrap().as_f64().unwrap();
        assert_eq!(back, 0.0); // f64 equality: -0.0 == 0.0 ...
        assert!(back.is_sign_negative()); // ... but the sign bit survived
        // Positive zero still takes the integer fast path.
        assert_eq!(Json::Num(0.0).to_string(), "0");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "format_version": 1,
          "pad_sentinel": 1e+17,
          "entries": [{"name": "lloyd_euclid_n1024_d4_k8", "n": 1024, "d": 4, "k": 8}]
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("format_version").unwrap().as_usize().unwrap(), 1);
        assert_eq!(v.get("pad_sentinel").unwrap().as_f64().unwrap(), 1e17);
        let e = &v.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("n").unwrap().as_usize().unwrap(), 1024);
    }

    #[test]
    fn accessor_type_mismatches() {
        let v = Json::parse("[1]").unwrap();
        assert!(v.as_obj().is_none());
        assert!(v.get("x").is_none());
        assert!(Json::Num(1.5).as_usize().is_none());
        assert!(Json::Num(-1.0).as_usize().is_none());
    }
}
