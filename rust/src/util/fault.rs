//! Deterministic fault injection for the remote shard plane.
//!
//! `ChaosProxy` is an in-process TCP proxy that sits between the coordinator
//! and a `shard-worker` and executes a **seeded, fully deterministic fault
//! schedule**. Each accepted connection `i` is assigned
//! `schedule.fault_for(i)` (round-robin over the schedule), so the same
//! schedule — whether written out explicitly or derived from a u64 seed —
//! always produces the same fault sequence. That makes every chaos test a
//! reproducible pin rather than a flaky roll of the dice.
//!
//! The proxy understands the `util::frame` wire format just enough to act on
//! *frame boundaries*: the server→client direction is parsed into frames
//! (9-byte head + payload + CRC trailer) so faults like "truncate mid-frame"
//! or "flip a payload byte of frame k" land exactly where the schedule says.
//! The client→server direction is copied verbatim — the faults we model are
//! a worker misbehaving, not a coordinator misbehaving.
//!
//! Fault classes (`Fault`):
//!
//! | schedule token | behavior |
//! |----------------|----------|
//! | `none`         | forward everything verbatim |
//! | `refuse`       | close the client socket immediately, never dial upstream |
//! | `hang`         | accept, then forward nothing in either direction |
//! | `delay@MS`     | forward, but sleep MS ms before each server→client frame |
//! | `truncate@K`   | forward K frames, then send only the 9-byte head of frame K and close |
//! | `corrupt@K`    | forward, but flip one payload byte of server→client frame K |
//! | `kill@K`       | forward K server→client frames, then close both sockets |
//! | `stall@K`      | forward K frames, then stop forwarding but keep the socket open |
//!
//! `corrupt` exercises the CRC-32 path in `util::frame` (the client must see
//! `BadChecksum`, never a silently wrong payload); `stall` is the "hung
//! worker" fault that the per-job deadline in `kmeans::remote` must bound.

use std::fmt;
use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Duration;

use crate::util::rng::Xoshiro256pp;

/// Size of the fixed frame head (`magic u32 | kind u8 | len u32`) — mirrors
/// the layout in `util::frame`.
const FRAME_HEAD: usize = 9;
/// CRC-32 trailer length.
const FRAME_TRAILER: usize = 4;
/// How often blocked proxy threads wake up to check the stop flag.
const POLL: Duration = Duration::from_millis(25);

/// One fault, applied to one proxied connection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Forward everything verbatim.
    None,
    /// Close the client socket at accept; upstream is never dialed.
    Refuse,
    /// Accept and hold the socket open, but never forward a byte.
    Hang,
    /// Forward, sleeping this many milliseconds before each downstream frame.
    Delay(u64),
    /// Forward `frames` whole frames, then emit only the head of the next
    /// frame and close — the client must observe `FrameError::Truncated`.
    Truncate { frames: u32 },
    /// Flip one payload byte of downstream frame `frame` — the client must
    /// observe `FrameError::BadChecksum`.
    Corrupt { frame: u32 },
    /// Forward `frames` downstream frames, then close both sockets.
    KillAfter { frames: u32 },
    /// Forward `frames` downstream frames, then go silent while keeping the
    /// connection open — the "hung worker" the per-job deadline must bound.
    Stall { frames: u32 },
}

impl fmt::Display for Fault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Fault::None => write!(f, "none"),
            Fault::Refuse => write!(f, "refuse"),
            Fault::Hang => write!(f, "hang"),
            Fault::Delay(ms) => write!(f, "delay@{ms}"),
            Fault::Truncate { frames } => write!(f, "truncate@{frames}"),
            Fault::Corrupt { frame } => write!(f, "corrupt@{frame}"),
            Fault::KillAfter { frames } => write!(f, "kill@{frames}"),
            Fault::Stall { frames } => write!(f, "stall@{frames}"),
        }
    }
}

impl Fault {
    fn parse(tok: &str) -> Result<Fault, String> {
        let (name, arg) = match tok.split_once('@') {
            Some((n, a)) => (n, Some(a)),
            None => (tok, None),
        };
        let num = || -> Result<u64, String> {
            arg.ok_or_else(|| format!("fault `{tok}` needs a numeric argument, e.g. `{name}@3`"))?
                .parse::<u64>()
                .map_err(|_| format!("bad number in fault `{tok}`"))
        };
        let bare = |fault: Fault| -> Result<Fault, String> {
            if arg.is_some() {
                Err(format!("fault `{name}` takes no argument (got `{tok}`)"))
            } else {
                Ok(fault)
            }
        };
        match name {
            "none" => bare(Fault::None),
            "refuse" => bare(Fault::Refuse),
            "hang" => bare(Fault::Hang),
            "delay" => Ok(Fault::Delay(num()?)),
            "truncate" => Ok(Fault::Truncate { frames: num()? as u32 }),
            "corrupt" => Ok(Fault::Corrupt { frame: num()? as u32 }),
            "kill" => Ok(Fault::KillAfter { frames: num()? as u32 }),
            "stall" => Ok(Fault::Stall { frames: num()? as u32 }),
            _ => Err(format!(
                "unknown fault `{tok}` (want none|refuse|hang|delay@MS|truncate@K|corrupt@K|kill@K|stall@K)"
            )),
        }
    }
}

/// A deterministic per-connection fault assignment: connection `i` gets
/// `faults[i % faults.len()]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultSchedule {
    faults: Vec<Fault>,
}

impl FaultSchedule {
    /// Build from an explicit fault list. An empty list behaves like `clean()`.
    pub fn new(faults: Vec<Fault>) -> Self {
        FaultSchedule { faults }
    }

    /// A schedule that never injects anything.
    pub fn clean() -> Self {
        FaultSchedule { faults: vec![Fault::None] }
    }

    /// Parse a comma-separated schedule, e.g. `"kill@4,none,corrupt@1"`.
    pub fn parse(s: &str) -> Result<Self, String> {
        let mut faults = Vec::new();
        for tok in s.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                return Err("empty fault token in schedule".to_string());
            }
            faults.push(Fault::parse(tok)?);
        }
        Ok(FaultSchedule { faults })
    }

    /// Derive `n` faults deterministically from a u64 seed: the same seed
    /// always yields the same schedule.
    pub fn seeded(seed: u64, n: usize) -> Self {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut faults = Vec::with_capacity(n);
        for _ in 0..n {
            let f = match rng.below(8) {
                0 => Fault::None,
                1 => Fault::Refuse,
                2 => Fault::Hang,
                3 => Fault::Delay(1 + rng.below(40)),
                4 => Fault::Truncate { frames: rng.below(6) as u32 },
                5 => Fault::Corrupt { frame: rng.below(6) as u32 },
                6 => Fault::KillAfter { frames: rng.below(6) as u32 },
                _ => Fault::Stall { frames: rng.below(6) as u32 },
            };
            faults.push(f);
        }
        FaultSchedule { faults }
    }

    /// The fault assigned to accepted connection `conn` (0-based).
    pub fn fault_for(&self, conn: usize) -> Fault {
        if self.faults.is_empty() {
            Fault::None
        } else {
            self.faults[conn % self.faults.len()]
        }
    }

    pub fn faults(&self) -> &[Fault] {
        &self.faults
    }
}

impl fmt::Display for FaultSchedule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, fault) in self.faults.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{fault}")?;
        }
        Ok(())
    }
}

/// In-process TCP chaos proxy. `spawn` binds a listener and returns
/// immediately; `addr()` is what the client should dial instead of the real
/// worker; `shutdown()` stops the accept loop and wakes lingering fault
/// threads (hang/stall poll a stop flag).
pub struct ChaosProxy {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl ChaosProxy {
    /// Bind `listen` (use `127.0.0.1:0` for an ephemeral port) and start
    /// proxying to `upstream` under `schedule`.
    pub fn spawn(listen: &str, upstream: &str, schedule: FaultSchedule) -> io::Result<ChaosProxy> {
        let listener = TcpListener::bind(listen)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let upstream = upstream.to_string();
        let accept = thread::Builder::new()
            .name("chaos-accept".to_string())
            .spawn(move || {
                let mut conn = 0usize;
                for incoming in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    let client = match incoming {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    let fault = schedule.fault_for(conn);
                    log::debug!("chaos: conn {} gets fault {}", conn, fault);
                    conn += 1;
                    let upstream = upstream.clone();
                    let stop3 = Arc::clone(&stop2);
                    let _ = thread::Builder::new()
                        .name(format!("chaos-conn-{}", conn))
                        .spawn(move || handle_conn(client, &upstream, fault, stop3));
                }
            })?;
        Ok(ChaosProxy { addr, stop, accept: Some(accept) })
    }

    /// The address clients should dial.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and wake any parked fault threads.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

/// Sleep `ms` in small chunks so a proxy shutdown is never blocked on a
/// long injected delay.
fn chunked_sleep(ms: u64, stop: &AtomicBool) {
    let mut left = Duration::from_millis(ms);
    while !left.is_zero() && !stop.load(Ordering::SeqCst) {
        let step = left.min(POLL);
        thread::sleep(step);
        left -= step;
    }
}

/// Park until shutdown (for `hang` / post-`stall`), keeping the socket open.
fn park(stop: &AtomicBool) {
    while !stop.load(Ordering::SeqCst) {
        thread::sleep(POLL);
    }
}

/// Read exactly `buf.len()` bytes, polling the stop flag across read
/// timeouts. `Ok(false)` means clean EOF before the first byte; EOF
/// mid-buffer is an error.
fn read_full(s: &mut TcpStream, buf: &mut [u8], stop: &AtomicBool) -> io::Result<bool> {
    let mut off = 0;
    while off < buf.len() {
        if stop.load(Ordering::SeqCst) {
            return Err(io::Error::new(io::ErrorKind::Interrupted, "chaos proxy stopping"));
        }
        match s.read(&mut buf[off..]) {
            Ok(0) => {
                if off == 0 {
                    return Ok(false);
                }
                return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "eof mid-frame"));
            }
            Ok(n) => off += n,
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut | io::ErrorKind::Interrupted
                ) =>
            {
                continue
            }
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

fn close_both(a: &TcpStream, b: &TcpStream) {
    let _ = a.shutdown(Shutdown::Both);
    let _ = b.shutdown(Shutdown::Both);
}

fn handle_conn(client: TcpStream, upstream: &str, fault: Fault, stop: Arc<AtomicBool>) {
    match fault {
        Fault::Refuse => {
            // Drop without dialing upstream: the client's handshake read
            // fails immediately, modeling a refused/unreachable worker.
            drop(client);
            return;
        }
        Fault::Hang => {
            // Hold the socket open but never answer; the client's own
            // timeouts decide how long this costs.
            park(&stop);
            return;
        }
        _ => {}
    }

    let server = match TcpStream::connect(upstream) {
        Ok(s) => s,
        Err(e) => {
            log::warn!("chaos: upstream {} unreachable: {}", upstream, e);
            return;
        }
    };
    let _ = client.set_nodelay(true);
    let _ = server.set_nodelay(true);
    let _ = client.set_read_timeout(Some(POLL));
    let _ = server.set_read_timeout(Some(POLL));

    // Uplink (client → server): verbatim byte copy.
    let up_client = match client.try_clone() {
        Ok(c) => c,
        Err(_) => return,
    };
    let up_server = match server.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let uplink = {
        let stop = Arc::clone(&stop);
        let mut from = up_client;
        let mut to = up_server;
        thread::spawn(move || {
            let mut buf = [0u8; 16 * 1024];
            loop {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                match from.read(&mut buf) {
                    Ok(0) => break,
                    Ok(n) => {
                        if to.write_all(&buf[..n]).is_err() {
                            break;
                        }
                    }
                    Err(e)
                        if matches!(
                            e.kind(),
                            io::ErrorKind::WouldBlock
                                | io::ErrorKind::TimedOut
                                | io::ErrorKind::Interrupted
                        ) =>
                    {
                        continue
                    }
                    Err(_) => break,
                }
            }
            close_both(&from, &to);
        })
    };

    // Downlink (server → client): frame-aware, fault-injecting.
    let mut server = server;
    let mut client = client;
    let _ = downlink(&mut server, &mut client, fault, &stop);
    close_both(&server, &client);
    let _ = uplink.join();
}

fn downlink(
    server: &mut TcpStream,
    client: &mut TcpStream,
    fault: Fault,
    stop: &AtomicBool,
) -> io::Result<()> {
    let mut frame_no: u32 = 0;
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        let mut head = [0u8; FRAME_HEAD];
        if !read_full(server, &mut head, stop)? {
            return Ok(()); // clean upstream EOF
        }
        let len = u32::from_le_bytes([head[5], head[6], head[7], head[8]]) as usize;
        let mut body = vec![0u8; len + FRAME_TRAILER];
        if !read_full(server, &mut body, stop)? {
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "eof mid-frame"));
        }

        match fault {
            Fault::Truncate { frames } if frame_no == frames => {
                // Ship only the head: the client learns the length, then
                // hits EOF mid-payload — FrameError::Truncated.
                let _ = client.write_all(&head);
                return Ok(());
            }
            Fault::KillAfter { frames } if frame_no == frames => {
                // Drop this frame entirely and sever the connection.
                return Ok(());
            }
            Fault::Stall { frames } if frame_no == frames => {
                // Swallow the frame and go silent, socket still open: the
                // client's per-job deadline is what bounds this.
                park(stop);
                return Ok(());
            }
            Fault::Corrupt { frame } if frame_no == frame => {
                // Flip one payload byte (or a CRC byte for empty payloads):
                // the CRC-32 check must reject the frame.
                body[len / 2] ^= 0x01;
                client.write_all(&head)?;
                client.write_all(&body)?;
            }
            Fault::Delay(ms) => {
                chunked_sleep(ms, stop);
                client.write_all(&head)?;
                client.write_all(&body)?;
            }
            _ => {
                client.write_all(&head)?;
                client.write_all(&body)?;
            }
        }
        frame_no += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::frame::{read_frame, write_frame, FrameError};

    #[test]
    fn schedule_parses_every_fault_class() {
        let s = FaultSchedule::parse("none,refuse,hang,delay@25,truncate@2,corrupt@1,kill@4,stall@3")
            .expect("parse");
        assert_eq!(
            s.faults(),
            &[
                Fault::None,
                Fault::Refuse,
                Fault::Hang,
                Fault::Delay(25),
                Fault::Truncate { frames: 2 },
                Fault::Corrupt { frame: 1 },
                Fault::KillAfter { frames: 4 },
                Fault::Stall { frames: 3 },
            ]
        );
    }

    #[test]
    fn schedule_display_round_trips() {
        let s = FaultSchedule::parse("none,refuse,hang,delay@7,truncate@0,corrupt@5,kill@2,stall@9")
            .expect("parse");
        let again = FaultSchedule::parse(&s.to_string()).expect("reparse");
        assert_eq!(s, again);
    }

    #[test]
    fn bad_schedules_are_rejected() {
        assert!(FaultSchedule::parse("bogus").is_err());
        assert!(FaultSchedule::parse("delay").is_err());
        assert!(FaultSchedule::parse("kill@x").is_err());
        assert!(FaultSchedule::parse("none@3").is_err());
        assert!(FaultSchedule::parse("").is_err());
        assert!(FaultSchedule::parse("none,,kill@1").is_err());
    }

    #[test]
    fn seeded_schedules_are_deterministic() {
        let a = FaultSchedule::seeded(7, 32);
        let b = FaultSchedule::seeded(7, 32);
        assert_eq!(a, b);
        assert_eq!(a.faults().len(), 32);
    }

    #[test]
    fn schedule_wraps_round_robin() {
        let s = FaultSchedule::parse("kill@1,none").expect("parse");
        assert_eq!(s.fault_for(0), Fault::KillAfter { frames: 1 });
        assert_eq!(s.fault_for(1), Fault::None);
        assert_eq!(s.fault_for(2), Fault::KillAfter { frames: 1 });
        assert_eq!(s.fault_for(5), Fault::None);
    }

    /// Spawn a one-shot upstream that writes the given frames and closes.
    fn one_shot_upstream(frames: Vec<(u8, Vec<u8>)>) -> (std::net::SocketAddr, thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind upstream");
        let addr = listener.local_addr().expect("addr");
        let h = thread::spawn(move || {
            if let Ok((mut s, _)) = listener.accept() {
                for (kind, payload) in frames {
                    if write_frame(&mut s, kind, &payload).is_err() {
                        break;
                    }
                }
                // Linger briefly so the proxy drains our bytes before EOF.
                let _ = s.flush();
            }
        });
        (addr, h)
    }

    // The proxy tests below need real TCP sockets, which Miri's isolated
    // interpreter cannot provide; the schedule/codec logic above still
    // runs under Miri, and the native test matrix keeps these covered.
    #[test]
    #[cfg_attr(miri, ignore)]
    fn clean_proxy_forwards_frames_verbatim() {
        let (up, uh) = one_shot_upstream(vec![(3, vec![1, 2, 3, 4]), (5, vec![9])]);
        let proxy = ChaosProxy::spawn("127.0.0.1:0", &up.to_string(), FaultSchedule::clean())
            .expect("spawn proxy");
        let mut c = TcpStream::connect(proxy.addr()).expect("dial proxy");
        let (k1, p1, _) = read_frame(&mut c).expect("frame 1");
        let (k2, p2, _) = read_frame(&mut c).expect("frame 2");
        assert_eq!((k1, p1.as_slice()), (3, &[1u8, 2, 3, 4][..]));
        assert_eq!((k2, p2.as_slice()), (5, &[9u8][..]));
        drop(c);
        uh.join().expect("upstream");
        proxy.shutdown();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn corrupt_fault_trips_the_checksum() {
        let (up, uh) = one_shot_upstream(vec![(3, vec![1, 2, 3, 4])]);
        let schedule = FaultSchedule::parse("corrupt@0").expect("parse");
        let proxy =
            ChaosProxy::spawn("127.0.0.1:0", &up.to_string(), schedule).expect("spawn proxy");
        let mut c = TcpStream::connect(proxy.addr()).expect("dial proxy");
        match read_frame(&mut c) {
            Err(FrameError::BadChecksum { .. }) => {}
            other => panic!("want BadChecksum, got {:?}", other.map(|(k, p, _)| (k, p.len()))),
        }
        drop(c);
        uh.join().expect("upstream");
        proxy.shutdown();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn kill_and_truncate_faults_sever_the_stream() {
        // kill@0: the client sees EOF before any frame → Truncated.
        let (up, uh) = one_shot_upstream(vec![(3, vec![1, 2, 3, 4])]);
        let proxy = ChaosProxy::spawn(
            "127.0.0.1:0",
            &up.to_string(),
            FaultSchedule::parse("kill@0").expect("parse"),
        )
        .expect("spawn proxy");
        let mut c = TcpStream::connect(proxy.addr()).expect("dial proxy");
        match read_frame(&mut c) {
            Err(FrameError::Truncated) => {}
            other => panic!("want Truncated, got {:?}", other.map(|(k, p, _)| (k, p.len()))),
        }
        drop(c);
        uh.join().expect("upstream");
        proxy.shutdown();

        // truncate@0: head arrives, payload does not → Truncated.
        let (up, uh) = one_shot_upstream(vec![(3, vec![1, 2, 3, 4])]);
        let proxy = ChaosProxy::spawn(
            "127.0.0.1:0",
            &up.to_string(),
            FaultSchedule::parse("truncate@0").expect("parse"),
        )
        .expect("spawn proxy");
        let mut c = TcpStream::connect(proxy.addr()).expect("dial proxy");
        match read_frame(&mut c) {
            Err(FrameError::Truncated) => {}
            other => panic!("want Truncated, got {:?}", other.map(|(k, p, _)| (k, p.len()))),
        }
        drop(c);
        uh.join().expect("upstream");
        proxy.shutdown();
    }
}
