//! The micro-batching cluster service: a bounded request queue, one or
//! more dispatcher threads that coalesce concurrent predict requests into
//! panel batches, and `std::thread::scope` panel workers doing the
//! distance arithmetic — the software mirror of the paper's PS core
//! dispatching batched work to multiple PL cores.
//!
//! Control flow:
//!
//! ```text
//! clients ──submit()──> bounded queue ──drain_batch()──> dispatcher(s) ("PS")
//!                                                            │ one PanelJobs batch each
//!                                                            ▼
//!                                             Predictor → ParCpuPanels
//!                                             (scope workers = "PL cores")
//!                                                            │ split rows per request
//!                                                            ▼
//! clients <──Ticket::wait()── reply channels <──────── fulfilled replies
//! ```
//!
//! Backpressure is real: `submit` blocks while the queue holds
//! `queue_cap` requests (`try_submit` refuses instead, `submit_timeout`
//! waits a bounded time then sheds the request as `Rejected`), and
//! shutdown drains the queue before the dispatchers exit, so every
//! accepted request is answered.
//!
//! The scaling knobs ride on [`ServeConfig`]:
//!
//! - `kernel` / `quantized` — the distance-arithmetic tier each
//!   dispatcher's predictor runs: scalar oracle, blocked, explicit SIMD
//!   ([`KernelKind`]), or the reduced-precision i8 shortlist path whose
//!   exact-f32 rescore keeps labels bitwise-identical to the oracle.
//! - `batch_deadline_us` — the deadline-based micro-batcher: a dispatcher
//!   holds a non-full batch until the *oldest* queued request has waited
//!   this long, trading bounded latency for better coalescing.  0 (the
//!   default) preserves immediate-drain behavior.
//! - `dispatchers` — the serve-side face of the shard plane: P dispatcher
//!   panels drain the shared queue concurrently (each with its own
//!   `Predictor` + worker pool slice), for models/loads where one panel
//!   pass per batch is the bottleneck.
//! - warm reload — [`ClusterService::reload`] swaps the served
//!   `Arc<KmeansModel>` without dropping the queue (dimension changes are
//!   rejected); every batch executes against exactly one model snapshot,
//!   so in-flight tickets always resolve consistently.

use super::metrics::{Recorder, ServeMetrics};
use crate::data::Dataset;
use crate::kmeans::bounds::BoundsMode;
use crate::kmeans::model::KmeansModel;
use crate::kmeans::panel::{KernelKind, ParCpuPanels};
use crate::kmeans::predict::Predictor;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bounded queue capacity, in requests; `submit` blocks when full.
    pub queue_cap: usize,
    /// Micro-batcher point budget: queued requests are coalesced into one
    /// panel batch until the next request would push past this many query
    /// points (a single larger request is still served, alone).
    pub max_batch_points: usize,
    /// Panel worker threads (the "PL core" count), shared out across the
    /// dispatchers.
    pub workers: usize,
    /// Panel kernel tier; `Blocked` is the production profile, `Scalar`
    /// the oracle arithmetic (bit-identical to training-side assignment),
    /// `Simd`/`Auto` the explicit vector kernels (lenient resolution:
    /// SIMD demotes to blocked on hosts without AVX2/FMA or NEON).
    pub kernel: KernelKind,
    /// Route panels through the reduced-precision i8 shortlist backend
    /// instead of `kernel`: candidates are scored in quantized arithmetic
    /// and survivors re-scored in exact f32, so labels stay
    /// bitwise-identical to the scalar oracle while most of the distance
    /// work runs 8-bit.  Telemetry lands in
    /// [`ServeMetrics::quantized_candidates`]/[`rescored_candidates`](ServeMetrics::rescored_candidates).
    pub quantized: bool,
    /// Centroid kd-tree prune override; `None` = the predictor's
    /// model-size auto rule.
    pub prune: Option<bool>,
    /// Triangle-inequality bounds tier for each dispatcher's predictor
    /// (DESIGN.md §10): candidate lists shrink *before* paneling, and
    /// the pruning telemetry lands in
    /// [`ServeMetrics::bound_pruned_points`] /
    /// [`bound_pruned_candidates`](ServeMetrics::bound_pruned_candidates) /
    /// [`bounds_matrix_cost`](ServeMetrics::bounds_matrix_cost).
    /// `Off` (the default) keeps the legacy path bit for bit.
    pub bounds: BoundsMode,
    /// Deadline-based micro-batcher: hold a non-full batch until the
    /// oldest queued request has waited this many microseconds, to
    /// coalesce more concurrent requests into one panel pass.  0 =
    /// immediate drain (the pre-deadline behavior).
    pub batch_deadline_us: u64,
    /// Dispatcher panel count P: this many dispatcher threads drain the
    /// shared queue concurrently, each owning a `Predictor` over
    /// `workers / dispatchers` panel threads.
    pub dispatchers: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            queue_cap: 256,
            max_batch_points: 4096,
            workers: std::thread::available_parallelism()
                .map(|c| c.get())
                .unwrap_or(1)
                .min(8),
            kernel: KernelKind::Blocked,
            quantized: false,
            prune: None,
            bounds: BoundsMode::Off,
            batch_deadline_us: 0,
            dispatchers: 1,
        }
    }
}

/// Why a request was not accepted / answered.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The service is shut down.
    Closed,
    /// Query dimensionality does not match the model.
    DimMismatch { expected: usize, got: usize },
    /// Bounded queue is full (only from [`ClusterService::try_submit`]).
    Full,
    /// The queue stayed full past a
    /// [`submit_timeout`](ClusterService::submit_timeout) deadline — the
    /// request was shed at admission (counted in
    /// [`ServeMetrics::rejected`]).
    Rejected,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Closed => write!(f, "cluster service is shut down"),
            ServeError::DimMismatch { expected, got } => {
                write!(f, "query dims {got} != model dims {expected}")
            }
            ServeError::Full => write!(f, "request queue is full"),
            ServeError::Rejected => {
                write!(f, "request rejected: queue stayed full past the submit deadline")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// One fulfilled predict request.
#[derive(Clone, Debug)]
pub struct PredictReply {
    /// Assigned centroid index per query point.
    pub labels: Vec<u32>,
    /// Distance to the assigned centroid per query point (squared-L2 for
    /// Euclid, per the repo convention).
    pub distances: Vec<f32>,
    /// How many requests shared this request's panel batch (>= 1; larger
    /// means micro-batching coalesced concurrent clients).
    pub batched_with: usize,
}

/// Handle to one in-flight request; `wait` blocks until the reply lands.
/// Same one-shot mpsc reply-mailbox idiom as the coordinator's offload
/// service.
#[must_use = "a Ticket must be waited on, or its reply is lost"]
pub struct Ticket {
    rx: Receiver<PredictReply>,
}

impl Ticket {
    /// Block until the service answers.  Accepted requests are normally
    /// always answered (shutdown drains the queue before the dispatchers
    /// exit); [`ServeError::Closed`] is returned only if a dispatcher
    /// died abnormally (panicked) with this request still in its batch.
    pub fn wait(self) -> Result<PredictReply, ServeError> {
        self.rx.recv().map_err(|_| ServeError::Closed)
    }
}

/// A queued request.
struct Pending {
    points: Dataset,
    reply: Sender<PredictReply>,
    enqueued: Instant,
}

#[derive(Default)]
struct QueueState {
    queue: VecDeque<Pending>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    /// The served model; [`ClusterService::reload`] swaps it, dispatchers
    /// snapshot it per batch.  Separate lock from `state` (always
    /// acquired *after* `state` when both are held).
    model: Mutex<Arc<KmeansModel>>,
}

impl Shared {
    /// Lock the queue state, recovering from poison: a dispatcher panic
    /// must degrade to [`ServeError::Closed`] on the client side, not
    /// cascade `lock().unwrap()` panics into every caller.
    fn lock_state(&self) -> MutexGuard<'_, QueueState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn current_model(&self) -> Arc<KmeansModel> {
        Arc::clone(&self.model.lock().unwrap_or_else(|p| p.into_inner()))
    }

    fn wait_on<'a>(
        &self,
        cv: &Condvar,
        guard: MutexGuard<'a, QueueState>,
    ) -> MutexGuard<'a, QueueState> {
        cv.wait(guard).unwrap_or_else(|p| p.into_inner())
    }

    fn wait_timeout_on<'a>(
        &self,
        cv: &Condvar,
        guard: MutexGuard<'a, QueueState>,
        dur: Duration,
    ) -> (MutexGuard<'a, QueueState>, bool) {
        match cv.wait_timeout(guard, dur) {
            Ok((g, res)) => (g, res.timed_out()),
            Err(p) => {
                let (g, res) = p.into_inner();
                (g, res.timed_out())
            }
        }
    }
}

/// Dropped by each dispatcher thread on *any* exit — normal or panic.
/// When the *last* dispatcher exits it marks the service shut down and
/// clears the queue so queued reply senders drop (turning blocked
/// `Ticket::wait`s into `ServeError::Closed`) and blocked submitters wake
/// into the closed path instead of waiting forever.
struct DispatcherExitGuard {
    shared: Arc<Shared>,
    alive: Arc<AtomicUsize>,
}

impl Drop for DispatcherExitGuard {
    fn drop(&mut self) {
        if self.alive.fetch_sub(1, Ordering::AcqRel) != 1 {
            return; // other dispatchers still drain the queue
        }
        let mut st = self.shared.lock_state();
        st.shutdown = true;
        st.queue.clear();
        drop(st);
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
    }
}

/// Pop a micro-batch off the queue: consecutive requests until the point
/// budget is hit (a single over-budget request still ships alone).
fn drain_batch(queue: &mut VecDeque<Pending>, max_points: usize) -> Vec<Pending> {
    let mut out = Vec::new();
    let mut pts = 0usize;
    while let Some(front) = queue.front() {
        let take = front.points.len();
        if !out.is_empty() && pts + take > max_points {
            break;
        }
        pts += take;
        out.push(queue.pop_front().unwrap());
        if pts >= max_points {
            break;
        }
    }
    out
}

/// How long a submit is willing to wait for queue space.
#[derive(Clone, Copy)]
enum Admission {
    /// Wait indefinitely (backpressure).
    Block,
    /// Refuse immediately with [`ServeError::Full`].
    Fail,
    /// Wait at most this long, then shed with [`ServeError::Rejected`].
    Deadline(Duration),
}

/// What a dispatcher decided to do after inspecting the queue.
enum Step {
    /// Serve this micro-batch.
    Batch(Vec<Pending>),
    /// The model was swapped: rebuild the predictor, then come back.
    Reload,
    /// Shutdown requested and the queue is drained.
    Exit,
}

/// One dispatcher thread: snapshot the model, serve batches until the
/// model is swapped (rebuild) or shutdown drains the queue (exit).
fn dispatcher_loop(shared: &Arc<Shared>, recorder: &Recorder, cfg: &ServeConfig, workers: usize) {
    'model: loop {
        // Every batch below executes against exactly this snapshot, so a
        // reload never splits one batch across two models.
        let model = shared.current_model();
        let mut predictor = if cfg.quantized {
            Predictor::quantized(model.as_ref())
        } else {
            Predictor::with_backend(
                model.as_ref(),
                ParCpuPanels::with_kind(workers, cfg.kernel),
            )
        };
        if let Some(on) = cfg.prune {
            predictor = predictor.prune(on);
        }
        predictor = predictor.bounds(cfg.bounds);
        let mut kernel_last = predictor.kernel_stats();
        // Zero baseline, not a post-build snapshot: the one-time k×k
        // matrix cost must land in the first batch's recorded delta.
        let mut bounds_last = crate::kmeans::bounds::BoundsStats::default();
        let d = model.dims();
        loop {
            let step = {
                let mut st = shared.lock_state();
                while st.queue.is_empty() && !st.shutdown {
                    st = shared.wait_on(&shared.not_empty, st);
                }
                if st.queue.is_empty() {
                    Step::Exit // shutdown requested and queue drained
                } else if !Arc::ptr_eq(&model, &shared.current_model()) {
                    // Swap before draining: the pending requests deserve
                    // the new model.
                    Step::Reload
                } else {
                    if cfg.batch_deadline_us > 0 && !st.shutdown {
                        // Deadline micro-batcher: hold the batch open until
                        // the oldest queued request has waited the deadline
                        // out (or the point budget fills), coalescing
                        // stragglers into this panel pass.
                        let deadline = st.queue.front().unwrap().enqueued
                            + Duration::from_micros(cfg.batch_deadline_us);
                        loop {
                            if st.queue.is_empty() {
                                break; // another dispatcher drained it
                            }
                            let pts: usize =
                                st.queue.iter().map(|p| p.points.len()).sum();
                            if pts >= cfg.max_batch_points || st.shutdown {
                                break;
                            }
                            let now = Instant::now();
                            if now >= deadline {
                                break;
                            }
                            let (g, timed_out) = shared.wait_timeout_on(
                                &shared.not_empty,
                                st,
                                deadline - now,
                            );
                            st = g;
                            if timed_out {
                                break;
                            }
                        }
                    }
                    let b = drain_batch(&mut st.queue, cfg.max_batch_points);
                    shared.not_full.notify_all();
                    Step::Batch(b)
                }
            };
            let batch = match step {
                Step::Exit => break 'model,
                Step::Reload => continue 'model,
                // A sibling dispatcher can empty the queue while this one
                // sat out a coalescing deadline; never record a 0-request
                // batch.
                Step::Batch(b) if b.is_empty() => continue,
                Step::Batch(b) => b,
            };
            let nreq = batch.len();
            let total: usize = batch.iter().map(|p| p.points.len()).sum();
            let mut flat = Vec::with_capacity(total * d);
            for p in &batch {
                flat.extend_from_slice(p.points.flat());
            }
            let queries = Dataset::from_flat(total, d, flat);
            let t0 = Instant::now();
            let (labels, dists) = predictor.assign_scored(&queries);
            let busy = t0.elapsed().as_secs_f64();
            let mut latencies = Vec::with_capacity(nreq);
            let mut off = 0usize;
            for p in batch {
                let n = p.points.len();
                // Receiver may have given up (client panic); ignore.
                let _ = p.reply.send(PredictReply {
                    labels: labels[off..off + n].to_vec(),
                    distances: dists[off..off + n].to_vec(),
                    batched_with: nreq,
                });
                off += n;
                latencies.push(p.enqueued.elapsed().as_secs_f64());
            }
            recorder.record_batch(total as u64, busy, &latencies);
            let ks = predictor.kernel_stats();
            recorder.record_kernel(ks.delta_from(&kernel_last));
            kernel_last = ks;
            let bs = predictor.bounds_stats();
            recorder.record_bounds(bs.delta_from(&bounds_last));
            bounds_last = bs;
        }
    }
}

/// The running micro-batching service; see module docs.
pub struct ClusterService {
    /// Query dimensionality — invariant across reloads (enforced by
    /// [`reload`](Self::reload)), so submit-side validation never races a
    /// swap.
    dims: usize,
    cfg: ServeConfig,
    shared: Arc<Shared>,
    recorder: Arc<Recorder>,
    dispatchers: Vec<JoinHandle<()>>,
}

impl ClusterService {
    /// Start the dispatcher(s) over a trained model.
    pub fn start(model: Arc<KmeansModel>, cfg: ServeConfig) -> Self {
        assert!(cfg.queue_cap >= 1, "queue_cap must be >= 1");
        assert!(cfg.max_batch_points >= 1, "max_batch_points must be >= 1");
        assert!(cfg.dispatchers >= 1, "dispatchers must be >= 1");
        let dims = model.dims();
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState::default()),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            model: Mutex::new(model),
        });
        let recorder = Arc::new(Recorder::new());
        let alive = Arc::new(AtomicUsize::new(cfg.dispatchers));
        // Share the panel workers out across the dispatcher panels.
        let per_workers = (cfg.workers / cfg.dispatchers).max(1);

        let dispatchers = (0..cfg.dispatchers)
            .map(|i| {
                let svc_shared = Arc::clone(&shared);
                let svc_recorder = Arc::clone(&recorder);
                let svc_cfg = cfg.clone();
                let guard = DispatcherExitGuard {
                    shared: Arc::clone(&shared),
                    alive: Arc::clone(&alive),
                };
                std::thread::Builder::new()
                    .name(format!("cluster-serve-{i}"))
                    .spawn(move || {
                        let _exit_guard = guard;
                        dispatcher_loop(&svc_shared, &svc_recorder, &svc_cfg, per_workers);
                    })
                    .expect("cannot spawn cluster-serve dispatcher")
            })
            .collect();

        Self {
            dims,
            cfg,
            shared,
            recorder,
            dispatchers,
        }
    }

    /// The currently served model (a reload may replace it at any time).
    pub fn model(&self) -> Arc<KmeansModel> {
        self.shared.current_model()
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Warm model reload: swap the served model without dropping the
    /// queue.  A replacement with different query dimensionality is
    /// rejected (queued requests were validated against the old dims).
    /// Each in-flight batch completes against whichever model snapshot
    /// its dispatcher drained it under — never a mix.
    pub fn reload(&self, model: Arc<KmeansModel>) -> Result<(), ServeError> {
        if model.dims() != self.dims {
            return Err(ServeError::DimMismatch {
                expected: self.dims,
                got: model.dims(),
            });
        }
        *self
            .shared
            .model
            .lock()
            .unwrap_or_else(|p| p.into_inner()) = model;
        Ok(())
    }

    fn check_dims(&self, points: &Dataset) -> Result<(), ServeError> {
        if points.dims() != self.dims {
            return Err(ServeError::DimMismatch {
                expected: self.dims,
                got: points.dims(),
            });
        }
        Ok(())
    }

    fn enqueue(&self, points: Dataset, admission: Admission) -> Result<Ticket, ServeError> {
        self.check_dims(&points)?;
        let (reply_tx, reply_rx) = channel();
        let pending = Pending {
            points,
            reply: reply_tx,
            enqueued: Instant::now(),
        };
        let deadline = match admission {
            Admission::Deadline(d) => Some(Instant::now() + d),
            _ => None,
        };
        let mut st = self.shared.lock_state();
        loop {
            if st.shutdown {
                return Err(ServeError::Closed);
            }
            if st.queue.len() < self.cfg.queue_cap {
                break;
            }
            match admission {
                Admission::Fail => return Err(ServeError::Full),
                Admission::Block => st = self.shared.wait_on(&self.shared.not_full, st),
                Admission::Deadline(_) => {
                    let deadline = deadline.unwrap();
                    let now = Instant::now();
                    if now >= deadline {
                        drop(st);
                        self.recorder.record_rejection();
                        return Err(ServeError::Rejected);
                    }
                    // Spurious wakeups loop back through the deadline
                    // check above, so the timed_out flag is redundant.
                    let (g, _timed_out) =
                        self.shared
                            .wait_timeout_on(&self.shared.not_full, st, deadline - now);
                    st = g;
                }
            }
        }
        st.queue.push_back(pending);
        drop(st);
        self.shared.not_empty.notify_one();
        Ok(Ticket { rx: reply_rx })
    }

    /// Enqueue a predict request, blocking while the queue is full
    /// (backpressure).  The returned [`Ticket`] resolves to the reply.
    pub fn submit(&self, points: Dataset) -> Result<Ticket, ServeError> {
        self.enqueue(points, Admission::Block)
    }

    /// Non-blocking [`submit`](Self::submit): fails with
    /// [`ServeError::Full`] instead of waiting.
    pub fn try_submit(&self, points: Dataset) -> Result<Ticket, ServeError> {
        self.enqueue(points, Admission::Fail)
    }

    /// Bounded-wait [`submit`](Self::submit): wait up to `timeout` for
    /// queue space, then shed the request with [`ServeError::Rejected`]
    /// (counted in [`ServeMetrics::rejected`]).  The admission-control
    /// client call: a saturated service costs a bounded wait, never a
    /// stalled client.
    pub fn submit_timeout(
        &self,
        points: Dataset,
        timeout: Duration,
    ) -> Result<Ticket, ServeError> {
        self.enqueue(points, Admission::Deadline(timeout))
    }

    /// Submit and wait — the closed-loop client call.
    pub fn predict(&self, points: Dataset) -> Result<PredictReply, ServeError> {
        self.submit(points)?.wait()
    }

    /// Current performance counters (callable while serving).
    pub fn metrics(&self) -> ServeMetrics {
        self.recorder.snapshot()
    }

    fn finish(&mut self) {
        {
            let mut st = self.shared.lock_state();
            st.shutdown = true;
        }
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
        for j in self.dispatchers.drain(..) {
            let _ = j.join();
        }
    }

    /// Stop accepting requests, drain the queue, join the dispatchers and
    /// return the final metrics snapshot.
    pub fn shutdown(mut self) -> ServeMetrics {
        self.finish();
        self.recorder.snapshot()
    }
}

impl Drop for ClusterService {
    fn drop(&mut self) {
        self.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pending(n: usize, d: usize) -> Pending {
        let (tx, _rx) = channel();
        Pending {
            points: Dataset::zeros(n, d),
            reply: tx,
            enqueued: Instant::now(),
        }
    }

    #[test]
    fn drain_batch_respects_point_budget() {
        let mut q: VecDeque<Pending> =
            [3, 4, 5, 10].into_iter().map(|n| pending(n, 2)).collect();
        // 3 + 4 fit in 8; 5 would overflow.
        let b = drain_batch(&mut q, 8);
        assert_eq!(b.iter().map(|p| p.points.len()).collect::<Vec<_>>(), [3, 4]);
        // 5 fits alone; 10 would overflow.
        let b = drain_batch(&mut q, 8);
        assert_eq!(b.iter().map(|p| p.points.len()).collect::<Vec<_>>(), [5]);
        // Oversized request still ships, alone.
        let b = drain_batch(&mut q, 8);
        assert_eq!(b.iter().map(|p| p.points.len()).collect::<Vec<_>>(), [10]);
        assert!(q.is_empty());
        assert!(drain_batch(&mut q, 8).is_empty());
    }

    #[test]
    fn drain_batch_stops_exactly_at_budget() {
        let mut q: VecDeque<Pending> = (0..4).map(|_| pending(4, 2)).collect();
        let b = drain_batch(&mut q, 8);
        assert_eq!(b.len(), 2, "4 + 4 hits the budget exactly; stop there");
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn ticket_round_trip() {
        let (tx, rx) = channel();
        let h = std::thread::spawn(move || {
            tx.send(PredictReply {
                labels: vec![1, 2],
                distances: vec![0.5, 0.25],
                batched_with: 1,
            })
            .unwrap();
        });
        let r = Ticket { rx }.wait().unwrap();
        h.join().unwrap();
        assert_eq!(r.labels, vec![1, 2]);
        assert_eq!(r.batched_with, 1);
    }

    #[test]
    fn default_config_preserves_immediate_drain() {
        let cfg = ServeConfig::default();
        assert_eq!(cfg.batch_deadline_us, 0);
        assert_eq!(cfg.dispatchers, 1);
    }
}
