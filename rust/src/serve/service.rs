//! The micro-batching cluster service: a bounded request queue, a
//! dispatcher thread that coalesces concurrent predict requests into one
//! panel batch, and `std::thread::scope` panel workers doing the distance
//! arithmetic — the software mirror of the paper's PS core dispatching
//! batched work to multiple PL cores.
//!
//! Control flow:
//!
//! ```text
//! clients ──submit()──> bounded queue ──drain_batch()──> dispatcher ("PS")
//!                                                            │ one PanelJobs batch
//!                                                            ▼
//!                                             Predictor → ParCpuPanels
//!                                             (scope workers = "PL cores")
//!                                                            │ split rows per request
//!                                                            ▼
//! clients <──Ticket::wait()── reply channels <──────── fulfilled replies
//! ```
//!
//! Backpressure is real: `submit` blocks while the queue holds
//! `queue_cap` requests (`try_submit` refuses instead), and shutdown
//! drains the queue before the dispatcher exits, so every accepted
//! request is answered.

use super::metrics::{Recorder, ServeMetrics};
use crate::data::Dataset;
use crate::kmeans::model::KmeansModel;
use crate::kmeans::panel::{PanelKernel, ParCpuPanels};
use crate::kmeans::predict::Predictor;
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Instant;

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bounded queue capacity, in requests; `submit` blocks when full.
    pub queue_cap: usize,
    /// Micro-batcher point budget: queued requests are coalesced into one
    /// panel batch until the next request would push past this many query
    /// points (a single larger request is still served, alone).
    pub max_batch_points: usize,
    /// Panel worker threads (the "PL core" count).
    pub workers: usize,
    /// Panel kernel; `Blocked` is the production profile, `Scalar` the
    /// oracle arithmetic (bit-identical to training-side assignment).
    pub kernel: PanelKernel,
    /// Centroid kd-tree prune override; `None` = the predictor's
    /// model-size auto rule.
    pub prune: Option<bool>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            queue_cap: 256,
            max_batch_points: 4096,
            workers: std::thread::available_parallelism()
                .map(|c| c.get())
                .unwrap_or(1)
                .min(8),
            kernel: PanelKernel::Blocked,
            prune: None,
        }
    }
}

/// Why a request was not accepted / answered.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The service is shut down.
    Closed,
    /// Query dimensionality does not match the model.
    DimMismatch { expected: usize, got: usize },
    /// Bounded queue is full (only from [`ClusterService::try_submit`]).
    Full,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Closed => write!(f, "cluster service is shut down"),
            ServeError::DimMismatch { expected, got } => {
                write!(f, "query dims {got} != model dims {expected}")
            }
            ServeError::Full => write!(f, "request queue is full"),
        }
    }
}

impl std::error::Error for ServeError {}

/// One fulfilled predict request.
#[derive(Clone, Debug)]
pub struct PredictReply {
    /// Assigned centroid index per query point.
    pub labels: Vec<u32>,
    /// Distance to the assigned centroid per query point (squared-L2 for
    /// Euclid, per the repo convention).
    pub distances: Vec<f32>,
    /// How many requests shared this request's panel batch (>= 1; larger
    /// means micro-batching coalesced concurrent clients).
    pub batched_with: usize,
}

/// Handle to one in-flight request; `wait` blocks until the reply lands.
/// Same one-shot mpsc reply-mailbox idiom as the coordinator's offload
/// service.
#[must_use = "a Ticket must be waited on, or its reply is lost"]
pub struct Ticket {
    rx: Receiver<PredictReply>,
}

impl Ticket {
    /// Block until the service answers.  Accepted requests are normally
    /// always answered (shutdown drains the queue before the dispatcher
    /// exits); [`ServeError::Closed`] is returned only if the dispatcher
    /// died abnormally (panicked) with this request still queued.
    pub fn wait(self) -> Result<PredictReply, ServeError> {
        self.rx.recv().map_err(|_| ServeError::Closed)
    }
}

/// A queued request.
struct Pending {
    points: Dataset,
    reply: Sender<PredictReply>,
    enqueued: Instant,
}

#[derive(Default)]
struct QueueState {
    queue: VecDeque<Pending>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
}

impl Shared {
    /// Lock the queue state, recovering from poison: a dispatcher panic
    /// must degrade to [`ServeError::Closed`] on the client side, not
    /// cascade `lock().unwrap()` panics into every caller.
    fn lock_state(&self) -> MutexGuard<'_, QueueState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn wait_on<'a>(
        &self,
        cv: &Condvar,
        guard: MutexGuard<'a, QueueState>,
    ) -> MutexGuard<'a, QueueState> {
        cv.wait(guard).unwrap_or_else(|p| p.into_inner())
    }
}

/// Dropped by the dispatcher thread on *any* exit — normal or panic.
/// Marks the service shut down and clears the queue so queued reply
/// senders drop (turning blocked `Ticket::wait`s into
/// `ServeError::Closed`) and blocked submitters wake into the closed
/// path instead of waiting forever.
struct DispatcherExitGuard(Arc<Shared>);

impl Drop for DispatcherExitGuard {
    fn drop(&mut self) {
        let mut st = self.0.lock_state();
        st.shutdown = true;
        st.queue.clear();
        drop(st);
        self.0.not_empty.notify_all();
        self.0.not_full.notify_all();
    }
}

/// Pop a micro-batch off the queue: consecutive requests until the point
/// budget is hit (a single over-budget request still ships alone).
fn drain_batch(queue: &mut VecDeque<Pending>, max_points: usize) -> Vec<Pending> {
    let mut out = Vec::new();
    let mut pts = 0usize;
    while let Some(front) = queue.front() {
        let take = front.points.len();
        if !out.is_empty() && pts + take > max_points {
            break;
        }
        pts += take;
        out.push(queue.pop_front().unwrap());
        if pts >= max_points {
            break;
        }
    }
    out
}

/// The running micro-batching service; see module docs.
pub struct ClusterService {
    model: Arc<KmeansModel>,
    cfg: ServeConfig,
    shared: Arc<Shared>,
    recorder: Arc<Recorder>,
    dispatcher: Option<JoinHandle<()>>,
}

impl ClusterService {
    /// Start the dispatcher over a trained model.
    pub fn start(model: Arc<KmeansModel>, cfg: ServeConfig) -> Self {
        assert!(cfg.queue_cap >= 1, "queue_cap must be >= 1");
        assert!(cfg.max_batch_points >= 1, "max_batch_points must be >= 1");
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState::default()),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        });
        let recorder = Arc::new(Recorder::new());

        let svc_shared = Arc::clone(&shared);
        let svc_recorder = Arc::clone(&recorder);
        let svc_model = Arc::clone(&model);
        let svc_cfg = cfg.clone();
        let dispatcher = std::thread::Builder::new()
            .name("cluster-serve".into())
            .spawn(move || {
                let _exit_guard = DispatcherExitGuard(Arc::clone(&svc_shared));
                let mut predictor = Predictor::with_backend(
                    svc_model.as_ref(),
                    ParCpuPanels::with_kernel(svc_cfg.workers, svc_cfg.kernel),
                );
                if let Some(on) = svc_cfg.prune {
                    predictor = predictor.prune(on);
                }
                let d = svc_model.dims();
                loop {
                    let batch = {
                        let mut st = svc_shared.lock_state();
                        while st.queue.is_empty() && !st.shutdown {
                            st = svc_shared.wait_on(&svc_shared.not_empty, st);
                        }
                        if st.queue.is_empty() {
                            break; // shutdown requested and queue drained
                        }
                        let b = drain_batch(&mut st.queue, svc_cfg.max_batch_points);
                        svc_shared.not_full.notify_all();
                        b
                    };
                    let nreq = batch.len();
                    let total: usize = batch.iter().map(|p| p.points.len()).sum();
                    let mut flat = Vec::with_capacity(total * d);
                    for p in &batch {
                        flat.extend_from_slice(p.points.flat());
                    }
                    let queries = Dataset::from_flat(total, d, flat);
                    let t0 = Instant::now();
                    let (labels, dists) = predictor.assign_scored(&queries);
                    let busy = t0.elapsed().as_secs_f64();
                    let mut latencies = Vec::with_capacity(nreq);
                    let mut off = 0usize;
                    for p in batch {
                        let n = p.points.len();
                        // Receiver may have given up (client panic); ignore.
                        let _ = p.reply.send(PredictReply {
                            labels: labels[off..off + n].to_vec(),
                            distances: dists[off..off + n].to_vec(),
                            batched_with: nreq,
                        });
                        off += n;
                        latencies.push(p.enqueued.elapsed().as_secs_f64());
                    }
                    svc_recorder.record_batch(total as u64, busy, &latencies);
                }
            })
            .expect("cannot spawn cluster-serve dispatcher");

        Self {
            model,
            cfg,
            shared,
            recorder,
            dispatcher: Some(dispatcher),
        }
    }

    pub fn model(&self) -> &Arc<KmeansModel> {
        &self.model
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    fn check_dims(&self, points: &Dataset) -> Result<(), ServeError> {
        if points.dims() != self.model.dims() {
            return Err(ServeError::DimMismatch {
                expected: self.model.dims(),
                got: points.dims(),
            });
        }
        Ok(())
    }

    fn enqueue(&self, points: Dataset, block: bool) -> Result<Ticket, ServeError> {
        self.check_dims(&points)?;
        let (reply_tx, reply_rx) = channel();
        let pending = Pending {
            points,
            reply: reply_tx,
            enqueued: Instant::now(),
        };
        let mut st = self.shared.lock_state();
        loop {
            if st.shutdown {
                return Err(ServeError::Closed);
            }
            if st.queue.len() < self.cfg.queue_cap {
                break;
            }
            if !block {
                return Err(ServeError::Full);
            }
            st = self.shared.wait_on(&self.shared.not_full, st);
        }
        st.queue.push_back(pending);
        drop(st);
        self.shared.not_empty.notify_one();
        Ok(Ticket { rx: reply_rx })
    }

    /// Enqueue a predict request, blocking while the queue is full
    /// (backpressure).  The returned [`Ticket`] resolves to the reply.
    pub fn submit(&self, points: Dataset) -> Result<Ticket, ServeError> {
        self.enqueue(points, true)
    }

    /// Non-blocking [`submit`](Self::submit): fails with
    /// [`ServeError::Full`] instead of waiting.
    pub fn try_submit(&self, points: Dataset) -> Result<Ticket, ServeError> {
        self.enqueue(points, false)
    }

    /// Submit and wait — the closed-loop client call.
    pub fn predict(&self, points: Dataset) -> Result<PredictReply, ServeError> {
        self.submit(points)?.wait()
    }

    /// Current performance counters (callable while serving).
    pub fn metrics(&self) -> ServeMetrics {
        self.recorder.snapshot()
    }

    fn finish(&mut self) {
        {
            let mut st = self.shared.lock_state();
            st.shutdown = true;
        }
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
        if let Some(j) = self.dispatcher.take() {
            let _ = j.join();
        }
    }

    /// Stop accepting requests, drain the queue, join the dispatcher and
    /// return the final metrics snapshot.
    pub fn shutdown(mut self) -> ServeMetrics {
        self.finish();
        self.recorder.snapshot()
    }
}

impl Drop for ClusterService {
    fn drop(&mut self) {
        self.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pending(n: usize, d: usize) -> Pending {
        let (tx, _rx) = channel();
        Pending {
            points: Dataset::zeros(n, d),
            reply: tx,
            enqueued: Instant::now(),
        }
    }

    #[test]
    fn drain_batch_respects_point_budget() {
        let mut q: VecDeque<Pending> =
            [3, 4, 5, 10].into_iter().map(|n| pending(n, 2)).collect();
        // 3 + 4 fit in 8; 5 would overflow.
        let b = drain_batch(&mut q, 8);
        assert_eq!(b.iter().map(|p| p.points.len()).collect::<Vec<_>>(), [3, 4]);
        // 5 fits alone; 10 would overflow.
        let b = drain_batch(&mut q, 8);
        assert_eq!(b.iter().map(|p| p.points.len()).collect::<Vec<_>>(), [5]);
        // Oversized request still ships, alone.
        let b = drain_batch(&mut q, 8);
        assert_eq!(b.iter().map(|p| p.points.len()).collect::<Vec<_>>(), [10]);
        assert!(q.is_empty());
        assert!(drain_batch(&mut q, 8).is_empty());
    }

    #[test]
    fn drain_batch_stops_exactly_at_budget() {
        let mut q: VecDeque<Pending> = (0..4).map(|_| pending(4, 2)).collect();
        let b = drain_batch(&mut q, 8);
        assert_eq!(b.len(), 2, "4 + 4 hits the budget exactly; stop there");
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn ticket_round_trip() {
        let (tx, rx) = channel();
        let h = std::thread::spawn(move || {
            tx.send(PredictReply {
                labels: vec![1, 2],
                distances: vec![0.5, 0.25],
                batched_with: 1,
            })
            .unwrap();
        });
        let r = Ticket { rx }.wait().unwrap();
        h.join().unwrap();
        assert_eq!(r.labels, vec![1, 2]);
        assert_eq!(r.batched_with, 1);
    }
}
