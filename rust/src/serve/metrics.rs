//! Serving metrics: what the micro-batching [`super::ClusterService`]
//! measures about itself — request/point/batch counts, coalescing
//! quality, wall vs busy time, and end-to-end latency percentiles.
//!
//! The recorder is a single mutex'd accumulator written once per *batch*
//! (not per request) by the dispatcher thread, so contention with the
//! submit path is negligible; snapshots compute percentiles on demand.

use crate::kmeans::bounds::BoundsStats;
use crate::kmeans::panel::KernelStats;
use crate::util::json::Json;
use crate::util::stats::{percentile_sorted, Accum};
use std::sync::Mutex;
use std::time::Instant;

/// Rolling latency window: beyond this many samples new latencies
/// overwrite old ones round-robin, bounding memory for long-lived
/// services while keeping percentiles representative.
const LATENCY_WINDOW: usize = 1 << 18;

/// Point-in-time snapshot of a service's performance counters.
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    /// Predict requests fulfilled.
    pub requests: u64,
    /// Query points across all fulfilled requests.
    pub points: u64,
    /// Panel batches executed (each coalesces >= 1 request).
    pub batches: u64,
    /// Mean requests coalesced per batch (the micro-batching win).
    pub mean_batch_requests: f64,
    /// Mean points per executed batch — how full the panel batches run
    /// (read against the configured point budget for fill ratio).
    pub mean_batch_points: f64,
    /// Largest number of requests coalesced into one batch.
    pub max_batch_requests: u64,
    /// Largest number of points in one batch.
    pub max_batch_points: u64,
    /// Wall-clock seconds since the service started.
    pub wall_s: f64,
    /// Seconds the dispatcher spent inside panel execution.
    pub busy_s: f64,
    /// End-to-end request latency percentiles (submit → reply), ms.
    pub latency_p50_ms: f64,
    pub latency_p95_ms: f64,
    pub latency_p99_ms: f64,
    pub latency_max_ms: f64,
    /// Fulfilled points per wall second.
    pub throughput_pps: f64,
    /// Fulfilled requests per wall second.
    pub throughput_rps: f64,
    /// Fraction of wall time the dispatcher spent inside panel execution
    /// (a low value under load points at queueing, not compute).
    pub busy_frac: f64,
    /// Requests refused at admission because the bounded queue stayed
    /// full past a [`submit_timeout`](super::ClusterService::submit_timeout)
    /// deadline — the shed load under saturation.
    pub rejected: u64,
    /// SIMD lane width of the dispatcher's panel kernel (8 = AVX2,
    /// 4 = NEON, 0 = scalar/blocked tier) — a gauge, not a counter.
    pub simd_lanes: u32,
    /// Candidates scored through the reduced-precision i8 shortlist path.
    pub quantized_candidates: u64,
    /// Shortlist survivors re-scored in exact f32 (the parity guarantee's
    /// cost; `rescored / quantized` is the shortlist survival rate).
    pub rescored_candidates: u64,
    /// Queries whose candidate list the triangle-inequality bounds tier
    /// (DESIGN.md §10) collapsed to a single, still-kernel-scored
    /// survivor.
    pub bound_pruned_points: u64,
    /// Candidate entries the bounds tier removed before paneling.
    pub bound_pruned_candidates: u64,
    /// True-distance evaluations spent maintaining the bounds (the
    /// per-snapshot k×k matrix plus per-query pivot distances).
    pub bounds_matrix_cost: u64,
}

impl ServeMetrics {
    /// One-line human summary for CLI output.
    pub fn summary(&self) -> String {
        format!(
            "serve: {} reqs ({} pts) in {} batches over {:.2}s wall ({:.2}s busy, \
             {:.0}% duty) | {:.1} req/batch (max {}), {:.1} pts/batch (max {}) | \
             {:.0} pts/s, {:.0} req/s | {} rejected | \
             kernel {} lanes, {} quantized / {} rescored | \
             bounds {} pruned pts / {} pruned cands / {} matrix cost | \
             latency p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms max {:.2}ms",
            self.requests,
            self.points,
            self.batches,
            self.wall_s,
            self.busy_s,
            self.busy_frac * 100.0,
            self.mean_batch_requests,
            self.max_batch_requests,
            self.mean_batch_points,
            self.max_batch_points,
            self.throughput_pps,
            self.throughput_rps,
            self.rejected,
            self.simd_lanes,
            self.quantized_candidates,
            self.rescored_candidates,
            self.bound_pruned_points,
            self.bound_pruned_candidates,
            self.bounds_matrix_cost,
            self.latency_p50_ms,
            self.latency_p95_ms,
            self.latency_p99_ms,
            self.latency_max_ms,
        )
    }

    /// Machine-readable form (the `BENCH_serve.json` payload).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::num(self.requests as f64)),
            ("points", Json::num(self.points as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("mean_batch_requests", Json::num(self.mean_batch_requests)),
            ("mean_batch_points", Json::num(self.mean_batch_points)),
            ("max_batch_requests", Json::num(self.max_batch_requests as f64)),
            ("max_batch_points", Json::num(self.max_batch_points as f64)),
            ("wall_s", Json::num(self.wall_s)),
            ("busy_s", Json::num(self.busy_s)),
            ("latency_p50_ms", Json::num(self.latency_p50_ms)),
            ("latency_p95_ms", Json::num(self.latency_p95_ms)),
            ("latency_p99_ms", Json::num(self.latency_p99_ms)),
            ("latency_max_ms", Json::num(self.latency_max_ms)),
            ("throughput_pps", Json::num(self.throughput_pps)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("busy_frac", Json::num(self.busy_frac)),
            ("rejected", Json::num(self.rejected as f64)),
            ("simd_lanes", Json::num(self.simd_lanes as f64)),
            ("quantized_candidates", Json::num(self.quantized_candidates as f64)),
            ("rescored_candidates", Json::num(self.rescored_candidates as f64)),
            ("bound_pruned_points", Json::num(self.bound_pruned_points as f64)),
            (
                "bound_pruned_candidates",
                Json::num(self.bound_pruned_candidates as f64),
            ),
            ("bounds_matrix_cost", Json::num(self.bounds_matrix_cost as f64)),
        ])
    }
}

#[derive(Debug, Default)]
struct State {
    requests: u64,
    points: u64,
    batches: u64,
    batch_requests: Accum,
    max_batch_points: u64,
    busy_s: f64,
    /// Rolling window of request latencies (seconds).
    latencies: Vec<f64>,
    /// Total latencies ever recorded (drives the rolling overwrite).
    recorded: u64,
    /// Requests shed at admission (deadline submits against a full queue).
    rejected: u64,
    /// Kernel-tier telemetry: lane gauge + lifetime candidate counters.
    kernel: KernelStats,
    /// Bounds-tier telemetry (all counters; accumulate across batches).
    bounds: BoundsStats,
}

/// Shared recorder: dispatcher writes, snapshots read.
#[derive(Debug)]
pub(crate) struct Recorder {
    state: Mutex<State>,
    started: Instant,
}

impl Recorder {
    pub(crate) fn new() -> Self {
        Self {
            state: Mutex::new(State::default()),
            started: Instant::now(),
        }
    }

    /// Record one executed batch: how many requests/points it coalesced,
    /// panel-execution seconds, and the per-request end-to-end latencies.
    pub(crate) fn record_batch(&self, points: u64, busy_s: f64, latencies_s: &[f64]) {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        st.requests += latencies_s.len() as u64;
        st.points += points;
        st.batches += 1;
        st.batch_requests.add(latencies_s.len() as f64);
        st.max_batch_points = st.max_batch_points.max(points);
        st.busy_s += busy_s;
        for &l in latencies_s {
            if st.latencies.len() < LATENCY_WINDOW {
                st.latencies.push(l);
            } else {
                let slot = (st.recorded as usize) % LATENCY_WINDOW;
                st.latencies[slot] = l;
            }
            st.recorded += 1;
        }
    }

    /// Count one request refused at admission (queue stayed full past the
    /// caller's submit deadline).
    pub(crate) fn record_rejection(&self) {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        st.rejected += 1;
    }

    /// Fold in one batch's kernel-telemetry delta (lane width is a gauge
    /// and overwrites; candidate counters accumulate).
    pub(crate) fn record_kernel(&self, delta: KernelStats) {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        st.kernel.simd_lanes = delta.simd_lanes;
        st.kernel.quantized_candidates += delta.quantized_candidates;
        st.kernel.rescored_candidates += delta.rescored_candidates;
    }

    /// Fold in one batch's bounds-telemetry delta (all three accumulate).
    pub(crate) fn record_bounds(&self, delta: BoundsStats) {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        st.bounds.absorb(&delta);
    }

    pub(crate) fn snapshot(&self) -> ServeMetrics {
        // Copy everything out under the lock, then release it before the
        // O(n log n) sort so a metrics poll never stalls the dispatcher's
        // record_batch behind a quarter-million-sample sort.
        let st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        let (requests, points, batches) = (st.requests, st.points, st.batches);
        let (mean_batch_requests, max_batch_requests) =
            (st.batch_requests.mean(), st.batch_requests.max as u64);
        let (max_batch_points, busy_s) = (st.max_batch_points, st.busy_s);
        let rejected = st.rejected;
        let kernel = st.kernel;
        let bounds = st.bounds;
        let mut lat = st.latencies.clone();
        drop(st);
        let wall_s = self.started.elapsed().as_secs_f64();
        let ms = 1e3;
        // One copy + one sort serves every percentile (and the max).
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ServeMetrics {
            requests,
            points,
            batches,
            mean_batch_requests,
            mean_batch_points: if batches > 0 {
                points as f64 / batches as f64
            } else {
                0.0
            },
            max_batch_requests,
            max_batch_points,
            wall_s,
            busy_s,
            busy_frac: if wall_s > 0.0 { (busy_s / wall_s).min(1.0) } else { 0.0 },
            latency_p50_ms: percentile_sorted(&lat, 50.0) * ms,
            latency_p95_ms: percentile_sorted(&lat, 95.0) * ms,
            latency_p99_ms: percentile_sorted(&lat, 99.0) * ms,
            latency_max_ms: lat.last().copied().unwrap_or(0.0) * ms,
            throughput_pps: if wall_s > 0.0 { points as f64 / wall_s } else { 0.0 },
            throughput_rps: if wall_s > 0.0 { requests as f64 / wall_s } else { 0.0 },
            rejected,
            simd_lanes: kernel.simd_lanes,
            quantized_candidates: kernel.quantized_candidates,
            rescored_candidates: kernel.rescored_candidates,
            bound_pruned_points: bounds.pruned_points,
            bound_pruned_candidates: bounds.pruned_candidates,
            bounds_matrix_cost: bounds.matrix_cost,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recorder_accumulates_and_snapshots() {
        let r = Recorder::new();
        r.record_batch(30, 0.01, &[0.001, 0.002, 0.003]);
        r.record_batch(10, 0.02, &[0.004]);
        let m = r.snapshot();
        assert_eq!(m.requests, 4);
        assert_eq!(m.points, 40);
        assert_eq!(m.batches, 2);
        assert_eq!(m.max_batch_requests, 3);
        assert_eq!(m.max_batch_points, 30);
        assert!((m.mean_batch_requests - 2.0).abs() < 1e-12);
        assert!((m.mean_batch_points - 20.0).abs() < 1e-12);
        assert!((m.busy_s - 0.03).abs() < 1e-12);
        assert!(m.busy_frac >= 0.0 && m.busy_frac <= 1.0);
        assert!(m.latency_max_ms >= m.latency_p99_ms);
        assert!(m.latency_p99_ms >= m.latency_p50_ms);
        assert!((m.latency_max_ms - 4.0).abs() < 1e-9);
        assert!(m.wall_s >= 0.0);
        assert!(m.throughput_rps > 0.0);
    }

    #[test]
    fn summary_and_json_carry_the_headline_numbers() {
        let r = Recorder::new();
        r.record_batch(64, 0.5, &[0.010; 8]);
        let m = r.snapshot();
        let s = m.summary();
        assert!(s.contains("8 reqs"), "{s}");
        assert!(s.contains("64 pts"), "{s}");
        let j = m.to_json();
        assert_eq!(j.get("requests").unwrap().as_usize().unwrap(), 8);
        assert_eq!(j.get("points").unwrap().as_usize().unwrap(), 64);
        assert!(j.get("latency_p50_ms").unwrap().as_f64().unwrap() > 9.0);
        assert_eq!(j.get("mean_batch_points").unwrap().as_f64().unwrap(), 64.0);
        assert!(j.get("busy_frac").unwrap().as_f64().is_some());
        assert_eq!(j.get("rejected").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn json_snapshot_carries_every_declared_counter() {
        // The complete ServeMetrics field set, pinned: `pallas-lint`'s
        // metrics-parity rule enforces this statically, this test proves
        // it dynamically (a field in both emitters but with a typo'd key
        // would pass the lint's token scan yet fail here).
        const FIELDS: [&str; 23] = [
            "requests",
            "points",
            "batches",
            "mean_batch_requests",
            "mean_batch_points",
            "max_batch_requests",
            "max_batch_points",
            "wall_s",
            "busy_s",
            "latency_p50_ms",
            "latency_p95_ms",
            "latency_p99_ms",
            "latency_max_ms",
            "throughput_pps",
            "throughput_rps",
            "busy_frac",
            "rejected",
            "simd_lanes",
            "quantized_candidates",
            "rescored_candidates",
            "bound_pruned_points",
            "bound_pruned_candidates",
            "bounds_matrix_cost",
        ];
        let r = Recorder::new();
        r.record_batch(16, 0.1, &[0.002; 4]);
        let m = r.snapshot();
        let j = m.to_json();
        let obj = j.as_obj().expect("serve metrics must serialize to an object");
        for f in FIELDS {
            assert!(obj.contains_key(f), "missing JSON key {f}");
        }
        assert_eq!(obj.len(), FIELDS.len(), "undocumented extra JSON keys");
        // And the human summary mentions the max-points coalescing bound
        // (the counter the parity rule once caught missing).
        assert!(m.summary().contains("pts/batch (max 16)"), "{}", m.summary());
    }

    #[test]
    fn rejections_are_counted_separately_from_requests() {
        let r = Recorder::new();
        r.record_rejection();
        r.record_rejection();
        r.record_batch(8, 0.01, &[0.001]);
        let m = r.snapshot();
        assert_eq!(m.rejected, 2);
        assert_eq!(m.requests, 1, "rejections never count as fulfilled");
        assert!(m.summary().contains("2 rejected"), "{}", m.summary());
    }

    #[test]
    fn kernel_telemetry_accumulates_counters_and_gauges_lanes() {
        let r = Recorder::new();
        r.record_kernel(KernelStats {
            simd_lanes: 8,
            quantized_candidates: 100,
            rescored_candidates: 12,
        });
        r.record_kernel(KernelStats {
            simd_lanes: 8,
            quantized_candidates: 50,
            rescored_candidates: 5,
        });
        let m = r.snapshot();
        assert_eq!(m.simd_lanes, 8, "lane width is a gauge");
        assert_eq!(m.quantized_candidates, 150, "counters accumulate");
        assert_eq!(m.rescored_candidates, 17);
        assert!(m.summary().contains("8 lanes"), "{}", m.summary());
        assert!(m.summary().contains("150 quantized / 17 rescored"), "{}", m.summary());
        let j = m.to_json();
        assert_eq!(j.get("quantized_candidates").unwrap().as_usize().unwrap(), 150);
    }

    #[test]
    fn bounds_telemetry_accumulates_all_three_counters() {
        let r = Recorder::new();
        r.record_bounds(BoundsStats {
            pruned_points: 5,
            pruned_candidates: 200,
            matrix_cost: 1128,
        });
        r.record_bounds(BoundsStats {
            pruned_points: 3,
            pruned_candidates: 100,
            matrix_cost: 8,
        });
        let m = r.snapshot();
        assert_eq!(m.bound_pruned_points, 8);
        assert_eq!(m.bound_pruned_candidates, 300);
        assert_eq!(m.bounds_matrix_cost, 1136);
        assert!(
            m.summary().contains("bounds 8 pruned pts / 300 pruned cands / 1136 matrix cost"),
            "{}",
            m.summary()
        );
        let j = m.to_json();
        assert_eq!(j.get("bound_pruned_candidates").unwrap().as_usize().unwrap(), 300);
    }

    #[test]
    fn empty_recorder_snapshot_is_zeroed() {
        let m = Recorder::new().snapshot();
        assert_eq!(m.requests, 0);
        assert_eq!(m.batches, 0);
        assert_eq!(m.latency_p50_ms, 0.0);
        assert_eq!(m.throughput_pps, 0.0);
    }
}
