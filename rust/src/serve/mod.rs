//! Serving layer: the fit/predict split's online half.
//!
//! [`ClusterService`] owns a trained [`crate::kmeans::KmeansModel`] and a
//! bounded request queue; P dispatcher threads (`ServeConfig::dispatchers`
//! — the serve-side face of the shard plane) micro-batch concurrent
//! predict requests into distance-panel batches executed across
//! `std::thread::scope` workers (via the [`crate::kmeans::predict`]
//! engine) — the software mirror of the paper's PS→multi-core-PL
//! dispatch, pointed at the ROADMAP's "heavy traffic" north star.
//! The micro-batcher can trade latency for coalescing via
//! `ServeConfig::batch_deadline_us`, and [`ClusterService::reload`] swaps
//! the served model warm (queue intact, dimension changes rejected).
//! [`ServeMetrics`] reports throughput, coalescing quality and latency
//! percentiles; the CLI's `serve-bench` subcommand drives a closed-loop
//! load through it and emits `BENCH_serve.json`.

pub mod metrics;
pub mod service;

pub use metrics::ServeMetrics;
pub use service::{ClusterService, PredictReply, ServeConfig, ServeError, Ticket};
