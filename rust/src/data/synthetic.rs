//! Synthetic workload generator.
//!
//! Reproduces the paper's evaluation recipe (section 5): "the test case is
//! generated with normal distribution with varying standard deviation, and
//! all centroids are distributed between data points uniformly" — i.e.
//! `true_k` cluster centers placed uniformly in a box, with points drawn
//! from isotropic normals around them.

use super::dataset::Dataset;
use crate::config::WorkloadConfig;
use crate::util::rng::Xoshiro256pp;

/// A generated dataset together with its ground truth.
#[derive(Clone, Debug)]
pub struct Synthetic {
    pub data: Dataset,
    /// Planted cluster centers, `[true_k, d]`.
    pub true_centroids: Dataset,
    /// Planted label of each point.
    pub labels: Vec<u32>,
}

/// Generate per the workload recipe.  Deterministic in `w.seed`.
pub fn generate(w: &WorkloadConfig) -> Synthetic {
    generate_params(w.n, w.d, w.true_k, w.sigma, w.spread, w.seed)
}

/// Explicit-parameter form used by sweeps.
pub fn generate_params(
    n: usize,
    d: usize,
    true_k: usize,
    sigma: f32,
    spread: f32,
    seed: u64,
) -> Synthetic {
    assert!(n >= 1 && d >= 1 && true_k >= 1);
    let mut rng = Xoshiro256pp::seed_from_u64(seed);

    // Centers uniform in [-spread, spread]^d.
    let mut centers = Vec::with_capacity(true_k * d);
    for _ in 0..true_k * d {
        centers.push(rng.uniform_f32(-spread, spread));
    }
    let true_centroids = Dataset::from_flat(true_k, d, centers);

    // Points: round-robin cluster membership then shuffled, so every
    // cluster is populated (the paper's workloads are balanced mixtures).
    let mut order: Vec<u32> = (0..n).map(|i| (i % true_k) as u32).collect();
    rng.shuffle(&mut order);

    let mut flat = Vec::with_capacity(n * d);
    for &lbl in &order {
        let c = true_centroids.point(lbl as usize);
        for &cj in c {
            flat.push(rng.normal(cj, sigma));
        }
    }

    Synthetic {
        data: Dataset::from_flat(n, d, flat),
        true_centroids,
        labels: order,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let w = WorkloadConfig {
            n: 500,
            d: 4,
            k: 3,
            true_k: 3,
            seed: 7,
            ..Default::default()
        };
        let a = generate(&w);
        let b = generate(&w);
        assert_eq!(a.data, b.data);
        assert_eq!(a.labels, b.labels);
        let c = generate(&WorkloadConfig { seed: 8, ..w });
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn shapes_and_label_range() {
        let s = generate_params(1000, 5, 7, 0.1, 1.0, 1);
        assert_eq!(s.data.len(), 1000);
        assert_eq!(s.data.dims(), 5);
        assert_eq!(s.true_centroids.len(), 7);
        assert_eq!(s.labels.len(), 1000);
        assert!(s.labels.iter().all(|&l| (l as usize) < 7));
        // Balanced mixture: every planted cluster appears.
        let mut counts = [0usize; 7];
        for &l in &s.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c >= 1000 / 7 - 1));
    }

    #[test]
    fn points_cluster_around_their_centers() {
        let sigma = 0.05f32;
        let s = generate_params(2000, 3, 4, sigma, 2.0, 3);
        // Mean squared distance from a point to its planted center should
        // be ~ d * sigma^2.
        let mut acc = 0f64;
        for (i, p) in s.data.iter().enumerate() {
            let c = s.true_centroids.point(s.labels[i] as usize);
            let d2: f32 = p.iter().zip(c).map(|(a, b)| (a - b) * (a - b)).sum();
            acc += d2 as f64;
        }
        let msd = acc / s.data.len() as f64;
        let expect = 3.0 * (sigma as f64) * (sigma as f64);
        assert!((msd - expect).abs() < expect * 0.2, "msd {msd} vs {expect}");
    }

    #[test]
    fn centers_respect_spread() {
        let s = generate_params(10, 2, 50, 0.0, 1.5, 11);
        for c in s.true_centroids.iter() {
            assert!(c.iter().all(|&v| (-1.5..1.5).contains(&v)));
        }
    }

    #[test]
    fn zero_sigma_collapses_to_centers() {
        let s = generate_params(100, 2, 5, 0.0, 1.0, 13);
        for (i, p) in s.data.iter().enumerate() {
            let c = s.true_centroids.point(s.labels[i] as usize);
            assert_eq!(p, c);
        }
    }
}
