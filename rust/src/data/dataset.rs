//! Dense f32 dataset storage.
//!
//! Points are stored row-major in one flat allocation (`[n, d]`), which is
//! what the kd-tree builder, the software kernels and the PJRT runtime all
//! consume directly — no per-point boxing, no pointer chasing on the hot
//! path.

/// A dense `[n, d]` matrix of f32 points.
#[derive(Clone, Debug, PartialEq)]
pub struct Dataset {
    n: usize,
    d: usize,
    data: Vec<f32>,
}

impl Dataset {
    /// Construct from a flat row-major buffer. Panics if the length is not
    /// `n * d`.
    pub fn from_flat(n: usize, d: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), n * d, "flat buffer length mismatch");
        assert!(d > 0, "dimensionality must be positive");
        Self { n, d, data }
    }

    pub fn zeros(n: usize, d: usize) -> Self {
        Self::from_flat(n, d, vec![0.0; n * d])
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    #[inline]
    pub fn dims(&self) -> usize {
        self.d
    }

    /// Borrow point `i` as a `&[f32; d]` slice.
    #[inline]
    pub fn point(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    #[inline]
    pub fn point_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.d..(i + 1) * self.d]
    }

    /// The whole flat buffer (row-major).
    #[inline]
    pub fn flat(&self) -> &[f32] {
        &self.data
    }

    /// Iterate over points as slices.
    pub fn iter(&self) -> impl Iterator<Item = &[f32]> {
        self.data.chunks_exact(self.d)
    }

    /// Gather a subset of rows into a new dataset (used by `Quarter`).
    pub fn gather(&self, idx: &[usize]) -> Dataset {
        let mut out = Vec::with_capacity(idx.len() * self.d);
        for &i in idx {
            out.extend_from_slice(self.point(i));
        }
        Dataset::from_flat(idx.len(), self.d, out)
    }

    /// Split into `parts` contiguous chunks whose sizes differ by at most
    /// one point.  Returns (datasets, starting row of each chunk).
    pub fn split_contiguous(&self, parts: usize) -> (Vec<Dataset>, Vec<usize>) {
        assert!(parts >= 1);
        let base = self.n / parts;
        let rem = self.n % parts;
        let mut out = Vec::with_capacity(parts);
        let mut offsets = Vec::with_capacity(parts);
        let mut start = 0usize;
        for p in 0..parts {
            let take = base + usize::from(p < rem);
            offsets.push(start);
            let chunk = self.data[start * self.d..(start + take) * self.d].to_vec();
            out.push(Dataset::from_flat(take, self.d, chunk));
            start += take;
        }
        (out, offsets)
    }

    /// Per-dimension bounding box `(mins, maxs)` over all points.
    pub fn bounds(&self) -> (Vec<f32>, Vec<f32>) {
        let mut mins = vec![f32::INFINITY; self.d];
        let mut maxs = vec![f32::NEG_INFINITY; self.d];
        for p in self.iter() {
            for (j, &v) in p.iter().enumerate() {
                if v < mins[j] {
                    mins[j] = v;
                }
                if v > maxs[j] {
                    maxs[j] = v;
                }
            }
        }
        (mins, maxs)
    }

    /// Size in bytes (the DDR3-capacity bookkeeping of section 4.2).
    pub fn bytes(&self) -> u64 {
        (self.data.len() * std::mem::size_of::<f32>()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> Dataset {
        Dataset::from_flat(3, 2, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0])
    }

    #[test]
    fn indexing_and_iter() {
        let d = ds();
        assert_eq!(d.len(), 3);
        assert_eq!(d.dims(), 2);
        assert_eq!(d.point(1), &[2.0, 3.0]);
        let pts: Vec<&[f32]> = d.iter().collect();
        assert_eq!(pts.len(), 3);
        assert_eq!(pts[2], &[4.0, 5.0]);
        assert_eq!(d.bytes(), 24);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn bad_flat_length_panics() {
        Dataset::from_flat(2, 3, vec![0.0; 5]);
    }

    #[test]
    fn gather_rows() {
        let d = ds();
        let g = d.gather(&[2, 0]);
        assert_eq!(g.len(), 2);
        assert_eq!(g.point(0), &[4.0, 5.0]);
        assert_eq!(g.point(1), &[0.0, 1.0]);
    }

    #[test]
    fn split_contiguous_covers_everything() {
        let d = Dataset::from_flat(10, 1, (0..10).map(|i| i as f32).collect());
        let (parts, offs) = d.split_contiguous(4);
        assert_eq!(parts.len(), 4);
        assert_eq!(offs, vec![0, 3, 6, 8]);
        let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
        let total: usize = sizes.iter().sum();
        assert_eq!(total, 10);
        // order preserved
        assert_eq!(parts[1].point(0), &[3.0]);
        assert_eq!(parts[3].point(1), &[9.0]);
    }

    #[test]
    fn split_more_parts_than_points() {
        let d = Dataset::from_flat(2, 1, vec![1.0, 2.0]);
        let (parts, _) = d.split_contiguous(4);
        let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        assert_eq!(sizes, vec![1, 1, 0, 0]);
    }

    #[test]
    fn bounds() {
        let d = Dataset::from_flat(3, 2, vec![0.0, 5.0, -1.0, 3.0, 4.0, 4.0]);
        let (mins, maxs) = d.bounds();
        assert_eq!(mins, vec![-1.0, 3.0]);
        assert_eq!(maxs, vec![4.0, 5.0]);
    }

    #[test]
    fn point_mut_writes_through() {
        let mut d = ds();
        d.point_mut(0)[1] = 9.0;
        assert_eq!(d.point(0), &[0.0, 9.0]);
    }
}
