//! Dataset substrate: dense storage, the paper's synthetic workload
//! recipe, and CSV I/O for external data.

pub mod csv;
pub mod dataset;
pub mod synthetic;

pub use dataset::Dataset;
pub use synthetic::{generate, generate_params, Synthetic};
