//! CSV load/save for datasets (plain comma-separated f32 rows, optional
//! `#` comment/header lines).  Used by the CLI so real datasets can be fed
//! through the same pipeline as the synthetic workloads.

use super::dataset::Dataset;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

#[derive(Debug)]
pub enum CsvError {
    Io(std::io::Error),
    Parse { line: usize, msg: String },
    Empty,
}

impl std::fmt::Display for CsvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CsvError::Io(e) => write!(f, "io error: {e}"),
            CsvError::Parse { line, msg } => write!(f, "line {line}: {msg}"),
            CsvError::Empty => write!(f, "empty dataset"),
        }
    }
}

impl std::error::Error for CsvError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CsvError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CsvError {
    fn from(e: std::io::Error) -> Self {
        CsvError::Io(e)
    }
}

/// Load a dataset; every non-comment line must have the same number of
/// comma-separated f32 fields.
pub fn load(path: &Path) -> Result<Dataset, CsvError> {
    let file = std::fs::File::open(path)?;
    read(BufReader::new(file))
}

/// Parse from any reader (exposed for tests).
pub fn read<R: BufRead>(reader: R) -> Result<Dataset, CsvError> {
    let mut flat: Vec<f32> = Vec::new();
    let mut d: Option<usize> = None;
    let mut n = 0usize;
    for (ln, line) in reader.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut count = 0usize;
        for field in t.split(',') {
            let v: f32 = field.trim().parse().map_err(|_| CsvError::Parse {
                line: ln + 1,
                msg: format!("bad float `{}`", field.trim()),
            })?;
            if !v.is_finite() {
                return Err(CsvError::Parse {
                    line: ln + 1,
                    msg: format!("non-finite value `{v}`"),
                });
            }
            flat.push(v);
            count += 1;
        }
        match d {
            None => d = Some(count),
            Some(dd) if dd != count => {
                return Err(CsvError::Parse {
                    line: ln + 1,
                    msg: format!("expected {dd} fields, found {count}"),
                })
            }
            _ => {}
        }
        n += 1;
    }
    let d = d.ok_or(CsvError::Empty)?;
    Ok(Dataset::from_flat(n, d, flat))
}

/// Save a dataset as CSV.
pub fn save(ds: &Dataset, path: &Path) -> Result<(), CsvError> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    writeln!(w, "# muchswift dataset: n={} d={}", ds.len(), ds.dims())?;
    for p in ds.iter() {
        let row: Vec<String> = p.iter().map(|v| format!("{v}")).collect();
        writeln!(w, "{}", row.join(","))?;
    }
    w.flush()?;
    Ok(())
}

/// Save cluster assignments (one `u32` label per line, `#` header) — the
/// `cluster --out` / `predict --out` artifact.
pub fn save_labels(labels: &[u32], path: &Path) -> Result<(), CsvError> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    writeln!(w, "# muchswift assignments: n={}", labels.len())?;
    for &l in labels {
        writeln!(w, "{l}")?;
    }
    w.flush()?;
    Ok(())
}

/// Load assignments written by [`save_labels`].
pub fn load_labels(path: &Path) -> Result<Vec<u32>, CsvError> {
    let file = std::fs::File::open(path)?;
    let mut out = Vec::new();
    for (ln, line) in BufReader::new(file).lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        out.push(t.parse::<u32>().map_err(|_| CsvError::Parse {
            line: ln + 1,
            msg: format!("bad label `{t}`"),
        })?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_simple() {
        let ds = read(Cursor::new("1,2,3\n4,5,6\n")).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.dims(), 3);
        assert_eq!(ds.point(1), &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn skips_comments_and_blanks() {
        let ds = read(Cursor::new("# header\n\n1.5, -2\n# mid\n3,4\n")).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.point(0), &[1.5, -2.0]);
    }

    #[test]
    fn rejects_ragged_and_bad_floats() {
        assert!(matches!(
            read(Cursor::new("1,2\n3\n")),
            Err(CsvError::Parse { line: 2, .. })
        ));
        assert!(matches!(
            read(Cursor::new("1,x\n")),
            Err(CsvError::Parse { line: 1, .. })
        ));
        assert!(matches!(read(Cursor::new("")), Err(CsvError::Empty)));
        assert!(matches!(
            read(Cursor::new("inf,1\n")),
            Err(CsvError::Parse { .. })
        ));
    }

    #[test]
    fn labels_roundtrip_and_reject_garbage() {
        let dir = std::env::temp_dir().join("muchswift_labels_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("labels.csv");
        let labels = vec![0u32, 3, 1, 1, 7];
        save_labels(&labels, &path).unwrap();
        assert_eq!(load_labels(&path).unwrap(), labels);
        std::fs::write(&path, "# h\n1\n-2\n").unwrap();
        assert!(matches!(
            load_labels(&path),
            Err(CsvError::Parse { line: 3, .. })
        ));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn save_load_roundtrip() {
        let ds = Dataset::from_flat(3, 2, vec![0.5, -1.25, 3.0, 4.0, -0.0625, 7.5]);
        let dir = std::env::temp_dir().join("muchswift_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.csv");
        save(&ds, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(ds, back);
        std::fs::remove_file(&path).ok();
    }
}
