//! Axis-aligned bounding boxes and the filtering algorithm's geometric
//! pruning test (`is_farther`, Alg. 1 line 9 — Kanungo et al. [7], Lemma).

use crate::kmeans::metrics::Metric;

/// An axis-aligned box `[min, max]^d`.
#[derive(Clone, Debug, PartialEq)]
pub struct BBox {
    pub min: Box<[f32]>,
    pub max: Box<[f32]>,
}

impl BBox {
    pub fn new(min: Vec<f32>, max: Vec<f32>) -> Self {
        assert_eq!(min.len(), max.len());
        debug_assert!(min.iter().zip(max.iter()).all(|(a, b)| a <= b));
        Self {
            min: min.into_boxed_slice(),
            max: max.into_boxed_slice(),
        }
    }

    /// Smallest box containing the given points (slice of rows).
    pub fn of_points<'a>(points: impl Iterator<Item = &'a [f32]>, d: usize) -> Self {
        let mut min = vec![f32::INFINITY; d];
        let mut max = vec![f32::NEG_INFINITY; d];
        for p in points {
            for j in 0..d {
                if p[j] < min[j] {
                    min[j] = p[j];
                }
                if p[j] > max[j] {
                    max[j] = p[j];
                }
            }
        }
        Self::new(min, max)
    }

    #[inline]
    pub fn dims(&self) -> usize {
        self.min.len()
    }

    /// Cell midpoint (the query point of Alg. 1 line 7).
    pub fn midpoint(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.dims()];
        self.midpoint_into(&mut out);
        out
    }

    /// Allocation-free midpoint into a caller scratch buffer (§Perf L3-3:
    /// the filtering hot loop calls this once per interior node visit).
    #[inline]
    pub fn midpoint_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dims());
        for j in 0..self.dims() {
            out[j] = 0.5 * (self.min[j] + self.max[j]);
        }
    }

    /// Widest dimension and its extent (the split axis rule).
    pub fn widest_dim(&self) -> (usize, f32) {
        let mut dim = 0;
        let mut ext = -1.0f32;
        for j in 0..self.dims() {
            let e = self.max[j] - self.min[j];
            if e > ext {
                ext = e;
                dim = j;
            }
        }
        (dim, ext)
    }

    pub fn contains(&self, p: &[f32]) -> bool {
        p.iter()
            .enumerate()
            .all(|(j, &v)| v >= self.min[j] && v <= self.max[j])
    }

    /// The filtering prune test: is candidate `z` farther than `z_star`
    /// from *every* point of this box?  If so, `z` can never win inside the
    /// cell and is dropped from the candidate set.
    ///
    /// Exact for both metrics:
    /// - Euclid: compare distances to the box vertex extremal in the
    ///   direction `z - z_star` (Kanungo et al. [7]).
    /// - Manhattan: L1 separates per dimension, so we maximize
    ///   `|z*_j - v| - |z_j - v|` over `v` in `[min_j, max_j]` per
    ///   dimension (attained at an interval endpoint or at `v = z_j`) and
    ///   prune iff the summed maximum is <= 0.
    pub fn is_farther(&self, z: &[f32], z_star: &[f32], metric: Metric) -> bool {
        match metric {
            Metric::Euclid => {
                let mut dz = 0f32; // squared dist from z to extremal vertex
                let mut dzs = 0f32; // squared dist from z_star to same vertex
                for j in 0..self.dims() {
                    // Vertex component farthest along z - z_star.
                    let v = if z[j] > z_star[j] {
                        self.max[j]
                    } else {
                        self.min[j]
                    };
                    let a = z[j] - v;
                    let b = z_star[j] - v;
                    dz += a * a;
                    dzs += b * b;
                }
                dz >= dzs
            }
            Metric::Manhattan => {
                // max over box of [ d1(z*, v) - d1(z, v) ]  <=  0  ==> prune
                let mut gap = 0f32;
                for j in 0..self.dims() {
                    let f = |v: f32| (z_star[j] - v).abs() - (z[j] - v).abs();
                    let mut m = f(self.min[j]).max(f(self.max[j]));
                    if z[j] >= self.min[j] && z[j] <= self.max[j] {
                        m = m.max(f(z[j]));
                    }
                    gap += m;
                }
                gap <= 0.0
            }
        }
    }

    /// Distance from `p` to the nearest point of the box (0 inside) —
    /// squared for [`Metric::Euclid`], matching the squared-L2-end-to-end
    /// convention.  This is the classic branch-and-bound lower bound: for
    /// every point `c` in the box, `min_dist(p) <= dist(p, c)`, so a
    /// subtree whose box bound exceeds the current best can be skipped
    /// (the predictor's kd-tree-over-centroids prune uses exactly this).
    #[inline]
    pub fn min_dist(&self, p: &[f32], metric: Metric) -> f32 {
        debug_assert_eq!(p.len(), self.dims());
        let mut acc = 0f32;
        for j in 0..self.dims() {
            let v = p[j];
            let excess = if v < self.min[j] {
                self.min[j] - v
            } else if v > self.max[j] {
                v - self.max[j]
            } else {
                0.0
            };
            acc += match metric {
                Metric::Euclid => excess * excess,
                Metric::Manhattan => excess,
            };
        }
        acc
    }

    /// Merge with another box (used when combining quarter kd-trees).
    pub fn union(&self, other: &BBox) -> BBox {
        let min = self
            .min
            .iter()
            .zip(other.min.iter())
            .map(|(a, b)| a.min(*b))
            .collect();
        let max = self
            .max
            .iter()
            .zip(other.max.iter())
            .map(|(a, b)| a.max(*b))
            .collect();
        BBox::new(min, max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmeans::metrics::{l1, sq_l2};
    use crate::util::proptest::proptest;
    use crate::util::rng::Xoshiro256pp;

    fn unit_box(d: usize) -> BBox {
        BBox::new(vec![0.0; d], vec![1.0; d])
    }

    #[test]
    fn midpoint_and_widest() {
        let b = BBox::new(vec![0.0, -2.0], vec![1.0, 4.0]);
        assert_eq!(b.midpoint(), vec![0.5, 1.0]);
        assert_eq!(b.widest_dim(), (1, 6.0));
        assert!(b.contains(&[0.5, 0.0]));
        assert!(!b.contains(&[1.5, 0.0]));
    }

    #[test]
    fn of_points_is_tight() {
        let pts: Vec<Vec<f32>> = vec![vec![1.0, 5.0], vec![-3.0, 2.0], vec![0.0, 7.0]];
        let b = BBox::of_points(pts.iter().map(|p| p.as_slice()), 2);
        assert_eq!(&*b.min, &[-3.0, 2.0]);
        assert_eq!(&*b.max, &[1.0, 7.0]);
    }

    #[test]
    fn is_farther_obvious_cases() {
        let b = unit_box(2);
        // z way outside, z* at center: z farther from every box point.
        assert!(b.is_farther(&[10.0, 10.0], &[0.5, 0.5], Metric::Euclid));
        assert!(b.is_farther(&[10.0, 10.0], &[0.5, 0.5], Metric::Manhattan));
        // z inside the box can never be pruned against an outside z*.
        assert!(!b.is_farther(&[0.5, 0.5], &[10.0, 10.0], Metric::Euclid));
        assert!(!b.is_farther(&[0.5, 0.5], &[10.0, 10.0], Metric::Manhattan));
    }

    /// Exhaustive-grid verification of the pruning test: `is_farther` must
    /// imply `dist(z, v) >= dist(z*, v)` for a dense sample of `v` in the
    /// box, and must not fire when some sampled `v` prefers `z`.
    #[test]
    fn is_farther_agrees_with_dense_sampling() {
        for metric in [Metric::Euclid, Metric::Manhattan] {
            proptest(200, |g| {
                let d = g.usize_in(1, 4);
                let mut lo = g.vec_f32(d, -2.0, 2.0);
                let mut hi = g.vec_f32(d, -2.0, 2.0);
                for j in 0..d {
                    if lo[j] > hi[j] {
                        std::mem::swap(&mut lo[j], &mut hi[j]);
                    }
                }
                let b = BBox::new(lo.clone(), hi.clone());
                let z = g.vec_f32(d, -3.0, 3.0);
                let zs = g.vec_f32(d, -3.0, 3.0);
                let pruned = b.is_farther(&z, &zs, metric);

                // Sample box points on a grid + random interior points.
                let mut rng = Xoshiro256pp::seed_from_u64(g.case as u64);
                let mut violated = false;
                for _ in 0..200 {
                    let v: Vec<f32> = (0..d)
                        .map(|j| rng.uniform_f32(lo[j], hi[j].max(lo[j] + f32::EPSILON)))
                        .collect();
                    let (dz, dzs) = match metric {
                        Metric::Euclid => (sq_l2(&z, &v), sq_l2(&zs, &v)),
                        Metric::Manhattan => (l1(&z, &v), l1(&zs, &v)),
                    };
                    if dz < dzs - 1e-5 {
                        violated = true;
                        break;
                    }
                }
                if pruned && violated {
                    return Err(format!(
                        "pruned but a box point prefers z: z={z:?} z*={zs:?} box=({lo:?},{hi:?}) metric={metric:?}"
                    ));
                }
                Ok(())
            });
        }
    }

    #[test]
    fn min_dist_hand_values_and_lower_bound() {
        let b = unit_box(2);
        // Inside → 0 for both metrics.
        assert_eq!(b.min_dist(&[0.5, 0.5], Metric::Euclid), 0.0);
        assert_eq!(b.min_dist(&[0.5, 0.5], Metric::Manhattan), 0.0);
        // Outside along one axis.
        assert_eq!(b.min_dist(&[2.0, 0.5], Metric::Euclid), 1.0);
        assert_eq!(b.min_dist(&[2.0, 0.5], Metric::Manhattan), 1.0);
        // Corner: squared-L2 vs L1.
        assert_eq!(b.min_dist(&[2.0, -1.0], Metric::Euclid), 2.0);
        assert_eq!(b.min_dist(&[2.0, -1.0], Metric::Manhattan), 2.0);
        // Lower-bound property against random box points.
        for metric in [Metric::Euclid, Metric::Manhattan] {
            proptest(100, |g| {
                let d = g.usize_in(1, 4);
                let mut lo = g.vec_f32(d, -2.0, 2.0);
                let mut hi = g.vec_f32(d, -2.0, 2.0);
                for j in 0..d {
                    if lo[j] > hi[j] {
                        std::mem::swap(&mut lo[j], &mut hi[j]);
                    }
                }
                let b = BBox::new(lo.clone(), hi.clone());
                let p = g.vec_f32(d, -4.0, 4.0);
                let bound = b.min_dist(&p, metric);
                let mut rng = Xoshiro256pp::seed_from_u64(g.case as u64 ^ 0x5EED);
                for _ in 0..100 {
                    let v: Vec<f32> = (0..d)
                        .map(|j| rng.uniform_f32(lo[j], hi[j].max(lo[j] + f32::EPSILON)))
                        .collect();
                    let dd = match metric {
                        Metric::Euclid => sq_l2(&p, &v),
                        Metric::Manhattan => l1(&p, &v),
                    };
                    if dd < bound - 1e-5 {
                        return Err(format!(
                            "min_dist not a lower bound: {bound} vs {dd} (p={p:?} box=({lo:?},{hi:?}) {metric:?})"
                        ));
                    }
                }
                Ok(())
            });
        }
    }

    #[test]
    fn union_covers_both() {
        let a = BBox::new(vec![0.0, 0.0], vec![1.0, 1.0]);
        let b = BBox::new(vec![-1.0, 0.5], vec![0.5, 2.0]);
        let u = a.union(&b);
        assert_eq!(&*u.min, &[-1.0, 0.0]);
        assert_eq!(&*u.max, &[1.0, 2.0]);
    }
}
