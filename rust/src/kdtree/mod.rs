//! Binary kd-tree for the filtering algorithm (paper section 3).
//!
//! Arena-allocated (children are `u32` indices into one `Vec<Node>`) so
//! traversal is cache-friendly and the whole tree frees in O(1).  Each node
//! stores exactly what Alg. 1 needs: the cell bounding box, the number of
//! points in the subtree (`count`), and the weighted centroid (`wgt_cent` —
//! the *sum* of the subtree's points).  Leaves own a small bucket of points
//! (a range of the permutation array) rather than exactly one point; the
//! filtering recursion handles buckets point-by-point, which preserves the
//! algorithm's semantics while keeping the node count and memory footprint
//! practical for 10^6-point workloads.

pub mod bbox;

pub use bbox::BBox;

use crate::data::Dataset;

/// Sentinel meaning "no child".
pub const NIL: u32 = u32::MAX;

/// One kd-tree node.
#[derive(Clone, Debug)]
pub struct Node {
    /// Tight bounding box of the points in this subtree (a subset of the
    /// split cell, so every pruning argument about the cell still holds,
    /// and tighter boxes prune strictly more).
    pub bbox: BBox,
    /// Sum of all points in the subtree (`wgtCent` of Alg. 1).
    pub wgt_cent: Box<[f32]>,
    /// Number of points in the subtree.
    pub count: u32,
    /// Children (NIL for leaves — always both or neither).
    pub left: u32,
    pub right: u32,
    /// Range `[start, start+len)` of `KdTree::perm` covered by the subtree.
    pub start: u32,
    pub len: u32,
    /// Depth of the node (root = 0) — used by the level-batched offload.
    pub depth: u16,
}

impl Node {
    #[inline]
    pub fn is_leaf(&self) -> bool {
        self.left == NIL
    }
}

/// The tree: an arena of nodes plus the point permutation.
#[derive(Clone, Debug)]
pub struct KdTree {
    pub nodes: Vec<Node>,
    /// Permutation of dataset row indices; each node covers a contiguous
    /// range of this array.
    pub perm: Vec<u32>,
    pub dims: usize,
    /// Leaf bucket capacity used at build time.
    pub leaf_size: usize,
}

/// Default leaf bucket size (see module docs).
pub const DEFAULT_LEAF_SIZE: usize = 8;

/// Below this many points a build stays single-threaded — the spawn and
/// merge overhead of the parallel build would exceed the split work.
pub const PAR_BUILD_MIN_POINTS: usize = 1 << 15;

/// Split-phase work item: node index + covered permutation range.
struct Work {
    node: u32,
    start: usize,
    len: usize,
    depth: u16,
}

/// Default parallel hand-off depth for `n` points on this machine:
/// 0 (sequential) for small inputs, otherwise deep enough to give each
/// available core a subtree (capped at depth 2 = 4 subtrees, the paper's
/// quad-A53 analogue).
fn auto_par_depth(n: usize) -> usize {
    if n < PAR_BUILD_MIN_POINTS {
        return 0;
    }
    let cores = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1);
    match cores {
        0 | 1 => 0,
        2 | 3 => 1,
        _ => 2,
    }
}

impl KdTree {
    /// Build over all points of `data` with the default leaf size.
    pub fn build(data: &Dataset) -> Self {
        Self::build_with(data, DEFAULT_LEAF_SIZE)
    }

    /// Build with an explicit leaf bucket capacity (>= 1).  Large inputs
    /// are built in parallel (see [`KdTree::build_par`]); the resulting
    /// tree geometry is identical to a sequential build — the split rule
    /// is deterministic and threads own disjoint permutation ranges — only
    /// the node arena order differs.
    pub fn build_with(data: &Dataset, leaf_size: usize) -> Self {
        Self::build_par(data, leaf_size, auto_par_depth(data.len()))
    }

    /// Build with an explicit parallel hand-off depth: the split phase
    /// runs single-threaded down to `par_depth`, then every surviving
    /// subtree at that depth is built by its own `std::thread::scope`
    /// worker on a disjoint slice of the permutation.  `par_depth == 0`
    /// is the fully sequential build.
    ///
    /// Split rule: median split (via quickselect) on the widest dimension
    /// of the node's tight bounding box — guarantees both children are
    /// non-empty and depth is O(log n) regardless of data skew.
    pub fn build_par(data: &Dataset, leaf_size: usize, par_depth: usize) -> Self {
        assert!(leaf_size >= 1);
        assert!(!data.is_empty(), "cannot build a kd-tree over zero points");
        let d = data.dims();
        let n = data.len();
        let mut perm: Vec<u32> = (0..n as u32).collect();
        // Arena capacity estimate: ~2 * ceil(n / leaf) internal+leaf nodes.
        let mut nodes: Vec<Node> = Vec::with_capacity(2 * (n / leaf_size + 2));

        // ---- top phase: sequential splits above the hand-off depth -----
        nodes.push(Self::make_node_seg(data, &perm, 0, n, 0, 0));
        let mut stack = vec![Work {
            node: 0,
            start: 0,
            len: n,
            depth: 0,
        }];
        let mut frontier: Vec<Work> = Vec::new();

        while let Some(w) = stack.pop() {
            if w.len <= leaf_size {
                continue; // stays a leaf
            }
            let (dim, extent) = nodes[w.node as usize].bbox.widest_dim();
            if extent <= 0.0 {
                // All points identical: cannot split meaningfully.
                continue;
            }
            if par_depth > 0 && w.depth as usize >= par_depth {
                frontier.push(w);
                continue;
            }
            let seg = &mut perm[w.start..w.start + w.len];
            let mid = w.len / 2;
            // §Perf L3-2: `total_cmp` is branch-free (bit tricks), and the
            // data is finite by construction (synthetic gen + CSV loader
            // both reject non-finite values), where total order == <=.
            seg.select_nth_unstable_by(mid, |&a, &b| {
                let va = data.point(a as usize)[dim];
                let vb = data.point(b as usize)[dim];
                va.total_cmp(&vb)
            });

            let left_idx = nodes.len() as u32;
            nodes.push(Self::make_node_seg(data, &perm, w.start, mid, 0, w.depth + 1));
            let right_idx = nodes.len() as u32;
            nodes.push(Self::make_node_seg(
                data,
                &perm,
                w.start + mid,
                w.len - mid,
                0,
                w.depth + 1,
            ));
            let node = &mut nodes[w.node as usize];
            node.left = left_idx;
            node.right = right_idx;
            stack.push(Work {
                node: left_idx,
                start: w.start,
                len: mid,
                depth: w.depth + 1,
            });
            stack.push(Work {
                node: right_idx,
                start: w.start + mid,
                len: w.len - mid,
                depth: w.depth + 1,
            });
        }

        // ---- parallel phase: one worker per frontier subtree -----------
        if !frontier.is_empty() {
            // Deterministic order (the stack pops right-first); each item
            // covers a disjoint contiguous range of `perm`.
            frontier.sort_by_key(|w| w.start);
            let mut results: Vec<Vec<Node>> = Vec::with_capacity(frontier.len());
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(frontier.len());
                let mut rest: &mut [u32] = &mut perm[..];
                let mut consumed = 0usize;
                for w in &frontier {
                    let (_, tail) = rest.split_at_mut(w.start - consumed);
                    let (seg, tail) = tail.split_at_mut(w.len);
                    rest = tail;
                    consumed = w.start + w.len;
                    let root = nodes[w.node as usize].clone();
                    let (abs_start, depth) = (w.start, w.depth);
                    handles.push(scope.spawn(move || {
                        Self::build_subtree(data, seg, abs_start, depth, leaf_size, root)
                    }));
                }
                for h in handles {
                    results.push(h.join().expect("kd-tree build worker panicked"));
                }
            });
            // Merge, remapping subtree-local indices into the shared arena
            // (local 0 is the frontier node itself, already in place).
            for (w, local) in frontier.iter().zip(results) {
                let base = nodes.len() as u32;
                for (li, mut node) in local.into_iter().enumerate() {
                    if node.left != NIL {
                        node.left = base + node.left - 1;
                        node.right = base + node.right - 1;
                    }
                    if li == 0 {
                        nodes[w.node as usize] = node;
                    } else {
                        nodes.push(node);
                    }
                }
            }
        }

        Self {
            nodes,
            perm,
            dims: d,
            leaf_size,
        }
    }

    /// Build one subtree over a disjoint permutation segment into a local
    /// arena (indices local; entry 0 is `root`).  `abs_start` anchors the
    /// segment's absolute position so `Node::start` stays global.
    fn build_subtree(
        data: &Dataset,
        seg: &mut [u32],
        abs_start: usize,
        depth0: u16,
        leaf_size: usize,
        root: Node,
    ) -> Vec<Node> {
        let mut nodes = vec![root];
        let mut stack = vec![Work {
            node: 0,
            start: 0,
            len: seg.len(),
            depth: depth0,
        }];
        while let Some(w) = stack.pop() {
            if w.len <= leaf_size {
                continue;
            }
            let (dim, extent) = nodes[w.node as usize].bbox.widest_dim();
            if extent <= 0.0 {
                continue;
            }
            let sub = &mut seg[w.start..w.start + w.len];
            let mid = w.len / 2;
            sub.select_nth_unstable_by(mid, |&a, &b| {
                let va = data.point(a as usize)[dim];
                let vb = data.point(b as usize)[dim];
                va.total_cmp(&vb)
            });

            let left_idx = nodes.len() as u32;
            nodes.push(Self::make_node_seg(data, seg, w.start, mid, abs_start, w.depth + 1));
            let right_idx = nodes.len() as u32;
            nodes.push(Self::make_node_seg(
                data,
                seg,
                w.start + mid,
                w.len - mid,
                abs_start,
                w.depth + 1,
            ));
            let node = &mut nodes[w.node as usize];
            node.left = left_idx;
            node.right = right_idx;
            stack.push(Work {
                node: left_idx,
                start: w.start,
                len: mid,
                depth: w.depth + 1,
            });
            stack.push(Work {
                node: right_idx,
                start: w.start + mid,
                len: w.len - mid,
                depth: w.depth + 1,
            });
        }
        nodes
    }

    /// Make a node over `seg[lo..lo+len]`; `abs_start + lo` is the range's
    /// absolute position in the full permutation.
    fn make_node_seg(
        data: &Dataset,
        seg: &[u32],
        lo: usize,
        len: usize,
        abs_start: usize,
        depth: u16,
    ) -> Node {
        let d = data.dims();
        // Single fused pass over the subtree's points for bbox min/max and
        // the weighted-centroid sum (§Perf L3-1: the build walks every
        // point once per level, so touching each row once instead of twice
        // cuts build time substantially).
        let mut min = vec![f32::INFINITY; d];
        let mut max = vec![f32::NEG_INFINITY; d];
        let mut wgt = vec![0f32; d];
        for &i in &seg[lo..lo + len] {
            let p = data.point(i as usize);
            for j in 0..d {
                let v = p[j];
                if v < min[j] {
                    min[j] = v;
                }
                if v > max[j] {
                    max[j] = v;
                }
                wgt[j] += v;
            }
        }
        Node {
            bbox: BBox::new(min, max),
            wgt_cent: wgt.into_boxed_slice(),
            count: len as u32,
            left: NIL,
            right: NIL,
            start: (abs_start + lo) as u32,
            len: len as u32,
            depth,
        }
    }

    #[inline]
    pub fn root(&self) -> &Node {
        &self.nodes[0]
    }

    /// Dataset row indices held by a (leaf) node.
    pub fn node_points<'a>(&'a self, node: &Node) -> &'a [u32] {
        &self.perm[node.start as usize..(node.start + node.len) as usize]
    }

    /// Maximum depth over all nodes.
    pub fn depth(&self) -> usize {
        self.nodes.iter().map(|n| n.depth as usize).max().unwrap_or(0)
    }

    /// Number of leaves.
    pub fn leaves(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_leaf()).count()
    }

    /// Approximate resident bytes (nodes + permutation) — the section 4.2
    /// DDR3 bookkeeping uses this.
    pub fn bytes(&self) -> u64 {
        let per_node = std::mem::size_of::<Node>() as u64 + (3 * self.dims * 4) as u64;
        self.nodes.len() as u64 * per_node + self.perm.len() as u64 * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::generate_params;
    use crate::util::proptest::proptest;

    fn check_invariants(tree: &KdTree, data: &Dataset) {
        let d = data.dims();
        // Permutation is a permutation.
        let mut p = tree.perm.clone();
        p.sort_unstable();
        assert_eq!(p, (0..data.len() as u32).collect::<Vec<_>>());

        for node in &tree.nodes {
            // Count matches range length.
            assert_eq!(node.count as usize, node.len as usize);
            // wgt_cent is the sum of covered points; bbox contains them.
            let mut sum = vec![0f64; d];
            for &i in tree.node_points(node) {
                let pt = data.point(i as usize);
                assert!(node.bbox.contains(pt), "bbox must contain subtree points");
                for j in 0..d {
                    sum[j] += pt[j] as f64;
                }
            }
            for j in 0..d {
                let got = node.wgt_cent[j] as f64;
                assert!(
                    (got - sum[j]).abs() <= 1e-3 * (1.0 + sum[j].abs()),
                    "wgt_cent mismatch: {got} vs {}",
                    sum[j]
                );
            }
            // Children partition the parent's range.
            if !node.is_leaf() {
                let l = &tree.nodes[node.left as usize];
                let r = &tree.nodes[node.right as usize];
                assert_eq!(l.start, node.start);
                assert_eq!(l.len + r.len, node.len);
                assert_eq!(r.start, node.start + l.len);
                assert!(l.count > 0 && r.count > 0, "median split never empties a side");
                assert_eq!(l.depth, node.depth + 1);
            } else {
                assert!(
                    node.len as usize <= tree.leaf_size
                        || node.bbox.widest_dim().1 <= 0.0,
                    "oversized leaf must be degenerate (all points equal)"
                );
            }
        }
    }

    #[test]
    fn build_small_and_invariants() {
        let s = generate_params(500, 3, 4, 0.2, 1.0, 5);
        let tree = KdTree::build(&s.data);
        check_invariants(&tree, &s.data);
        assert!(tree.depth() <= 16, "depth {} too deep for 500 pts", tree.depth());
        assert!(tree.leaves() >= 500 / DEFAULT_LEAF_SIZE / 2);
    }

    #[test]
    fn leaf_size_one_gives_singleton_leaves() {
        let s = generate_params(64, 2, 2, 0.3, 1.0, 9);
        let tree = KdTree::build_with(&s.data, 1);
        check_invariants(&tree, &s.data);
        for n in tree.nodes.iter().filter(|n| n.is_leaf()) {
            assert_eq!(n.len, 1);
        }
    }

    #[test]
    fn degenerate_identical_points() {
        let data = Dataset::from_flat(10, 2, vec![1.0; 20]);
        let tree = KdTree::build_with(&data, 2);
        check_invariants(&tree, &data);
        // Unsplittable: single (leaf) root with all 10 points.
        assert_eq!(tree.nodes.len(), 1);
        assert!(tree.root().is_leaf());
    }

    #[test]
    fn single_point_tree() {
        let data = Dataset::from_flat(1, 3, vec![1.0, 2.0, 3.0]);
        let tree = KdTree::build(&data);
        assert_eq!(tree.nodes.len(), 1);
        assert_eq!(tree.root().count, 1);
        assert_eq!(&*tree.root().wgt_cent, &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn build_property_random_shapes() {
        proptest(30, |g| {
            let n = g.size(1, 400);
            let d = g.usize_in(1, 6);
            let leaf = g.usize_in(1, 16);
            let sigma = g.f32_in(0.0, 0.5);
            let s = generate_params(n, d, g.usize_in(1, 5), sigma, 1.0, g.case as u64);
            let tree = KdTree::build_with(&s.data, leaf);
            // Reuse the assert-based checker; convert panics to Err.
            let r = std::panic::catch_unwind(|| check_invariants(&tree, &s.data));
            r.map_err(|e| format!("invariant violated (n={n} d={d} leaf={leaf}): {e:?}"))
        });
    }

    #[test]
    fn depth_is_logarithmic() {
        let s = generate_params(4096, 2, 8, 0.25, 1.0, 3);
        let tree = KdTree::build_with(&s.data, 1);
        // Median splits: depth == ceil(log2(4096)) = 12 (+1 slack).
        assert!(tree.depth() <= 13, "depth {}", tree.depth());
    }

    /// The parallel build produces the same tree geometry as the
    /// sequential build — identical permutation and identical node set
    /// (order in the arena may differ).
    #[test]
    fn parallel_build_matches_sequential_geometry() {
        for (n, d, leaf, par_depth) in
            [(2000, 3, 8, 2), (513, 2, 1, 3), (64, 4, 4, 2), (40, 2, 16, 2)]
        {
            let s = generate_params(n, d, 4, 0.25, 1.0, 77);
            let seq = KdTree::build_par(&s.data, leaf, 0);
            let par = KdTree::build_par(&s.data, leaf, par_depth);
            check_invariants(&par, &s.data);
            assert_eq!(seq.perm, par.perm, "n={n} leaf={leaf}");
            assert_eq!(seq.nodes.len(), par.nodes.len());
            assert_eq!(seq.depth(), par.depth());
            assert_eq!(seq.leaves(), par.leaves());
            let key = |t: &KdTree| {
                let mut v: Vec<(u32, u32, u16, bool)> = t
                    .nodes
                    .iter()
                    .map(|nd| (nd.start, nd.len, nd.depth, nd.is_leaf()))
                    .collect();
                v.sort_unstable();
                v
            };
            assert_eq!(key(&seq), key(&par), "node multiset differs (n={n})");
        }
    }

    /// Degenerate data through the parallel path: unsplittable subtrees
    /// stay leaves, invariants hold.
    #[test]
    fn parallel_build_degenerate_data() {
        let mut flat = vec![1.0f32; 400];
        // Two distinct columns so the root splits once, then each half is
        // constant along every axis.
        for v in flat.iter_mut().skip(200) {
            *v = 2.0;
        }
        let data = Dataset::from_flat(200, 2, flat);
        let tree = KdTree::build_par(&data, 4, 2);
        check_invariants(&tree, &data);
        assert_eq!(tree.root().count, 200);
    }
}
