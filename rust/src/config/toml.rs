//! TOML-subset parser for platform/workload config files.
//!
//! Supports the subset the `configs/*.toml` files use: `[section]` headers,
//! `key = value` with string / integer / float / bool / homogeneous array
//! values, `#` comments, and bare or dotted keys.  No inline tables, no
//! multi-line strings, no datetime — config files in this repo don't need
//! them (and the offline crate set has no `toml`).

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed document: `section.key -> value`.  Keys outside any section are
/// stored under their bare name.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    pub entries: BTreeMap<String, Value>,
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl Doc {
    pub fn parse(src: &str) -> Result<Doc, TomlError> {
        let mut doc = Doc::default();
        let mut section = String::new();
        for (ln, raw) in src.lines().enumerate() {
            let line = ln + 1;
            let trimmed = strip_comment(raw).trim().to_string();
            if trimmed.is_empty() {
                continue;
            }
            if let Some(inner) = trimmed.strip_prefix('[') {
                let name = inner.strip_suffix(']').ok_or(TomlError {
                    line,
                    msg: "unterminated section header".into(),
                })?;
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(TomlError {
                        line,
                        msg: "empty section name".into(),
                    });
                }
                continue;
            }
            let (key, raw_val) = trimmed.split_once('=').ok_or(TomlError {
                line,
                msg: "expected `key = value`".into(),
            })?;
            let key = key.trim();
            if key.is_empty() {
                return Err(TomlError {
                    line,
                    msg: "empty key".into(),
                });
            }
            let full_key = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let value = parse_value(raw_val.trim()).map_err(|msg| TomlError { line, msg })?;
            doc.entries.insert(full_key, value);
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Value::as_f64)
    }

    pub fn usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(Value::as_usize)
    }

    pub fn str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Value::as_str)
    }

    pub fn bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Value::as_bool)
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        if inner.contains('"') {
            return Err("embedded quote in string".into());
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let mut items = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(parse_value(part)?);
        }
        return Ok(Value::Array(items));
    }
    // Numbers: underscores allowed as separators, scientific notation ok.
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if !cleaned.contains('.') && !cleaned.contains('e') && !cleaned.contains('E') {
        if let Ok(i) = cleaned.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value `{s}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = Doc::parse(
            r#"
            # top comment
            name = "zcu102"
            [pl]
            freq_hz = 300_000_000
            efficiency = 0.7   # trailing comment
            enabled = true
            ks = [2, 3, 4]
            "#,
        )
        .unwrap();
        assert_eq!(doc.str("name"), Some("zcu102"));
        assert_eq!(doc.usize("pl.freq_hz"), Some(300_000_000));
        assert_eq!(doc.f64("pl.efficiency"), Some(0.7));
        assert_eq!(doc.bool("pl.enabled"), Some(true));
        match doc.get("pl.ks").unwrap() {
            Value::Array(a) => assert_eq!(a.len(), 3),
            v => panic!("expected array, got {v:?}"),
        }
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = Doc::parse(r##"tag = "a#b""##).unwrap();
        assert_eq!(doc.str("tag"), Some("a#b"));
    }

    #[test]
    fn numeric_forms() {
        let doc = Doc::parse("a = 1e9\nb = -3\nc = 2.5\nd = 1_000").unwrap();
        assert_eq!(doc.f64("a"), Some(1e9));
        assert_eq!(doc.get("b").unwrap().as_i64(), Some(-3));
        assert_eq!(doc.f64("c"), Some(2.5));
        assert_eq!(doc.usize("d"), Some(1000));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Doc::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
        let err = Doc::parse("[unterminated").unwrap_err();
        assert_eq!(err.line, 1);
        assert!(Doc::parse("x = ").is_err());
        assert!(Doc::parse("x = \"abc").is_err());
        assert!(Doc::parse("x = [1, 2").is_err());
        assert!(Doc::parse("x = zzz").is_err());
    }

    #[test]
    fn int_vs_float_and_usize_conversion() {
        let doc = Doc::parse("i = 5\nf = 5.0\nneg = -1").unwrap();
        assert_eq!(doc.get("i").unwrap().as_i64(), Some(5));
        assert_eq!(doc.get("f").unwrap().as_i64(), None);
        assert_eq!(doc.usize("neg"), None);
        assert_eq!(doc.f64("i"), Some(5.0));
    }
}
