//! Configuration system: platform models (`configs/*.toml`) and workload
//! descriptions, parsed with the in-crate TOML-subset parser.

pub mod platform;
pub mod toml;
pub mod workload;

pub use platform::PlatformConfig;
pub use workload::WorkloadConfig;
