//! Workload description: what to cluster, with which algorithm parameters.

use super::toml::Doc;
use crate::kmeans::metrics::Metric;
use std::path::Path;

/// A clustering workload (dataset recipe + algorithm parameters).
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadConfig {
    /// Number of data points.
    pub n: usize,
    /// Dimensionality of each point.
    pub d: usize,
    /// Number of clusters to find.
    pub k: usize,
    /// Number of planted generator clusters (defaults to `k`).
    pub true_k: usize,
    /// Standard deviation of each planted normal cluster (the paper sweeps
    /// this: "normal distribution with varying standard deviation").
    pub sigma: f32,
    /// Half-width of the box centroids are placed in uniformly.
    pub spread: f32,
    /// Distance metric (the paper's PL computes Manhattan; the analysis
    /// uses Euclidean — both are supported end to end).
    pub metric: Metric,
    /// Lloyd / filtering convergence threshold on centroid movement
    /// (squared L2 per centroid).
    pub tol: f32,
    /// Iteration cap.
    pub max_iters: usize,
    /// RNG seed for data generation and initialization.
    pub seed: u64,
    /// Level-1 shard count P for the two-level architecture (the paper's
    /// 4; the shard plane and the MUCH-SWIFT cost model scale with it).
    pub shards: usize,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self {
            n: 100_000,
            d: 15,
            k: 8,
            true_k: 8,
            sigma: 0.15,
            spread: 1.0,
            metric: Metric::Euclid,
            tol: 1e-6,
            max_iters: 100,
            seed: 42,
            shards: 4,
        }
    }
}

impl WorkloadConfig {
    /// A workload sized like the paper's Fig. 3 evaluation point
    /// (10^6 points, 15 dimensions).
    pub fn fig3(k: usize) -> Self {
        Self {
            n: 1_000_000,
            d: 15,
            k,
            true_k: k,
            ..Self::default()
        }
    }

    pub fn from_toml_file(path: &Path) -> anyhow::Result<Self> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let doc = Doc::parse(&src)?;
        Self::from_doc(&doc)
    }

    pub fn from_doc(doc: &Doc) -> anyhow::Result<Self> {
        let mut w = Self::default();
        if let Some(v) = doc.usize("workload.n") {
            w.n = v;
        }
        if let Some(v) = doc.usize("workload.d") {
            w.d = v;
        }
        if let Some(v) = doc.usize("workload.k") {
            w.k = v;
            w.true_k = v;
        }
        if let Some(v) = doc.usize("workload.true_k") {
            w.true_k = v;
        }
        if let Some(v) = doc.f64("workload.sigma") {
            w.sigma = v as f32;
        }
        if let Some(v) = doc.f64("workload.spread") {
            w.spread = v as f32;
        }
        if let Some(v) = doc.str("workload.metric") {
            w.metric = v.parse()?;
        }
        if let Some(v) = doc.f64("workload.tol") {
            w.tol = v as f32;
        }
        if let Some(v) = doc.usize("workload.max_iters") {
            w.max_iters = v;
        }
        if let Some(v) = doc.usize("workload.seed") {
            w.seed = v as u64;
        }
        if let Some(v) = doc.usize("workload.shards") {
            w.shards = v;
        }
        w.validate()?;
        Ok(w)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n >= 1, "n must be >= 1");
        anyhow::ensure!(self.d >= 1, "d must be >= 1");
        anyhow::ensure!(self.k >= 1, "k must be >= 1");
        anyhow::ensure!(self.k <= self.n, "k={} exceeds n={}", self.k, self.n);
        anyhow::ensure!(self.true_k >= 1, "true_k must be >= 1");
        anyhow::ensure!(self.sigma >= 0.0, "sigma must be non-negative");
        anyhow::ensure!(self.max_iters >= 1, "max_iters must be >= 1");
        anyhow::ensure!(self.shards >= 1, "shards must be >= 1");
        Ok(())
    }

    /// Dataset footprint in bytes (f32), used by the DDR3 capacity check
    /// the paper makes in section 4.2.
    pub fn dataset_bytes(&self) -> u64 {
        (self.n as u64) * (self.d as u64) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        WorkloadConfig::default().validate().unwrap();
        WorkloadConfig::fig3(100).validate().unwrap();
    }

    #[test]
    fn from_doc_round_trip() {
        let doc = Doc::parse(
            r#"
            [workload]
            n = 5000
            d = 3
            k = 7
            sigma = 0.25
            metric = "manhattan"
            seed = 9
            "#,
        )
        .unwrap();
        let w = WorkloadConfig::from_doc(&doc).unwrap();
        assert_eq!(w.n, 5000);
        assert_eq!(w.d, 3);
        assert_eq!(w.k, 7);
        assert_eq!(w.true_k, 7);
        assert_eq!(w.sigma, 0.25);
        assert_eq!(w.metric, Metric::Manhattan);
        assert_eq!(w.seed, 9);
        assert_eq!(w.shards, 4, "shards defaults to the paper quartet");
        let doc = Doc::parse("[workload]\nshards = 8").unwrap();
        assert_eq!(WorkloadConfig::from_doc(&doc).unwrap().shards, 8);
        let doc = Doc::parse("[workload]\nshards = 0").unwrap();
        assert!(WorkloadConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn invalid_workloads_rejected() {
        let doc = Doc::parse("[workload]\nn = 2\nk = 5").unwrap();
        assert!(WorkloadConfig::from_doc(&doc).is_err());
        let doc = Doc::parse("[workload]\nmetric = \"cosine\"").unwrap();
        assert!(WorkloadConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn dataset_bytes_matches_paper_example() {
        // Paper section 4.2: N = 100000, K = 1024 fits easily in 1 GB.
        let w = WorkloadConfig {
            n: 100_000,
            d: 15,
            ..Default::default()
        };
        assert_eq!(w.dataset_bytes(), 100_000 * 15 * 4);
        assert!(w.dataset_bytes() < (1 << 30));
    }
}
