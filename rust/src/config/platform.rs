//! Platform model configuration: the ZCU102 / Zynq UltraScale+ parameters
//! the hardware simulator and cost models consume.
//!
//! Defaults reproduce the paper's board (section 4): quad Cortex-A53 @
//! 1.5 GHz, dual Cortex-R5 @ 600 MHz, ZU9EG programmable logic, 1 GB DDR3
//! with a 128-bit bus, a 128-bit AXI PS<->PL link, a 64-bit AXI DMA channel
//! between PCIe and DDR3, and a BRAM-based FIFO bridge into the PL.
//! All numbers are overridable from a TOML file (`configs/zcu102.toml`) so
//! ablations can sweep them.

use super::toml::Doc;
use std::path::Path;

/// Frequencies, bus widths and cost-model constants for one platform.
#[derive(Clone, Debug, PartialEq)]
pub struct PlatformConfig {
    /// Human-readable platform name.
    pub name: String,

    // ---- processing system ------------------------------------------------
    /// Cortex-A53 application cores ("workers" of the two-level scheme).
    pub a53_cores: usize,
    pub a53_freq_hz: f64,
    /// Cortex-R5 control cores (DMA handling + update stage control).
    pub r5_cores: usize,
    pub r5_freq_hz: f64,

    // ---- programmable logic ----------------------------------------------
    pub pl_freq_hz: f64,
    /// Distance-pipeline depth (cycles of fill before first result).
    pub pl_pipeline_depth: u64,
    /// f32 lanes consumed per PL cycle per module (128-bit AXI beat = 4).
    pub pl_lanes: usize,
    /// Largest cluster count with fully parallel per-cluster modules
    /// (Table 1: K = 20 exhausts the ZU9EG; beyond that modules are shared).
    pub pl_max_parallel_clusters: usize,

    // ---- interconnect & memory -------------------------------------------
    /// Effective PCIe host->board bandwidth, bytes/s (Gen2 x4 ~ 1.6 GB/s).
    pub pcie_bytes_per_s: f64,
    /// Per-DMA-descriptor setup latency, seconds.
    pub pcie_setup_s: f64,
    /// DDR3 peak bandwidth, bytes/s (128-bit @ 1066 MT/s ~ 17 GB/s raw;
    /// the paper's 1 GB single-rank part sustains far less — default 8.5e9
    /// * efficiency).
    pub ddr3_bytes_per_s: f64,
    /// Sustained fraction of DDR3 peak (row misses, refresh).
    pub ddr3_efficiency: f64,
    /// DDR3 capacity in bytes (1 GB on the ZCU102).
    pub ddr3_capacity: u64,
    /// First-word DDR3 access latency, seconds.
    pub ddr3_latency_s: f64,
    /// AXI PS<->PL data width in bytes (128-bit = 16).
    pub axi_ps_pl_bytes: usize,
    /// AXI DMA (PCIe<->DDR3) width in bytes (64-bit = 8).
    pub axi_dma_bytes: usize,
    /// BRAM FIFO bridge capacity per direction, bytes.
    pub bram_fifo_bytes: usize,

    // ---- software cost model ----------------------------------------------
    /// A53 cycles per (dimension, centroid) term of a software distance
    /// computation (scalar FPU, load + sub + abs/mul + add).
    pub sw_cycles_per_term: f64,
    /// A53 cycles of overhead per kd-tree node visit (pointer chase,
    /// candidate bookkeeping).
    pub sw_node_visit_cycles: f64,
    /// A53 cycles per point for the update step (accumulate + count).
    pub sw_update_cycles_per_dim: f64,
}

impl Default for PlatformConfig {
    fn default() -> Self {
        Self::zcu102()
    }
}

impl PlatformConfig {
    /// The paper's evaluation board.
    pub fn zcu102() -> Self {
        Self {
            name: "zcu102".into(),
            a53_cores: 4,
            a53_freq_hz: 1.5e9,
            r5_cores: 2,
            r5_freq_hz: 600e6,
            pl_freq_hz: 300e6,
            pl_pipeline_depth: 12,
            pl_lanes: 4,
            pl_max_parallel_clusters: 20,
            pcie_bytes_per_s: 1.6e9,
            pcie_setup_s: 5e-6,
            ddr3_bytes_per_s: 8.5e9,
            ddr3_efficiency: 0.70,
            ddr3_capacity: 1 << 30,
            ddr3_latency_s: 60e-9,
            axi_ps_pl_bytes: 16,
            axi_dma_bytes: 8,
            bram_fifo_bytes: 64 * 1024,
            sw_cycles_per_term: 4.0,
            sw_node_visit_cycles: 40.0,
            sw_update_cycles_per_dim: 2.0,
        }
    }

    /// The single-core platform of Winterstein et al. [13] (the Fig. 2
    /// baseline): one filtering datapath (single traversal engine, single
    /// control core) at a lower clock, with per-centroid parallel distance
    /// units but no transfer/compute double-buffering.
    pub fn winterstein_fpl13() -> Self {
        Self {
            name: "fpl13-singlecore".into(),
            a53_cores: 1,
            a53_freq_hz: 800e6,
            r5_cores: 0,
            r5_freq_hz: 0.0,
            pl_freq_hz: 200e6,
            ..Self::zcu102()
        }
    }

    /// The multi-core Zynq-7000 platform of Canilho et al. [17] (the
    /// Fig. 3 baseline): dual Cortex-A9 @ 667 MHz, PL fabric at 142 MHz,
    /// a *fixed* set of parallel MAC units (parallelism does not scale
    /// with K — the contrast the paper draws in section 5).
    pub fn canilho_fpl16() -> Self {
        Self {
            name: "fpl16-zynq7000".into(),
            a53_cores: 2,       // Cortex-A9 pair
            a53_freq_hz: 667e6,
            r5_cores: 0,
            r5_freq_hz: 0.0,
            pl_freq_hz: 142e6,
            ddr3_bytes_per_s: 4.2e9, // DDR3-1066 x32 on Zynq-7000
            ..Self::zcu102()
        }
    }

    /// Load from a TOML file, starting from ZCU102 defaults — every key is
    /// optional so config files only state what they change.
    pub fn from_toml_file(path: &Path) -> anyhow::Result<Self> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let doc = Doc::parse(&src)?;
        Ok(Self::from_doc(&doc))
    }

    pub fn from_doc(doc: &Doc) -> Self {
        let mut c = Self::zcu102();
        if let Some(v) = doc.str("name") {
            c.name = v.to_string();
        }
        macro_rules! take {
            ($field:ident, $key:expr, f64) => {
                if let Some(v) = doc.f64($key) {
                    c.$field = v;
                }
            };
            ($field:ident, $key:expr, usize) => {
                if let Some(v) = doc.usize($key) {
                    c.$field = v;
                }
            };
            ($field:ident, $key:expr, u64) => {
                if let Some(v) = doc.usize($key) {
                    c.$field = v as u64;
                }
            };
        }
        take!(a53_cores, "ps.a53_cores", usize);
        take!(a53_freq_hz, "ps.a53_freq_hz", f64);
        take!(r5_cores, "ps.r5_cores", usize);
        take!(r5_freq_hz, "ps.r5_freq_hz", f64);
        take!(pl_freq_hz, "pl.freq_hz", f64);
        take!(pl_pipeline_depth, "pl.pipeline_depth", u64);
        take!(pl_lanes, "pl.lanes", usize);
        take!(pl_max_parallel_clusters, "pl.max_parallel_clusters", usize);
        take!(pcie_bytes_per_s, "io.pcie_bytes_per_s", f64);
        take!(pcie_setup_s, "io.pcie_setup_s", f64);
        take!(ddr3_bytes_per_s, "io.ddr3_bytes_per_s", f64);
        take!(ddr3_efficiency, "io.ddr3_efficiency", f64);
        take!(ddr3_capacity, "io.ddr3_capacity", u64);
        take!(ddr3_latency_s, "io.ddr3_latency_s", f64);
        take!(axi_ps_pl_bytes, "io.axi_ps_pl_bytes", usize);
        take!(axi_dma_bytes, "io.axi_dma_bytes", usize);
        take!(bram_fifo_bytes, "io.bram_fifo_bytes", usize);
        take!(sw_cycles_per_term, "sw.cycles_per_term", f64);
        take!(sw_node_visit_cycles, "sw.node_visit_cycles", f64);
        take!(sw_update_cycles_per_dim, "sw.update_cycles_per_dim", f64);
        c
    }

    /// Sustained DDR3 bandwidth after efficiency derating.
    pub fn ddr3_sustained(&self) -> f64 {
        self.ddr3_bytes_per_s * self.ddr3_efficiency
    }

    /// Sanity checks used by config-loading paths and tests.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.a53_cores >= 1, "need at least one A53 core");
        anyhow::ensure!(self.a53_freq_hz > 0.0, "a53 frequency must be positive");
        anyhow::ensure!(self.pl_freq_hz > 0.0, "pl frequency must be positive");
        anyhow::ensure!(self.pl_lanes >= 1, "pl lanes must be >= 1");
        anyhow::ensure!(
            self.pl_max_parallel_clusters >= 1,
            "pl_max_parallel_clusters must be >= 1"
        );
        anyhow::ensure!(self.pcie_bytes_per_s > 0.0, "pcie bandwidth must be positive");
        anyhow::ensure!(
            self.ddr3_efficiency > 0.0 && self.ddr3_efficiency <= 1.0,
            "ddr3 efficiency must be in (0, 1]"
        );
        anyhow::ensure!(self.bram_fifo_bytes >= 4096, "bram fifo unrealistically small");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_board() {
        let c = PlatformConfig::zcu102();
        assert_eq!(c.a53_cores, 4);
        assert_eq!(c.r5_cores, 2);
        assert_eq!(c.a53_freq_hz, 1.5e9);
        assert_eq!(c.r5_freq_hz, 600e6);
        assert_eq!(c.ddr3_capacity, 1 << 30);
        assert_eq!(c.axi_ps_pl_bytes, 16); // 128-bit
        assert_eq!(c.axi_dma_bytes, 8); // 64-bit
        assert_eq!(c.pl_max_parallel_clusters, 20); // Table 1 limit
        c.validate().unwrap();
    }

    #[test]
    fn toml_overrides_only_what_it_states() {
        let doc = Doc::parse(
            r#"
            name = "ablation"
            [pl]
            freq_hz = 150e6
            [ps]
            a53_cores = 2
            "#,
        )
        .unwrap();
        let c = PlatformConfig::from_doc(&doc);
        assert_eq!(c.name, "ablation");
        assert_eq!(c.pl_freq_hz, 150e6);
        assert_eq!(c.a53_cores, 2);
        // untouched key keeps default
        assert_eq!(c.pcie_bytes_per_s, 1.6e9);
    }

    #[test]
    fn validate_rejects_nonsense() {
        let mut c = PlatformConfig::zcu102();
        c.a53_cores = 0;
        assert!(c.validate().is_err());
        let mut c = PlatformConfig::zcu102();
        c.ddr3_efficiency = 1.5;
        assert!(c.validate().is_err());
        let mut c = PlatformConfig::zcu102();
        c.pl_freq_hz = -1.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn winterstein_profile_is_single_core() {
        let c = PlatformConfig::winterstein_fpl13();
        assert_eq!(c.a53_cores, 1);
        assert_eq!(c.pl_freq_hz, 200e6);
        c.validate().unwrap();
    }

    #[test]
    fn ddr3_sustained_applies_efficiency() {
        let c = PlatformConfig::zcu102();
        assert!((c.ddr3_sustained() - 8.5e9 * 0.70).abs() < 1.0);
    }
}
