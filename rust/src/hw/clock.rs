//! Clock domains.  The ZCU102 runs three of interest here: the A53 cluster
//! (1.5 GHz), the R5 pair (600 MHz) and the PL fabric clock (300 MHz in
//! our model).  Conversions round *up* to whole cycles — hardware cannot
//! finish mid-cycle.

use super::{Time, PS_PER_S};

/// A fixed-frequency clock domain.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClockDomain {
    hz: f64,
    /// Period in picoseconds.
    period_ps: f64,
}

impl ClockDomain {
    pub fn new(hz: f64) -> Self {
        assert!(hz > 0.0, "clock frequency must be positive");
        Self {
            hz,
            period_ps: PS_PER_S / hz,
        }
    }

    #[inline]
    pub fn hz(&self) -> f64 {
        self.hz
    }

    /// Duration of `cycles` whole cycles.
    #[inline]
    pub fn cycles_to_ps(&self, cycles: u64) -> Time {
        (cycles as f64 * self.period_ps).round() as Time
    }

    /// Fractional cycle count (used by cost models before rounding).
    #[inline]
    pub fn cycles_to_secs(&self, cycles: f64) -> f64 {
        cycles / self.hz
    }

    /// Whole cycles needed to span `t` (rounded up).
    #[inline]
    pub fn ps_to_cycles(&self, t: Time) -> u64 {
        (t as f64 / self.period_ps).ceil() as u64
    }

    /// Whole cycles needed to span `s` seconds (rounded up).
    #[inline]
    pub fn secs_to_cycles(&self, s: f64) -> u64 {
        (s * self.hz).ceil() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions() {
        let pl = ClockDomain::new(300e6);
        // 300 MHz period = 3333.3. ps
        assert_eq!(pl.cycles_to_ps(3), 10_000);
        assert_eq!(pl.ps_to_cycles(10_000), 3);
        assert_eq!(pl.ps_to_cycles(10_001), 4); // rounds up
        assert_eq!(pl.secs_to_cycles(1.0), 300_000_000);
        assert!((pl.cycles_to_secs(300e6) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn zcu102_domains() {
        let a53 = ClockDomain::new(1.5e9);
        let r5 = ClockDomain::new(600e6);
        assert_eq!(a53.cycles_to_ps(3), 2_000);
        assert_eq!(r5.cycles_to_ps(3), 5_000);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_hz_rejected() {
        ClockDomain::new(0.0);
    }
}
