//! The composed ZCU102 platform model: turns algorithm work counters
//! ([`IterStats`]) into time on a configurable Zynq-style platform.
//!
//! Used by `arch::*` to model MUCH-SWIFT itself and every comparison
//! architecture of the paper's evaluation (different module counts,
//! clocks, core counts and overlap capabilities of the same machinery).

use super::clock::ClockDomain;
use super::dma::DmaEngine;
use super::pl::PlArray;
use super::stream::{simulate, StreamParams};
use super::{ps_to_secs, secs_to_ps};
use crate::config::PlatformConfig;
use crate::kmeans::IterStats;

/// Time breakdown of one simulated phase (seconds).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PhaseTime {
    /// Wall-clock of the phase.
    pub total_s: f64,
    /// PL compute time (before overlap accounting).
    pub pl_s: f64,
    /// PS (software) time.
    pub ps_s: f64,
    /// Data movement time (before overlap accounting).
    pub xfer_s: f64,
    /// Time lost to FIFO stalls (memory-boundedness indicator).
    pub stall_s: f64,
}

impl PhaseTime {
    pub fn add(&mut self, other: &PhaseTime) {
        self.total_s += other.total_s;
        self.pl_s += other.pl_s;
        self.ps_s += other.ps_s;
        self.xfer_s += other.xfer_s;
        self.stall_s += other.stall_s;
    }
}

/// Per-job stream payload: query vector + candidate bitmap in, winner id +
/// distance out.
fn job_bytes(d: usize) -> u64 {
    (d as u64) * 4 + 4 + 8
}

/// The platform model.
#[derive(Clone, Debug)]
pub struct ZynqSim {
    pub cfg: PlatformConfig,
    pub a53: ClockDomain,
    pub r5: ClockDomain,
}

impl ZynqSim {
    pub fn new(cfg: PlatformConfig) -> Self {
        cfg.validate().expect("invalid platform config");
        let a53 = ClockDomain::new(cfg.a53_freq_hz);
        let r5 = ClockDomain::new(if cfg.r5_freq_hz > 0.0 {
            cfg.r5_freq_hz
        } else {
            cfg.a53_freq_hz
        });
        Self { cfg, a53, r5 }
    }

    /// Host → DDR3 dataset ingest over PCIe DMA (charged once per run; the
    /// paper counts PCIe traffic in its timings).
    pub fn ingest_time_s(&self, bytes: u64) -> f64 {
        let mut dma = DmaEngine::new(&self.cfg);
        ps_to_secs(dma.ingest(0, bytes).finish_ps)
    }

    /// Producer rate into the BRAM FIFO: DDR3 sustained, capped by the
    /// PS<->PL AXI port width at the PL clock.
    fn fifo_fill_rate(&self) -> f64 {
        self.cfg
            .ddr3_sustained()
            .min(self.cfg.axi_ps_pl_bytes as f64 * self.cfg.pl_freq_hz)
    }

    /// Time for one PL-offloaded phase moving `bytes` while the PL spends
    /// `pl_cycles`.  With `overlap`, transfer and compute run through the
    /// FIFO pipeline (double buffering); without, store-and-forward.
    pub fn pl_phase(&self, pl: &PlArray, bytes: u64, pl_cycles: u64, overlap: bool) -> PhaseTime {
        self.pl_phase_from(pl, bytes, pl_cycles, overlap, self.fifo_fill_rate())
    }

    /// [`pl_phase`](Self::pl_phase) with an explicit source bandwidth —
    /// used by the conventional baseline that has no DDR3 residency and
    /// must re-stream every iteration's data from the host over PCIe.
    pub fn pl_phase_from(
        &self,
        pl: &PlArray,
        bytes: u64,
        pl_cycles: u64,
        overlap: bool,
        fill: f64,
    ) -> PhaseTime {
        let pl_s = pl.cycles_to_secs(pl_cycles);
        let xfer_s = bytes as f64 / fill + self.cfg.ddr3_latency_s;
        if bytes == 0 {
            return PhaseTime {
                total_s: pl_s,
                pl_s,
                ..Default::default()
            };
        }
        if overlap {
            let rep = simulate(&StreamParams {
                total_bytes: bytes,
                burst_bytes: (self.cfg.bram_fifo_bytes as u64 / 4).max(1024),
                producer_bytes_per_s: fill,
                producer_latency_ps: secs_to_ps(self.cfg.ddr3_latency_s),
                consumer_bytes_per_s: pl.drain_bytes_per_s(bytes, pl_cycles),
                fifo_bytes: self.cfg.bram_fifo_bytes as u64,
            });
            PhaseTime {
                total_s: ps_to_secs(rep.finish_ps),
                pl_s,
                ps_s: 0.0,
                xfer_s,
                stall_s: ps_to_secs(rep.producer_stall_ps + rep.consumer_stall_ps),
            }
        } else {
            PhaseTime {
                total_s: pl_s + xfer_s,
                pl_s,
                ps_s: 0.0,
                xfer_s,
                stall_s: 0.0,
            }
        }
    }

    /// PS software cycles for the traversal/bookkeeping side of a
    /// filtering iteration — the part that stays on the A53s in the
    /// co-design.  All floating-point (distances *and* the `is_farther`
    /// vertex geometry) is charged to the PL (paper section 5 item (2):
    /// "all floating point arithmetic operations ... have been
    /// accomplished in PL"); the PS pays only pointer/queue/candidate-list
    /// bookkeeping.
    pub fn filter_ps_cycles(&self, it: &IterStats, _d: usize) -> f64 {
        let c = &self.cfg;
        it.node_visits as f64 * c.sw_node_visit_cycles
            // candidate-list copies / result consumption, ~2 cycles per
            // candidate slot
            + it.dist_evals as f64 * 2.0
            // leaf/interior result handling (assignment writes; interior
            // range writes stream at cache-line granularity)
            + it.leaf_points as f64 * 2.0
            + it.interior_assigns as f64 * 0.25
    }

    /// One filtering iteration with the distance panels offloaded to the
    /// PL, streamed level by level (MUCH-SWIFT and [13]-style machines).
    ///
    /// `cores` = A53 workers sharing the PS-side bookkeeping; `overlap` =
    /// whether transfer/compute double-buffer through the FIFO.
    pub fn filter_iteration(
        &self,
        it: &IterStats,
        d: usize,
        pl: &PlArray,
        cores: usize,
        overlap: bool,
    ) -> PhaseTime {
        assert!(cores >= 1);
        let mut agg = PhaseTime::default();
        for lvl in &it.levels {
            let jobs = lvl.interior_jobs + lvl.leaf_jobs;
            if jobs == 0 {
                continue;
            }
            // PL arithmetic: candidate distances + the is_farther vertex
            // geometry (a pair of point-to-vertex distances per test).
            let evals = lvl.cand_evals + 2 * lvl.prune_tests;
            let cycles = pl.distance_cycles(evals, d);
            let bytes = jobs * job_bytes(d);
            let phase = self.pl_phase(pl, bytes, cycles, overlap);
            agg.add(&phase);
        }
        // Centroid update stage (R5-controlled, k*d accumulates) is folded
        // into the PS term below via interior/leaf handling; the division
        // at iteration end is negligible (k*d ops).
        let ps_s = self.filter_ps_cycles(it, d) / (self.cfg.a53_freq_hz * cores as f64);
        agg.ps_s = ps_s;
        // PS bookkeeping pipelines against the PL waves at job batch
        // granularity: the iteration is bounded by the slower of the two.
        agg.total_s = agg.total_s.max(ps_s);
        agg
    }

    /// One plain-Lloyd iteration offloaded to the PL ([17]-style and the
    /// "conventional FPGA" baseline): all `n*k` distances, points streamed
    /// from DDR3.
    pub fn lloyd_iteration(
        &self,
        n: u64,
        d: usize,
        k: usize,
        pl: &PlArray,
        overlap: bool,
    ) -> PhaseTime {
        // Each point is streamed once; its K distances fan out across the
        // module array.
        let evals = n * k as u64;
        let cycles = pl.distance_cycles(evals, d) + pl.update_cycles(n, d);
        let bytes = n * (d as u64 * 4 + 8);
        let mut phase = self.pl_phase(pl, bytes, cycles, overlap);
        // Control software: per-block DMA kicks + iteration bookkeeping.
        let ps_s = (n as f64 * 0.5) / self.cfg.a53_freq_hz;
        phase.ps_s = ps_s;
        phase.total_s = phase.total_s.max(ps_s);
        phase
    }

    /// One software-only Lloyd iteration on `cores` A53 cores.
    pub fn sw_lloyd_iteration(&self, n: u64, d: usize, k: usize, cores: usize) -> PhaseTime {
        let c = &self.cfg;
        let cycles = n as f64 * k as f64 * d as f64 * c.sw_cycles_per_term
            + n as f64 * d as f64 * c.sw_update_cycles_per_dim;
        let s = cycles / (c.a53_freq_hz * cores as f64);
        PhaseTime {
            total_s: s,
            ps_s: s,
            ..Default::default()
        }
    }

    /// One software-only filtering iteration on `cores` A53 cores (here
    /// the distance *and* pruning floating-point runs in software too).
    pub fn sw_filter_iteration(&self, it: &IterStats, d: usize, cores: usize) -> PhaseTime {
        let c = &self.cfg;
        let cycles = (it.dist_evals + 2 * it.prune_tests) as f64
            * d as f64
            * c.sw_cycles_per_term
            + self.filter_ps_cycles(it, d);
        let s = cycles / (c.a53_freq_hz * cores as f64);
        PhaseTime {
            total_s: s,
            ps_s: s,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmeans::LevelWork;

    fn sim() -> ZynqSim {
        ZynqSim::new(PlatformConfig::zcu102())
    }

    fn fake_iter(levels: usize, jobs_per_level: u64, cand: u64) -> IterStats {
        IterStats {
            dist_evals: levels as u64 * jobs_per_level * cand,
            node_visits: levels as u64 * jobs_per_level,
            leaf_points: jobs_per_level,
            prune_tests: levels as u64 * jobs_per_level * (cand - 1),
            levels: (0..levels)
                .map(|_| LevelWork {
                    interior_jobs: jobs_per_level,
                    leaf_jobs: 0,
                    cand_evals: jobs_per_level * cand,
                    prune_tests: jobs_per_level * (cand - 1),
                })
                .collect(),
            ..Default::default()
        }
    }

    #[test]
    fn overlap_beats_store_and_forward() {
        let s = sim();
        let pl = PlArray::for_workload(&s.cfg, 8, 4);
        let it = fake_iter(10, 50_000, 8);
        let over = s.filter_iteration(&it, 15, &pl, 4, true);
        let serial = s.filter_iteration(&it, 15, &pl, 4, false);
        assert!(
            over.total_s < serial.total_s,
            "overlap {} !< serial {}",
            over.total_s,
            serial.total_s
        );
        assert!(over.total_s > 0.0);
    }

    #[test]
    fn more_modules_is_faster() {
        let s = sim();
        let big = PlArray::for_workload(&s.cfg, 20, 4);
        let small = PlArray::naive(&s.cfg);
        let t_big = s.lloyd_iteration(100_000, 15, 20, &big, true);
        let t_small = s.lloyd_iteration(100_000, 15, 20, &small, false);
        assert!(
            t_small.total_s / t_big.total_s > 100.0,
            "80 pipelined modules should crush the naive datapath: {} vs {}",
            t_small.total_s,
            t_big.total_s
        );
    }

    #[test]
    fn software_is_much_slower_than_pl() {
        let s = sim();
        let pl = PlArray::for_workload(&s.cfg, 20, 4);
        let hwt = s.lloyd_iteration(1_000_000, 15, 20, &pl, true);
        let swt = s.sw_lloyd_iteration(1_000_000, 15, 20, 1);
        // Full-Lloyd offload re-streams every point each iteration, so the
        // AXI/DDR3 path binds well before the 80-module array does — this
        // is the memory-boundedness the filtering algorithm then removes.
        let ratio = swt.total_s / hwt.total_s;
        assert!(
            ratio > 20.0,
            "expected >20x PL advantage on Lloyd, got {ratio:.1}x"
        );
        assert!(hwt.xfer_s > hwt.pl_s, "full-Lloyd offload should be memory-bound");
    }

    #[test]
    fn more_cores_shrink_ps_side() {
        let s = sim();
        let it = fake_iter(12, 20_000, 6);
        let one = s.sw_filter_iteration(&it, 15, 1);
        let four = s.sw_filter_iteration(&it, 15, 4);
        assert!((one.total_s / four.total_s - 4.0).abs() < 1e-6);
    }

    #[test]
    fn ingest_charges_pcie() {
        let s = sim();
        let t = s.ingest_time_s(60_000_000); // 10^6 x 15 dims x 4 B
        let wire = 60_000_000f64 / s.cfg.pcie_bytes_per_s;
        assert!(t >= wire && t < wire * 1.3, "ingest {t} vs wire {wire}");
    }

    #[test]
    fn empty_iteration_costs_nothing_on_pl() {
        let s = sim();
        let pl = PlArray::for_workload(&s.cfg, 4, 4);
        let it = IterStats::default();
        let t = s.filter_iteration(&it, 8, &pl, 4, true);
        assert_eq!(t.pl_s, 0.0);
        assert_eq!(t.total_s, 0.0);
    }
}
