//! Programmable-logic arithmetic array cost model.
//!
//! The paper's PL instantiates, for K clusters and 4 sub-datasets, K×4
//! parallel modules, each a pipelined Manhattan-distance datapath feeding a
//! comparator tree and an updater (section 5, item (3): "if we set the
//! number of clusters to K=5 ... we will have 20 (5×4) parallel modules").
//! Table 1 caps the fully-parallel configuration at K=20; beyond that
//! "it has to share the parallel modules between clusters uniformly".
//!
//! Model: a module consumes one 128-bit AXI beat (= `lanes` f32 dims) per
//! PL cycle, so one distance evaluation of a D-dimensional point costs
//! `ceil(D / lanes)` beats; the module array retires `modules` evaluations
//! per beat-slot in parallel.  Compare is a `log2` tree and update a
//! single accumulate, both pipelined behind the distance units (they add
//! fill latency, not throughput).

use super::clock::ClockDomain;
use crate::config::PlatformConfig;

/// The PL array for a given workload configuration.
#[derive(Clone, Debug)]
pub struct PlArray {
    pub clock: ClockDomain,
    /// f32 lanes consumed per cycle per module (128-bit beat = 4).
    pub lanes: usize,
    /// Pipeline fill depth (distance + compare + update stages).
    pub pipeline_depth: u64,
    /// Instantiated parallel distance modules.
    pub modules: usize,
    /// Clusters each module is time-shared across (1 when fully parallel).
    pub share: usize,
    /// Initiation interval: cycles between successive beats retired by a
    /// module.  1 for the pipelined MUCH-SWIFT datapath; ~8 for a naive
    /// direct-mapped loop whose II is bound by the floating-point
    /// accumulation chain latency (~8 cycles at 300 MHz).
    pub ii: u64,
}

impl PlArray {
    /// Size the array for `k` clusters across `groups` parallel
    /// sub-datasets (4 in MUCH-SWIFT, 1 in the single-core baselines),
    /// respecting the platform's fully-parallel cluster cap.
    pub fn for_workload(cfg: &PlatformConfig, k: usize, groups: usize) -> Self {
        assert!(k >= 1 && groups >= 1);
        let kp = k.min(cfg.pl_max_parallel_clusters);
        let share = k.div_ceil(kp);
        Self {
            clock: ClockDomain::new(cfg.pl_freq_hz),
            lanes: cfg.pl_lanes,
            pipeline_depth: cfg.pl_pipeline_depth + (usize::BITS - k.leading_zeros()) as u64,
            modules: kp * groups,
            share,
            ii: 1,
        }
    }

    /// The "conventional FPGA-based architecture without optimization"
    /// baseline: a direct, non-optimized mapping of the software loop onto
    /// one scalar datapath — one f32 lane, unpipelined accumulation (II
    /// bound by the FP-add chain, ~8 cycles at 300 MHz), no parallel
    /// modules.  This is the paper's section-1 strawman: "such direct and
    /// non-optimized mapping of software intended for CPUs to FPGAs does
    /// not result in best utilizing all FPGA resources".
    pub fn naive(cfg: &PlatformConfig) -> Self {
        Self {
            clock: ClockDomain::new(cfg.pl_freq_hz),
            lanes: 1,
            pipeline_depth: cfg.pl_pipeline_depth,
            modules: 1,
            share: 1,
            ii: 8,
        }
    }

    /// Beats per single distance evaluation.
    #[inline]
    pub fn beats_per_eval(&self, d: usize) -> u64 {
        (d as u64).div_ceil(self.lanes as u64)
    }

    /// PL cycles to perform `evals` distance evaluations of `d`-dim data,
    /// including pipeline fill and module sharing.
    pub fn distance_cycles(&self, evals: u64, d: usize) -> u64 {
        if evals == 0 {
            return 0;
        }
        let slots = evals.div_ceil(self.modules as u64) * self.share as u64;
        slots * self.beats_per_eval(d) * self.ii + self.pipeline_depth
    }

    /// PL cycles for the update stage over `points` winning points
    /// (accumulate one point per beat into the register bank).
    pub fn update_cycles(&self, points: u64, d: usize) -> u64 {
        if points == 0 {
            return 0;
        }
        // Updaters are per-cluster-group; accumulation is pipelined with
        // the compare output, so throughput-bound by beats only.
        points.div_ceil(self.modules as u64) * self.beats_per_eval(d) * self.ii
    }

    /// Seconds for `cycles` PL cycles.
    #[inline]
    pub fn cycles_to_secs(&self, cycles: u64) -> f64 {
        self.clock.cycles_to_secs(cycles as f64)
    }

    /// Input-stream drain rate (bytes/s) while computing `cycles` over
    /// `bytes` of streamed input — what the FIFO consumer side sustains.
    pub fn drain_bytes_per_s(&self, bytes: u64, cycles: u64) -> f64 {
        if bytes == 0 || cycles == 0 {
            return f64::INFINITY;
        }
        bytes as f64 / self.cycles_to_secs(cycles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PlatformConfig {
        PlatformConfig::zcu102()
    }

    #[test]
    fn paper_example_module_counts() {
        // K=5, 4 sub-datasets => 20 parallel modules (paper section 5).
        let pl = PlArray::for_workload(&cfg(), 5, 4);
        assert_eq!(pl.modules, 20);
        assert_eq!(pl.share, 1);
        // K=20 is the cap: 80 modules.
        let pl = PlArray::for_workload(&cfg(), 20, 4);
        assert_eq!(pl.modules, 80);
        assert_eq!(pl.share, 1);
        // K=40 shares each module across 2 clusters.
        let pl = PlArray::for_workload(&cfg(), 40, 4);
        assert_eq!(pl.modules, 80);
        assert_eq!(pl.share, 2);
    }

    #[test]
    fn distance_cycle_scaling() {
        let pl = PlArray::for_workload(&cfg(), 8, 1); // 8 modules
        let d = 16; // 4 beats/eval
        let c1 = pl.distance_cycles(8, d); // one slot
        let c2 = pl.distance_cycles(16, d); // two slots
        assert_eq!(c1, 4 + pl.pipeline_depth);
        assert_eq!(c2, 8 + pl.pipeline_depth);
        assert_eq!(pl.distance_cycles(0, d), 0);
        // D=3 on 4 lanes is one beat.
        assert_eq!(pl.beats_per_eval(3), 1);
        assert_eq!(pl.beats_per_eval(5), 2);
    }

    #[test]
    fn sharing_doubles_cycles() {
        let full = PlArray::for_workload(&cfg(), 20, 4);
        let shared = PlArray::for_workload(&cfg(), 40, 4);
        let evals = 80_000;
        assert_eq!(
            shared.distance_cycles(evals, 16) - shared.pipeline_depth,
            2 * (full.distance_cycles(evals, 16) - full.pipeline_depth)
        );
    }

    #[test]
    fn naive_datapath_is_slowest() {
        let one = PlArray::naive(&cfg());
        let many = PlArray::for_workload(&cfg(), 8, 4);
        // 1 lane x II=8 vs 32 pipelined 4-lane modules: orders of magnitude.
        assert!(one.distance_cycles(1000, 8) > many.distance_cycles(1000, 8) * 100);
        assert_eq!(one.ii, 8);
        assert_eq!(one.beats_per_eval(8), 8);
    }

    #[test]
    fn drain_rate_sane() {
        let pl = PlArray::for_workload(&cfg(), 8, 1);
        let cycles = pl.distance_cycles(1024, 16);
        let bytes = 1024 * 16 * 4;
        let rate = pl.drain_bytes_per_s(bytes, cycles);
        assert!(rate > 0.0 && rate.is_finite());
        assert_eq!(pl.drain_bytes_per_s(0, 10), f64::INFINITY);
    }
}
