//! Transaction-level simulator of the paper's Zynq UltraScale+ platform.
//!
//! The reproduction band for this paper is hardware-gated (no ZCU102, no
//! Vivado bitstream), so the platform is *simulated*: the clustering
//! algorithms run functionally in `kmeans`/`coordinator` and report work
//! counters ([`crate::kmeans::IterStats`]), and this module turns those
//! counters into time on a modelled ZCU102 (DESIGN.md "Simulation
//! substitutions" table).
//!
//! Components:
//! - [`clock`]    — clock domains (A53 1.5 GHz / R5 600 MHz / PL 300 MHz).
//! - [`engine`]   — a small discrete-event core (time-ordered event queue).
//! - [`link`]     — bandwidth×latency channels (PCIe, AXI, DDR3 port).
//! - [`stream`]   — event-driven producer/FIFO/consumer pipeline: models
//!   the DDR3 → BRAM-FIFO → PL streaming path with finite buffering and
//!   backpressure (paper section 4.2), burst by burst.
//! - [`dma`]      — descriptor-based PCIe→DDR3 DMA engine (R5-managed).
//! - [`pl`]       — the PL arithmetic-core array cost model (K×4 parallel
//!   distance/compare/update pipelines).
//! - [`resources`]— the Table 1 LUT/FF/BRAM/DSP utilization model.
//! - [`zynq`]     — the composed platform used by `arch::*`.

pub mod clock;
pub mod dma;
pub mod engine;
pub mod link;
pub mod pl;
pub mod resources;
pub mod stream;
pub mod zynq;

/// Simulation time in picoseconds (u64 wraps after ~5 months of simulated
/// time — far beyond any run here).
pub type Time = u64;

/// Picoseconds per second.
pub const PS_PER_S: f64 = 1e12;

/// Convert seconds to [`Time`].
#[inline]
pub fn secs_to_ps(s: f64) -> Time {
    debug_assert!(s >= 0.0);
    (s * PS_PER_S).round() as Time
}

/// Convert [`Time`] to seconds.
#[inline]
pub fn ps_to_secs(t: Time) -> f64 {
    t as f64 / PS_PER_S
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_conversions_round_trip() {
        assert_eq!(secs_to_ps(1.0), 1_000_000_000_000);
        assert_eq!(secs_to_ps(0.0), 0);
        let t = secs_to_ps(3.25e-6);
        assert!((ps_to_secs(t) - 3.25e-6).abs() < 1e-15);
    }
}
