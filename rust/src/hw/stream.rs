//! Event-driven producer → BRAM-FIFO → consumer pipeline.
//!
//! Models the paper's section-4.2 data path: DDR3 bursts are DMA'd into
//! the BRAM-based FIFO bridge while the PL drains it at its compute rate.
//! Finite FIFO capacity creates backpressure (producer stalls when full)
//! and cold-start bubbles (consumer stalls when empty) — exactly the
//! effects that decide whether a phase is memory-bound (the paper's
//! explanation for the 8.5× over [13]: with double-buffered DDR3 streaming
//! "the computation is no longer memory bound").
//!
//! Burst-level discrete-event simulation on [`EventQueue`]; deterministic.

use super::engine::EventQueue;
use super::Time;

/// Pipeline parameters for one streaming phase.
#[derive(Clone, Debug)]
pub struct StreamParams {
    /// Total payload to move through the FIFO.
    pub total_bytes: u64,
    /// Burst granularity (DMA descriptor / AXI burst size).
    pub burst_bytes: u64,
    /// Producer (DDR3→FIFO) bandwidth.
    pub producer_bytes_per_s: f64,
    /// First-burst latency (DDR3 access + DMA setup).
    pub producer_latency_ps: Time,
    /// Consumer (PL) drain bandwidth — derived from the PL's compute
    /// throughput over this phase's data.
    pub consumer_bytes_per_s: f64,
    /// FIFO capacity in bytes.
    pub fifo_bytes: u64,
}

/// What happened during the phase.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StreamReport {
    /// Time the last burst finished being consumed.
    pub finish_ps: Time,
    /// Producer time lost waiting for FIFO space.
    pub producer_stall_ps: Time,
    /// Consumer time lost waiting for data.
    pub consumer_stall_ps: Time,
    /// Peak FIFO occupancy in bytes.
    pub high_water_bytes: u64,
    /// Number of bursts moved.
    pub bursts: u64,
}

#[derive(Debug, PartialEq, Eq)]
enum Ev {
    ProducerDone,
    ConsumerDone,
}

/// Run the pipeline to completion.
pub fn simulate(p: &StreamParams) -> StreamReport {
    assert!(p.burst_bytes > 0 && p.fifo_bytes >= p.burst_bytes);
    assert!(p.producer_bytes_per_s > 0.0 && p.consumer_bytes_per_s > 0.0);
    if p.total_bytes == 0 {
        return StreamReport::default();
    }

    let bursts = p.total_bytes.div_ceil(p.burst_bytes);
    let t_prod = |bytes: u64| -> Time {
        (bytes as f64 / p.producer_bytes_per_s * 1e12).round() as Time
    };
    let t_cons =
        |bytes: u64| -> Time { (bytes as f64 / p.consumer_bytes_per_s * 1e12).round() as Time };
    let burst_size = |i: u64| -> u64 {
        if i + 1 == bursts {
            p.total_bytes - (bursts - 1) * p.burst_bytes
        } else {
            p.burst_bytes
        }
    };

    let mut q: EventQueue<Ev> = EventQueue::new();
    let mut report = StreamReport {
        bursts,
        ..Default::default()
    };

    // State.
    let mut fifo_fill: u64 = 0;
    let mut produced: u64 = 0; // bursts fully in FIFO
    let mut consumed: u64 = 0; // bursts fully drained
    let mut prod_inflight = false;
    let mut cons_inflight = false;
    let mut prod_blocked_since: Option<Time> = None;
    let mut cons_blocked_since: Option<Time> = Some(0); // cold start

    // Try to start the next production/consumption at the current time.
    macro_rules! pump {
        ($q:expr) => {{
            let now = $q.now();
            // Producer: next burst if it fits.
            if !prod_inflight && produced + (prod_inflight as u64) < bursts {
                let next = produced;
                let sz = burst_size(next);
                if fifo_fill + sz <= p.fifo_bytes {
                    if let Some(t0) = prod_blocked_since.take() {
                        report.producer_stall_ps += now - t0;
                    }
                    let lat = if next == 0 { p.producer_latency_ps } else { 0 };
                    $q.schedule_in(lat + t_prod(sz), Ev::ProducerDone);
                    prod_inflight = true;
                } else if prod_blocked_since.is_none() {
                    prod_blocked_since = Some(now);
                }
            }
            // Consumer: next burst if available.
            if !cons_inflight && consumed < produced {
                if let Some(t0) = cons_blocked_since.take() {
                    report.consumer_stall_ps += now - t0;
                }
                let sz = burst_size(consumed);
                $q.schedule_in(t_cons(sz), Ev::ConsumerDone);
                cons_inflight = true;
            } else if !cons_inflight && consumed < bursts && cons_blocked_since.is_none() {
                cons_blocked_since = Some(now);
            }
        }};
    }

    pump!(q);
    while let Some((now, ev)) = q.pop() {
        match ev {
            Ev::ProducerDone => {
                let sz = burst_size(produced);
                fifo_fill += sz;
                report.high_water_bytes = report.high_water_bytes.max(fifo_fill);
                produced += 1;
                prod_inflight = false;
            }
            Ev::ConsumerDone => {
                let sz = burst_size(consumed);
                fifo_fill -= sz;
                consumed += 1;
                cons_inflight = false;
                report.finish_ps = now;
            }
        }
        pump!(q);
    }

    debug_assert_eq!(consumed, bursts);
    debug_assert_eq!(fifo_fill, 0);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(total: u64, prod: f64, cons: f64, fifo: u64) -> StreamParams {
        StreamParams {
            total_bytes: total,
            burst_bytes: 1024,
            producer_bytes_per_s: prod,
            producer_latency_ps: 0,
            consumer_bytes_per_s: cons,
            fifo_bytes: fifo,
        }
    }

    #[test]
    fn compute_bound_matches_consumer_rate() {
        // Producer 10x faster: finish ~= total / consumer_rate (+1 burst fill).
        let p = params(1 << 20, 10e9, 1e9, 64 * 1024);
        let r = simulate(&p);
        let ideal = (1u64 << 20) as f64 / 1e9 * 1e12;
        let slack = (1024f64 / 10e9) * 1e12; // first burst fill
        assert!(
            (r.finish_ps as f64) < ideal + slack * 2.0 + 1e3,
            "finish {} vs ideal {}",
            r.finish_ps,
            ideal
        );
        // Producer must have stalled on the full FIFO.
        assert!(r.producer_stall_ps > 0);
        assert!(r.high_water_bytes <= 64 * 1024);
    }

    #[test]
    fn memory_bound_matches_producer_rate() {
        let p = params(1 << 20, 1e9, 10e9, 64 * 1024);
        let r = simulate(&p);
        let ideal = (1u64 << 20) as f64 / 1e9 * 1e12;
        assert!(
            (r.finish_ps as f64) < ideal * 1.02 + 2e5,
            "finish {} vs ideal {}",
            r.finish_ps,
            ideal
        );
        // Consumer starves while the producer trickles.
        assert!(r.consumer_stall_ps > 0);
        assert_eq!(r.producer_stall_ps, 0);
    }

    #[test]
    fn balanced_rates_overlap_fully() {
        let p = params(1 << 20, 2e9, 2e9, 16 * 1024);
        let r = simulate(&p);
        let ideal = (1u64 << 20) as f64 / 2e9 * 1e12;
        // Overlapped: close to one-pass time, NOT 2x (store-and-forward).
        assert!((r.finish_ps as f64) < ideal * 1.1, "finish {}", r.finish_ps);
    }

    #[test]
    fn tiny_fifo_serializes() {
        // FIFO of one burst forces lock-step: finish ~= sum of both passes.
        let p = params(64 * 1024, 1e9, 1e9, 1024);
        let r = simulate(&p);
        let one_pass = (64 * 1024) as f64 / 1e9 * 1e12;
        assert!(
            (r.finish_ps as f64) > one_pass * 1.9,
            "lock-step expected: {} vs {}",
            r.finish_ps,
            one_pass
        );
    }

    #[test]
    fn producer_latency_shifts_start() {
        let mut p = params(4096, 1e9, 1e9, 8192);
        let base = simulate(&p).finish_ps;
        p.producer_latency_ps = 5_000_000;
        let delayed = simulate(&p).finish_ps;
        assert_eq!(delayed, base + 5_000_000);
    }

    #[test]
    fn conservation_and_empty() {
        assert_eq!(simulate(&params(0, 1e9, 1e9, 4096)), StreamReport::default());
        let p = params(10_000, 1e9, 3e9, 4096);
        let r = simulate(&p);
        assert_eq!(r.bursts, 10); // 9 full + 1 tail (10000 = 9*1024 + 784)
        assert!(r.finish_ps > 0);
    }
}
